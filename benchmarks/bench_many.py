"""Workload throughput: instances/second of `solve_many` vs sequential
`mac_solve` -> the "many" section of BENCH_engines.json, plus the host-traffic
telemetry of the device-resident frontier -> the "frontier" section.

The multi-instance amortization story (DESIGN.md §6) in one number: B
independent Model-RB / coloring instances solved to completion, once as B
sequential `mac_solve` calls and once as a single lockstep `solve_many`
portfolio whose every round is one fused frontier dispatch. Results are
verified identical before timings are reported.

The frontier section (DESIGN.md §8) records what each lockstep round actually
moves across the host boundary: ``host_bytes_per_round`` (the O(R·d)
metadata) against ``domain_bytes_per_round`` (the O(R·n·d) domains the
pre-frontier protocol shipped both ways). `check_regression.py` gates the
section — transferred-bytes growth is a regression like any latency one.

    PYTHONPATH=src python -m benchmarks.run --only many
"""

from __future__ import annotations

import time
from pathlib import Path

from repro import obs
from repro.core import mac_solve, solve_many
from repro.core.search import check_solution
from repro.problems import generate_batch
from . import tracker
from .tracker import OUT_PATH

#: (family, knobs, count, engine, speculation). The pallas_packed workload is
#: small (the stacked kernel runs interpret-mode on CPU); it gates that the
#: packed enforce_many path keeps working at speed, not an absolute
#: throughput. The speculative model_rb leg re-runs the hardness-1.0
#: straggler workload with tree splitting + portfolio racing on (DESIGN.md
#: §9) — n_solved must match the sequential oracle and the row records
#: whether duplication actually buys straggler wall-clock.
WORKLOADS = [
    ("model_rb", {"n": 12, "hardness": 1.0}, 32, "einsum", None),
    ("coloring_random", {"n": 16, "edge_prob": 0.25, "k": 3}, 32, "einsum", None),
    ("model_rb", {"n": 10, "hardness": 1.0}, 6, "pallas_packed", None),
    ("model_rb", {"n": 12, "hardness": 1.0}, 32, "einsum",
     {"split_budget": 2, "portfolio": 2}),
]


def bench_workload(family: str, knobs: dict, count: int, engine: str = "einsum",
                   seed: int = 0, speculation: dict | None = None) -> tuple:
    csps = generate_batch(family, count, seed=seed, **knobs)

    t0 = time.perf_counter()
    seq = [mac_solve(c, engine=engine)[0] for c in csps]
    seq_s = time.perf_counter() - t0

    telemetry: dict = {}
    rpi_before = len(obs.REGISTRY.samples("many.rounds_per_instance"))
    t0 = time.perf_counter()
    sols, _ = solve_many(csps, engine=engine, telemetry=telemetry,
                         **(speculation or {}))
    many_s = time.perf_counter() - t0
    # solve_many published this workload's figures into the obs registry
    # (one launches_per_solve sample per call, one rounds sample per
    # instance) — the row reads them back from there, not from telemetry
    lps_samples = obs.REGISTRY.samples("many.launches_per_solve")
    rpi_delta = list(obs.REGISTRY.samples("many.rounds_per_instance"))[rpi_before:]

    if speculation:
        # speculative members race with different heuristics, so the WITNESS
        # may legitimately differ — the verdict may not, and any witness must
        # actually satisfy its instance
        for i, (s, q) in enumerate(zip(sols, seq)):
            if (s is None) != (q is None):
                raise AssertionError(
                    f"{family}+spec[{i}]: verdict diverged from sequential"
                )
            if s is not None and not check_solution(csps[i], s):
                raise AssertionError(f"{family}+spec[{i}]: invalid witness")
    elif sols != seq:  # throughput numbers are meaningless if results diverge
        raise AssertionError(f"{family}: solve_many diverged from sequential mac_solve")

    many_row = {
        "family": family + "+spec" if speculation else family,
        "knobs": knobs,
        "count": count,
        "engine": engine,
        "n_solved": sum(s is not None for s in sols),
        "sequential_s": round(seq_s, 3),
        "solve_many_s": round(many_s, 3),
        "sequential_instances_per_s": round(count / seq_s, 3),
        "many_instances_per_s": round(count / many_s, 3),
        "speedup": round(seq_s / many_s, 3),
        "host_bytes_per_round": round(telemetry.get("host_bytes_per_round", 0.0), 1),
        # the one-launch-per-round claim, visible in history: a fused
        # in-kernel fixpoint bills 1 launch per lockstep round, the stepped
        # while_loop bills the round's max recurrence depth
        "launches": telemetry.get("launches", 0),
        "launches_per_round": round(telemetry.get("launches_per_round", 0.0), 3),
        "launches_per_solve": round(
            lps_samples[-1] if lps_samples
            else telemetry.get("launches", 0) / max(count, 1), 3
        ),
        "fused_fixpoint": bool(telemetry.get("fused_fixpoint", False)),
    }
    if speculation:
        many_row["speculation"] = dict(speculation)
    if "rounds_per_instance" in telemetry:
        # per-instance rounds-to-solution spread: the straggler story in one
        # line (p90/max vs p50) plus the log2 histogram
        many_row["rounds_per_instance"] = telemetry["rounds_per_instance"]
        many_row["rounds_hist"] = telemetry["rounds_hist"]
    elif rpi_delta:
        # registry-only path: summarize the per-instance samples solve_many
        # observed into the same {min,p50,p90,max} shape telemetry uses
        many_row["rounds_per_instance"] = {
            "min": int(min(rpi_delta)),
            "p50": int(obs.percentile(rpi_delta, 50)),
            "p90": int(obs.percentile(rpi_delta, 90)),
            "max": int(max(rpi_delta)),
        }
    frontier_row = None
    if telemetry.get("device_frontier"):
        frontier_row = {
            "engine": engine,
            "family": family + "+spec" if speculation else family,
            "rounds": telemetry["rounds"],
            "rows_dispatched": telemetry["rows_dispatched"],
            "rows_per_round": round(
                telemetry["rows_dispatched"] / max(telemetry["rounds"], 1), 2
            ),
            "rows_padded": telemetry["rows_padded"],
            "host_bytes_per_round": round(telemetry["host_bytes_per_round"], 1),
            "domain_bytes_per_round": round(telemetry["domain_bytes_per_round"], 1),
            "metadata_fraction": round(
                telemetry["host_bytes_per_round"]
                / max(telemetry["domain_bytes_per_round"], 1e-9),
                3,
            ),
            "root_bytes": telemetry["root_bytes"],
            "extract_bytes": telemetry["extract_bytes"],
            "launches": telemetry["launches"],
            "launches_per_round": round(telemetry["launches_per_round"], 3),
            "rounds_per_s": round(
                telemetry["rounds"] / max(telemetry["round_seconds_total"], 1e-9), 3
            ),
            "fused_fixpoint": bool(telemetry.get("fused_fixpoint", False)),
        }
    return many_row, frontier_row


def main(out_path: Path = OUT_PATH) -> list:
    rows, frontier = [], []
    for f, knobs, count, engine, speculation in WORKLOADS:
        many_row, frontier_row = bench_workload(
            f, knobs, count, engine=engine, speculation=speculation
        )
        rows.append(many_row)
        if frontier_row is not None:
            frontier.append(frontier_row)
    for r in rows:
        print(
            f"many,{r['engine']},{r['family']},{r['count']},"
            f"{r['sequential_instances_per_s']:.3f},{r['many_instances_per_s']:.3f},"
            f"{r['speedup']:.3f}"
        )
    for r in frontier:
        print(
            f"frontier,{r['engine']},{r['family']},{r['rounds']},"
            f"{r['host_bytes_per_round']:.1f},{r['domain_bytes_per_round']:.1f},"
            f"launches/round={r['launches_per_round']:.2f}"
        )
    tracker.merge_section("many", rows, out_path)
    tracker.merge_section("frontier", frontier, out_path)
    # process-wide registry snapshot rides along (ungated "obs" section)
    tracker.merge_section("obs", obs.snapshot(), out_path)
    print(f"many: wrote {out_path}")
    return rows


if __name__ == "__main__":
    main()
