"""Workload throughput: instances/second of `solve_many` vs sequential
`mac_solve` -> the "many" section of BENCH_engines.json.

The multi-instance amortization story (DESIGN.md §6) in one number: B
independent Model-RB / coloring instances solved to completion, once as B
sequential `mac_solve` calls and once as a single lockstep `solve_many`
portfolio whose every round is one `enforce_many` dispatch. Results are
verified identical before timings are reported.

    PYTHONPATH=src python -m benchmarks.run --only many
"""

from __future__ import annotations

import time
from pathlib import Path

from repro.core import mac_solve, solve_many
from repro.problems import generate_batch
from . import tracker
from .tracker import OUT_PATH

#: (family, knobs, count, engine). The pallas_packed workload is small (the
#: stacked kernel runs interpret-mode on CPU); it gates that the packed
#: enforce_many path keeps working at speed, not an absolute throughput.
WORKLOADS = [
    ("model_rb", {"n": 12, "hardness": 1.0}, 32, "einsum"),
    ("coloring_random", {"n": 16, "edge_prob": 0.25, "k": 3}, 32, "einsum"),
    ("model_rb", {"n": 10, "hardness": 1.0}, 6, "pallas_packed"),
]


def bench_workload(family: str, knobs: dict, count: int, engine: str = "einsum",
                   seed: int = 0) -> dict:
    csps = generate_batch(family, count, seed=seed, **knobs)

    t0 = time.perf_counter()
    seq = [mac_solve(c, engine=engine)[0] for c in csps]
    seq_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    sols, _ = solve_many(csps, engine=engine)
    many_s = time.perf_counter() - t0

    if sols != seq:  # throughput numbers are meaningless if results diverge
        raise AssertionError(f"{family}: solve_many diverged from sequential mac_solve")

    return {
        "family": family,
        "knobs": knobs,
        "count": count,
        "engine": engine,
        "n_solved": sum(s is not None for s in sols),
        "sequential_s": round(seq_s, 3),
        "solve_many_s": round(many_s, 3),
        "sequential_instances_per_s": round(count / seq_s, 3),
        "many_instances_per_s": round(count / many_s, 3),
        "speedup": round(seq_s / many_s, 3),
    }


def main(out_path: Path = OUT_PATH) -> list:
    rows = [
        bench_workload(f, knobs, count, engine=engine)
        for f, knobs, count, engine in WORKLOADS
    ]
    for r in rows:
        print(
            f"many,{r['engine']},{r['family']},{r['count']},"
            f"{r['sequential_instances_per_s']:.3f},{r['many_instances_per_s']:.3f},"
            f"{r['speedup']:.3f}"
        )
    tracker.merge_section("many", rows, out_path)
    print(f"many: wrote {out_path}")
    return rows


if __name__ == "__main__":
    main()
