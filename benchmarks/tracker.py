"""The one owner of BENCH_engines.json section merging.

Every benchmark that records into the cross-PR tracker file goes through
``merge_section``: read the prior report, keep every section a *different*
benchmark owns (same-schema only — never graft onto a stale/foreign schema),
replace this benchmark's section, write back. One implementation means a
schema bump happens in exactly one place and no benchmark can silently drop
a sibling's section.

Beyond the live sections, the file carries a ``history`` list — one entry per
(commit, run) with a timestamp and per-section median summaries, APPENDED (all
prior entries preserved) where the sections themselves are replaced in place.
That is the cross-PR trajectory: successive PRs regenerate the sections but
accumulate history, and CI's bench-smoke uploads the whole file as an
artifact, so the trend survives even between baseline regenerations.
"""

from __future__ import annotations

import json
import os
import subprocess
import time
from pathlib import Path
from typing import Optional

import numpy as np

SCHEMA = "bench_engines/v2"

OUT_PATH = Path(__file__).resolve().parents[1] / "BENCH_engines.json"

#: the sections check_regression gates; `--reset-sections` strips exactly
#: these so a fresh CI run must rebuild every one of them from scratch
GATED_SECTIONS = ("engines", "many", "service", "frontier", "faults")

#: history never grows without bound — older runs roll off
HISTORY_MAX = 200


def current_commit() -> str:
    """The commit this run measures: CI's GITHUB_SHA, else git HEAD."""
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha[:12]
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=Path(__file__).resolve().parents[1],
        )
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return "unknown"


def _summarize(key: str, value) -> Optional[dict]:
    """A compact per-section median summary for one history entry."""
    try:
        if key == "engines":
            return {
                engine: round(float(np.median(
                    [c["enforce_ms_median"] for c in cells
                     if not c.get("inconsistent_root")]
                )), 3)
                for engine, cells in value.items()
            }
        if key == "many":
            return {
                f"{r['engine']}/{r['family']}": {
                    "many_instances_per_s": r["many_instances_per_s"],
                    # work-per-answer trend (obs registry figures): kernel
                    # launches amortized per solved instance, and the shape
                    # of the per-instance round distribution
                    "launches_per_solve": r.get("launches_per_solve", 0.0),
                    "rounds_p50": round(
                        float(r.get("rounds_per_instance", {}).get("p50", 0)), 2
                    ),
                    "rounds_p90": round(
                        float(r.get("rounds_per_instance", {}).get("p90", 0)), 2
                    ),
                }
                for r in value
            }
        if key == "service":
            return {
                f"{r['engine']}/{r['trace']}": {
                    "p95_ms": r["p95_ms"],
                    "throughput_rps": r["throughput_rps"],
                    # speculation occupancy trend: rows one request consumes
                    # over its lifetime (1/round when speculation is off)
                    "median_rows_per_request": r.get("median_rows_per_request", 0.0),
                    # fused-fixpoint health: >1 means rounds split launches
                    "mean_launches_per_round": r.get("mean_launches_per_round", 0.0),
                    # robustness outcome mix under the (fault-free) replay —
                    # any nonzero shed/failed here flags a capacity regression
                    "shed": r.get("shed", 0),
                    "failed": r.get("failed", 0),
                }
                for r in value
            }
        if key == "faults":
            # the chaos drill: outcome mix + recovery machinery engagement
            return {
                f"{r['engine']}/{r['recipe']}": {
                    "error_rate": r["error_rate"],
                    "shed_rate": r["shed_rate"],
                    "unresolved": r["unresolved"],
                    "retries": r["retries"],
                    "demotions": r["demotions"],
                    "recovered": r["recovered"],
                }
                for r in value
            }
        if key == "frontier":
            return {
                f"{r['engine']}/{r['family']}": r["host_bytes_per_round"]
                for r in value
            }
        if key == "sweeps":
            # ungated: the sweep studies' wall cost per PR, so a study that
            # quietly balloons shows up in the trajectory
            return {
                r["sweep"]: {
                    "n_cells": r["n_cells"],
                    "total_seconds": r["total_seconds"],
                }
                for r in value
            }
    except (KeyError, TypeError, ValueError):
        return None
    return None


def _record_history(report: dict, key: str, value) -> None:
    summary = _summarize(key, value)
    if summary is None:
        return
    history = report.setdefault("history", [])
    commit = current_commit()
    stamp = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    # one entry per (commit, run): benchmarks of the same run merge their
    # sections into the trailing entry instead of appending duplicates
    if history and history[-1].get("commit") == commit:
        history[-1]["sections"][key] = summary
        history[-1]["timestamp"] = stamp
    else:
        history.append({"commit": commit, "timestamp": stamp, "sections": {key: summary}})
    del history[:-HISTORY_MAX]


def merge_section(key: str, value, out_path: Path = OUT_PATH,
                  extra: Optional[dict] = None) -> dict:
    """Set ``report[key] = value`` in the tracker file, preserving every other
    section of a same-schema prior report and appending this run to the
    ``history`` trajectory. ``extra`` merges top-level metadata (e.g.
    platform). Returns the full report written."""
    report = {"schema": SCHEMA, "engines": {}}
    if out_path.exists():
        try:
            prior = json.loads(out_path.read_text())
            if prior.get("schema") == SCHEMA:
                report = prior
        except (json.JSONDecodeError, OSError):
            pass
    report[key] = value
    _record_history(report, key, value)
    if extra:
        report.update(extra)
    out_path.write_text(json.dumps(report, indent=1))
    return report


def reset_sections(out_path: Path = OUT_PATH) -> None:
    """Strip the gated sections (keeping schema, history, metadata) so the
    next benchmark run rebuilds them from scratch. CI's bench-smoke runs this
    right after setting the baseline aside: a benchmark that stops recording
    then leaves a *genuinely* missing section for check_regression to fail on,
    rather than silently inheriting the committed copy."""
    if not out_path.exists():
        return
    try:
        report = json.loads(out_path.read_text())
    except (json.JSONDecodeError, OSError):
        return
    if report.get("schema") != SCHEMA:
        return
    for key in GATED_SECTIONS:
        report.pop(key, None)
    out_path.write_text(json.dumps(report, indent=1))
    print(f"tracker: reset sections {GATED_SECTIONS} in {out_path}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reset-sections", action="store_true",
                    help="strip the gated sections, keeping history/metadata")
    args = ap.parse_args()
    if args.reset_sections:
        reset_sections()
