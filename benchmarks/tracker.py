"""The one owner of BENCH_engines.json section merging.

Every benchmark that records into the cross-PR tracker file goes through
``merge_section``: read the prior report, keep every section a *different*
benchmark owns (same-schema only — never graft onto a stale/foreign schema),
replace this benchmark's section, write back. One implementation means a
schema bump happens in exactly one place and no benchmark can silently drop
a sibling's section.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Optional

SCHEMA = "bench_engines/v2"

OUT_PATH = Path(__file__).resolve().parents[1] / "BENCH_engines.json"


def merge_section(key: str, value, out_path: Path = OUT_PATH,
                  extra: Optional[dict] = None) -> dict:
    """Set ``report[key] = value`` in the tracker file, preserving every other
    section of a same-schema prior report. ``extra`` merges top-level metadata
    (e.g. platform). Returns the full report written."""
    report = {"schema": SCHEMA, "engines": {}}
    if out_path.exists():
        try:
            prior = json.loads(out_path.read_text())
            if prior.get("schema") == SCHEMA:
                report = prior
        except (json.JSONDecodeError, OSError):
            pass
    report[key] = value
    if extra:
        report.update(extra)
    out_path.write_text(json.dumps(report, indent=1))
    return report
