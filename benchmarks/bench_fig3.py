"""Paper Fig. 3: running time (ms) of one assignment's enforcement.

Same sampling protocol as bench_table1 (which also records wall times); this
module adds the batched-enforcement variant — the beyond-paper lever where B
candidate assignments are enforced simultaneously by one vmapped fixpoint
against the prepared-once network (`Engine.enforce_batch`) — and reports
per-assignment amortized time.

Claims under test (paper §5.3): RTAC per-assignment time is ~flat as n and
density grow; AC3 time grows. (Absolute numbers are CPU-host numbers in this
container — the GPU/TPU gap is the point of the roofline analysis instead.)
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import CSPBenchSpec, assign_np
from repro.engines import get_engine


def run_batched_cell(
    spec: CSPBenchSpec, batch: int = 16, engine: str = "einsum", seed: int = 0
) -> dict:
    csp = spec.build()
    n, d = csp.dom.shape
    rng = np.random.default_rng(seed)
    prepared = get_engine(engine).prepare(csp)  # once per cell
    root = prepared.enforce()
    if not bool(root.consistent):
        return {"spec": spec, "inconsistent_root": True}
    root_np = np.asarray(root.dom)

    doms, chs = [], []
    for _ in range(batch):
        var = int(rng.integers(n))
        vals = np.nonzero(root_np[var])[0]
        doms.append(assign_np(root_np, var, int(rng.choice(vals))))
        ch = np.zeros((n,), bool)
        ch[var] = True
        chs.append(ch)
    dom_b = np.stack(doms)
    ch_b = np.stack(chs)

    res = prepared.enforce_batch(dom_b, ch_b)  # warmup/compile
    jax.block_until_ready(res.dom)
    t0 = time.perf_counter()
    res = prepared.enforce_batch(dom_b, ch_b)
    jax.block_until_ready(res.dom)  # no D2H copy inside the timed region
    dt = time.perf_counter() - t0
    return {
        "n_vars": spec.n_vars,
        "density": spec.density,
        "engine": engine,
        "batched_total_ms": 1e3 * dt,
        "batched_per_assignment_ms": 1e3 * dt / batch,
        "batch": batch,
    }


def main(quick: bool = True):
    ns = (100, 250) if quick else (100, 250, 500, 750, 1000)
    print("fig3_batched: n_vars,density,batch,per_assignment_ms,total_ms")
    rows = []
    for n in ns:
        for p in (0.10, 0.50, 1.00):
            spec = CSPBenchSpec(n_vars=n, density=p)
            r = run_batched_cell(spec, batch=8 if quick else 32)
            rows.append(r)
            if "inconsistent_root" in r:
                continue
            print(
                f"fig3_batched,{r['n_vars']},{r['density']:.2f},{r['batch']},"
                f"{r['batched_per_assignment_ms']:.3f},{r['batched_total_ms']:.3f}"
            )
    return rows


if __name__ == "__main__":
    main(quick=False)
