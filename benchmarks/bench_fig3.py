"""Paper Fig. 3: running time (ms) of one assignment's enforcement.

Same sampling protocol as bench_table1 (which also records wall times); this
module adds the batched-enforcement variant — the beyond-paper lever where B
candidate assignments are enforced simultaneously by one vmapped fixpoint —
and reports per-assignment amortized time, plus the dense kernel path timing.

Claims under test (paper §5.3): RTAC per-assignment time is ~flat as n and
density grow; AC3 time grows. (Absolute numbers are CPU-host numbers in this
container — the GPU/TPU gap is the point of the roofline analysis instead.)
"""

from __future__ import annotations

import time
from typing import List

import numpy as np
import jax.numpy as jnp

from repro.core import CSPBenchSpec, assign, enforce, enforce_batch


def run_batched_cell(spec: CSPBenchSpec, batch: int = 16, seed: int = 0) -> dict:
    csp = spec.build()
    n, d = csp.dom.shape
    rng = np.random.default_rng(seed)
    root = enforce(csp.cons, csp.mask, csp.dom)
    if not bool(root.consistent):
        return {"spec": spec, "inconsistent_root": True}
    root_np = np.asarray(root.dom)

    doms, chs = [], []
    for _ in range(batch):
        var = int(rng.integers(n))
        vals = np.nonzero(root_np[var])[0]
        val = int(rng.choice(vals))
        doms.append(np.asarray(assign(jnp.asarray(root_np), var, val)))
        ch = np.zeros((n,), bool)
        ch[var] = True
        chs.append(ch)
    dom_b = jnp.asarray(np.stack(doms))
    ch_b = jnp.asarray(np.stack(chs))

    res = enforce_batch(csp.cons, csp.mask, dom_b, ch_b)  # warmup/compile
    res.dom.block_until_ready()
    t0 = time.perf_counter()
    res = enforce_batch(csp.cons, csp.mask, dom_b, ch_b)
    res.dom.block_until_ready()
    dt = time.perf_counter() - t0
    return {
        "n_vars": spec.n_vars,
        "density": spec.density,
        "batched_total_ms": 1e3 * dt,
        "batched_per_assignment_ms": 1e3 * dt / batch,
        "batch": batch,
    }


def main(quick: bool = True):
    ns = (100, 250) if quick else (100, 250, 500, 750, 1000)
    print("fig3_batched: n_vars,density,batch,per_assignment_ms,total_ms")
    rows = []
    for n in ns:
        for p in (0.10, 0.50, 1.00):
            spec = CSPBenchSpec(n_vars=n, density=p)
            r = run_batched_cell(spec, batch=8 if quick else 32)
            rows.append(r)
            if "inconsistent_root" in r:
                continue
            print(
                f"fig3_batched,{r['n_vars']},{r['density']:.2f},{r['batch']},"
                f"{r['batched_per_assignment_ms']:.3f},{r['batched_total_ms']:.3f}"
            )
    return rows


if __name__ == "__main__":
    main(quick=False)
