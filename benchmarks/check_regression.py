"""Compare a fresh BENCH_engines.json against the committed baseline and fail
on latency regressions — CI's bench-smoke gate.

    python -m benchmarks.check_regression BASELINE FRESH [--tolerance 3.0]

A cell regresses when ``fresh/baseline > tolerance`` on ``enforce_ms_median``.
The default 3× tolerance absorbs shared-runner noise while still catching
order-of-magnitude mistakes (accidental re-preparation, lost jit caching, a
host sync in the hot path). Cells are matched by (engine, label); an engine or
cell present in the baseline but missing from the fresh run fails the check,
new cells are reported but pass (the baseline is regenerated in the same PR
that adds them).

A whole SECTION present (non-empty) in the baseline but absent from the fresh
run is a hard failure, not a silent pass — a benchmark that stops writing its
section must not look like zero regressions.

The "many" section (solve_many workload throughput) is gated on
``many_instances_per_s``: a ``> tolerance``× throughput drop fails, matched by
(engine, family). ``n_solved`` is additionally a hard FLOOR — fewer instances
solved than the baseline is a completeness bug (a speculative search dropping
a verdict), never runner noise, so no tolerance applies. The "service"
section (bench_service trace replays) is gated the same way: p95 AND p99 tail
latency may not regress ``> tolerance``× and sustained throughput may not
drop ``> tolerance``×, matched by (engine, trace). The "frontier" section (device-resident lockstep rounds,
DESIGN.md §8) gates ``host_bytes_per_round`` AND ``metadata_fraction``: a
``> tolerance``× growth in per-round host↔device traffic — absolute bytes, or
the fraction of the counterfactual full-domain protocol — e.g. a domain
tensor sneaking back onto the boundary — fails like any latency regression.
The "faults" section (bench_service chaos drill, DESIGN.md §12) is gated on
ABSOLUTE ceilings instead of ratios: ``unresolved == 0`` always, plus hard
error-rate/shed-rate bounds — liveness under chaos is a correctness contract,
not a trend. Exit code 0 = ok, 1 = regression/mismatch.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .tracker import GATED_SECTIONS as SECTIONS  # single owner of the list

METRIC = "enforce_ms_median"


def index_cells(report: dict) -> dict:
    out = {}
    for engine, cells in report.get("engines", {}).items():
        for cell in cells:
            if cell.get("inconsistent_root"):
                continue
            out[(engine, cell["label"])] = cell
    return out


def compare(baseline: dict, fresh: dict, tolerance: float) -> list:
    """Returns a list of failure strings (empty = pass); prints a cell table."""
    failures = []
    if baseline.get("schema") != fresh.get("schema"):
        return [
            f"schema mismatch: baseline {baseline.get('schema')!r} vs fresh "
            f"{fresh.get('schema')!r} — regenerate the committed BENCH_engines.json"
        ]
    for sec in SECTIONS:
        if baseline.get(sec) and not fresh.get(sec):
            failures.append(
                f"section {sec!r} present in baseline but missing from fresh run "
                "— its benchmark stopped recording"
            )
    base_cells, fresh_cells = index_cells(baseline), index_cells(fresh)
    for key in sorted(base_cells):
        engine, label = key
        if key not in fresh_cells:
            failures.append(f"{engine} {label}: cell missing from fresh run")
            continue
        b, f = base_cells[key][METRIC], fresh_cells[key][METRIC]
        # one rounding quantum (bench_engines rounds to 3 decimals) as a floor,
        # so a 0.000 baseline doesn't turn every later run into inf/FAIL
        eps = 1e-3
        ratio = (f + eps) / (b + eps)
        status = "FAIL" if ratio > tolerance else "ok"
        print(f"{status:4s} {engine:14s} {label:34s} {b:10.3f} -> {f:10.3f} ms ({ratio:.2f}x)")
        if ratio > tolerance:
            failures.append(f"{engine} {label}: {METRIC} {b} -> {f} ({ratio:.2f}x > {tolerance}x)")
    for key in sorted(set(fresh_cells) - set(base_cells)):
        print(f"new  {key[0]:14s} {key[1]:34s} (no baseline — passes)")
    failures.extend(compare_many(baseline, fresh, tolerance))
    failures.extend(compare_service(baseline, fresh, tolerance))
    failures.extend(compare_frontier(baseline, fresh, tolerance))
    failures.extend(compare_faults(baseline, fresh))
    return failures


def index_many(report: dict) -> dict:
    return {(r["engine"], r["family"]): r for r in report.get("many", [])}


def compare_many(baseline: dict, fresh: dict, tolerance: float) -> list:
    """Gate the many section: solve_many throughput (instances/second) may not
    drop more than ``tolerance``×. Same missing/new-row policy as the cells."""
    failures = []
    base_rows, fresh_rows = index_many(baseline), index_many(fresh)
    eps = 1e-3
    for key in sorted(base_rows):
        engine, family = key
        if key not in fresh_rows:
            failures.append(f"many {engine} {family}: row missing from fresh run")
            continue
        b = base_rows[key]["many_instances_per_s"]
        f = fresh_rows[key]["many_instances_per_s"]
        ratio = (b + eps) / (f + eps)  # throughput DROP factor
        b_solved = base_rows[key].get("n_solved")
        f_solved = fresh_rows[key].get("n_solved", 0)
        solved_ok = b_solved is None or f_solved >= b_solved
        status = "FAIL" if ratio > tolerance or not solved_ok else "ok"
        print(
            f"{status:4s} many:{engine:10s} {family:34s} "
            f"{b:8.3f} -> {f:8.3f} inst/s ({1 / max(ratio, eps):.2f}x), "
            f"solved {b_solved} -> {f_solved}"
        )
        if ratio > tolerance:
            failures.append(
                f"many {engine} {family}: many_instances_per_s {b} -> {f} "
                f"({ratio:.2f}x drop > {tolerance}x)"
            )
        if not solved_ok:
            failures.append(
                f"many {engine} {family}: n_solved {b_solved} -> {f_solved} "
                "(below baseline floor — verdicts went missing)"
            )
    for key in sorted(set(fresh_rows) - set(base_rows)):
        print(f"new  many:{key[0]:10s} {key[1]:34s} (no baseline — passes)")
    return failures


def index_frontier(report: dict) -> dict:
    return {(r["engine"], r["family"]): r for r in report.get("frontier", [])}


def compare_frontier(baseline: dict, fresh: dict, tolerance: float) -> list:
    """Gate the frontier section: per-round host↔device metadata traffic may
    not GROW more than ``tolerance``× — neither the absolute
    ``host_bytes_per_round`` nor the relative ``metadata_fraction`` (bytes as
    a fraction of the counterfactual full-domain protocol; the fraction
    catches a creep that absolute bytes hide when the workload also shrank).
    A domain tensor creeping back onto the host boundary shows up here long
    before it shows up as latency. Same missing/new-row policy as the other
    sections."""
    failures = []
    base_rows, fresh_rows = index_frontier(baseline), index_frontier(fresh)
    eps = 1e-3
    for key in sorted(base_rows):
        engine, family = key
        if key not in fresh_rows:
            failures.append(f"frontier {engine} {family}: row missing from fresh run")
            continue
        for metric, fmt, eps_m in (
            ("host_bytes_per_round", "{:10.1f} -> {:10.1f} B/round", eps),
            # fractions live in [0, 1]; a 1e-3 floor would swamp tiny
            # baselines, so use a proportionally tiny quantum
            ("metadata_fraction", "{:10.4f} -> {:10.4f} frac", 1e-6),
        ):
            b = base_rows[key].get(metric)
            f = fresh_rows[key].get(metric)
            if b is None:  # pre-gate baseline row: report once regenerated
                continue
            if f is None:
                failures.append(
                    f"frontier {engine} {family}: {metric} missing from fresh run"
                )
                continue
            ratio = (f + eps_m) / (b + eps_m)  # GROWTH factor
            status = "FAIL" if ratio > tolerance else "ok"
            print(
                f"{status:4s} frontier:{engine:7s} {family:34s} "
                + fmt.format(b, f)
                + f" ({ratio:.2f}x)"
            )
            if ratio > tolerance:
                failures.append(
                    f"frontier {engine} {family}: {metric} {b} -> {f} "
                    f"({ratio:.2f}x growth > {tolerance}x)"
                )
    for key in sorted(set(fresh_rows) - set(base_rows)):
        print(f"new  frontier:{key[0]:7s} {key[1]:34s} (no baseline — passes)")
    return failures


def index_faults(report: dict) -> dict:
    return {(r["engine"], r["recipe"]): r for r in report.get("faults", [])}


#: absolute ceilings for the chaos cells — correctness bounds, not trends,
#: so no tolerance multiplier applies (DESIGN.md §12 acceptance)
FAULTS_MAX_ERROR_RATE = 0.25
FAULTS_MAX_SHED_RATE = 0.90


def compare_faults(baseline: dict, fresh: dict) -> list:
    """Gate the faults section (the chaos drill) on ABSOLUTE ceilings rather
    than baseline ratios: ``unresolved`` must be exactly 0 (every future under
    chaos reaches a terminal state — the liveness contract), the failure rate
    must stay under `FAULTS_MAX_ERROR_RATE`, and the shed rate under
    `FAULTS_MAX_SHED_RATE` (the overload drill sheds most of its burst by
    design; shedding *everything* would mean admission is wedged). Missing
    rows fail like the other sections."""
    failures = []
    base_rows, fresh_rows = index_faults(baseline), index_faults(fresh)
    for key in sorted(base_rows):
        engine, recipe = key
        if key not in fresh_rows:
            failures.append(f"faults {engine} {recipe}: row missing from fresh run")
            continue
        f = fresh_rows[key]
        checks = [
            ("unresolved", f.get("unresolved", -1), 0),
            ("error_rate", f.get("error_rate", 1.0), FAULTS_MAX_ERROR_RATE),
            ("shed_rate", f.get("shed_rate", 1.0), FAULTS_MAX_SHED_RATE),
        ]
        bad = [(m, v, ceil) for m, v, ceil in checks if v > ceil]
        status = "FAIL" if bad else "ok"
        print(
            f"{status:4s} faults:{engine:8s} {recipe:28s} "
            f"unresolved={f.get('unresolved')} error_rate={f.get('error_rate')} "
            f"shed_rate={f.get('shed_rate')} recovered={f.get('recovered')} "
            f"demotions={f.get('demotions')}"
        )
        for metric, v, ceil in bad:
            failures.append(
                f"faults {engine} {recipe}: {metric} {v} > ceiling {ceil}"
            )
    for key in sorted(set(fresh_rows) - set(base_rows)):
        print(f"new  faults:{key[0]:8s} {key[1]:28s} (no baseline — passes)")
    return failures


def index_service(report: dict) -> dict:
    return {(r["engine"], r["trace"]): r for r in report.get("service", [])}


def compare_service(baseline: dict, fresh: dict, tolerance: float) -> list:
    """Gate the service section: p95 or p99 tail latency up, throughput
    down, or per-round kernel launches up, by more than ``tolerance``× fails.
    The p99 gate exists specifically for speculation: duplication that helps
    the median but starves the queue shows up in the extreme tail first. The
    launches gate holds the fused-fixpoint claim: a round splitting back into
    per-recurrence launches regresses here before it shows up as latency.
    Same missing/new-cell policy as engine cells."""
    failures = []
    base_rows, fresh_rows = index_service(baseline), index_service(fresh)
    eps = 1e-3  # one rounding quantum floor, as for the latency cells
    for key in sorted(base_rows):
        engine, trace = key
        if key not in fresh_rows:
            failures.append(f"service {engine} {trace}: row missing from fresh run")
            continue
        b, f = base_rows[key], fresh_rows[key]
        lat_ratio = (f["p95_ms"] + eps) / (b["p95_ms"] + eps)
        # pre-gate baselines may lack p99 in old files; treat missing as pass
        p99_ratio = (
            (f["p99_ms"] + eps) / (b["p99_ms"] + eps)
            if b.get("p99_ms") is not None and f.get("p99_ms") is not None
            else 1.0
        )
        tput_ratio = (b["throughput_rps"] + eps) / (f["throughput_rps"] + eps)
        # pre-gate baselines may lack the launches figure; missing = pass
        lpr_ratio = (
            (f["mean_launches_per_round"] + eps)
            / (b["mean_launches_per_round"] + eps)
            if b.get("mean_launches_per_round") is not None
            and f.get("mean_launches_per_round") is not None
            else 1.0
        )
        worst = max(lat_ratio, p99_ratio, tput_ratio, lpr_ratio)
        status = "FAIL" if worst > tolerance else "ok"
        print(
            f"{status:4s} service:{engine:7s} {trace:34s} "
            f"p95 {b['p95_ms']:8.1f} -> {f['p95_ms']:8.1f} ms ({lat_ratio:.2f}x), "
            f"p99 ({p99_ratio:.2f}x), "
            f"tput {b['throughput_rps']:.2f} -> {f['throughput_rps']:.2f} rps "
            f"({1 / max(tput_ratio, eps):.2f}x), "
            f"launches/round ({lpr_ratio:.2f}x)"
        )
        if lat_ratio > tolerance:
            failures.append(
                f"service {engine} {trace}: p95_ms {b['p95_ms']} -> {f['p95_ms']} "
                f"({lat_ratio:.2f}x > {tolerance}x)"
            )
        if p99_ratio > tolerance:
            failures.append(
                f"service {engine} {trace}: p99_ms {b['p99_ms']} -> {f['p99_ms']} "
                f"({p99_ratio:.2f}x > {tolerance}x)"
            )
        if tput_ratio > tolerance:
            failures.append(
                f"service {engine} {trace}: throughput_rps {b['throughput_rps']} -> "
                f"{f['throughput_rps']} ({tput_ratio:.2f}x drop > {tolerance}x)"
            )
        if lpr_ratio > tolerance:
            failures.append(
                f"service {engine} {trace}: mean_launches_per_round "
                f"{b['mean_launches_per_round']} -> {f['mean_launches_per_round']} "
                f"({lpr_ratio:.2f}x growth > {tolerance}x)"
            )
    for key in sorted(set(fresh_rows) - set(base_rows)):
        print(f"new  service:{key[0]:7s} {key[1]:34s} (no baseline — passes)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", type=Path)
    ap.add_argument("fresh", type=Path)
    ap.add_argument("--tolerance", type=float, default=3.0)
    args = ap.parse_args(argv)
    baseline = json.loads(args.baseline.read_text())
    fresh = json.loads(args.fresh.read_text())
    failures = compare(baseline, fresh, args.tolerance)
    for f in failures:
        print(f"regression: {f}", file=sys.stderr)
    print(f"check_regression: {'FAIL' if failures else 'PASS'} "
          f"({len(failures)} failure(s), tolerance {args.tolerance}x)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
