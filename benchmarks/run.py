"""Benchmark orchestrator — one function per paper table/figure or subsystem.

Prints ``name,...`` CSV rows. Quick mode keeps CPU runtime in minutes; pass
--full for the paper's complete grid (n up to 1000).

  engines  per-engine enforce latency on 3 problem families × 3 sizes ->
           BENCH_engines.json (the cross-PR perf trajectory)
  many     instances/second of solve_many vs sequential mac_solve ->
           BENCH_engines.json "many" section
  service  SolverService trace replay: sustained throughput + tail latency ->
           BENCH_engines.json "service" section
  sweeps   the committed `repro.sweeps` studies (resume-aware: completed
           cells in results/ are never re-run) -> ungated "sweeps" section
           + a per-sweep history row. The paper's Table 1 / Fig. 3
           protocols live here now, as the ``recurrence_density``
           assignments-mode sweep (formerly the table1/fig3 targets).

``--only <target>`` runs one target; an unknown target exits non-zero and
prints the valid target list (no more silently running nothing on a typo).
"""

from __future__ import annotations

import argparse
import sys


def _run_engines(quick: bool) -> None:
    from . import bench_engines

    bench_engines.main()


def _run_many(quick: bool) -> None:
    from . import bench_many

    bench_many.main()


def _run_service(quick: bool) -> None:
    from . import bench_service

    bench_service.main(quick=quick)


def _run_sweeps(quick: bool) -> None:
    from repro.sweeps import available_specs, load_cells, load_spec, run_spec

    from . import tracker

    rows = []
    for name in available_specs():
        if name == "smoke":  # CI fixture, not a study
            continue
        spec = load_spec(name)
        d = run_spec(spec)  # resume-aware; a complete study is a no-op
        records = load_cells(d / "cells.jsonl")
        secs = sorted(r["cell_seconds"] for r in records)
        row = {
            "sweep": name,
            "mode": spec.mode,
            "n_cells": len(records),
            "total_seconds": round(sum(secs), 3),
            "median_cell_seconds": round(secs[len(secs) // 2], 3) if secs else 0.0,
        }
        rows.append(row)
        print(f"sweeps,{name},{spec.mode},{row['n_cells']},"
              f"{row['total_seconds']:.1f}s")
    tracker.merge_section("sweeps", rows)
    print(f"sweeps: wrote {tracker.OUT_PATH}")


#: registration order is execution order for a full run
TARGETS = {
    "engines": _run_engines,
    "many": _run_many,
    "service": _run_service,
    "sweeps": _run_sweeps,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale grid")
    ap.add_argument("--only", default=None, metavar="TARGET",
                    help=f"run one target; valid: {', '.join(TARGETS)}")
    args = ap.parse_args(argv)
    quick = not args.full

    if args.only is not None and args.only not in TARGETS:
        print(
            f"benchmarks.run: unknown target {args.only!r}; "
            f"valid targets: {', '.join(TARGETS)}",
            file=sys.stderr,
        )
        return 2
    for name, fn in TARGETS.items():
        if args.only in (None, name):
            fn(quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
