"""Benchmark orchestrator — one function per paper table/figure or subsystem.

Prints ``name,...`` CSV rows. Quick mode keeps CPU runtime in minutes; pass
--full for the paper's complete grid (n up to 1000).

  table1   paper Table 1 — #Revision (AC3) vs #Recurrence (RTAC) per assignment
  fig3     paper Fig. 3 — per-assignment enforcement time (+ batched variant)
  engines  per-engine enforce latency on 3 problem families × 3 sizes ->
           BENCH_engines.json (the cross-PR perf trajectory)
  many     instances/second of solve_many vs sequential mac_solve ->
           BENCH_engines.json "many" section
  service  SolverService trace replay: sustained throughput + tail latency ->
           BENCH_engines.json "service" section
  roofline deliverable (g) — three-term roofline per dry-run artifact (reads
           artifacts/dryrun; run `python -m repro.launch.dryrun --all` first)

``--only <target>`` runs one target; an unknown target exits non-zero and
prints the valid target list (no more silently running nothing on a typo).
"""

from __future__ import annotations

import argparse
import sys


def _run_table1(quick: bool) -> None:
    from . import bench_table1

    bench_table1.main(quick=quick)


def _run_fig3(quick: bool) -> None:
    from . import bench_fig3

    bench_fig3.main(quick=quick)


def _run_engines(quick: bool) -> None:
    from . import bench_engines

    bench_engines.main()


def _run_many(quick: bool) -> None:
    from . import bench_many

    bench_many.main()


def _run_service(quick: bool) -> None:
    from . import bench_service

    bench_service.main(quick=quick)


def _run_roofline(quick: bool) -> None:
    from . import roofline

    try:
        roofline.main()
    except Exception as e:  # unexpected failure; missing artifacts are
        print(f"roofline,skipped,{e}", file=sys.stderr)  # handled inside


#: registration order is execution order for a full run
TARGETS = {
    "table1": _run_table1,
    "fig3": _run_fig3,
    "engines": _run_engines,
    "many": _run_many,
    "service": _run_service,
    "roofline": _run_roofline,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale grid")
    ap.add_argument("--only", default=None, metavar="TARGET",
                    help=f"run one target; valid: {', '.join(TARGETS)}")
    args = ap.parse_args(argv)
    quick = not args.full

    if args.only is not None and args.only not in TARGETS:
        print(
            f"benchmarks.run: unknown target {args.only!r}; "
            f"valid targets: {', '.join(TARGETS)}",
            file=sys.stderr,
        )
        return 2
    for name, fn in TARGETS.items():
        if args.only in (None, name):
            fn(quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
