"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,...`` CSV rows. Quick mode keeps CPU runtime in minutes; pass
--full for the paper's complete grid (n up to 1000).

  table1   paper Table 1 — #Revision (AC3) vs #Recurrence (RTAC) per assignment
  fig3     paper Fig. 3 — per-assignment enforcement time (+ batched variant)
  engines  per-engine enforce latency on 3 problem families × 3 sizes ->
           BENCH_engines.json (the cross-PR perf trajectory)
  many     instances/second of solve_many vs sequential mac_solve ->
           BENCH_engines.json "many" section
  roofline deliverable (g) — three-term roofline per dry-run artifact (reads
           artifacts/dryrun; run `python -m repro.launch.dryrun --all` first)
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale grid")
    ap.add_argument(
        "--only",
        choices=["table1", "fig3", "engines", "many", "roofline"],
        default=None,
    )
    args = ap.parse_args()
    quick = not args.full

    if args.only in (None, "table1"):
        from . import bench_table1

        bench_table1.main(quick=quick)
    if args.only in (None, "fig3"):
        from . import bench_fig3

        bench_fig3.main(quick=quick)
    if args.only in (None, "engines"):
        from . import bench_engines

        bench_engines.main()
    if args.only in (None, "many"):
        from . import bench_many

        bench_many.main()
    if args.only in (None, "roofline"):
        from . import roofline

        try:
            roofline.main()
        except Exception as e:  # unexpected failure; missing artifacts are
            print(f"roofline,skipped,{e}", file=sys.stderr)  # handled inside


if __name__ == "__main__":
    main()
