"""Paper Table 1: #Revision (AC3) vs #Recurrence (RTAC) over the random-CSP grid.

Protocol: for each (n_vars, density) cell, take the AC-closed root network,
sample N assignments (uniform var, uniform surviving value), and enforce after
each with changed={var} — the paper's per-assignment statistics without the
50K-node search budget (deviation noted in EXPERIMENTS.md; trend and magnitude
are the claims under test: #Recurrence flat in ~[3,5], #Revision growing with
n·density).

Each engine prepares the network ONCE per cell (`Engine.prepare`) and enforces
all sampled assignments against the resident prepared form.
"""

from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.core import CSPBenchSpec, assign_np
from repro.engines import get_engine


def run_cell(
    spec: CSPBenchSpec,
    n_assignments: int = 20,
    engines=("einsum", "ac3"),
    seed: int = 0,
) -> dict:
    csp = spec.build()
    n, d = csp.dom.shape
    rng = np.random.default_rng(seed)

    out = {"spec": spec, "n_vars": spec.n_vars, "density": spec.density}

    # root closure (shared across engines)
    root = get_engine("einsum").prepare(csp).enforce()
    if not bool(root.consistent):
        out["inconsistent_root"] = True
        return out
    root_np = np.asarray(root.dom)

    # sample assignment sites once, reuse across engines
    sites = []
    for _ in range(n_assignments):
        var = int(rng.integers(n))
        vals = np.nonzero(root_np[var])[0]
        sites.append((var, int(rng.choice(vals))))

    for name in engines:
        eng = get_engine(name)
        prepared = eng.prepare(csp)  # once per cell — the expensive part
        # warmup compile on the first site's shape
        var0, val0 = sites[0]
        ch0 = np.zeros((n,), bool)
        ch0[var0] = True
        r = prepared.enforce(assign_np(root_np, var0, val0), ch0)
        jax.block_until_ready(r.dom)

        counts, times = [], []
        for var, val in sites:
            dom_a = assign_np(root_np, var, val)
            ch = np.zeros((n,), bool)
            ch[var] = True
            t0 = time.perf_counter()
            r = prepared.enforce(dom_a, ch)
            jax.block_until_ready(r.dom)  # no D2H copy inside the timed region
            times.append(time.perf_counter() - t0)
            counts.append(int(np.asarray(r.n_recurrences)))
        key = "revisions" if eng.count_unit == "revisions" else "recurrences"
        out[f"{name}_{key}"] = float(np.mean(counts))
        out[f"{name}_ms"] = 1e3 * float(np.mean(times))
    return out


def run(
    n_vars_list=(100, 250, 500),
    densities=(0.10, 0.25, 0.50, 0.75, 1.00),
    dom_size: int = 20,
    tightness: float = 0.3,
    n_assignments: int = 20,
) -> List[dict]:
    rows = []
    for n in n_vars_list:
        for p in densities:
            spec = CSPBenchSpec(n_vars=n, density=p, dom_size=dom_size, tightness=tightness)
            rows.append(run_cell(spec, n_assignments))
    return rows


def main(quick: bool = True):
    rows = run(n_vars_list=(100, 250) if quick else (100, 250, 500, 750, 1000),
               n_assignments=10 if quick else 50)
    print("table1: n_vars,density,ac3_revisions,rtac_recurrences,ac3_ms,rtac_ms")
    for r in rows:
        if r.get("inconsistent_root"):
            continue
        print(
            f"table1,{r['n_vars']},{r['density']:.2f},"
            f"{r.get('ac3_revisions', float('nan')):.1f},"
            f"{r.get('einsum_recurrences', float('nan')):.3f},"
            f"{r.get('ac3_ms', float('nan')):.3f},{r.get('einsum_ms', float('nan')):.3f}"
        )
    return rows


if __name__ == "__main__":
    main(quick=False)
