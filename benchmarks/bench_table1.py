"""Paper Table 1: #Revision (AC3) vs #Recurrence (RTAC) over the random-CSP grid.

Protocol: for each (n_vars, density) cell, take the AC-closed root network,
sample N assignments (uniform var, uniform surviving value), and enforce after
each with changed={var} — the paper's per-assignment statistics without the
50K-node search budget (deviation noted in EXPERIMENTS.md; trend and magnitude
are the claims under test: #Recurrence flat in ~[3,5], #Revision growing with
n·density).
"""

from __future__ import annotations

import time
from typing import List, Optional

import numpy as np
import jax.numpy as jnp

from repro.core import CSPBenchSpec, assign, enforce, enforce_ac3, assign_np


def run_cell(
    spec: CSPBenchSpec,
    n_assignments: int = 20,
    engines=("rtac", "ac3"),
    seed: int = 0,
) -> dict:
    csp = spec.build()
    n, d = csp.dom.shape
    cons_np, mask_np = np.asarray(csp.cons), np.asarray(csp.mask)
    rng = np.random.default_rng(seed)

    out = {"spec": spec, "n_vars": spec.n_vars, "density": spec.density}

    # root closure (shared)
    root = enforce(csp.cons, csp.mask, csp.dom)
    if not bool(root.consistent):
        out["inconsistent_root"] = True
        return out
    root_np = np.asarray(root.dom)
    root_j = jnp.asarray(root_np)

    # sample assignment sites once, reuse across engines
    sites = []
    for _ in range(n_assignments):
        var = int(rng.integers(n))
        vals = np.nonzero(root_np[var])[0]
        sites.append((var, int(rng.choice(vals))))

    if "rtac" in engines:
        ks, times = [], []
        # warmup compile
        ch0 = jnp.zeros((n,), jnp.bool_).at[0].set(True)
        enforce(csp.cons, csp.mask, root_j, ch0).dom.block_until_ready()
        for var, val in sites:
            dom_a = assign(root_j, var, val)
            ch = jnp.zeros((n,), jnp.bool_).at[var].set(True)
            t0 = time.perf_counter()
            r = enforce(csp.cons, csp.mask, dom_a, ch)
            r.dom.block_until_ready()
            times.append(time.perf_counter() - t0)
            ks.append(int(r.n_recurrences))
        out["rtac_recurrences"] = float(np.mean(ks))
        out["rtac_ms"] = 1e3 * float(np.mean(times))

    if "ac3" in engines:
        revs, times = [], []
        for var, val in sites:
            dom_a = assign_np(root_np, var, val)
            ch = np.zeros((n,), bool)
            ch[var] = True
            t0 = time.perf_counter()
            r = enforce_ac3(cons_np, mask_np, dom_a, ch)
            times.append(time.perf_counter() - t0)
            revs.append(r.n_revisions)
        out["ac3_revisions"] = float(np.mean(revs))
        out["ac3_ms"] = 1e3 * float(np.mean(times))
    return out


def run(
    n_vars_list=(100, 250, 500),
    densities=(0.10, 0.25, 0.50, 0.75, 1.00),
    dom_size: int = 20,
    tightness: float = 0.3,
    n_assignments: int = 20,
) -> List[dict]:
    rows = []
    for n in n_vars_list:
        for p in densities:
            spec = CSPBenchSpec(n_vars=n, density=p, dom_size=dom_size, tightness=tightness)
            rows.append(run_cell(spec, n_assignments))
    return rows


def main(quick: bool = True):
    rows = run(n_vars_list=(100, 250) if quick else (100, 250, 500, 750, 1000),
               n_assignments=10 if quick else 50)
    print("table1: n_vars,density,ac3_revisions,rtac_recurrences,ac3_ms,rtac_ms")
    for r in rows:
        if r.get("inconsistent_root"):
            continue
        print(
            f"table1,{r['n_vars']},{r['density']:.2f},"
            f"{r.get('ac3_revisions', float('nan')):.1f},"
            f"{r.get('rtac_recurrences', float('nan')):.3f},"
            f"{r.get('ac3_ms', float('nan')):.3f},{r.get('rtac_ms', float('nan')):.3f}"
        )
    return rows


if __name__ == "__main__":
    main(quick=False)
