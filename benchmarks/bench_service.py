"""Service throughput + tail latency -> the "service" section of
BENCH_engines.json.

Replays a fixed seeded Poisson trace through `SolverService` (DESIGN.md §7)
and records sustained instances/second, p50/p95/p99 latency, and dispatch
occupancy. The replay clock fast-forwards idle gaps, so the numbers measure
the service machinery (continuous batching, cache, buckets), not sleeps.

    PYTHONPATH=src python -m benchmarks.run --only service
"""

from __future__ import annotations

import time
from pathlib import Path

from repro.service import FastForwardClock, SolverService, poisson_trace, replay
from . import tracker
from .tracker import OUT_PATH

#: (engine, label, families, rate/s, duration s) — fixed seeds so runs are
#: comparable. The pallas_packed replay exercises the device-resident packed
#: slot table end-to-end (stacked kernels run interpret-mode on CPU, so its
#: trace is deliberately small — the gated quantity is the trajectory, not the
#: absolute number).
TRACES = [
    ("einsum", "poisson_mixed_r12_d4", ["model_rb", "coloring_random"], 12.0, 4.0),
    ("pallas_packed", "poisson_packed_r6_d2", ["model_rb"], 6.0, 2.0),
]
FULL_TRACES = TRACES + [
    ("einsum", "poisson_mixed_r8_d20", ["model_rb", "coloring_random"], 8.0, 20.0),
]


def bench_trace(label: str, families, rate: float, duration: float,
                engine: str = "einsum", seed: int = 0) -> dict:
    events = poisson_trace(families, rate=rate, duration=duration, seed=seed)
    clock = FastForwardClock()
    svc = SolverService(engine=engine, clock=clock)
    t0 = time.perf_counter()
    requests = replay(svc, events, clock)
    wall_s = time.perf_counter() - t0
    snap = svc.snapshot()
    return {
        "trace": label,
        "engine": engine,
        "families": list(families),
        "rate": rate,
        "duration": duration,
        "requests": len(requests),
        "completed": snap["completed"],
        "n_solved": sum(r.solution is not None for r in requests),
        "wall_s": round(wall_s, 3),
        "throughput_rps": snap["throughput_rps"],
        "p50_ms": snap["p50_ms"],
        "p95_ms": snap["p95_ms"],
        "p99_ms": snap["p99_ms"],
        "mean_rows_per_dispatch": snap["mean_rows_per_dispatch"],
        "rounds": snap["rounds"],
        "cache": snap["cache"],
    }


def main(quick: bool = True, out_path: Path = OUT_PATH) -> list:
    rows = [
        bench_trace(label, fams, rate, dur, engine=engine)
        for engine, label, fams, rate, dur in (TRACES if quick else FULL_TRACES)
    ]
    for r in rows:
        print(
            f"service,{r['engine']},{r['trace']},{r['requests']},"
            f"{r['throughput_rps']:.3f},{r['p50_ms']:.3f},{r['p95_ms']:.3f},"
            f"{r['p99_ms']:.3f},{r['mean_rows_per_dispatch']:.3f}"
        )
    tracker.merge_section("service", rows, out_path)
    print(f"service: wrote {out_path}")
    return rows


if __name__ == "__main__":
    main()
