"""Service throughput + tail latency -> the "service" section of
BENCH_engines.json.

Replays fixed seeded arrival traces through `SolverService` (DESIGN.md §7)
and records sustained instances/second, p50/p95/p99 latency, dispatch
occupancy, per-round kernel launches, and prepared-network cache hit-rate.
The replay clock fast-forwards idle gaps, so the numbers measure the service
machinery (continuous batching, cache, buckets), not sleeps.

Two trace kinds: `poisson_trace` seeds every event uniquely (cache hit-rate
pinned at 0 — the cold-traffic worst case), `dedup_trace` draws instances
from a small recurring pool, so the prepared-network LRU actually serves hits
and the recorded ``cache_hit_rate`` is meaningful.

    PYTHONPATH=src python -m benchmarks.run --only service
"""

from __future__ import annotations

from pathlib import Path

from repro import faults, obs
from repro.service import replay_rate_cell

from . import tracker
from .tracker import OUT_PATH

#: (engine, label, kind, families, rate/s, duration s) — fixed seeds so runs
#: are comparable. The pallas_packed replay exercises the device-resident
#: packed slot table end-to-end (stacked kernels run interpret-mode on CPU, so
#: its trace is deliberately small — the gated quantity is the trajectory, not
#: the absolute number). The dedup trace repeats instances from a 3-seed pool,
#: so the prepared-network LRU serves real hits.
TRACES = [
    ("einsum", "poisson_mixed_r12_d4", "poisson",
     ["model_rb", "coloring_random"], 12.0, 4.0, None),
    ("einsum", "dedup_mixed_r12_d4", "dedup",
     ["model_rb", "coloring_random"], 12.0, 4.0, None),
    ("pallas_packed", "poisson_packed_r6_d2", "poisson", ["model_rb"], 6.0, 2.0,
     None),
    # same mixed trace with speculation on: admission sizes duplication
    # against queue depth, so under this load rows_per_request stays modest —
    # the gated quantities are tail latency and the cancel rate
    ("einsum", "poisson_mixed_r12_d4_spec", "poisson",
     ["model_rb", "coloring_random"], 12.0, 4.0,
     {"split_budget": 2, "portfolio": 2}),
]
FULL_TRACES = TRACES + [
    ("einsum", "poisson_mixed_r8_d20", "poisson",
     ["model_rb", "coloring_random"], 8.0, 20.0, None),
]

#: (engine, recipe, service knobs, trace overrides) — the chaos drill behind
#: the gated "faults" section (DESIGN.md §12). Backoffs are shortened so the
#: replay stays seconds-long; the FastForwardClock jumps the gates anyway.
#: NOTE a round-level fault requeues (and charges a retry to) EVERY request
#: in flight on that driver, so failure rates amplify with batch depth — the
#: ceilings in check_regression are set against that, not the raw site rate.
_CHAOS_KW = {"backoff_base_s": 0.01, "backoff_cap_s": 0.05}
CHAOS = [
    # every site at 5%: recovery is retry-shaped (einsum's ladder has one
    # rung), the gate is unresolved == 0 + a bounded error rate. The retry
    # cap is generous because a round-level fault charges every co-batched
    # request and retries breed more rounds (more fault draws) — round
    # membership also shifts with host timing, so failures must stay rare
    # across runner speeds, not just on one box
    ("einsum", "all:0.05", dict(_CHAOS_KW, retry_cap=25), {}),
    # retry_cap=0 turns every kernel fault into a demotion; max_fires=2 bounds
    # the storm so the fallback ladder pallas_packed -> stepped -> einsum
    # carries the demoted cohort to verdicts (recovered > 0, demotions > 0)
    ("pallas_packed", "kernel.launch:0.5:oom:2", dict(_CHAOS_KW, retry_cap=0),
     {"families": ["model_rb"], "rate": 6.0, "duration": 2.0}),
    # overload drill, no faults: a burst against a short queue bound must
    # shed typed Overloaded verdicts instead of blowing the tail latency
    ("einsum", None, dict(_CHAOS_KW, shed_queue_depth=10),
     {"rate": 60.0, "duration": 1.0}),
]


def bench_chaos(engine: str, recipe, service_kwargs: dict,
                overrides: dict = None, seed: int = 0) -> dict:
    """One seeded chaos replay: a poisson trace under an injected fault plan
    (``recipe=None`` replays fault-free — the pure-overload drill). Records
    the outcome mix (recovered / shed / failed) and the recovery-machinery
    engagement the tracker history and `check_regression` gate on —
    ``unresolved`` must be 0 (every future reaches a terminal state) and the
    error/shed rates must stay under absolute ceilings."""
    cell = dict(families=["model_rb", "coloring_random"], rate=12.0, duration=4.0)
    cell.update(overrides or {})
    with faults.injected(recipe or "all:0.0", seed=seed) as plan:
        row = replay_rate_cell(
            engine=engine, seed=seed, service_kwargs=service_kwargs, **cell,
        )
    n = max(1, row["requests"])
    row.update(
        recipe=recipe or "none",
        fires=plan.total_fires,
        fires_by_site={s: f for s, f in sorted(plan.fires.items()) if f},
        error_rate=round(row["failed"] / n, 4),
        shed_rate=round(row["shed"] / n, 4),
    )
    return row


def bench_trace(label: str, families, rate: float, duration: float,
                engine: str = "einsum", seed: int = 0,
                kind: str = "poisson", speculation: dict | None = None) -> dict:
    """One labelled trace replay: `repro.service.replay_rate_cell` (the same
    driver the sweep harness's service mode uses — one measurement path, two
    consumers) plus the tracker-facing ``trace`` / ``speculation`` fields."""
    row = replay_rate_cell(
        engine=engine, families=families, rate=rate, duration=duration,
        seed=seed, kind=kind, pool_size=3,
        service_kwargs=speculation,
    )
    row["trace"] = label
    row["speculation"] = dict(speculation) if speculation else None
    return row


def dump_obs_artifacts(out_dir: Path) -> list:
    """With tracing on (``REPRO_TRACE=1``), drop the run's obs artifacts next
    to the tracker file: the full run payload (registry snapshot + spans,
    consumable by ``python -m repro.obs summarize``) and the Perfetto/Chrome
    trace ready for ui.perfetto.dev. No-op (returns []) when tracing is off."""
    if not obs.enabled():
        return []
    out_dir.mkdir(parents=True, exist_ok=True)
    run_path = out_dir / "service_obs_run.json"
    trace_path = out_dir / "trace.perfetto.json"
    tracer = obs.get_tracer()
    obs.dump_run(run_path, tracer=tracer)
    obs.write_trace(trace_path, tracer)
    spans = tracer.snapshot_spans()
    cov = obs.child_coverage(spans, "driver.round")
    print(
        f"service: obs run -> {run_path} ({len(spans)} spans, "
        f"driver.round child coverage {cov:.1%}); trace -> {trace_path}"
    )
    return [run_path, trace_path]


def main(quick: bool = True, out_path: Path = OUT_PATH) -> list:
    rows = [
        bench_trace(label, fams, rate, dur, engine=engine, kind=kind,
                    speculation=spec)
        for engine, label, kind, fams, rate, dur, spec
        in (TRACES if quick else FULL_TRACES)
    ]
    for r in rows:
        print(
            f"service,{r['engine']},{r['trace']},{r['requests']},"
            f"{r['throughput_rps']:.3f},{r['p50_ms']:.3f},{r['p95_ms']:.3f},"
            f"{r['p99_ms']:.3f},{r['mean_rows_per_dispatch']:.3f},"
            f"hit_rate={r['cache_hit_rate']:.3f}"
        )
    tracker.merge_section("service", rows, out_path)
    chaos_rows = [bench_chaos(engine, recipe, kw, ov)
                  for engine, recipe, kw, ov in CHAOS]
    for r in chaos_rows:
        print(
            f"faults,{r['engine']},{r['recipe']},{r['requests']},"
            f"fires={r['fires']},recovered={r['recovered']},shed={r['shed']},"
            f"failed={r['failed']},retries={r['retries']},"
            f"demotions={r['demotions']},unresolved={r['unresolved']}"
        )
    tracker.merge_section("faults", chaos_rows, out_path)
    # process-wide registry figures ride along as an ungated "obs" section —
    # per-solve rates and speculation outcomes across every trace above
    tracker.merge_section("obs", obs.snapshot(), out_path)
    print(f"service: wrote {out_path}")
    dump_obs_artifacts(out_path.parent / "artifacts")
    return rows


if __name__ == "__main__":
    main()
