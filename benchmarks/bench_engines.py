"""Per-engine enforce latency on a fixed grid slice -> BENCH_engines.json.

The perf-trajectory tracker: every registered engine enforces the same sampled
assignments against its prepared-once network on 3 cells of the paper's §5.2
grid; median per-enforcement latency (and prepare time) land in
``BENCH_engines.json`` at the repo root so successive PRs can diff them.

    PYTHONPATH=src python -m benchmarks.run --only engines
"""

from __future__ import annotations

import json
import platform
import time
from pathlib import Path

import jax
import numpy as np

from repro.core import CSPBenchSpec, assign_np
from repro.engines import available_engines, get_engine

OUT_PATH = Path(__file__).resolve().parents[1] / "BENCH_engines.json"

# 3 cells: sparse / medium / dense. n kept CI-sized — the tracked quantity is
# the *relative* per-engine trajectory across PRs, not paper-scale absolutes.
CELLS = [
    CSPBenchSpec(n_vars=60, density=0.10),
    CSPBenchSpec(n_vars=60, density=0.50),
    CSPBenchSpec(n_vars=60, density=1.00),
]


def bench_cell(engine_name: str, spec: CSPBenchSpec, n_assignments: int = 8, seed: int = 0) -> dict:
    csp = spec.build()
    n, _ = csp.dom.shape
    rng = np.random.default_rng(seed)
    eng = get_engine(engine_name)

    t0 = time.perf_counter()
    prepared = eng.prepare(csp)
    root = prepared.enforce()
    jax.block_until_ready(root.dom)  # include first-compile in prepare_ms
    prepare_ms = 1e3 * (time.perf_counter() - t0)
    if not bool(root.consistent):
        return {"n_vars": spec.n_vars, "density": spec.density, "inconsistent_root": True}
    root_np = np.asarray(root.dom)

    sites = []
    for _ in range(n_assignments):
        var = int(rng.integers(n))
        vals = np.nonzero(root_np[var])[0]
        sites.append((var, int(rng.choice(vals))))

    lat = []
    for var, val in sites:
        ch = np.zeros((n,), bool)
        ch[var] = True
        dom_a = assign_np(root_np, var, val)
        t0 = time.perf_counter()
        r = prepared.enforce(dom_a, ch)
        jax.block_until_ready(r.dom)  # no D2H copy inside the timed region
        lat.append(1e3 * (time.perf_counter() - t0))
    return {
        "n_vars": spec.n_vars,
        "density": spec.density,
        "prepare_ms": round(prepare_ms, 3),
        "enforce_ms_median": round(float(np.median(lat)), 3),
        "enforce_ms_mean": round(float(np.mean(lat)), 3),
        "n_assignments": n_assignments,
    }


def main(engines=None, out_path: Path = OUT_PATH) -> dict:
    engines = list(engines) if engines else available_engines()
    report = {
        "schema": "bench_engines/v1",
        "platform": platform.platform(),
        "engines": {},
    }
    for name in engines:
        cells = [bench_cell(name, spec) for spec in CELLS]
        report["engines"][name] = cells
        for c in cells:
            if c.get("inconsistent_root"):
                continue
            print(
                f"engines,{name},{c['n_vars']},{c['density']:.2f},"
                f"{c['prepare_ms']:.3f},{c['enforce_ms_median']:.3f}"
            )
    out_path.write_text(json.dumps(report, indent=1))
    print(f"engines: wrote {out_path}")
    return report


if __name__ == "__main__":
    main()
