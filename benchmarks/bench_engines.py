"""Per-engine enforce latency on the workload suite -> BENCH_engines.json.

The perf-trajectory tracker: every registered engine enforces the same sampled
assignments against its prepared-once network on a 3-family × 3-size slice of
the `repro.problems` registry (Model RB at the phase transition, random graph
coloring, n-queens); median per-enforcement latency (and prepare time) land in
``BENCH_engines.json`` at the repo root so successive PRs can diff them —
CI's bench-smoke job fails on a >3× regression of any cell
(`benchmarks/check_regression.py`).

    PYTHONPATH=src python -m benchmarks.run --only engines
"""

from __future__ import annotations

import platform
import time
from pathlib import Path

import jax
import numpy as np

from repro import obs
from repro.core import assign_np
from repro.engines import available_engines, get_engine
from repro.problems import generate
from . import tracker
from .tracker import OUT_PATH

# 3 families × 3 sizes, CI-sized — the tracked quantity is the *relative*
# per-engine trajectory across PRs, not paper-scale absolutes.
CELLS = [
    ("model_rb", {"n": 16, "hardness": 0.9}),
    ("model_rb", {"n": 24, "hardness": 0.9}),
    ("model_rb", {"n": 32, "hardness": 0.9}),
    ("coloring_random", {"n": 30, "edge_prob": 0.15, "k": 4}),
    ("coloring_random", {"n": 45, "edge_prob": 0.15, "k": 4}),
    ("coloring_random", {"n": 60, "edge_prob": 0.15, "k": 4}),
    ("nqueens", {"n": 8}),
    ("nqueens", {"n": 12}),
    ("nqueens", {"n": 16}),
]


def cell_label(family: str, knobs: dict) -> str:
    # ';' between knobs: labels land in comma-separated print rows
    return f"{family}/" + ";".join(f"{k}={v}" for k, v in sorted(knobs.items()))


def bench_cell(engine_name: str, family: str, knobs: dict, n_assignments: int = 8,
               seed: int = 0) -> dict:
    csp = generate(family, seed=seed, **knobs)
    n, _ = csp.dom.shape
    rng = np.random.default_rng(seed)
    eng = get_engine(engine_name)

    t0 = time.perf_counter()
    prepared = eng.prepare(csp)
    root = prepared.enforce()
    jax.block_until_ready(root.dom)  # include first-compile in prepare_ms
    prepare_ms = 1e3 * (time.perf_counter() - t0)
    out = {
        "family": family,
        "label": cell_label(family, knobs),
        "n_vars": n,
        "dom_size": csp.dom_size,
    }
    if not bool(root.consistent):
        return {**out, "inconsistent_root": True}
    root_np = np.asarray(root.dom)

    sites = []
    for _ in range(n_assignments):
        var = int(rng.integers(n))
        vals = np.nonzero(root_np[var])[0]
        sites.append((var, int(rng.choice(vals))))

    lat = []
    for var, val in sites:
        ch = np.zeros((n,), bool)
        ch[var] = True
        dom_a = assign_np(root_np, var, val)
        t0 = time.perf_counter()
        r = prepared.enforce(dom_a, ch)
        jax.block_until_ready(r.dom)  # no D2H copy inside the timed region
        lat.append(1e3 * (time.perf_counter() - t0))
    return {
        **out,
        "prepare_ms": round(prepare_ms, 3),
        "enforce_ms_median": round(float(np.median(lat)), 3),
        "enforce_ms_mean": round(float(np.mean(lat)), 3),
        "n_assignments": n_assignments,
    }


def main(engines=None, out_path: Path = OUT_PATH) -> dict:
    engines = list(engines) if engines else available_engines()
    results = {}
    for name in engines:
        cells = [bench_cell(name, family, knobs) for family, knobs in CELLS]
        results[name] = cells
        for c in cells:
            if c.get("inconsistent_root"):
                continue
            print(
                f"engines,{name},{c['label']},"
                f"{c['prepare_ms']:.3f},{c['enforce_ms_median']:.3f}"
            )
    report = tracker.merge_section(
        "engines", results, out_path, extra={"platform": platform.platform()}
    )
    # registry ride-along: distinct kernel program families built and
    # autotune searches run during this sweep (ungated "obs" section)
    tracker.merge_section("obs", obs.snapshot(), out_path)
    print(
        f"engines: wrote {out_path} "
        f"(fn_builds={obs.REGISTRY.counter('kernels.fn_builds')}, "
        f"autotuned={obs.REGISTRY.counter('autotune.tuned_buckets')})"
    )
    return report


if __name__ == "__main__":
    main()
