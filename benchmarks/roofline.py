"""Roofline analysis (§g): three terms per (arch × shape × mesh) from the
dry-run artifacts.

    compute    = HLO_FLOPs / (chips x peak)      peak = 197 TFLOP/s bf16 (v5e)
    memory     = HLO_bytes / (chips x HBM_bw)    HBM  = 819 GB/s
    collective = wire_bytes / (chips x link_bw)  ICI  = 50 GB/s/link

HLO_FLOPs / HLO_bytes / wire_bytes come from the trip-count-corrected dry-run
extrapolation and are already PER DEVICE (the SPMD-partitioned module), so no
further division by chip count is applied. MODEL_FLOPS = 6·N·D (train) or
2·N·D (prefill/decode), with N = matmul params (active-expert fraction for
MoE) + the attention term; the MODEL/HLO ratio flags remat/redundancy waste.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

ART_DIR = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def _matmul_params(cfg) -> float:
    """Matmul-visible params per token (dense + active-expert for MoE)."""
    from repro.models.model import build_model
    from repro.models.param import count_params, is_decl
    import jax

    model = build_model(cfg)
    decls = model.decls()
    total = 0.0
    embed_tok = decls["embed"]["tok"]
    import numpy as np

    for path, d in jax.tree_util.tree_flatten_with_path(decls, is_leaf=is_decl)[0]:
        keys = [str(getattr(p, "key", "")) for p in path]
        n = float(np.prod(d.shape))
        if keys[-1] in ("tok", "pos"):
            continue  # gathers, not matmuls (unembed accounted below)
        if "moe" in keys and keys[-1] in ("w_up", "w_down", "w_gate"):
            n *= cfg.top_k / cfg.n_experts  # active fraction per token
        total += n
    total += cfg.padded_vocab * cfg.d_model  # unembed matmul (tied or not)
    return total


def model_flops(cfg, shape) -> float:
    """Global MODEL_FLOPS per step (standard 6ND / 2ND + attention term)."""
    n_mat = _matmul_params(cfg)
    b, s = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    if shape.kind == "train":
        tokens = b * s
        flops = 6.0 * n_mat * tokens
        if cfg.attention != "none":
            s_eff = min(s, cfg.window) if cfg.attention == "swa" else s
            flops += 12.0 * cfg.n_layers * b * s * s_eff * cfg.n_heads * hd * 0.5
        return flops
    if shape.kind == "prefill":
        tokens = b * s
        flops = 2.0 * n_mat * tokens
        if cfg.attention != "none":
            s_eff = min(s, cfg.window) if cfg.attention == "swa" else s
            flops += 4.0 * cfg.n_layers * b * s * s_eff * cfg.n_heads * hd * 0.5
        return flops
    # decode: one token per sequence against a seq_len cache
    flops = 2.0 * n_mat * b
    if cfg.family == "hybrid":
        n_attn_layers = cfg.n_layers // cfg.attn_every
    elif cfg.attention != "none":
        n_attn_layers = cfg.n_layers
    else:
        n_attn_layers = 0
    if n_attn_layers:
        s_eff = min(s, cfg.window) if cfg.attention == "swa" else s
        flops += 4.0 * n_attn_layers * b * s_eff * cfg.n_heads * hd
    return flops


def analyze(rec: dict) -> dict:
    from repro.configs import SHAPES, get_config

    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    e = rec["cost_extrapolated"]
    chips = rec["n_devices"]
    compute_s = e["flops"] / PEAK_FLOPS
    memory_s = e["bytes"] / HBM_BW
    collective_s = e["wire_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=lambda k: terms[k])
    mf = model_flops(cfg, shape)
    hlo_global = e["flops"] * chips
    mem = rec.get("memory_analysis", {})
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "roofline_fraction": compute_s / terms[dominant] if terms[dominant] > 0 else 0.0,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global > 0 else 0.0,
        "temp_gib": mem.get("temp_size_in_bytes", 0) / 2**30,
        "args_gib": mem.get("argument_size_in_bytes", 0) / 2**30,
        "fits_16g": (mem.get("temp_size_in_bytes", 0) + mem.get("argument_size_in_bytes", 0)) / 2**30 <= 16.0,
    }


def load_all(mesh: Optional[str] = None) -> List[dict]:
    rows = []
    for f in sorted(ART_DIR.glob("*.json")):
        rec = json.loads(f.read_text())
        if "arch" not in rec:
            continue  # RTAC-workload artifacts (reported in §Perf H1)
        if mesh and rec["mesh"] != mesh:
            continue
        rows.append(analyze(rec))
    return rows


def to_markdown(rows: List[dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) | "
        "dominant | roofline frac | useful ratio | temp GiB | args GiB | fits |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['roofline_fraction']:.2f} | {r['useful_ratio']:.2f} | "
            f"{r['temp_gib']:.1f} | {r['args_gib']:.1f} | "
            f"{'Y' if r['fits_16g'] else 'N'} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main():
    if not ART_DIR.is_dir() or not any(ART_DIR.glob("*.json")):
        print(f"roofline: no dry-run artifacts under {ART_DIR}")
        print("roofline: run `python -m repro.launch.dryrun --all` first, then re-run")
        return []
    rows = load_all()
    print("roofline: arch,shape,mesh,compute_s,memory_s,collective_s,dominant,frac,useful")
    for r in rows:
        print(
            f"roofline,{r['arch']},{r['shape']},{r['mesh']},{r['compute_s']:.4e},"
            f"{r['memory_s']:.4e},{r['collective_s']:.4e},{r['dominant']},"
            f"{r['roofline_fraction']:.3f},{r['useful_ratio']:.3f}"
        )
    out = Path(__file__).resolve().parents[1] / "artifacts" / "roofline.md"
    out.write_text(to_markdown(load_all("single")) + "\n" + to_markdown(load_all("multi")))
    print(f"wrote {out}")
    return rows


if __name__ == "__main__":
    main()
