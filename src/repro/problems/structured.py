"""Structured families: pigeonhole, n-queens, seeded Sudoku puzzles.

``nqueens`` and ``sudoku`` are the workloads that previously lived only as
``examples/`` scripts; here they are registry citizens with seeds and
difficulty knobs so they can be swept and batched like every other family.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.csp import CSP, coloring_csp, nqueens_csp, sudoku_csp
from . import register_problem


@register_problem(
    "pigeonhole",
    difficulty_knob="n",
    description=(
        "n pigeons into h holes (all-different on a complete graph); "
        "holes=None ⇒ h = n − 1, the classically UNSAT pigeonhole principle "
        "that resolution-style solvers need exponential search to refute"
    ),
    deterministic=True,
)
def pigeonhole(seed=0, n: int = 6, holes: Optional[int] = None) -> CSP:
    del seed  # deterministic
    h = (n - 1) if holes is None else holes
    if h < 1:
        raise ValueError(f"need at least one hole, got holes={h}")
    adj = ~np.eye(n, dtype=bool)  # complete graph: every pair of pigeons differs
    return coloring_csp(adj, h)


@register_problem(
    "nqueens",
    difficulty_knob="n",
    description="n-queens as a binary CSP (one variable per column, domain = row)",
    deterministic=True,
)
def nqueens(seed=0, n: int = 8) -> CSP:
    del seed  # deterministic
    return nqueens_csp(n)


def sudoku_solution_grid(seed=0) -> np.ndarray:
    """A seeded complete Sudoku grid: the canonical band pattern
    ``(3·(r mod 3) + r//3 + c) mod 9`` relabelled and shuffled by the
    validity-preserving symmetries (digit permutation, row/column permutations
    within bands/stacks, band/stack permutations). Returns (9, 9) ints 1..9."""
    rng = np.random.default_rng(seed)
    r = np.arange(9)
    base = (3 * (r[:, None] % 3) + r[:, None] // 3 + r[None, :]) % 9

    def shuffled_axis() -> np.ndarray:
        groups = rng.permutation(3)
        return np.concatenate([3 * g + rng.permutation(3) for g in groups])

    grid = base[shuffled_axis()][:, shuffled_axis()]
    digits = rng.permutation(9)
    return digits[grid] + 1


@register_problem(
    "sudoku",
    difficulty_knob="givens",
    description=(
        "seeded 9×9 Sudoku: a shuffled complete grid with `givens` clues kept "
        "(fewer givens ⇒ harder; uniqueness of the solution is not enforced)"
    ),
)
def sudoku(seed=0, givens: int = 32) -> CSP:
    if not 0 <= givens <= 81:
        raise ValueError(f"givens={givens} outside [0, 81]")
    rng = np.random.default_rng(seed)
    solution = sudoku_solution_grid(seed=rng)
    keep = rng.choice(81, size=givens, replace=False)
    puzzle = np.zeros((81,), dtype=int)
    puzzle[keep] = solution.reshape(-1)[keep]
    return sudoku_csp(puzzle.reshape(9, 9))
