"""Random binary CSP families: Model RB (phase transition) + classic model A.

Model RB (Xu & Li, JAIR 2000) is the standard generator with *proven* exact
satisfiability phase transitions and guaranteed-hard instances at the
threshold — the workload class behind the paper's Table 1 / Fig. 3 evaluation:

    d = ⌈n^alpha⌉                  domain size grows polynomially with n
    m = ⌈r · n · ln n⌉             number of binary constraints
    q = round(p · d²)              disallowed tuples per constraint (exact)

and the (binary, k=2) threshold is at tightness

    p_cr = 1 − exp(−alpha / r)

(instances are a.a.s. satisfiable for p < p_cr, unsatisfiable beyond; the hard
region hugs the threshold). The ``hardness`` knob positions the instance
relative to the threshold: ``p = hardness · p_cr``, so hardness < 1 is the
under-constrained SAT side, 1.0 the transition, > 1 the over-constrained side.

One deliberate deviation from the literature: Model RB samples constraint
*scopes* with repetition, but the dense tensor encoding merges duplicate
scopes into one relation, so we sample ``m`` *distinct* pairs (m is capped at
n(n−1)/2). The declared constraint count is therefore exact — a property the
test suite checks.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np

from repro.core.csp import CSP, make_csp, random_csp
from . import register_problem


def model_rb_params(n: int, alpha: float, r: float) -> Tuple[int, int, float]:
    """(dom_size d, #constraints m, critical tightness p_cr) for Model RB."""
    d = max(2, math.ceil(n**alpha))
    m = min(math.ceil(r * n * math.log(n)), n * (n - 1) // 2)
    p_cr = 1.0 - math.exp(-alpha / r)
    return d, m, p_cr


@register_problem(
    "model_rb",
    difficulty_knob="hardness",
    description=(
        "Xu–Li Model RB random binary CSP: d=⌈n^alpha⌉, m=⌈r·n·ln n⌉ distinct "
        "constraint scopes, exactly round(p·d²) disallowed tuples each; "
        "tightness p = hardness · p_cr with p_cr = 1 − e^(−alpha/r)"
    ),
)
def model_rb(
    seed=0,
    n: int = 24,
    alpha: float = 0.8,
    r: float = 0.7,
    hardness: float = 1.0,
    p: Optional[float] = None,
) -> CSP:
    """Model RB instance at tightness ``p`` (default ``hardness · p_cr``).

    Knobs (all sweepable axes; the ``model_rb_phase`` study sweeps n ×
    hardness): ``n`` variables; ``alpha`` sets domain size d = ⌈n^alpha⌉;
    ``r`` sets constraint count m = ⌈r·n·ln n⌉ (distinct scopes, see module
    docstring); ``hardness`` positions tightness relative to the proven
    threshold (< 1 a.a.s. SAT, > 1 a.a.s. UNSAT); ``p`` overrides the
    tightness outright, ignoring hardness."""
    rng = np.random.default_rng(seed)
    d, m, p_cr = model_rb_params(n, alpha, r)
    if p is None:
        p = hardness * p_cr
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"tightness p={p} outside [0, 1]")
    q = int(round(p * d * d))  # exact #disallowed tuples per constraint

    # m distinct scopes, uniform over the n(n-1)/2 unordered pairs
    iu = np.triu_indices(n, k=1)
    pick = rng.choice(len(iu[0]), size=m, replace=False)
    xs, ys = iu[0][pick], iu[1][pick]

    mask = np.zeros((n, n), dtype=bool)
    mask[xs, ys] = True
    mask |= mask.T

    cons = np.zeros((n, n, d, d), dtype=bool)
    for x, y in zip(xs, ys):
        allowed = np.ones((d * d,), dtype=bool)
        allowed[rng.choice(d * d, size=q, replace=False)] = False
        rel = allowed.reshape(d, d)
        cons[x, y] = rel
        cons[y, x] = rel.T  # Cons[y,x,b,a] == Cons[x,y,a,b]

    dom = np.ones((n, d), dtype=bool)
    return make_csp(cons, mask, dom)


@register_problem(
    "random_binary",
    difficulty_knob="tightness",
    description=(
        "classic model-A random binary CSP (paper §5.2 grid): each pair is "
        "constrained with prob density, each tuple disallowed with prob tightness"
    ),
)
def random_binary(
    seed=0,
    n: int = 50,
    d: int = 20,
    density: float = 0.25,
    tightness: float = 0.3,
) -> CSP:
    """Classic model-A random binary CSP (the paper's §5.2 grid cells).

    Knobs (all sweepable axes; the ``recurrence_density`` study sweeps n ×
    density): ``n`` variables with uniform domain size ``d``; ``density`` is
    the fraction of the n(n−1)/2 variable pairs that get a constraint;
    ``tightness`` the independent probability a value pair is disallowed.
    Unlike Model RB there is no proven threshold — density × tightness
    together set the difficulty."""
    return random_csp(n, d, density=density, tightness=tightness, seed=seed)
