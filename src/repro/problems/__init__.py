"""Problem registry — named, seeded CSP workload generators (DESIGN.md §6).

Every family produces reproducible `repro.core.CSP` instances from a seed and
a small set of knobs, with one designated *difficulty knob* so workloads can be
swept from easy to phase-transition hard:

    from repro.problems import generate, generate_batch, available_problems

    csp  = generate("model_rb", n=24, seed=0)              # one instance
    csps = generate_batch("model_rb", 32, n=24, seed=0)    # 32 instances
                                                           # sharing (n, d)

Registered families (see the family's ``description`` for knob semantics):

    model_rb          Xu–Li Model RB random binary CSPs at the phase
                      transition — the paper's Table 1 / Fig. 3 workload class
    random_binary     classic model-A generator (paper §5.2 grid cells)
    coloring_random   k-coloring of an Erdős–Rényi G(n, p) graph
    coloring_kneser   k-coloring of a Kneser graph K(m, j) (χ = m − 2j + 2;
                      (5, 2) is the Petersen graph)
    pigeonhole        n pigeons into h holes (h = n − 1 ⇒ classically UNSAT)
    nqueens           n-queens (lifted from examples/)
    sudoku            seeded 9×9 puzzles with a givens-count difficulty knob
                      (lifted from examples/)

``generate_batch`` derives per-instance seeds as ``(seed, i)`` through
``numpy.random.default_rng``, so batches are reproducible AND instance i is
stable regardless of batch size. All instances of one batch share the same
``(n_vars, dom_size)`` — the shape contract `Engine.prepare_many` requires.
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, Dict, List, Mapping

from repro.core.csp import CSP

Seed = Any  # int or tuple of ints — anything numpy.random.default_rng accepts


@dataclasses.dataclass(frozen=True)
class ProblemFamily:
    """One registered generator: ``generator(seed=..., **knobs) -> CSP``."""

    name: str
    generator: Callable[..., CSP]
    defaults: Mapping[str, Any]
    difficulty_knob: str
    description: str
    deterministic: bool = False  # True: the seed does not affect the instance

    def params(self, **overrides) -> Dict[str, Any]:
        """Resolved knob dict (defaults + overrides), overrides validated."""
        unknown = set(overrides) - set(self.defaults)
        if unknown:
            raise TypeError(
                f"{self.name}: unknown knob(s) {sorted(unknown)}; "
                f"available: {sorted(self.defaults)}"
            )
        return {**self.defaults, **overrides}

    def generate(self, seed: Seed = 0, **overrides) -> CSP:
        return self.generator(seed=seed, **self.params(**overrides))

    def generate_batch(self, count: int, seed: int = 0, **overrides) -> List[CSP]:
        """``count`` independent instances sharing (n, d): instance i is seeded
        ``(seed, i)``, so it is reproducible and batch-size independent."""
        params = self.params(**overrides)
        return [self.generator(seed=(seed, i), **params) for i in range(count)]

    def validate_sweep(self, knobs: Mapping[str, Any]) -> Dict[str, List[Any]]:
        """Validate a sweep-axis mapping (``knob -> scalar | list of values``)
        against this family's knob set and return it normalized to lists.

        This is how `repro.sweeps` exposes generator knobs as sweep axes: a
        spec's ``[problem.knobs]`` table goes through here at load time, so an
        unknown knob (or an axis on a family that does not have it) fails when
        the spec is parsed, not hours into a sweep. Scalars normalize to
        one-element lists; the ``difficulty_knob`` gets no special treatment —
        any knob may be an axis."""
        unknown = set(knobs) - set(self.defaults)
        if unknown:
            raise TypeError(
                f"{self.name}: unknown sweep knob(s) {sorted(unknown)}; "
                f"available: {sorted(self.defaults)}"
            )
        return {
            k: list(v) if isinstance(v, (list, tuple)) else [v]
            for k, v in knobs.items()
        }


_REGISTRY: Dict[str, ProblemFamily] = {}


def register_problem(
    name: str,
    *,
    difficulty_knob: str,
    description: str,
    deterministic: bool = False,
):
    """Decorator: register ``fn(seed=..., **knobs) -> CSP`` under ``name``.
    Knob defaults are read off the function signature."""

    def deco(fn: Callable[..., CSP]) -> Callable[..., CSP]:
        defaults = {
            p.name: p.default
            for p in inspect.signature(fn).parameters.values()
            if p.name != "seed"
        }
        missing = [k for k, v in defaults.items() if v is inspect.Parameter.empty]
        if missing:
            raise TypeError(f"{name}: knobs {missing} need defaults")
        if difficulty_knob not in defaults:
            raise TypeError(f"{name}: difficulty knob {difficulty_knob!r} not a knob")
        _REGISTRY[name] = ProblemFamily(
            name=name,
            generator=fn,
            defaults=defaults,
            difficulty_knob=difficulty_knob,
            description=description,
            deterministic=deterministic,
        )
        return fn

    return deco


def available_problems() -> List[str]:
    return sorted(_REGISTRY)


def get_problem(name: str) -> ProblemFamily:
    if name not in _REGISTRY:
        raise ValueError(f"unknown problem {name!r}; available: {available_problems()}")
    return _REGISTRY[name]


def generate(name: str, seed: Seed = 0, **overrides) -> CSP:
    """One seeded instance of a registered family."""
    return get_problem(name).generate(seed=seed, **overrides)


def generate_batch(name: str, count: int, seed: int = 0, **overrides) -> List[CSP]:
    """``count`` seeded instances sharing (n, d) — ready for
    `Engine.prepare_many` / `repro.core.solve_many`."""
    return get_problem(name).generate_batch(count, seed=seed, **overrides)


# Import for side effect: each module registers its families.
from . import random_binary as _random_binary  # noqa: E402,F401
from . import coloring as _coloring  # noqa: E402,F401
from . import structured as _structured  # noqa: E402,F401

model_rb = _random_binary.model_rb
model_rb_params = _random_binary.model_rb_params

__all__ = [
    "ProblemFamily",
    "register_problem",
    "available_problems",
    "get_problem",
    "generate",
    "generate_batch",
    "model_rb",
    "model_rb_params",
]
