"""Graph k-coloring families: random G(n, p) graphs and Kneser graphs.

Coloring maps onto the binary-CSP tensor encoding via `repro.core.coloring_csp`
(one variable per vertex, domain = colors, ≠ on every edge). Two graph classes:

- ``coloring_random``: Erdős–Rényi G(n, p). The difficulty knob is the number
  of colors ``k`` — random graphs have a sharp k-colorability threshold in the
  average degree, so sweeping k (or ``edge_prob``) crosses SAT → UNSAT.
- ``coloring_kneser``: the Kneser graph K(m, j) — vertices are the j-subsets
  of {0..m−1}, edges between disjoint subsets. Its chromatic number is the
  celebrated χ = m − 2j + 2 (Lovász 1978), so ``excess`` colors relative to χ
  gives a calibrated knob: excess ≥ 0 is satisfiable, −1 provably not.
  K(5, 2) is the Petersen graph.
"""

from __future__ import annotations

from itertools import combinations

import numpy as np

from repro.core.csp import CSP, coloring_csp
from . import register_problem


@register_problem(
    "coloring_random",
    difficulty_knob="k",
    description=(
        "k-coloring of an Erdős–Rényi G(n, edge_prob) graph; fewer colors / "
        "denser edges is harder"
    ),
)
def coloring_random(seed=0, n: int = 30, edge_prob: float = 0.2, k: int = 4) -> CSP:
    """k-coloring of a seeded Erdős–Rényi G(``n``, ``edge_prob``) graph.

    Knobs (all sweepable axes via ``[problem.knobs]`` in a sweep spec):
    ``n`` vertices = CSP variables; ``edge_prob`` independent edge
    probability — mean degree (n−1)·edge_prob; ``k`` colors = domain size,
    the difficulty knob (the k-colorability threshold is sharp in the mean
    degree, so lowering k or raising edge_prob crosses SAT → UNSAT)."""
    rng = np.random.default_rng(seed)
    iu = np.triu_indices(n, k=1)
    edge = rng.random(len(iu[0])) < edge_prob
    adj = np.zeros((n, n), dtype=bool)
    adj[iu[0][edge], iu[1][edge]] = True
    adj |= adj.T
    return coloring_csp(adj, k)


def kneser_adjacency(m: int, j: int) -> np.ndarray:
    """Adjacency of K(m, j): j-subsets of an m-set, adjacent iff disjoint."""
    if not 0 < j or not 2 * j < m:
        raise ValueError(f"Kneser graph needs 0 < j and 2j < m, got m={m}, j={j}")
    verts = [frozenset(c) for c in combinations(range(m), j)]
    n = len(verts)
    adj = np.zeros((n, n), dtype=bool)
    for a in range(n):
        for b in range(a + 1, n):
            if not verts[a] & verts[b]:
                adj[a, b] = adj[b, a] = True
    return adj


@register_problem(
    "coloring_kneser",
    difficulty_knob="excess",
    description=(
        "k-coloring of the Kneser graph K(m, j) with k = χ + excess colors, "
        "χ = m − 2j + 2; excess ≥ 0 is SAT, −1 UNSAT (K(5,2) = Petersen)"
    ),
    deterministic=True,
)
def coloring_kneser(seed=0, m: int = 5, j: int = 2, excess: int = 0) -> CSP:
    """Coloring of the Kneser graph K(``m``, ``j``) with χ + ``excess`` colors.

    Vertices are the C(m, j) j-subsets of an m-set (so the CSP has C(m, j)
    variables), edges join disjoint subsets, and χ = m − 2j + 2 exactly
    (Lovász 1978). ``excess`` is the calibrated difficulty knob: 0 gives a
    tight-but-SAT instance, −1 a provably UNSAT one, larger values are easy.
    The instance is deterministic — the seed is ignored."""
    del seed  # the graph is deterministic
    chromatic = m - 2 * j + 2
    k = chromatic + excess
    if k < 1:
        raise ValueError(f"excess={excess} leaves {k} colors")
    return coloring_csp(kneser_adjacency(m, j), k)
