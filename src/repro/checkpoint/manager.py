"""Checkpointing — atomic, latest-k, async, mesh-elastic.

Fault-tolerance contract (DESIGN.md §5):
  * atomic: write to ``<dir>/tmp.<step>`` then ``rename`` — a crash mid-write
    never corrupts the restore set;
  * latest-k GC keeps disk bounded on long runs;
  * async: ``save_async`` snapshots to host memory synchronously (cheap) and
    writes on a background thread — training continues immediately;
  * elastic: checkpoints store plain host arrays + the pytree structure; restore
    ``device_put``s onto the CURRENT mesh's shardings, so a run checkpointed on
    one mesh resumes on another (tested: save on (1,2) restore on (2,1)).

Format: one ``.npz`` per checkpoint with flattened dotted keys + a JSON manifest
(step, keypaths, dtypes). No orbax dependency — this container is offline.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np


def _to_savable(v: np.ndarray) -> np.ndarray:
    # npz cannot roundtrip ml_dtypes (bfloat16 etc.) — store a uint view;
    # restore() views back based on the target tree's dtype.
    if v.dtype.name == "bfloat16":
        return v.view(np.uint16)
    return v


def _from_saved(arr: np.ndarray, target_dtype) -> np.ndarray:
    if np.dtype(target_dtype).name == "bfloat16" and arr.dtype == np.uint16:
        return arr.view(ml_dtypes.bfloat16)
    return arr


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- write ---------------------------------------------------------------

    def save(self, step: int, tree) -> Path:
        self.wait()  # one in-flight async save at a time
        host = [(k, _to_savable(np.asarray(v))) for k, v in _flatten(tree)]
        return self._write(step, host)

    def save_async(self, step: int, tree) -> None:
        self.wait()
        # synchronous device->host snapshot (consistent view), async file IO
        host = [(k, _to_savable(np.asarray(v))) for k, v in _flatten(tree)]

        def work():
            try:
                self._write(step, host)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host: List[Tuple[str, np.ndarray]]) -> Path:
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f"tmp.{step}.{os.getpid()}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / "arrays.npz", **dict(host))
        manifest = {
            "step": step,
            "keys": [k for k, _ in host],
            "dtypes": [str(v.dtype) for _, v in host],
            "shapes": [list(v.shape) for _, v in host],
            "time": time.time(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like_tree, shardings=None):
        """Restore onto the current mesh. ``like_tree`` provides structure;
        ``shardings`` (same structure, NamedSharding leaves) reshards for
        elasticity. Leaves are cast to like_tree's dtypes."""
        path = self.dir / f"step_{step:010d}"
        data = np.load(path / "arrays.npz")
        keys = [k for k, _ in _flatten(like_tree)]
        leaves = []
        flat_like, treedef = jax.tree_util.tree_flatten(like_tree)
        flat_sh = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(flat_like)
        )
        for key, like, sh in zip(keys, flat_like, flat_sh):
            arr = _from_saved(data[key], like.dtype).astype(like.dtype)
            if arr.shape != tuple(like.shape):
                raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {like.shape}")
            leaves.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)
