"""AdamW + schedules + global-norm clipping — pure-pytree, no optax dependency.

Optimizer state shards exactly like the params (m/v inherit the param
PartitionSpecs), which is what makes FSDP-style sharding of optimizer memory
work for the ≥100B configs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array  # () int32
    m: object  # pytree like params
    v: object  # pytree like params
    master: object = None  # fp32 master weights when params are bf16 on the wire


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[Array], Array]  # schedule: step -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32  # bf16 for ≥50B-param configs (memory)
    # keep_master=True: params live in bf16 (halving FSDP weight-gather wire
    # bytes — the update path reads/writes an fp32 master copy held here).
    keep_master: bool = False

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=self.moment_dtype), params
        )
        master = (
            jax.tree.map(lambda p: p.astype(jnp.float32), params)
            if self.keep_master
            else None
        )
        return AdamWState(
            jnp.zeros((), jnp.int32), zeros, jax.tree.map(jnp.copy, zeros), master
        )

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

        md = self.moment_dtype
        m = jax.tree.map(
            lambda m_, g: (self.b1 * m_.astype(jnp.float32) + (1 - self.b1) * g).astype(md),
            state.m, grads,
        )
        v = jax.tree.map(
            lambda v_, g: (self.b2 * v_.astype(jnp.float32) + (1 - self.b2) * jnp.square(g)).astype(md),
            state.v, grads,
        )
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self.lr(step)

        def upd(p, m_, v_):
            mhat = m_.astype(jnp.float32) / bc1
            vhat = v_.astype(jnp.float32) / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * p.astype(
                jnp.float32
            )
            return p.astype(jnp.float32) - lr * delta  # fp32

        if self.keep_master:
            new_master = jax.tree.map(upd, state.master, m, v)
            new_params = jax.tree.map(
                lambda mp, p: mp.astype(p.dtype), new_master, params
            )
            return new_params, AdamWState(step, m, v, new_master), {
                "grad_norm": gnorm, "lr": lr,
            }
        new_params = jax.tree.map(
            lambda p, m_, v_: upd(p, m_, v_).astype(p.dtype), params, m, v
        )
        return new_params, AdamWState(step, m, v, None), {"grad_norm": gnorm, "lr": lr}


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def cosine_schedule(
    peak_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1
) -> Callable[[Array], Array]:
    def lr(step: Array) -> Array:
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup_steps, warm, cos)

    return lr


def constant_schedule(lr_value: float) -> Callable[[Array], Array]:
    return lambda step: jnp.full((), lr_value, jnp.float32)
