"""Gradient compression — int8 quantization with error feedback.

Distributed-optimization trick for the slow inter-pod links (DESIGN.md §5): the
'pod' axis carries pure data-parallel gradient reduction, which tolerates lossy
compression when the quantization error is fed back into the next step
(1-bit-Adam / EF-SGD lineage). Two entry points:

- :func:`compress_decompress` + :class:`ErrorFeedback` — drop-in grad transform
  for the automatic-collective (pjit) path: quantize→dequantize with EF before
  the optimizer so training numerics match what a compressed wire would give.
- :func:`compressed_psum` — the explicit shard_map form: quantize, psum the
  int8 payload (4× less ICI traffic), dequantize, for manual-DP training loops.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Quantized(NamedTuple):
    q: jax.Array  # int8 payload
    scale: jax.Array  # () f32 per-tensor scale


def quantize_int8(x: jax.Array) -> Quantized:
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return Quantized(q, scale)


def dequantize(qt: Quantized, dtype=jnp.float32) -> jax.Array:
    return (qt.q.astype(jnp.float32) * qt.scale).astype(dtype)


class ErrorFeedback(NamedTuple):
    residual: object  # pytree like grads


def init_error_feedback(grads_like) -> ErrorFeedback:
    return ErrorFeedback(
        jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def compress_decompress(
    grads, ef: ErrorFeedback
) -> Tuple[object, ErrorFeedback, dict]:
    """Quantize (g + residual) to int8, return dequantized grads + new residual."""

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        qt = quantize_int8(corrected)
        dq = dequantize(qt)
        return dq, corrected - dq

    flat = jax.tree.map(one, grads, ef.residual)
    dq = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    err_norm = jnp.sqrt(
        sum(jnp.sum(jnp.square(r)) for r in jax.tree.leaves(res))
    )
    return dq, ErrorFeedback(res), {"compression_err_norm": err_norm}


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """shard_map building block: psum int8 payloads instead of f32.

    Scales are made uniform by psum-max first so payloads are additive.
    Wire cost: 1 byte/element + one scalar, vs 4 bytes/element for f32 psum.
    """
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)).astype(jnp.float32), axis_name)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    # int8 accumulation across g shards can reach ±127·g, so the summed payload
    # is int16 (exact for g ≤ 256): 2 bytes on the wire vs 4 for f32 — an exact
    # 2× ICI saving. True 1-byte wire needs saturating/tree reduction in the
    # backend collective, which XLA does not expose; recorded in DESIGN.md §5.
    total = jax.lax.psum(q.astype(jnp.int16), axis_name)
    return (total.astype(jnp.float32) * scale).astype(x.dtype)
