import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init), and only here — smoke tests and benches see 1 device.

Per cell this script:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. builds the right step (train_4k/prefill_32k -> train/prefill step;
     decode_32k/long_500k -> serve_step) with full in/out shardings,
  3. ``.lower()`` on ShapeDtypeStruct inputs (no allocation), ``.compile()``,
  4. records memory_analysis / cost_analysis / HLO collective bytes to
     ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-done]
"""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES, cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_decode_step, build_prefill_step, build_train_step
from repro.parallel.hlo_stats import collective_stats, total_wire_bytes
from repro.parallel.sharding import make_ctx

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _mem_dict(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}
    if ma is None:
        return {"error": "memory_analysis() returned None"}
    for k in dir(ma):
        if k.startswith("_"):
            continue
        try:
            v = getattr(ma, k)
        except Exception:
            continue
        if isinstance(v, (int, float)):
            out[k] = v
    return out


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": str(e)}
    if ca is None:
        return {"error": "cost_analysis() returned None"}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}


def _build_and_compile(cfg, shape, mesh_kind, microbatches: int = 0):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    ctx = make_ctx(mesh)
    if shape.kind == "train":
        jit_fn, _, (abstract_state, in_specs) = build_train_step(
            cfg, shape, ctx, microbatches=microbatches
        )
        args = (abstract_state, in_specs)
    elif shape.kind == "prefill":
        jit_fn, _, (abstract_p, in_specs) = build_prefill_step(cfg, shape, ctx)
        args = (abstract_p, in_specs)
    else:  # decode
        jit_fn, _, (abstract_p, abstract_cache, tok) = build_decode_step(cfg, shape, ctx)
        args = (abstract_p, abstract_cache, tok)
    lowered = jit_fn.lower(*args)
    compiled = lowered.compile()
    return mesh, ctx, compiled


def _layer_unit(cfg) -> int:
    """Smallest layer-count unit that preserves the block pattern."""
    return cfg.attn_every if cfg.attn_every else 1


def _with_layers(cfg, n: int, unroll: bool = False):
    kw = {"n_layers": n, "scan_unroll": unroll}
    if cfg.family == "encdec":
        kw["encoder_layers"] = n
    return cfg.replace(**kw)


def _cell_costs(compiled) -> dict:
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    cost = _cost_dict(compiled)
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "wire_bytes": total_wire_bytes(coll),
        "collectives": coll,
    }


def extrapolated_costs(cfg, shape, mesh_kind, allow_seq_fit: bool = True) -> dict:
    """XLA's cost_analysis counts a `lax.scan` body ONCE regardless of trip
    count (verified empirically), so FLOPs/bytes/collective bytes of the
    layer stack are recovered by lowering at L=u and L=2u (u = block-pattern
    unit) with ALL scans fully unrolled (straight-line counting, including the
    SSM chunk scans and attention q-chunk scans) and extrapolating
    cost(L) = a + (L/u - 1) * delta — exact for linear-in-depth stacks."""
    if (
        allow_seq_fit
        and cfg.family in ("ssm", "hybrid")
        and shape.kind in ("train", "prefill")
        and shape.seq_len // cfg.ssm_chunk > 16
    ):
        return seq_fit_costs(cfg, shape, mesh_kind)
    u = _layer_unit(cfg)
    # depth points: decode caches hit XLA special cases at L=1, so decode uses
    # (2u, 4u); train/prefill use (u, 2u) — or (2, 4) for u=1 — to keep the
    # unrolled graphs small (cost lowering is the compile-time hot spot on
    # this 1-core container). Cost lowers always use microbatches=1: the
    # accumulation scan changes loop structure, not totals, and unrolling it
    # would replicate the whole model graph m times.
    if shape.kind in ("train", "prefill"):
        p1, p2 = (2, 4) if u == 1 else (u, 2 * u)
    else:
        p1, p2 = 2 * u, 4 * u
    _, _, c1 = _build_and_compile(
        _with_layers(cfg, p1, unroll=True), shape, mesh_kind, microbatches=1
    )
    _, _, c2 = _build_and_compile(
        _with_layers(cfg, p2, unroll=True), shape, mesh_kind, microbatches=1
    )
    a = _cell_costs(c1)  # at p1
    b = _cell_costs(c2)  # at p2
    n_units = cfg.n_layers / u
    span = (p2 - p1) / u
    out = {}
    for k in ("flops", "bytes", "wire_bytes"):
        delta = (b[k] - a[k]) / span  # per layer-unit
        base = a[k] - (p1 / u) * delta
        out[k] = base + delta * n_units
    out["per_layer_unit"] = {k: (b[k] - a[k]) / span for k in ("flops", "bytes", "wire_bytes")}
    out["base"] = {k: 2 * a[k] - b[k] for k in ("flops", "bytes", "wire_bytes")}
    out["unit"] = u
    out["collectives_delta"] = {
        kind: {
            kk: b["collectives"].get(kind, {}).get(kk, 0.0)
            - a["collectives"].get(kind, {}).get(kk, 0.0)
            for kk in ("count", "wire_bytes")
        }
        for kind in set(a["collectives"]) | set(b["collectives"])
    }
    return out


def seq_fit_costs(cfg, shape, mesh_kind) -> dict:
    """SSM/hybrid train/prefill at long S: unrolling S/chunk inner-scan
    iterations is a compile-time bomb, so measure the depth-extrapolated cost
    at small S and fit the known functional form — exact, because with fixed
    chunk size every term is linear in S for attention-free stacks and
    linear+quadratic when (shared) attention is present."""
    pts = [512, 1024] if cfg.family == "ssm" else [512, 1024, 2048]
    # hybrid: fix the cost-lowering chunk at 128 (≤16 unrolled iterations per
    # layer) — the chunk-dependent intra term is ~2% of mamba matmul FLOPs, so
    # the ≤2× distortion on it is ≤~2% total while compile time halves.
    cfg_cost = cfg.replace(ssm_chunk=128) if cfg.family == "hybrid" else cfg
    xs, ys = [], []
    for s_pt in pts:
        sp = type(shape)(shape.name, s_pt, shape.global_batch, shape.kind)
        xs.append(s_pt)
        ys.append(extrapolated_costs(cfg_cost, sp, mesh_kind, allow_seq_fit=False))
    out = {"seq_fit_points": xs}
    import numpy as _np

    deg = 1 if len(pts) == 2 else 2
    for k in ("flops", "bytes", "wire_bytes"):
        coeffs = _np.polyfit(_np.array(xs, float), _np.array([y[k] for y in ys]), deg)
        out[k] = float(_np.polyval(coeffs, shape.seq_len))
    out["unit"] = ys[0].get("unit", 1)
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    t0 = time.time()
    mesh, ctx, compiled = _build_and_compile(cfg, shape, mesh_kind)
    t_compile = time.time() - t0
    t_lower = 0.0

    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    extra = extrapolated_costs(cfg, shape, mesh_kind)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "kind": shape.kind,
        "n_devices": int(mesh.devices.size),
        "mesh_shape": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "compile_s": round(t_compile, 2),
        "memory_analysis": _mem_dict(compiled),
        "cost_analysis_raw": _cost_dict(compiled),  # scan bodies counted once!
        "collectives_raw": coll,
        "cost_extrapolated": extra,  # trip-count-corrected (see extrapolated_costs)
        "sharding_demotions": ctx.log,
        "hlo_bytes": len(hlo),
    }
    if verbose:
        mem = rec["memory_analysis"]
        print(
            f"[dryrun] {arch} × {shape_name} × {mesh_kind}: "
            f"compile {t_compile:.1f}s "
            f"flops/dev={extra['flops']:.3e} "
            f"bytes/dev={extra['bytes']:.3e} "
            f"wire/dev={extra['wire_bytes']:.3e}B "
            f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
            f"args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB",
            flush=True,
        )
    return rec


def cell_path(arch: str, shape_name: str, mesh_kind: str) -> Path:
    return ART_DIR / f"{arch}__{shape_name}__{mesh_kind}.json"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--last", default="", help="comma list of archs to run LAST")
    args = ap.parse_args()

    ART_DIR.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        todo = [(a, s.name, m) for a, s, _ in cells() for m in meshes]
        if args.last:
            lasts = set(args.last.split(","))
            todo = [t for t in todo if t[0] not in lasts] + [
                t for t in todo if t[0] in lasts
            ]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        todo = [(args.arch, args.shape, m) for m in meshes]

    failures = []
    for arch, shape_name, mesh_kind in todo:
        path = cell_path(arch, shape_name, mesh_kind)
        if args.skip_done and path.exists():
            print(f"[dryrun] skip (done): {path.name}", flush=True)
            continue
        try:
            rec = run_cell(arch, shape_name, mesh_kind)
            path.write_text(json.dumps(rec, indent=1))
        except Exception:
            traceback.print_exc()
            failures.append((arch, shape_name, mesh_kind))
    if failures:
        print(f"[dryrun] FAILURES: {failures}", flush=True)
        return 1
    print(f"[dryrun] all {len(todo)} cells OK", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
