"""Jitted step builders — train / prefill / decode — with full shardings.

Each builder returns (jitted_fn, in_shardings, out_shardings, abstract_inputs)
so the same machinery serves real execution (train.py/serve.py) and the
multi-pod dry-run (ShapeDtypeStruct lowering, no allocation).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def lax_scan_unrollable(body, init, xs, unroll: bool):
    return lax.scan(body, init, xs, unroll=True if unroll else 1)

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.model import build_model, input_specs
from repro.models.param import abstract_params
from repro.optim.adamw import AdamW, AdamWState, cosine_schedule
from repro.parallel.sharding import (
    ShardingCtx,
    make_ctx,
    param_pspecs,
    sharding_ctx,
    spec_for,
)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


_INPUT_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "loss_mask": ("batch", "seq"),
    "enc_embed": ("batch", None, "embed"),
    "pos3": ("batch", "seq", None),
}


def batch_pspecs(specs: Dict[str, jax.ShapeDtypeStruct], ctx: ShardingCtx):
    out = {}
    for name, s in specs.items():
        axes = _INPUT_AXES.get(name, ("batch",) + (None,) * (len(s.shape) - 1))
        out[name] = spec_for(axes[: len(s.shape)], s.shape, ctx.act_rules, ctx.mesh_shape, ctx.log)
    return out


def _named(ctx, tree):
    return jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def make_optimizer(
    total_steps: int = 10000, n_params: int = 0, keep_master: bool = False
) -> AdamW:
    # ≥50B params: bf16 Adam moments (8-bit-Adam-style memory saving) so the
    # optimizer state fits the 16GB/chip HBM envelope; recorded in DESIGN.md §5.
    moment_dtype = jnp.bfloat16 if n_params >= 50e9 else jnp.float32
    return AdamW(
        lr=cosine_schedule(3e-4, 200, total_steps),
        moment_dtype=moment_dtype,
        keep_master=keep_master,
    )


ACTIVATION_BUDGET_BYTES = 3e9  # HBM share for saved activation checkpoints


def default_microbatches(cfg: ModelConfig, shape: ShapeSpec, ctx: ShardingCtx) -> int:
    """Gradient-accumulation factor keeping per-layer activation checkpoints in
    HBM. Dominant saved tensor per layer = residual stream
    (B/m/dp)·S·d_model·2 bytes; pick the smallest m with total ≤ budget,
    subject to m | B and dp | (B/m) (batch stays evenly data-sharded)."""
    ms = ctx.mesh_shape
    dp = 1
    for ax in ("pod", "data"):
        dp *= ms.get(ax, 1)
    tp = ms.get("model", 1)
    b, s = shape.global_batch, shape.seq_len
    # attention-score working set is NOT rematerialized away (the q-chunk scan
    # lives inside the checkpointed block): if heads don't shard over 'model'
    # (e.g. whisper's 20 heads on a 16-way axis), it dominates.
    h_local = cfg.n_heads // tp if cfg.n_heads % tp == 0 else cfg.n_heads
    qc = min(cfg.q_chunk or s, s)
    m = 1
    while True:
        b_loc = b // m // dp
        resid = cfg.n_layers * b_loc * s * cfg.d_model * 2
        scores = 2 * b_loc * h_local * qc * s * 4 if cfg.attention != "none" else 0
        if resid + scores <= ACTIVATION_BUDGET_BYTES:
            return m
        nxt = m * 2
        if b % nxt != 0 or (b // nxt) % dp != 0:
            return m  # smallest legal batch per device reached
        m = nxt


def build_train_step(
    cfg: ModelConfig,
    shape: ShapeSpec,
    ctx: ShardingCtx,
    microbatches: int = 0,  # 0 = auto
    bf16_params: bool = False,  # bf16 wire params + fp32 master in optimizer
):
    """Returns (jit_fn, (state_shardings, batch_shardings), abstract (state, batch))."""
    model = build_model(cfg)
    from repro.models.param import count_params

    opt = make_optimizer(
        n_params=count_params(build_model(cfg).decls()), keep_master=bf16_params
    )
    m = microbatches or default_microbatches(cfg, shape, ctx)
    assert shape.global_batch % m == 0, (shape.global_batch, m)
    # If one-sequence-per-device microbatches still overflow the activation
    # budget, shard the saved residual stream over 'model' (sequence parallel).
    ms = ctx.mesh_shape
    dp = 1
    for ax in ("pod", "data"):
        dp *= ms.get(ax, 1)
    per_dev = cfg.n_layers * (shape.global_batch // m // dp) * shape.seq_len * cfg.d_model * 2
    if per_dev > ACTIVATION_BUDGET_BYTES and ms.get("model", 1) > 1:
        ctx.act_rules["seq_resid"] = ("model",)
        ctx.log.append(
            f"seq_resid -> model (saved resid {per_dev/2**30:.1f}GiB/dev > budget; microbatches={m})"
        )

    def grads_of(params, mb_batch):
        return jax.value_and_grad(model.loss, has_aux=True)(params, mb_batch)

    def train_step(state: TrainState, batch):
        with sharding_ctx(ctx):
            if m == 1:
                (loss, metrics), grads = grads_of(state.params, batch)
            else:
                # gradient accumulation: scan over microbatches; the grads
                # accumulator shards like the params (FSDP), so accumulation
                # adds no per-device memory beyond one param-sized buffer.
                def reshape_mb(name, x):
                    y = x.reshape((m, x.shape[0] // m) + x.shape[1:])
                    axes = _INPUT_AXES.get(
                        name, ("batch",) + (None,) * (x.ndim - 1)
                    )[: x.ndim]
                    from repro.parallel.sharding import shard_act

                    return shard_act(y, (None,) + tuple(axes))

                mb_batch = {k: reshape_mb(k, v) for k, v in batch.items()}
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), state.params
                )

                def body(carry, mb):
                    g_acc, loss_acc, aux_acc = carry
                    (loss, metrics), grads = grads_of(state.params, mb)
                    g_acc = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                    )
                    aux_acc = {k: aux_acc[k] + metrics[k] for k in aux_acc}
                    return (g_acc, loss_acc + loss, aux_acc), None

                aux0 = {"ce": jnp.zeros((), jnp.float32),
                        "moe_aux_loss": jnp.zeros((), jnp.float32),
                        "moe_z_loss": jnp.zeros((), jnp.float32)}
                (grads, loss, aux), _ = lax_scan_unrollable(
                    body, (zeros, jnp.zeros((), jnp.float32), aux0), mb_batch,
                    unroll=cfg.scan_unroll,
                )
                grads = jax.tree.map(lambda g: g / m, grads)
                loss = loss / m
                metrics = {k: v / m for k, v in aux.items()}
            new_params, new_opt, opt_metrics = opt.update(grads, state.opt, state.params)
            metrics = {"loss": loss, **metrics, **opt_metrics}
            return TrainState(new_params, new_opt), metrics

    decls = model.decls()
    pspecs = param_pspecs(decls, ctx)
    state_pspecs = TrainState(
        params=pspecs,
        opt=AdamWState(
            step=P(), m=pspecs, v=pspecs, master=pspecs if bf16_params else None
        ),
    )
    abstract_p = abstract_params(
        decls, dtype_override=jnp.bfloat16 if bf16_params else None
    )
    abstract_m = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, opt.moment_dtype), abstract_p
    )
    abstract_master = (
        jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), abstract_p)
        if bf16_params
        else None
    )
    abstract_state = TrainState(
        params=abstract_p,
        opt=AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=abstract_m,
            v=jax.tree.map(lambda s: s, abstract_m),
            master=abstract_master,
        ),
    )
    in_specs = input_specs(cfg, shape)
    b_pspecs = batch_pspecs(in_specs, ctx)

    metrics_sh = None  # replicated by default
    jit_fn = jax.jit(
        train_step,
        in_shardings=(_named(ctx, state_pspecs), _named(ctx, b_pspecs)),
        out_shardings=(_named(ctx, state_pspecs), metrics_sh),
        donate_argnums=(0,),
    )
    return jit_fn, (state_pspecs, b_pspecs), (abstract_state, in_specs)


def build_prefill_step(cfg: ModelConfig, shape: ShapeSpec, ctx: ShardingCtx):
    """Inference prefill: bf16 params, logits out (no loss/grad)."""
    model = build_model(cfg)

    def prefill_step(params, batch):
        with sharding_ctx(ctx):
            logits, _ = model.forward(params, batch)
            return logits

    decls = model.decls()
    pspecs = param_pspecs(decls, ctx)
    abstract_p = abstract_params(decls, dtype_override=jnp.bfloat16)
    in_specs = input_specs(cfg, shape)
    b_pspecs = batch_pspecs(in_specs, ctx)
    logits_spec = spec_for(
        ("batch", "seq", "vocab"),
        (shape.global_batch, shape.seq_len, cfg.padded_vocab),
        ctx.act_rules,
        ctx.mesh_shape,
    )
    jit_fn = jax.jit(
        prefill_step,
        in_shardings=(_named(ctx, pspecs), _named(ctx, b_pspecs)),
        out_shardings=NamedSharding(ctx.mesh, logits_spec),
    )
    return jit_fn, (pspecs, b_pspecs), (abstract_p, in_specs)


def build_decode_step(cfg: ModelConfig, shape: ShapeSpec, ctx: ShardingCtx):
    """serve_step: one new token against a seq_len-deep cache."""
    model = build_model(cfg)
    b = shape.global_batch

    def serve_step(params, cache, tokens):
        with sharding_ctx(ctx):
            return model.decode_step(params, cache, tokens)

    decls = model.decls()
    pspecs = param_pspecs(decls, ctx)
    abstract_p = abstract_params(decls, dtype_override=jnp.bfloat16)
    cache_decls = model.cache_decls(b, shape.seq_len)
    cache_pspecs = param_pspecs(cache_decls, _cache_ctx(ctx))
    abstract_cache = abstract_params(cache_decls)
    tok_spec = spec_for(("batch",), (b,), ctx.act_rules, ctx.mesh_shape)
    logits_pspec = spec_for(
        ("batch", "vocab"), (b, cfg.padded_vocab), ctx.act_rules, ctx.mesh_shape
    )
    jit_fn = jax.jit(
        serve_step,
        in_shardings=(
            _named(ctx, pspecs),
            _named(ctx, cache_pspecs),
            NamedSharding(ctx.mesh, tok_spec),
        ),
        out_shardings=(
            NamedSharding(ctx.mesh, logits_pspec),
            _named(ctx, cache_pspecs),
        ),
        donate_argnums=(1,),
    )
    tok_abstract = jax.ShapeDtypeStruct((b,), jnp.int32)
    return jit_fn, (pspecs, cache_pspecs, tok_spec), (abstract_p, abstract_cache, tok_abstract)


def _cache_ctx(ctx: ShardingCtx) -> ShardingCtx:
    """Cache decls are declared with activation-style logical axes (batch,
    cache_seq, ...) — shard them under the ACT rules."""
    return ShardingCtx(mesh=ctx.mesh, param_rules=ctx.act_rules, act_rules=ctx.act_rules, log=ctx.log)
