"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches jax
device state). Single pod: (data=16, model=16) = 256 chips; multi-pod adds the
leading 'pod' axis (2 × 256 = 512 chips) carrying only data-parallel gradient
traffic (TP stays intra-pod — inter-pod links are the slow tier, DESIGN.md §5).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

# ``AxisType`` only exists in newer jax releases; feature-detect so this module
# imports (and plain Meshes work) on the installed version.
try:  # pragma: no cover - depends on installed jax
    from jax.sharding import AxisType

    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # older jax: no explicit axis types
    AxisType = None
    _AXIS_KW = lambda n: {}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh (tests / examples / elasticity)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_AXIS_KW(len(axes)))


def host_device_mesh(n_data: int = 1, n_model: int = 1) -> Mesh:
    """Small mesh over however many (host) devices exist — smoke/integration."""
    return make_mesh((n_data, n_model), ("data", "model"))
