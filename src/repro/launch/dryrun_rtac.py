import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Production-scale dry-run for the PAPER'S OWN workload — distributed RTAC.

The "most representative of the paper's technique" hillclimb cell
(EXPERIMENTS.md §Perf): a production CSP (n=4096 vars, d=32 values — the
constraint tensor is 16 GiB dense, 64 MiB/chip over the model axis) with a
batch of 512 search-node domains over (pod ×) data, enforced by the
shard_map fixpoint of `core/sharded.py`.

Variants (the hillclimb axis):
  einsum-bf16   paper-faithful tensorized contraction (matmul on the MXU)
  einsum-u8     dense uint8 support test on the VPU (2× less traffic)
  bitpacked     uint32 AND/any words (16× less constraint traffic than bf16)

Note on counting: the fixpoint is a `while` loop whose body XLA counts once —
all numbers below are therefore PER RECURRENCE (multiply by the empirical
3–5 recurrences of Table 1 for a full enforcement).

    python -m repro.launch.dryrun_rtac [--mesh both]
"""

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.engines import ShardedEngine
from repro.launch.mesh import make_production_mesh
from repro.parallel.hlo_stats import collective_stats, total_wire_bytes

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

N_VARS = 4096
DOM = 32
BATCH = 512


def _mem_dict(compiled) -> dict:
    """Numeric fields of ``compiled.memory_analysis()`` (backend-dependent
    attribute set, so reflect rather than enumerate)."""
    out = {}
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}
    if ma is None:
        return {"error": "memory_analysis() returned None"}
    for k in dir(ma):
        if k.startswith("_"):
            continue
        try:
            v = getattr(ma, k)
        except Exception:
            continue
        if isinstance(v, (int, float)):
            out[k] = v
    return out


def _cost_dict(compiled) -> dict:
    """Numeric fields of ``compiled.cost_analysis()`` (list-wrapped on some
    backends)."""
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": str(e)}
    if ca is None:
        return {"error": "cost_analysis() returned None"}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}


def run_variant(variant: str, mesh_kind: str) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    batch_axes = ("pod", "data") if mesh_kind == "multi" else ("data",)
    impl = "bitpacked" if variant == "bitpacked" else "einsum"
    dtype = {"einsum-bf16": jnp.bfloat16, "einsum-u8": jnp.uint8}.get(variant, jnp.bfloat16)
    # the engine's AOT hook: the same jitted fn its prepare() would bind,
    # lowered here on ShapeDtypeStructs (no 16 GiB allocation)
    eng = ShardedEngine(mesh=mesh, batch_axes=batch_axes, dtype=dtype, impl=impl)
    enf = eng.build_enforcer()

    w = DOM // 32
    if variant == "bitpacked":
        cons = jax.ShapeDtypeStruct((N_VARS, N_VARS, DOM, w), jnp.uint32)
    else:
        cons = jax.ShapeDtypeStruct((N_VARS, N_VARS, DOM, DOM), jnp.bool_)
    mask = jax.ShapeDtypeStruct((N_VARS, N_VARS), jnp.bool_)
    dom = jax.ShapeDtypeStruct((BATCH, N_VARS, DOM), jnp.bool_)
    ch = jax.ShapeDtypeStruct((BATCH, N_VARS), jnp.bool_)

    t0 = time.time()
    lowered = enf.lower(cons, mask, dom, ch)
    compiled = lowered.compile()
    t_compile = time.time() - t0
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    rec = {
        "workload": "rtac",
        "variant": variant,
        "mesh": mesh_kind,
        "n_vars": N_VARS,
        "dom": DOM,
        "batch": BATCH,
        "n_devices": int(mesh.devices.size),
        "compile_s": round(t_compile, 2),
        "memory_analysis": _mem_dict(compiled),
        "cost_analysis": _cost_dict(compiled),  # per recurrence (while body)
        "collectives": coll,
        "collective_wire_bytes": total_wire_bytes(coll),
    }
    ca = rec["cost_analysis"]
    mem = rec["memory_analysis"]
    print(
        f"[dryrun-rtac] {variant:12s} × {mesh_kind}: compile {t_compile:.1f}s "
        f"flops/dev={ca.get('flops', 0):.3e} bytes/dev={ca.get('bytes accessed', 0):.3e} "
        f"wire/dev={rec['collective_wire_bytes']:.3e}B "
        f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
        f"args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB",
        flush=True,
    )
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument(
        "--variants", default="einsum-bf16,einsum-u8,bitpacked"
    )
    args = ap.parse_args()
    ART_DIR.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mesh_kind in meshes:
        for variant in args.variants.split(","):
            rec = run_variant(variant, mesh_kind)
            path = ART_DIR / f"rtac__{variant}__{mesh_kind}.json"
            path.write_text(json.dumps(rec, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
