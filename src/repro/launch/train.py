"""Training driver — real execution on whatever devices exist.

Production behaviors exercised even at laptop scale:
  * auto-resume: scans the checkpoint dir at startup, restores the latest
    step and continues (crash/restart == no-op for the loss curve);
  * async checkpointing every ``--ckpt-every`` steps (off the critical path);
  * stateless data addressing: batch = f(seed, step), so resume/skip-ahead is
    exact (straggler mitigation posture, DESIGN.md §5);
  * mesh-elastic restore: restore reshards onto the current mesh.

Usage:
  python -m repro.launch.train --arch qwen1.5-0.5b --steps 100 --smoke
  python -m repro.launch.train --arch <id> --mesh-data 2 --mesh-model 1 ...
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import SHAPES, get_config, smoke_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import DataConfig, make_batch
from repro.launch.mesh import make_mesh
from repro.launch.steps import TrainState, build_train_step, make_optimizer
from repro.models.model import build_model, input_specs
from repro.models.param import count_params
from repro.parallel.sharding import make_ctx, param_shardings


def train(
    arch: str,
    steps: int = 100,
    smoke: bool = False,
    seq_len: int = 128,
    global_batch: int = 8,
    mesh_shape=(1, 1),
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    microbatches: int = 1,
    seed: int = 0,
    log_every: int = 10,
):
    cfg = get_config(arch)
    if smoke:
        cfg = smoke_config(cfg)
    shape = ShapeSpec("run", seq_len, global_batch, "train")
    mesh = make_mesh(mesh_shape, ("data", "model"))
    ctx = make_ctx(mesh)
    model = build_model(cfg)
    print(f"[train] {cfg.name}: {count_params(model.decls()):,} params, "
          f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    jit_step, (state_pspecs, _), _ = build_train_step(cfg, shape, ctx, microbatches)

    opt = make_optimizer()
    with mesh:
        params = model.init(jax.random.PRNGKey(seed))
        state = TrainState(params=params, opt=opt.init(params))

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if mgr is not None:
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(latest, state)
            start_step = latest
            print(f"[train] resumed from step {latest}")

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch)
    extra = {k: v for k, v in input_specs(cfg, shape).items()
             if k not in ("tokens", "labels", "loss_mask")}

    losses = []
    t0 = time.time()
    for step in range(start_step, steps):
        batch = make_batch(data_cfg, step, extra)
        state, metrics = jit_step(state, batch)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss {losses[-1]:8.4f} "
                  f"ce {float(metrics['ce']):8.4f} gnorm {float(metrics['grad_norm']):7.3f} "
                  f"({dt:.1f}s)", flush=True)
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save_async(step + 1, state)
    if mgr is not None:
        mgr.wait()
        mgr.save(steps, state)
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    train(
        args.arch,
        steps=args.steps,
        smoke=args.smoke,
        seq_len=args.seq_len,
        global_batch=args.global_batch,
        mesh_shape=(args.mesh_data, args.mesh_model),
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        microbatches=args.microbatches,
    )


if __name__ == "__main__":
    main()
