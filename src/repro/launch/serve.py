"""Serving driver — batched autoregressive decode with a sharded KV/state cache.

Exercises the decode path end-to-end on real devices (same `build_decode_step`
the dry-run lowers for decode_32k / long_500k):

    python -m repro.launch.serve --arch qwen1.5-0.5b --smoke --tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.configs.base import ShapeSpec
from repro.launch.mesh import make_mesh
from repro.models.model import build_model
from repro.parallel.sharding import make_ctx, sharding_ctx


def serve(
    arch: str,
    smoke: bool = True,
    batch: int = 4,
    cache_len: int = 128,
    tokens: int = 32,
    mesh_shape=(1, 1),
    seed: int = 0,
    greedy: bool = True,
):
    cfg = smoke_config(get_config(arch)) if smoke else get_config(arch)
    mesh = make_mesh(mesh_shape, ("data", "model"))
    ctx = make_ctx(mesh)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    cache = model.init_cache(batch=batch, cache_len=cache_len)

    def step(params, cache, toks):
        with sharding_ctx(ctx):
            return model.decode_step(params, cache, toks)

    jit_step = jax.jit(step, donate_argnums=(1,))

    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(batch,)), jnp.int32)
    out_tokens = [np.asarray(toks)]
    # warmup / compile
    logits, cache = jit_step(params, cache, toks)
    t0 = time.perf_counter()
    for _ in range(tokens - 1):
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32) if greedy else toks
        logits, cache = jit_step(params, cache, toks)
        out_tokens.append(np.asarray(toks))
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    seqs = np.stack(out_tokens, axis=1)
    tput = batch * (tokens - 1) / dt
    print(f"[serve] {cfg.name}: {tokens} steps, batch {batch}, "
          f"{1e3 * dt / (tokens - 1):.1f} ms/step, {tput:.1f} tok/s")
    return seqs, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()
    seqs, dt = serve(args.arch, smoke=args.smoke, batch=args.batch,
                     cache_len=args.cache_len, tokens=args.tokens)
    print(f"[serve] sample tokens: {seqs[0][:16].tolist()}")


if __name__ == "__main__":
    main()
