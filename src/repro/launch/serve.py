"""Serving driver — replay a seeded arrival trace through `SolverService`.

The service entry point (DESIGN.md §7): draws a Poisson arrival trace over
the `repro.problems` registry, feeds it through the continuous-batching
solver service against a fast-forward clock (idle gaps are skipped, queueing
under load is real), and prints sustained throughput plus tail latency.

    python -m repro.launch.serve --trace poisson \
        --families model_rb,coloring_random --rate 8 --duration 20 --engine einsum

With ``--trace-out run.json`` (or ``REPRO_TRACE=1`` in the environment) the
replay runs under the `repro.obs` tracer and drops the full run payload plus
a ``run.perfetto.json`` timeline next to it — load the latter in
ui.perfetto.dev, or ``python -m repro.obs summarize run.json``.

With ``--faults RECIPE`` (or ``REPRO_FAULTS`` in the environment) the replay
runs under seeded fault injection — the chaos drill CI's chaos-smoke leg
exercises: every future must still resolve, demotions ride the fallback
ladder, and the outcome line breaks down recovered / shed / failed.
"""

from __future__ import annotations

import argparse
from pathlib import Path

from repro import faults, obs
from repro.service import (
    DEFAULT_VARIANTS,
    FastForwardClock,
    RequestStatus,
    SolverService,
    poisson_trace,
    replay,
)

TRACES = ("poisson",)


def serve(
    families=("model_rb", "coloring_random"),
    trace: str = "poisson",
    rate: float = 8.0,
    duration: float = 20.0,
    engine: str = "einsum",
    seed: int = 0,
    cache_mb: int = 256,
    deadline_s: float = None,
    max_assignments: int = None,
    initial_slots: int = 8,
    quiet: bool = False,
    trace_out: str = None,
    trace_timing: str = "async",
    faults_recipe: str = None,
    faults_seed: int = 0,
    service_kwargs: dict = None,
):
    """Run one trace replay; returns (service, requests). With ``trace_out``
    set, the replay is traced (enabling the obs tracer if the environment
    didn't already) and the run payload + Perfetto timeline land on disk.
    ``faults_recipe`` installs a seeded `repro.faults` plan for the replay
    (on top of any ``REPRO_FAULTS`` already active); ``service_kwargs``
    forwards extra `SolverService` knobs (retry caps, watchdog limits, shed
    thresholds)."""
    if trace not in TRACES:
        raise ValueError(f"unknown trace {trace!r}; available: {list(TRACES)}")
    if trace_out and not obs.enabled():
        obs.enable(timing=trace_timing)
    if faults_recipe:
        faults.configure(faults_recipe, seed=faults_seed)
    events = poisson_trace(list(families), rate=rate, duration=duration, seed=seed)
    clock = FastForwardClock()
    svc = SolverService(
        engine=engine,
        cache_bytes=cache_mb << 20,
        initial_slots=initial_slots,
        clock=clock,
        **(service_kwargs or {}),
    )
    if not quiet:
        print(
            f"[serve] engine={engine} trace={trace} families={','.join(families)} "
            f"rate={rate:g}/s duration={duration:g}s seed={seed} "
            f"-> {len(events)} requests"
        )
    requests = replay(
        svc, events, clock, deadline_s=deadline_s, max_assignments=max_assignments
    )

    snap = svc.snapshot()
    if not quiet:
        n_to = snap["timed_out"]
        print(
            f"[serve] completed {snap['completed']}/{snap['submitted']}"
            + (f" ({n_to} timed out)" if n_to else "")
            + f" over {snap['span_s']:.2f}s of service time"
        )
        plan = faults.active()
        if plan is not None or snap["shed"] or snap["failed"]:
            n_rec = sum(
                r.status is RequestStatus.DONE
                and (r.retries > 0 or r.engine_level > 0)
                for r in requests
            )
            print(
                f"[serve] robustness: {plan.total_fires if plan else 0} faults "
                f"injected | {n_rec} recovered, {snap['shed']} shed, "
                f"{snap['failed']} failed | {snap['retries']} retries, "
                f"{snap['demotions']} demotions, "
                f"{snap['breaker_trips']} breaker trips"
            )
        print(
            f"[serve] throughput {snap['throughput_rps']:.2f} inst/s | "
            f"latency p50 {snap['p50_ms']:.1f} ms  p95 {snap['p95_ms']:.1f} ms  "
            f"p99 {snap['p99_ms']:.1f} ms"
        )
        cache = snap["cache"]
        print(
            f"[serve] {snap['rounds']} rounds, {snap['mean_rows_per_dispatch']:.1f} "
            f"rows/dispatch | cache {cache['hits']} hits / {cache['misses']} misses "
            f"/ {cache['evictions']} evictions | buckets "
            + " ".join(
                f"{b}:{info['capacity']}slots" for b, info in snap["buckets"].items()
            )
        )
        n_solved = sum(r.solution is not None for r in requests)
        n_capped = sum(
            r.status is RequestStatus.DONE and r.solution is None
            and r.stats is not None and r.stats.exhausted
            for r in requests
        )
        n_unsat = sum(
            r.status is RequestStatus.DONE and r.solution is None
            and not (r.stats is not None and r.stats.exhausted)
            for r in requests
        )
        print(
            f"[serve] outcomes: {n_solved} SAT, {n_unsat} UNSAT"
            + (f", {n_capped} budget-capped (inconclusive)" if n_capped else "")
        )
    if trace_out and obs.enabled():
        run_path = Path(trace_out)
        tracer = obs.get_tracer()
        obs.dump_run(run_path, tracer=tracer)
        perfetto_path = run_path.with_name(run_path.stem + ".perfetto.json")
        obs.write_trace(perfetto_path, tracer)
        if not quiet:
            spans = tracer.snapshot_spans()
            cov = obs.child_coverage(spans, "driver.round")
            print(
                f"[serve] obs run -> {run_path} ({len(spans)} spans, "
                f"driver.round child coverage {cov:.1%}); "
                f"timeline -> {perfetto_path}"
            )
    return svc, requests


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default="poisson", choices=TRACES)
    ap.add_argument(
        "--families",
        default="model_rb,coloring_random",
        help=f"comma-separated problem families (known: {sorted(DEFAULT_VARIANTS)})",
    )
    ap.add_argument("--rate", type=float, default=8.0, help="arrivals per second")
    ap.add_argument("--duration", type=float, default=20.0, help="trace length (s)")
    ap.add_argument("--engine", default="einsum")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-mb", type=int, default=256, help="prepared-network cache budget")
    ap.add_argument("--deadline", type=float, default=None, help="per-request deadline (s)")
    ap.add_argument("--budget", type=int, default=None, help="per-request assignment budget")
    ap.add_argument("--slots", type=int, default=8, help="initial slots per bucket")
    ap.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="trace the replay and write the obs run payload here "
             "(a .perfetto.json timeline lands next to it)",
    )
    ap.add_argument(
        "--trace-timing", default="async", choices=("async", "fenced"),
        help="span timing mode: 'fenced' blocks on device results inside "
             "kernel.launch spans so durations are true device time",
    )
    ap.add_argument(
        "--faults", default=None, metavar="RECIPE",
        help="seeded fault-injection recipe, e.g. 'all:0.05' or "
             "'frontier.step:0.1:oom' (same syntax as REPRO_FAULTS)",
    )
    ap.add_argument("--faults-seed", type=int, default=0)
    args = ap.parse_args(argv)
    serve(
        families=[f.strip() for f in args.families.split(",") if f.strip()],
        trace=args.trace,
        rate=args.rate,
        duration=args.duration,
        engine=args.engine,
        seed=args.seed,
        cache_mb=args.cache_mb,
        deadline_s=args.deadline,
        max_assignments=args.budget,
        initial_slots=args.slots,
        trace_out=args.trace_out,
        trace_timing=args.trace_timing,
        faults_recipe=args.faults,
        faults_seed=args.faults_seed,
    )


if __name__ == "__main__":
    main()
