"""Distributed RTAC — shard_map over the (data, model) production mesh.

Sharding story (DESIGN.md §2/§5): the constraint tensor is O(n²d²) and dominates
memory, so its *x*-rows are sharded over the ``model`` axis — each model shard
revises its own block of variables against the full (replicated) domain tensor,
then the updated domain blocks are ``all_gather``-ed (n·d bool per recurrence,
tiny next to the contraction). The batch of domains (search nodes / restarts) is
embarrassingly parallel over the ``data`` axis (and ``pod`` when present).

The entire fixpoint (``lax.while_loop``) lives INSIDE ``shard_map``: the loop
predicate is computed redundantly-but-identically on every shard from the
gathered domain, so no host sync or scalar collective is needed per recurrence.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .rtac import EnforceResult

Array = jax.Array


def _local_revise(cons_blk, mask_blk, dom, changed, dtype):
    """Revise this shard's x-block against the full domain.

    cons_blk: (nx, n, d, d) — x-rows owned by this model shard
    dom:      (n, d) full (replicated within the model axis)
    returns violated_blk: (nx, d)
    """
    cnt = jnp.einsum(
        "xyab,yb->xya",
        cons_blk.astype(dtype),
        dom.astype(dtype),
        preferred_element_type=jnp.float32,
    )
    has = (cnt > 0) | ~mask_blk[:, :, None]  # (nx, n, d)
    return jnp.any(changed[None, :, None] & ~has, axis=1)  # (nx, d)


def _local_revise_bitpacked(cons_blk_pk, mask_blk, dom, changed, dtype):
    """Bitpacked revise (beyond paper, DESIGN.md §2): the b-axis of the
    constraint block is packed into uint32 words, the support test becomes
    AND + any-nonzero — 8× less constraint traffic than uint8, 16× than bf16.

    cons_blk_pk: (nx, n, d, W) uint32;  dom: (n, d) bool (packed on the fly —
    n·d bits, negligible next to the constraint stream).
    """
    from repro.kernels.ref import pack_bits_ref

    dom_pk = pack_bits_ref(dom)  # (n, W) uint32
    anded = cons_blk_pk & dom_pk[None, :, None, :]  # (nx, n, d, W)
    has = jnp.any(anded != 0, axis=-1) | ~mask_blk[:, :, None]
    return jnp.any(changed[None, :, None] & ~has, axis=1)


def _enforce_one(cons_blk, mask_blk, dom0, changed0, *, axis_name, dtype,
                 revise=_local_revise):
    """Fixpoint for ONE domain tensor (vmapped over the local batch)."""
    nx = cons_blk.shape[0]
    idx = lax.axis_index(axis_name)
    x0 = idx * nx

    consistent0 = ~jnp.any(jnp.sum(dom0, axis=-1) == 0)

    def cond(state):
        dom, changed, consistent, k = state
        return jnp.logical_and(consistent, jnp.any(changed))

    def body(state):
        dom, changed, consistent, k = state
        violated = revise(cons_blk, mask_blk, dom, changed, dtype)
        old_blk = lax.dynamic_slice_in_dim(dom, x0, nx, axis=0)
        new_blk = old_blk & ~violated
        # Reassemble the full domain: every shard contributes its x-block.
        new_dom = lax.all_gather(new_blk, axis_name, axis=0, tiled=True)
        new_changed = jnp.any(new_dom != dom, axis=-1)
        new_consistent = ~jnp.any(jnp.sum(new_dom, axis=-1) == 0)
        return (new_dom, new_changed, new_consistent, k + 1)

    state0 = (dom0, changed0 & consistent0, consistent0, jnp.zeros((), jnp.int32))
    dom, _, consistent, k = lax.while_loop(cond, body, state0)
    return EnforceResult(dom, consistent, k)


def make_sharded_enforcer(
    mesh: Mesh,
    model_axis: str = "model",
    batch_axes=("data",),
    dtype=jnp.bfloat16,
    impl: str = "einsum",  # "einsum" (paper-faithful dense) | "bitpacked"
):
    """Build a jitted (cons, mask, dom_batch, changed_batch) -> EnforceResult.

    cons (n,n,d,d) bool — or (n,n,d,W) uint32 for impl="bitpacked" — sharded
    P(model); mask (n,n) sharded P(model); dom_batch (B,n,d) and
    changed_batch (B,n) sharded P(batch_axes). Returned dom is sharded like
    the input batch.
    """
    revise = _local_revise if impl == "einsum" else _local_revise_bitpacked
    batch_spec = P(batch_axes)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(
            P(model_axis),  # cons x-rows
            P(model_axis),  # mask x-rows
            batch_spec,  # dom batch
            batch_spec,  # changed batch
        ),
        out_specs=EnforceResult(batch_spec, batch_spec, batch_spec),
        check_rep=False,
    )
    def _sharded(cons_blk, mask_blk, dom_b, changed_b):
        fn = functools.partial(
            _enforce_one, axis_name=model_axis, dtype=dtype, revise=revise
        )
        return jax.vmap(lambda d, c: fn(cons_blk, mask_blk, d, c))(dom_b, changed_b)

    @jax.jit
    def enforce_sharded(cons, mask, dom_batch, changed_batch):
        return _sharded(cons, mask, dom_batch, changed_batch)

    return enforce_sharded


def shard_csp_arrays(mesh: Mesh, cons, mask, dom_batch, model_axis="model", batch_axes=("data",)):
    """Place CSP arrays with the shardings `make_sharded_enforcer` expects."""
    cons_s = jax.device_put(cons, NamedSharding(mesh, P(model_axis)))
    mask_s = jax.device_put(mask, NamedSharding(mesh, P(model_axis)))
    dom_s = jax.device_put(dom_batch, NamedSharding(mesh, P(batch_axes)))
    return cons_s, mask_s, dom_s
