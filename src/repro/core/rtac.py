"""RTAC — Recurrent Tensor Arc Consistency enforcement (paper Eq. 1 / Alg. 1).

The whole fixpoint runs as ONE XLA program (``lax.while_loop``), in contrast to
the paper's PyTorch loop which syncs with the host every recurrence. Two variants:

- :func:`enforce_full` — the bare recurrence of Eq. 1: every step recomputes the
  support test for all (x, a) pairs. This is the *paper-faithful dense baseline*.
- :func:`enforce` — the incremental variant licensed by Proposition 2: a value can
  only die because a *last-step-deleted* support vanished, so the revision test is
  masked to neighbours whose domain changed. On TPU (static shapes) the paper's
  ``changed_idx`` gather becomes a boolean mask; see DESIGN.md §2.

Both are jittable, ``vmap``-able over a batch of domains (shared network), and
take a pluggable ``support_fn`` so the Pallas kernels (`repro.kernels`) can
replace the einsum contraction.

Support-test convention (DESIGN.md §2): ``cons`` holds zero blocks for
unconstrained pairs and ``mask`` marks real constraints, so

    has_support[x, y, a] = (Σ_b cons[x,y,a,b]·dom[y,b] > 0) | ~mask[x, y]

which is identical to the paper's all-ones-block encoding.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .csp import CSP

Array = jax.Array

# support_fn(cons, mask, dom) -> has_support bool (n, n, d):
#   has_support[x, y, a] == (x,a) has a support in dom(y) under c_xy, or x,y unconstrained
SupportFn = Callable[[Array, Array, Array], Array]


def einsum_support(cons: Array, mask: Array, dom: Array, dtype=jnp.bfloat16) -> Array:
    """Reference contraction — the paper's ``matmul`` (Alg. 1 line 14) in einsum form.

    bf16 is exact here: we only test count > 0, and partial sums ≤ d fit the
    MXU accumulator (f32 accumulation in XLA dots).
    """
    cnt = jnp.einsum(
        "xyab,yb->xya",
        cons.astype(dtype),
        dom.astype(dtype),
        preferred_element_type=jnp.float32,
    )
    return (cnt > 0) | ~mask[:, :, None]


class EnforceResult(NamedTuple):
    dom: Array  # (n, d) bool — the AC closure D_ac (valid only if consistent)
    consistent: Array  # () bool — False iff some domain wiped out
    n_recurrences: Array  # () int32 — K of Eq. 1 (Table 1 "#Recurrence")


class _State(NamedTuple):
    dom: Array
    changed: Array  # (n,) bool — variables whose domain shrank last step
    consistent: Array
    k: Array


def _cond(state: _State) -> Array:
    return jnp.logical_and(state.consistent, jnp.any(state.changed))


# revise_fn(network, dom, changed) -> violated (n, d) bool:
#   violated[x,a] == some *changed* neighbour y offers no support for (x,a).
# ``network`` is an opaque pytree owned by the revise implementation — (cons, mask)
# for the einsum/dense paths, bitpacked words for the packed kernel.
ReviseFn = Callable


def make_einsum_revise(support_fn: SupportFn = einsum_support) -> ReviseFn:
    def revise(network, dom, changed):
        cons, mask = network
        has = support_fn(cons, mask, dom)  # (n, n, d)
        # (x,a) dies iff some *changed* neighbour y offers no support (Alg.1 l.16).
        return jnp.any(changed[None, :, None] & ~has, axis=1)  # (n, d)

    return revise


def _step(network, revise_fn, state: _State) -> _State:
    violated = revise_fn(network, state.dom, state.changed)
    new_dom = state.dom & ~violated
    changed = jnp.any(new_dom != state.dom, axis=-1)  # (n,)
    consistent = ~jnp.any(jnp.sum(new_dom, axis=-1) == 0)  # Alg.1 line 6
    return _State(new_dom, changed, consistent, state.k + 1)


@functools.partial(jax.jit, static_argnames=("revise_fn",))
def enforce_generic(
    network,
    dom: Array,
    changed0: Optional[Array] = None,
    revise_fn: ReviseFn = make_einsum_revise(),
) -> EnforceResult:
    """Incremental RTAC (Prop. 2) over an opaque network representation."""
    n = dom.shape[0]
    if changed0 is None:
        changed0 = jnp.ones((n,), dtype=jnp.bool_)
    # Initial wipeout check (a variable may start with an empty domain).
    consistent0 = ~jnp.any(jnp.sum(dom, axis=-1) == 0)
    state = _State(
        dom=dom,
        changed=changed0 & consistent0,
        consistent=consistent0,
        k=jnp.zeros((), jnp.int32),
    )
    body = functools.partial(_step, network, revise_fn)
    final = lax.while_loop(_cond, body, state)
    return EnforceResult(final.dom, final.consistent, final.k)


_EINSUM_REVISE = make_einsum_revise()
_REVISE_CACHE: dict = {}


def enforce(
    cons: Array,
    mask: Array,
    dom: Array,
    changed0: Optional[Array] = None,
    support_fn: SupportFn = einsum_support,
) -> EnforceResult:
    """Incremental RTAC (Prop. 2). ``changed0`` seeds the revision set — all
    variables for a fresh network, ``one_hot(idx)`` after an assignment (Alg. 2).

    ``support_fn`` must be a module-level function (it keys the jit cache)."""
    if support_fn is einsum_support:
        revise_fn = _EINSUM_REVISE
    else:
        revise_fn = _REVISE_CACHE.setdefault(support_fn, make_einsum_revise(support_fn))
    return enforce_generic((cons, mask), dom, changed0, revise_fn=revise_fn)


def _step_full(cons, mask, support_fn, state: _State) -> _State:
    has = support_fn(cons, mask, state.dom)
    alive = jnp.all(has, axis=1)  # (n, d): supported on EVERY neighbour
    new_dom = state.dom & alive
    changed = jnp.any(new_dom != state.dom, axis=-1)
    consistent = ~jnp.any(jnp.sum(new_dom, axis=-1) == 0)
    return _State(new_dom, changed, consistent, state.k + 1)


@functools.partial(jax.jit, static_argnames=("support_fn",))
def enforce_full(
    cons: Array,
    mask: Array,
    dom: Array,
    support_fn: SupportFn = einsum_support,
) -> EnforceResult:
    """Paper-faithful dense recurrence (Eq. 1, no incrementality)."""
    n = dom.shape[0]
    consistent0 = ~jnp.any(jnp.sum(dom, axis=-1) == 0)
    state = _State(
        dom=dom,
        changed=jnp.ones((n,), jnp.bool_) & consistent0,
        consistent=consistent0,
        k=jnp.zeros((), jnp.int32),
    )
    body = functools.partial(_step_full, cons, mask, support_fn)
    final = lax.while_loop(_cond, body, state)
    return EnforceResult(final.dom, final.consistent, final.k)


@functools.partial(jax.jit, static_argnames=("support_fn",))
def enforce_full_batch(
    cons: Array,
    mask: Array,
    dom: Array,  # (B, n, d)
    support_fn: SupportFn = einsum_support,
) -> EnforceResult:
    """Batched paper-faithful recurrence: B domains, one shared network."""
    fn = functools.partial(enforce_full.__wrapped__, cons, mask, support_fn=support_fn)
    return jax.vmap(fn)(dom)


# ---------------------------------------------------------------------------
# Batched enforcement — the beyond-paper throughput lever (DESIGN.md §2):
# one shared network, B candidate domains (search nodes / restarts) enforced
# simultaneously. vmap-of-while_loop runs until the *slowest* node converges;
# converged nodes no-op (the revision is idempotent), so correctness holds.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("revise_fn",))
def enforce_batch_generic(
    network,
    dom: Array,  # (B, n, d)
    changed0: Optional[Array] = None,  # (B, n) or None
    revise_fn: ReviseFn = _EINSUM_REVISE,
) -> EnforceResult:
    fn = functools.partial(enforce_generic.__wrapped__, revise_fn=revise_fn)
    if changed0 is None:
        return jax.vmap(lambda d: fn(network, d))(dom)
    return jax.vmap(lambda d, c: fn(network, d, c))(dom, changed0)


def enforce_batch(
    cons: Array,
    mask: Array,
    dom: Array,  # (B, n, d)
    changed0: Optional[Array] = None,  # (B, n) or None
    support_fn: SupportFn = einsum_support,
) -> EnforceResult:
    if support_fn is einsum_support:
        revise_fn = _EINSUM_REVISE
    else:
        revise_fn = _REVISE_CACHE.setdefault(support_fn, make_einsum_revise(support_fn))
    return enforce_batch_generic((cons, mask), dom, changed0, revise_fn=revise_fn)


# ---------------------------------------------------------------------------
# Multi-instance enforcement — R domains, each against its OWN network.
# ``networks`` is a pytree whose leaves carry a leading instance axis (B, ...)
# (B stacked constraint networks sharing (n, d)); ``instance_idx ∈ [0,B)^R``
# maps each domain row to its network. One vmapped fixpoint resolves a whole
# workload of independent CSPs in a single device dispatch (DESIGN.md §6).
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("revise_fn",))
def enforce_many_generic(
    networks,
    dom: Array,  # (R, n, d)
    changed0: Optional[Array],  # (R, n) or None
    instance_idx: Array,  # (R,) int32
    revise_fn: ReviseFn = _EINSUM_REVISE,
) -> EnforceResult:
    net = jax.tree_util.tree_map(lambda a: a[instance_idx], networks)
    fn = functools.partial(enforce_generic.__wrapped__, revise_fn=revise_fn)
    if changed0 is None:
        return jax.vmap(lambda nw, d: fn(nw, d))(net, dom)
    return jax.vmap(lambda nw, d, c: fn(nw, d, c))(net, dom, changed0)


# revise_rows_fn(net_g, doms, changed) -> violated (R, n, d) bool — the stacked
# analogue of ReviseFn: ``net_g`` is a pytree whose leaves carry a leading row
# axis (row i's network, already gathered), and row i is revised against its
# own network. The Pallas stacked kernels bind here (`repro.kernels.ops`).
ReviseRowsFn = Callable


class _RowState(NamedTuple):
    dom: Array  # (R, n, d)
    changed: Array  # (R, n)
    consistent: Array  # (R,)
    k: Array  # (R,) int32


@functools.partial(jax.jit, static_argnames=("revise_rows_fn",))
def enforce_rows_generic(
    networks,
    dom: Array,  # (R, n, d)
    changed0: Optional[Array],  # (R, n) or None
    instance_idx: Array,  # (R,) int32
    revise_rows_fn: ReviseRowsFn,
) -> EnforceResult:
    """R incremental fixpoints, row i against ``networks[instance_idx[i]]``,
    as ONE while_loop over a *stacked* revise (no vmap): every step revises all
    still-active rows in a single stacked-kernel launch. Per-row results are
    bit-identical to running `enforce_generic` on each row alone — a row is
    *active* while ``consistent & any(changed)`` (exactly the solo loop
    predicate), an inactive row's revision seed is zeroed (the incremental
    revise is then a no-op, freezing its domain), and ``k`` counts only the
    steps the row was active — so per-row recurrence counts match solo runs
    even though the loop runs until the slowest row converges.
    """
    net = jax.tree_util.tree_map(lambda a: a[instance_idx], networks)
    r, n, _ = dom.shape
    if changed0 is None:
        changed0 = jnp.ones((r, n), dtype=jnp.bool_)
    consistent0 = ~jnp.any(jnp.sum(dom, axis=-1) == 0, axis=-1)  # (R,)
    state = _RowState(
        dom=dom,
        changed=changed0 & consistent0[:, None],
        consistent=consistent0,
        k=jnp.zeros((r,), jnp.int32),
    )

    def cond(s: _RowState) -> Array:
        return jnp.any(s.consistent & jnp.any(s.changed, axis=-1))

    def body(s: _RowState) -> _RowState:
        active = s.consistent & jnp.any(s.changed, axis=-1)  # (R,)
        violated = revise_rows_fn(net, s.dom, s.changed & active[:, None])
        new_dom = s.dom & ~violated
        changed = jnp.any(new_dom != s.dom, axis=-1)
        consistent = s.consistent & ~jnp.any(jnp.sum(new_dom, axis=-1) == 0, axis=-1)
        return _RowState(new_dom, changed, consistent, s.k + active.astype(jnp.int32))

    final = lax.while_loop(cond, body, state)
    return EnforceResult(final.dom, final.consistent, final.k)


@functools.partial(jax.jit, static_argnames=("support_fn",))
def enforce_full_many(
    cons: Array,  # (B, n, n, d, d)
    mask: Array,  # (B, n, n)
    dom: Array,  # (R, n, d)
    instance_idx: Array,  # (R,) int32
    support_fn: SupportFn = einsum_support,
) -> EnforceResult:
    fn = functools.partial(enforce_full.__wrapped__, support_fn=support_fn)
    return jax.vmap(lambda c, m, d: fn(c, m, d))(
        cons[instance_idx], mask[instance_idx], dom
    )


# ---------------------------------------------------------------------------
# Fused assign + revise — the frontier dispatch (DESIGN.md §8).
# A search round no longer ships domains: the device gathers each row's parent
# closure, applies the Alg. 2 assignment, seeds the Prop. 2 revision set, and
# runs the stacked fixpoint — all inside ONE traced program.
# ---------------------------------------------------------------------------


def assign_and_seed(doms: Array, var: Array, val: Array) -> Tuple[Array, Array]:
    """Batched Alg. 2 ``assign`` fused with the Prop. 2 revision seed.

    Row i collapses ``dom(var[i])`` to ``{val[i]}`` and seeds
    ``changed = one_hot(var[i])``; ``var[i] < 0`` marks a *root* row — the
    domain is left untouched and every variable is seeded (a fresh network).
    Returns (doms', changed) of shapes (R, n, d) / (R, n)."""
    r, n, _ = doms.shape
    is_root = var < 0
    safe_var = jnp.maximum(var, 0)
    assigned = jax.vmap(assign)(doms, safe_var, val)
    doms = jnp.where(is_root[:, None, None], doms, assigned)
    onehot = jnp.arange(n, dtype=var.dtype)[None, :] == safe_var[:, None]
    changed = jnp.where(is_root[:, None], jnp.ones((r, n), jnp.bool_), onehot)
    return doms, changed


def assign_enforce_many(
    networks,
    doms: Array,  # (R, n, d) parent closures
    var: Array,  # (R,) int32; < 0 = root row (no assignment, all-changed seed)
    val: Array,  # (R,) int32
    instance_idx: Array,  # (R,) int32
    revise_fn: ReviseFn = _EINSUM_REVISE,
) -> EnforceResult:
    """Fused frontier dispatch for the contraction engines: assignment + seed
    + the gather/vmap incremental fixpoint of `enforce_many_generic`, one
    traced program (called from inside the jitted frontier step)."""
    doms, changed = assign_and_seed(doms, var, val)
    return enforce_many_generic(networks, doms, changed, instance_idx, revise_fn=revise_fn)


def assign_enforce_full_many(
    cons: Array,
    mask: Array,
    doms: Array,
    var: Array,
    val: Array,
    instance_idx: Array,
    support_fn: SupportFn = einsum_support,
) -> EnforceResult:
    """Fused frontier dispatch for the paper-faithful recurrence (Eq. 1 ignores
    the revision seed — every step re-tests all pairs, exactly as published)."""
    doms, _ = assign_and_seed(doms, var, val)
    return enforce_full_many(cons, mask, doms, instance_idx, support_fn=support_fn)


# CSP-level conveniences ------------------------------------------------------


def assign(dom: Array, var_idx, val_idx) -> Array:
    """Alg. 2 ``assign``: collapse dom(var) to {val} (traced-index safe)."""
    n, d = dom.shape
    row = jnp.zeros((d,), dom.dtype).at[val_idx].set(True)
    return dom.at[var_idx].set(row)
