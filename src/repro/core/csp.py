"""CSP tensor representation and instance generators.

The paper (RTAC, §4 / Alg. 2 `init`) represents a binary CSP as dense tensors:

    Cons ∈ {0,1}^{n×n×d×d}   Cons[x,y,a,b] = 1  iff (x=a, y=b) jointly allowed
    Vars ∈ {0,1}^{n×d}       Vars[x,a]     = 1  iff value a currently in dom(x)

The paper stores all-ones d×d blocks for unconstrained pairs so that the uniform
"support on every neighbour" test works. We keep an explicit ``mask ∈ {0,1}^{n×n}``
of *constrained* pairs instead and store zeros for unconstrained blocks — this is
algebraically identical (``has_support = (count > 0) | ~mask``) and lets the
kernels skip/bitpack unconstrained blocks. ``to_paper_cons`` recovers the paper's
exact all-ones encoding for the faithful-baseline path.

All domains are padded to ``d`` columns; ``dom_sizes`` (host-side) records true
sizes, with padding columns permanently False in ``dom``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class CSP(NamedTuple):
    """Dense tensor CSP. A pytree; leading batch dims are allowed on ``dom``."""

    cons: Array  # (n, n, d, d) bool — allowed value pairs; zero block if unconstrained
    mask: Array  # (n, n) bool — True where a constraint exists (symmetric, False diag)
    dom: Array  # (n, d) bool — current domains

    @property
    def n_vars(self) -> int:
        return self.cons.shape[0]

    @property
    def dom_size(self) -> int:
        return self.cons.shape[-1]


def to_paper_cons(csp: CSP) -> Array:
    """The paper's exact encoding: all-ones d×d blocks for unconstrained pairs."""
    ones = jnp.ones_like(csp.cons)
    return jnp.where(csp.mask[:, :, None, None], csp.cons, ones)


def make_csp(cons: np.ndarray, mask: np.ndarray, dom: np.ndarray) -> CSP:
    return CSP(
        cons=jnp.asarray(cons, dtype=jnp.bool_),
        mask=jnp.asarray(mask, dtype=jnp.bool_),
        dom=jnp.asarray(dom, dtype=jnp.bool_),
    )


# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------


def random_csp(
    n_vars: int,
    dom_size: int,
    density: float,
    tightness: float = 0.3,
    seed: int = 0,
) -> CSP:
    """Paper §5.2: each of the n(n-1)/2 pairs gets a constraint with prob ``density``.

    Each existing constraint's relation is a uniform random subset of the d×d
    tuple space where each tuple is *disallowed* with prob ``tightness``
    (standard model-A random CSPs; the paper does not pin tightness).
    """
    rng = np.random.default_rng(seed)
    iu = np.triu_indices(n_vars, k=1)
    edge = rng.random(len(iu[0])) < density
    mask = np.zeros((n_vars, n_vars), dtype=bool)
    mask[iu[0][edge], iu[1][edge]] = True
    mask |= mask.T

    allowed = rng.random((n_vars, n_vars, dom_size, dom_size)) >= tightness
    # symmetrize: Cons[y,x,b,a] == Cons[x,y,a,b]
    upper = np.triu(np.ones((n_vars, n_vars), dtype=bool), k=1)
    allowed = np.where(
        upper[:, :, None, None], allowed, np.transpose(allowed, (1, 0, 3, 2))
    )
    cons = allowed & mask[:, :, None, None]
    dom = np.ones((n_vars, dom_size), dtype=bool)
    return make_csp(cons, mask, dom)


def nqueens_csp(n: int) -> CSP:
    """N-queens as a binary CSP: one variable per column, domain = row index."""
    a = np.arange(n)
    ra, rb = np.meshgrid(a, a, indexing="ij")  # (d, d) candidate rows
    cons = np.zeros((n, n, n, n), dtype=bool)
    mask = np.zeros((n, n), dtype=bool)
    for x in range(n):
        for y in range(n):
            if x == y:
                continue
            ok = (ra != rb) & (np.abs(ra - rb) != abs(x - y))
            cons[x, y] = ok
            mask[x, y] = True
    dom = np.ones((n, n), dtype=bool)
    return make_csp(cons, mask, dom)


def coloring_csp(adjacency: np.ndarray, n_colors: int) -> CSP:
    """Graph colouring: adjacent vertices take different colours."""
    n = adjacency.shape[0]
    neq = ~np.eye(n_colors, dtype=bool)
    mask = adjacency.astype(bool) & ~np.eye(n, dtype=bool)
    cons = mask[:, :, None, None] & neq[None, None, :, :]
    dom = np.ones((n, n_colors), dtype=bool)
    return make_csp(cons, mask, dom)


def sudoku_csp(givens: "np.ndarray") -> CSP:
    """9x9 sudoku as a binary CSP: 81 variables, dom=9, all-diff on rows,
    columns and 3x3 boxes. ``givens``: (9,9) ints, 0 = empty."""
    n, d = 81, 9
    neq = ~np.eye(d, dtype=bool)
    mask = np.zeros((n, n), dtype=bool)
    for i in range(n):
        ri, ci = divmod(i, 9)
        for j in range(n):
            if i == j:
                continue
            rj, cj = divmod(j, 9)
            same_box = (ri // 3 == rj // 3) and (ci // 3 == cj // 3)
            if ri == rj or ci == cj or same_box:
                mask[i, j] = True
    cons = mask[:, :, None, None] & neq[None, None, :, :]
    dom = np.ones((n, d), dtype=bool)
    for i in range(n):
        ri, ci = divmod(i, 9)
        g = int(givens[ri, ci])
        if g:
            dom[i, :] = False
            dom[i, g - 1] = True
    return make_csp(cons, mask, dom)


def pad_domains(csp: CSP, pad_to: int) -> CSP:
    """Pad the value axis to ``pad_to`` (kernel tile alignment). Padding values are
    absent from every domain and allowed by no constraint, so the closure is
    unchanged."""
    d = csp.dom_size
    if pad_to < d:
        raise ValueError(f"pad_to={pad_to} < dom_size={d}")
    if pad_to == d:
        return csp
    p = pad_to - d
    cons = jnp.pad(csp.cons, ((0, 0), (0, 0), (0, p), (0, p)))
    dom = jnp.pad(csp.dom, ((0, 0), (0, p)))
    return CSP(cons=cons, mask=csp.mask, dom=dom)


@dataclasses.dataclass(frozen=True)
class CSPBenchSpec:
    """One cell of the paper's §5.2 benchmark grid."""

    n_vars: int
    density: float
    dom_size: int = 20
    tightness: float = 0.3
    seed: int = 0

    def build(self) -> CSP:
        return random_csp(
            self.n_vars, self.dom_size, self.density, self.tightness, self.seed
        )


# The 25-cell grid from paper §5.2 / Table 1.
PAPER_GRID = [
    CSPBenchSpec(n_vars=n, density=p)
    for n in (100, 250, 500, 750, 1000)
    for p in (0.10, 0.25, 0.50, 0.75, 1.00)
]
