"""The Engine protocol — prepare-once, enforce-many arc consistency (DESIGN.md §3).

Every enforcement backend (einsum, paper-faithful full recompute, Pallas
kernels, sharded, AC3) satisfies one small contract:

    engine.prepare(csp)            -> PreparedNetwork       (expensive, once)
    prepared.enforce(dom, ch)      -> EnforceResult         (hot path)
    prepared.enforce_batch(doms, ch) -> EnforceResult       (B domains at once)
    engine.prepare_many(csps)      -> PreparedMany          (stacked workload)
    many.enforce_many(doms, ch, idx) -> EnforceResult       (R domains, each
                                                             vs its OWN network)

``prepare`` does everything that depends only on the *constraint network*:
padding the O(n²d²) constraint tensor to kernel tiles, bitpacking, reshaping,
device placement / sharding, and constructing the (jit-cache-stable) revise
closure. The per-call path touches only O(n·d) domain data. MAC search
(`core/search.py`) calls ``prepare`` exactly once per CSP and then enforces
thousands of candidate domains against the same prepared network — previously
the kernel paths re-padded and re-bitpacked the constraint tensor on every
single enforcement.

``enforce``/``enforce_batch`` accept domains in *caller* coordinates
(n, d) / (B, n, d); engines that pad internally (the Pallas backends) pad the
domain per call and un-pad the result, so callers never see padded shapes.

Padding contract (DESIGN.md §2): padded variables are unconstrained with a
non-empty domain ({value 0}), so they never change, never violate, and never
trip the wipeout check; padded values are absent from every domain and allowed
by no constraint. The AC closure over the original (n, d) slice is unchanged.
This module is the only place that implements that contract.
"""

from __future__ import annotations

import abc
import bisect
import functools
import warnings
from typing import Any, Callable, ClassVar, Dict, List, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults, obs

from .csp import CSP
from .rtac import EnforceResult

Array = jax.Array
Changed = Optional[Union[Array, np.ndarray]]


# ---------------------------------------------------------------------------
# Padding contract — the ONE implementation (kernels and engines import these)
# ---------------------------------------------------------------------------


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def next_pow2(x: int) -> int:
    """The next power of two ≥ x (x ≥ 1) — the ONE copy of the jit-shape
    quantization every batching layer uses (frontier rounds, child frontiers,
    admission buckets)."""
    return 1 << (x - 1).bit_length()


def pad_round_rows(arrays: Sequence[np.ndarray], r_p: int) -> List[np.ndarray]:
    """Pad each (R, ...) array to ``r_p`` rows by replicating its LAST row —
    enforcement is idempotent per element (and duplicate scatters write
    identical values), so padded rows are inert. The ONE copy of the
    round-padding idiom every dispatch path uses (the host stores and the
    device `FrontierTable`)."""
    r = arrays[0].shape[0]
    if r_p == r:
        return list(arrays)
    return [np.concatenate([a, np.repeat(a[-1:], r_p - r, axis=0)]) for a in arrays]


def padded_shape(n: int, d: int, n_block: int, d_mult: int):
    """The kernel-tile shape `pad_network` pads (n, d) to. The ONE place the
    formula lives — engines that size slot tables without a CSP in hand
    (`_open_stacked_slot_pool`) must agree with `pad_network` by construction,
    not by convention."""
    return round_up(max(n, n_block), n_block), round_up(d, d_mult)


def pad_network(csp: CSP, n_block: int, d_mult: int):
    """Pad the *network* (cons, mask) to kernel tiles.

    Returns (cons, mask, n_p, d_p). Padded pairs are unconstrained
    (mask False, cons zero blocks) so they never produce a violation.
    """
    n, d = csp.dom.shape
    n_p, d_p = padded_shape(n, d, n_block, d_mult)
    cons = jnp.pad(csp.cons, ((0, n_p - n), (0, n_p - n), (0, d_p - d), (0, d_p - d)))
    mask = jnp.pad(csp.mask, ((0, n_p - n), (0, n_p - n)))
    return cons, mask, n_p, d_p


def pad_dom(dom: Array, n_p: int, d_p: int) -> Array:
    """Pad a domain tensor (..., n, d) -> (..., n_p, d_p).

    Padded variables get the singleton domain {0} (never empty → never trips
    the wipeout check); padded values are False everywhere.
    """
    *batch, n, d = dom.shape
    dom = jnp.pad(dom, [(0, 0)] * len(batch) + [(0, 0), (0, d_p - d)])
    pad_rows = jnp.zeros((*batch, n_p - n, d_p), jnp.bool_).at[..., :, 0].set(True)
    return jnp.concatenate([dom, pad_rows], axis=-2)


def pad_changed(changed0: Changed, n: int, n_p: int, batch: tuple = ()) -> Array:
    """Normalize+pad a changed seed (..., n) -> (..., n_p); None = all-changed.
    Padded variables are never marked changed (their domains never shrink)."""
    if changed0 is None:
        changed0 = jnp.ones((*batch, n), jnp.bool_)
    changed0 = jnp.asarray(changed0, dtype=jnp.bool_)
    return jnp.pad(changed0, [(0, 0)] * len(batch) + [(0, n_p - n)])


def as_changed(changed0: Changed) -> Optional[Array]:
    """Normalize a caller-supplied changed seed to a jax bool array (or None)."""
    if changed0 is None:
        return None
    return jnp.asarray(changed0, dtype=jnp.bool_)


# ---------------------------------------------------------------------------
# PreparedNetwork + Engine
# ---------------------------------------------------------------------------


class PreparedNetwork:
    """A CSP's constraint network compiled into one backend's resident form.

    Holds the engine that built it, the source CSP (for shapes and the root
    domain), and an opaque ``payload`` owned by the backend (padded/bitpacked
    tensors, revise closures, sharded jitted functions, host-side adjacency —
    whatever the backend's hot path needs so it never touches the raw CSP
    again).
    """

    __slots__ = ("engine", "csp", "payload")

    def __init__(self, engine: "Engine", csp: CSP, payload: Any):
        self.engine = engine
        self.csp = csp
        self.payload = payload

    @property
    def n_vars(self) -> int:
        return self.csp.dom.shape[0]

    @property
    def dom_size(self) -> int:
        return self.csp.dom.shape[1]

    def enforce(self, dom=None, changed0: Changed = None) -> EnforceResult:
        """Enforce AC on one domain (n, d); ``dom=None`` uses the CSP's root
        domain. ``changed0`` seeds the revision set (None = all variables)."""
        if dom is None:
            dom = self.csp.dom
        return self.engine.enforce(self, dom, changed0)

    def enforce_batch(self, doms, changed0: Changed = None) -> EnforceResult:
        """Enforce AC on B domains (B, n, d) in one dispatch; result fields
        carry a leading batch axis."""
        return self.engine.enforce_batch(self, doms, changed0)


class PreparedMany:
    """B constraint networks sharing (n, d), compiled into one backend's
    *stacked* resident form (DESIGN.md §6).

    Where `PreparedNetwork` amortizes preparation across the many enforcements
    of ONE search, `PreparedMany` amortizes the device across MANY independent
    instances: ``enforce_many`` resolves R domains, each against its own
    network, in one dispatch on backends that support it. ``payload`` is
    backend-owned — stacked tensors for the vmapped engines, a plain list of
    per-instance `PreparedNetwork`s for the generic fallback.
    """

    __slots__ = ("engine", "csps", "payload")

    def __init__(self, engine: "Engine", csps: Sequence[CSP], payload: Any):
        self.engine = engine
        self.csps = list(csps)
        self.payload = payload

    @property
    def n_instances(self) -> int:
        return len(self.csps)

    @property
    def n_vars(self) -> int:
        return self.csps[0].dom.shape[0]

    @property
    def dom_size(self) -> int:
        return self.csps[0].dom.shape[1]

    def enforce_many(
        self, doms, changed0: Changed = None, instance_idx=None
    ) -> EnforceResult:
        """Enforce AC on R domains (R, n, d), row i against the network of
        instance ``instance_idx[i]`` (default: ``arange(B)``, requiring R == B).
        Result fields carry the leading R axis."""
        return self.engine.enforce_many(self, doms, changed0, instance_idx)


def route_rows_on_host(enforce_row, doms, changed0: Changed, idx) -> EnforceResult:
    """The generic host-routing dispatch shared by `Engine.enforce_many` and
    `SlotPool.enforce_rows`: row i goes through ``enforce_row(idx[i], dom_i,
    changed_i)`` and the per-row results are stacked into one EnforceResult."""
    results = [
        enforce_row(int(j), doms[i], None if changed0 is None else changed0[i])
        for i, j in enumerate(idx)
    ]
    return EnforceResult(
        dom=np.stack([np.asarray(r.dom) for r in results]),
        consistent=np.asarray([bool(r.consistent) for r in results]),
        n_recurrences=np.asarray([int(r.n_recurrences) for r in results]),
    )


class SlotPool:
    """An *open-world* `PreparedMany`: a fixed-capacity table of resident
    network slots that searches join and leave mid-flight (DESIGN.md §7).

    Where `PreparedMany` stacks a closed batch of networks once, a `SlotPool`
    is the continuous-batching substrate of `repro.service`: ``install``
    compiles one network into a slot (the only O(n²d²) step, paid once per
    distinct network), ``enforce_rows`` resolves R domains — row i against
    slot ``slot_idx[i]`` — and ``release`` frees a slot for reuse when its
    last in-flight search retires. All slots share one (n_vars, dom_size)
    bucket shape, so every round reuses the same jitted program.

    This generic implementation keeps one `PreparedNetwork` per slot and
    routes rows on the host (works for every engine, including AC3). Engines
    that advertise ``slot_table = True`` get a device-resident `StackedSlotPool`
    instead — stacked tables, donated slot installs, one gather+fixpoint
    dispatch per round (`repro.engines.einsum`, `repro.engines.pallas`).
    """

    stacked: ClassVar[bool] = False

    def __init__(self, engine: "Engine", n_vars: int, dom_size: int, capacity: int):
        if capacity < 1:
            raise ValueError("SlotPool needs capacity >= 1")
        self.engine = engine
        self.n_vars = n_vars
        self.dom_size = dom_size
        self._nets: List[Optional[PreparedNetwork]] = [None] * capacity

    @property
    def capacity(self) -> int:
        return len(self._nets)

    def _check(self, slot: int, installing: bool) -> None:
        if not 0 <= slot < self.capacity:
            raise ValueError(f"slot {slot} out of range [0, {self.capacity})")
        if installing and self._nets[slot] is not None:
            raise ValueError(f"slot {slot} already installed; release it first")

    def install(self, slot: int, csp: CSP) -> None:
        """Compile ``csp``'s network into ``slot`` (must match the pool shape)."""
        self._check(slot, installing=True)
        if tuple(csp.dom.shape) != (self.n_vars, self.dom_size):
            raise ValueError(
                f"install: csp shape {tuple(csp.dom.shape)} != pool bucket "
                f"({self.n_vars}, {self.dom_size})"
            )
        faults.inject("slot.install", slot=slot)
        # the service's one O(n²d²) admission step — worth its own span
        with obs.span("slot.install", cat="engine", slot=slot,
                      n=self.n_vars, d=self.dom_size):
            self._nets[slot] = self._prepare_slot(slot, csp)
        obs.REGISTRY.counter_add("slots.installed")

    def _prepare_slot(self, slot: int, csp: CSP):
        """Backend hook: build the slot's resident form. The generic pool keeps
        a `PreparedNetwork`; stacked pools write device tensors and return a
        truthy sentinel."""
        return self.engine.prepare(csp)

    def release(self, slot: int) -> None:
        """Free a slot (its network may be overwritten by a later install)."""
        self._check(slot, installing=False)
        self._nets[slot] = None

    def grow(self, capacity: int) -> None:
        """Enlarge the table (amortized doubling in the service layer)."""
        if capacity < self.capacity:
            raise ValueError("SlotPool.grow cannot shrink")
        self._nets.extend([None] * (capacity - self.capacity))

    def enforce_rows(self, doms, changed0: Changed = None, slot_idx=None):
        """Enforce R domains (R, n, d), row i against slot ``slot_idx[i]``."""
        doms = np.asarray(doms)
        idx = resolve_instance_idx(slot_idx, self.capacity, doms.shape[0])

        def enforce_row(j, dom, ch):
            net = self._nets[j]
            if net is None:
                raise ValueError(f"enforce_rows: slot {j} is empty")
            return net.enforce(dom, ch)

        return route_rows_on_host(enforce_row, doms, changed0, idx)

    @property
    def resident_nbytes(self) -> int:
        """Device bytes this pool's resident networks occupy, in the engine's
        OWN representation (`Engine.network_nbytes`) — packed words for the
        bitpacked backend, not logical cons bytes."""
        occupied = sum(net is not None for net in self._nets)
        return occupied * self.engine.network_nbytes(self.n_vars, self.dom_size)


@functools.partial(jax.jit, donate_argnums=(0,))
def _slot_write(table, slot, value):
    """In-place-ish slot update: with buffer donation XLA updates the resident
    table without a copy (TPU/GPU; CPU falls back to a copy and warns once)."""
    return table.at[slot].set(value)


class StackedSlotPool(SlotPool):
    """A device-resident `SlotPool`: the networks live in *stacked* device
    tensors (a pytree of ``(C, ...)`` tables), installs write one slot row via
    a donated ``.at[slot].set``, and ``enforce_rows`` is ONE dispatch that
    gathers each row's network from the tables — the open-world analogue of
    `PreparedMany`'s stacked dispatch (DESIGN.md §7).

    The backend supplies its representation as three pieces:

    - ``tables``: the initial (zeroed) slot tables — ``(C, n, n, d, d)`` bool
      cons for the einsum engines, ``(C, n_p·d_p, n_p·W)`` packed uint32 words
      for `pallas_packed`;
    - ``encode(csp)``: one network compiled into a matching pytree of slot
      rows (the only O(n²d²) step, paid once per install);
    - ``dispatch(tables, doms, changed0, idx)``: the jitted gather + fixpoint
      over the whole round.
    """

    stacked: ClassVar[bool] = True

    def __init__(
        self,
        engine: "Engine",
        n_vars: int,
        dom_size: int,
        capacity: int,
        tables,
        encode: Callable[[CSP], Any],
        dispatch,
    ):
        super().__init__(engine, n_vars, dom_size, capacity)
        self._tables = tables
        self._encode = encode
        self._dispatch = dispatch

    def _prepare_slot(self, slot: int, csp: CSP):
        row = self._encode(csp)
        s = jnp.int32(slot)
        with warnings.catch_warnings():
            # CPU backends can't honour donation; the copy fallback is correct.
            warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
            self._tables = jax.tree_util.tree_map(
                lambda t, v: _slot_write(t, s, jnp.asarray(v)), self._tables, row
            )
        return True  # occupancy sentinel; the network lives in the tables

    def grow(self, capacity: int) -> None:
        old = self.capacity
        super().grow(capacity)
        if capacity > old:
            self._tables = jax.tree_util.tree_map(
                lambda t: jnp.pad(
                    t, [(0, capacity - old)] + [(0, 0)] * (t.ndim - 1)
                ),
                self._tables,
            )

    def require_installed(self, slot_idx) -> None:
        """Fail loudly if any routed slot has no resident network (also the
        `FrontierTable` round's ``check_net`` hook in the service)."""
        for j in np.unique(np.asarray(slot_idx)):
            if self._nets[int(j)] is None:
                raise ValueError(f"enforce_rows: slot {int(j)} is empty")

    def enforce_rows(self, doms, changed0: Changed = None, slot_idx=None):
        idx = resolve_instance_idx(slot_idx, self.capacity, np.shape(doms)[0])
        self.require_installed(idx)
        return self._dispatch(self._tables, doms, changed0, idx)

    @property
    def tables(self):
        """The live stacked slot tables — what a `FrontierTable` round reads
        its networks from (re-read every dispatch, so installs and growth
        between rounds are picked up)."""
        return self._tables

    @property
    def resident_nbytes(self) -> int:
        """The actual footprint of the resident slot tables (all slots — the
        table is allocated whole, occupied or not)."""
        return sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree_util.tree_leaves(self._tables)
        )


# ---------------------------------------------------------------------------
# FrontierTable — device-resident search frontiers (DESIGN.md §8)
# ---------------------------------------------------------------------------


class FrontierRow(NamedTuple):
    """One row of a frontier dispatch: create (and enforce) the child of
    ``parent`` obtained by assigning ``var := val``; ``var < 0`` marks a root
    row — ``parent`` already holds the root domain and is enforced in place.
    ``assigned`` is the (n,) bool assignment mask of the *child* (the state its
    own MRV selection must see); ``net`` routes the row to its constraint
    network (a `PreparedMany` instance index or a `SlotPool` slot)."""

    key: Any
    parent: int
    var: int
    val: int
    assigned: np.ndarray
    net: int


class RoundMeta(NamedTuple):
    """What a frontier round ships back to the host: O(R·d) metadata, never an
    (R, n, d) domain tensor. Domain sizes never ship at all — the on-device
    MRV reduction consumes them where they live. ``handles[i]`` is row i's
    closure handle (None where inconsistent — the row was freed);
    ``branch_var``/``value_row`` are the MRV decision (garbage, and ignored,
    for inconsistent or fully-assigned rows)."""

    handles: List[Optional[int]]
    consistent: np.ndarray  # (R,) bool
    k: np.ndarray  # (R,) int32 — per-row recurrence counts
    branch_var: np.ndarray  # (R,) int32
    value_row: np.ndarray  # (R, d) bool — the branching variable's domain row
    #: kernel launches this round's enforcement cost: 1 on a fused in-kernel
    #: fixpoint, the round's max recurrence depth on the stepped while_loop
    launches: int = 1
    #: anti-MRV decision (portfolio heuristic diversity, DESIGN.md §9): the
    #: argmax counterpart of ``branch_var``/``value_row``. ``None`` unless the
    #: store was asked for it (`FrontierTable.enable_alt`) — the extra O(R·d)
    #: metadata only ships when some admitted member actually branches anti.
    alt_var: Optional[np.ndarray] = None  # (R,) int32
    alt_row: Optional[np.ndarray] = None  # (R, d) bool


_INT32_MAX = np.iinfo(np.int32).max


@functools.partial(
    jax.jit, donate_argnums=(0, 1), static_argnames=("fix", "want_alt")
)
def _frontier_step(buf, abuf, networks, parent, var, val, dest, net_idx, *, fix,
                   want_alt=False):
    """ONE fused round: gather parent closures AND assignment masks from the
    resident frontier planes, assign + enforce (the engine's fused ``fix``),
    scatter the children back, and reduce the per-row metadata — neither
    domains nor assignment masks ever leave the device. ``buf``/``abuf`` are
    donated: XLA updates the tables in place. ``want_alt`` additionally
    reduces the anti-MRV decision (portfolio heuristic diversity) — a second
    O(R·d) metadata pair, compiled in only when some search branches anti."""
    doms = buf[parent]  # (R, n, d)
    res = fix(networks, doms, var, val, net_idx)
    buf = buf.at[dest].set(res.dom)
    # the child's assignment mask: parent's mask plus the assigned variable
    # (root rows, var < 0, inherit the parent mask unchanged) — maintained on
    # device, bit-identical to the coroutine's host-side bookkeeping
    n = buf.shape[1]
    one_hot = (jnp.arange(n, dtype=var.dtype)[None, :] == jnp.maximum(var, 0)[:, None])
    assigned = abuf[parent] | (one_hot & (var >= 0)[:, None])  # (R, n)
    abuf = abuf.at[dest].set(assigned)
    # MRV on device — identical to search._select_var: first argmin over
    # unassigned domain sizes (assigned variables hidden behind a sentinel).
    # The sizes are consumed HERE; they are never shipped to the host.
    sizes = jnp.sum(res.dom, axis=-1).astype(jnp.int32)  # (R, n)
    bvar = jnp.argmin(jnp.where(assigned, _INT32_MAX, sizes), axis=-1).astype(jnp.int32)
    vrow = jnp.take_along_axis(res.dom, bvar[:, None, None], axis=1)[:, 0, :]  # (R, d)
    out = (buf, abuf, res.consistent, res.n_recurrences, bvar, vrow)
    if want_alt:
        # anti-MRV: first argmax over unassigned domain sizes — identical
        # ints + ties to search._select_var_anti (assigned → -1 sentinel)
        avar = jnp.argmax(
            jnp.where(assigned, jnp.int32(-1), sizes), axis=-1
        ).astype(jnp.int32)
        arow = jnp.take_along_axis(res.dom, avar[:, None, None], axis=1)[:, 0, :]
        out = out + (avar, arow)
    return out


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _root_write(buf, abuf, row, dom, assigned):
    """Donated single-row install (root domain + assignment-mask upload)."""
    return buf.at[row].set(dom), abuf.at[row].set(assigned)


@jax.jit
def _row_read(buf, row):
    """One-row gather (solution extraction) — jitted so the row index rides
    as a device scalar instead of an implicit eager-slice transfer."""
    return buf[row]


def _buffer_zeros(shape):
    """A zeroed device buffer. Allocation is not data motion: the fill value
    is a scalar constant, so it is exempted from the transfer audit the
    frontier runs under (`jax.transfer_guard("disallow")` stays clean)."""
    with jax.transfer_guard("allow"):
        return jnp.zeros(shape, jnp.bool_)


class _PendingFrontierRound:
    """Handle for one in-flight frontier dispatch: the metadata arrays are
    still device futures (JAX async dispatch); ``resolve()`` fetches them —
    the round's only device→host transfer — and frees inconsistent rows."""

    def __init__(self, table: "FrontierTable", meta, dest: List[int], keys: List[Any], r: int):
        self._table = table
        self._meta = meta
        self._dest = dest
        self._keys = keys
        self._r = r

    def resolve(self) -> RoundMeta:
        cons, k, bvar, vrow, *alt = jax.device_get(self._meta)
        self._table._count_d2h(cons, k, bvar, vrow, *alt)
        r = self._r
        handles: List[Optional[int]] = []
        for i, (key, row) in enumerate(zip(self._keys, self._dest)):
            if bool(cons[i]):
                handles.append(row)
            else:  # a wiped-out child is never revisited — free its row now
                self._table.free(key, row)
                handles.append(None)
        # the round's launch bill: a fused fixpoint is ONE kernel regardless
        # of recurrence depth; the stepped path launched one revise per
        # iteration of the deepest row (XLA while_loop runs to the max k)
        launches = 1 if self._table.fused_fixpoint else max(1, int(k[:r].max()))
        self._table.launches += launches
        avar, arow = (alt[0][:r], alt[1][:r]) if alt else (None, None)
        return RoundMeta(
            handles, cons[:r], k[:r], bvar[:r], vrow[:r], launches, avar, arow
        )


class FrontierTable:
    """Device-resident search frontiers (DESIGN.md §8): a donated
    ``(R_cap, n, d)`` buffer holding every live search node's AC closure for
    the life of the search, plus the fused round dispatch over it.

    The host never touches domains: ``begin`` uploads one root domain per
    admitted search (the only O(n·d) host→device transfer a search ever
    makes), ``dispatch`` launches the fused gather→assign→enforce→scatter→
    reduce step (`_frontier_step`) whose host traffic is O(R·d) metadata
    both ways, and ``extract`` fetches one closure exactly once, at solution
    extraction. Rows are owned per search key: ``free`` returns a single row
    (dead branch), ``release`` reclaims everything a retired search held.
    Capacity grows by doubling (a device-side pad; O(log) reallocations).

    All host↔device traffic is *explicit* (`jax.device_put`/`device_get`) and
    metered — ``jax.transfer_guard("disallow")`` passes over a whole lockstep
    run, which is exactly what `tests/test_frontier.py` asserts — and the
    cumulative byte counters feed the ``frontier`` benchmark section.
    """

    pipelined: ClassVar[bool] = True

    def __init__(
        self,
        n_vars: int,
        dom_size: int,
        networks: Callable[[], Any],
        fix: Callable,
        capacity: int = 64,
        pad_rounds: bool = True,
        check_net: Optional[Callable] = None,
        fused_fixpoint: bool = False,
    ):
        if capacity < 2:
            raise ValueError("FrontierTable needs capacity >= 2")
        #: optional per-round validation of the row→network routing (the
        #: service passes the slot pool's occupancy check, so a stale route
        #: fails loudly instead of solving against a zeroed network)
        self._check_net = check_net
        self.n_vars = n_vars
        self.dom_size = dom_size
        self._networks = networks  # () -> pytree; re-read every round, so slot
        # installs and pool growth between rounds are picked up automatically
        self._fix = fix
        self._buf = _buffer_zeros((capacity, n_vars, dom_size))
        self._abuf = _buffer_zeros((capacity, n_vars))  # assignment masks
        self._free_rows: List[int] = list(range(capacity - 1, -1, -1))
        self._rows_of: Dict[Any, set] = {}
        self._net_of: Dict[Any, int] = {}
        self._pad_rounds = pad_rounds
        # Every XLA program is shaped on the round width, so a draining tail
        # that walked back down the pow2 ladder would compile a fresh program
        # per step — the dominant cost of a cold run. Rounds therefore pad to
        # the nearest ALREADY-COMPILED width ≥ r (compiling a new pow2 width
        # only when r exceeds them all): compiles happen on the way up only,
        # and tails reuse the smallest adequate program. Padded rows replicate
        # the last real row (idempotent, no extra fixpoint iterations), so a
        # somewhat wider round costs linear width, strictly cheaper than a
        # compile.
        self._widths: List[int] = []
        #: whether ``fix`` runs the whole recurrence in one kernel launch
        #: (drives the launch accounting in `_PendingFrontierRound.resolve`)
        self.fused_fixpoint = bool(fused_fixpoint)
        # transfer telemetry (metadata bytes; root/extract counted separately)
        self.rounds = 0
        self.launches = 0  # cumulative kernel launches across rounds
        self.rows_dispatched = 0  # real rows
        self.rows_padded = 0  # rows actually shaped into the dispatches
        self.rows_pow2 = 0  # plain next-pow2 rows (the pre-§8 round widths)
        self.h2d_bytes = 0
        self.d2h_bytes = 0
        self.root_bytes = 0
        self.extract_bytes = 0
        #: ship the anti-MRV metadata pair with every round (DESIGN.md §9) —
        #: off by default so the O(R·d) budget is unchanged unless some
        #: admitted portfolio member actually branches anti-MRV
        self._want_alt = False

    def enable_alt(self) -> None:
        """Opt this table into anti-MRV metadata for all subsequent rounds
        (a static jit arg — flipping it compiles fresh round programs, so the
        driver sets it once at group admission, not per round)."""
        self._want_alt = True

    @property
    def capacity(self) -> int:
        return self._buf.shape[0]

    @property
    def rows_live(self) -> int:
        return self.capacity - len(self._free_rows)

    def spare_rows(self) -> int:
        """Rows currently unoccupied — what speculative admission sizes its
        duplication budget against (capacity can still grow by doubling, but
        speculation should fill slack, not force reallocations)."""
        return len(self._free_rows)

    @property
    def host_bytes_per_round(self) -> float:
        """Mean metadata bytes (both directions) one lockstep round moves —
        the number the O(R·n·d)→O(R·d) claim is measured by."""
        return (self.h2d_bytes + self.d2h_bytes) / max(self.rounds, 1)

    @property
    def domain_bytes_per_round(self) -> float:
        """The counterfactual: what the pre-§8 protocol moved per round — the
        full (R, n, d) bool domains, host→device and back, at the plain
        next-pow2 round widths it actually padded to (NOT this table's
        ratcheted widths — the comparison stays honest)."""
        return 2.0 * self.rows_pow2 * self.n_vars * self.dom_size / max(self.rounds, 1)

    def _count_d2h(self, *arrays) -> None:
        nbytes = sum(np.asarray(a).nbytes for a in arrays)
        self.d2h_bytes += nbytes
        obs.REGISTRY.counter_add("frontier.d2h_bytes", nbytes)

    def _alloc(self, key) -> int:
        if not self._free_rows:
            old = self.capacity
            # doubling is an on-device allocation, not data motion (the pad
            # fill is a scalar constant) — exempt from the transfer audit
            with jax.transfer_guard("allow"):
                self._buf = jnp.pad(self._buf, ((0, old), (0, 0), (0, 0)))
                self._abuf = jnp.pad(self._abuf, ((0, old), (0, 0)))
            self._free_rows.extend(range(2 * old - 1, old - 1, -1))
        row = self._free_rows.pop()
        self._rows_of[key].add(row)
        return row

    # --- search lifecycle ---------------------------------------------------

    def register(self, key, net: int) -> None:
        """Register a search key with its network routing but NO root upload —
        how a split sibling joins the table: its first frontier row is a
        child-create against the parent's still-resident row, so the sibling
        never moves a domain across the host boundary at all."""
        if key in self._rows_of:
            raise ValueError(f"search key {key!r} already registered")
        self._rows_of[key] = set()
        self._net_of[key] = int(net)

    def begin(self, key, net: int, root_dom: np.ndarray, assigned=None) -> int:
        """Register a search and upload its root domain + initial assignment
        mask into a fresh row — the ONE domain-sized host→device transfer of
        the search's lifetime (``assigned`` marks bucket-padding variables as
        born assigned; the mask lives on device from here on)."""
        self.register(key, net)
        row = self._alloc(key)
        dom = jax.device_put(np.asarray(root_dom, dtype=bool))
        if assigned is None:
            assigned = np.zeros((self.n_vars,), dtype=bool)
        mask = jax.device_put(np.asarray(assigned, dtype=bool))
        self.root_bytes += int(dom.nbytes) + int(mask.nbytes)
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
            self._buf, self._abuf = _root_write(
                self._buf, self._abuf, jax.device_put(np.int32(row)), dom, mask
            )
        return row

    def free(self, key, row: int) -> None:
        """Return one row (a dead branch) to the free list."""
        rows = self._rows_of.get(key)
        if rows is not None and row in rows:
            rows.discard(row)
            self._free_rows.append(row)

    def release(self, key) -> None:
        """Reclaim every row a retired search still holds."""
        self._free_rows.extend(self._rows_of.pop(key, ()))
        self._net_of.pop(key, None)

    def extract(self, key, row: int) -> np.ndarray:
        """Fetch one closure — exactly once per search, at solution
        extraction (an explicit device→host transfer)."""
        dom = np.asarray(
            jax.device_get(_row_read(self._buf, jax.device_put(np.int32(row))))
        )
        self.extract_bytes += int(dom.nbytes)
        return dom

    # --- the fused round ----------------------------------------------------

    def dispatch(self, specs: Sequence[FrontierRow], net_idx=None) -> _PendingFrontierRound:
        """Launch one fused round over ``specs`` (JAX async — returns
        immediately; ``resolve()`` on the result blocks on the metadata).
        ``net_idx`` optionally supplies the per-row network routing (the
        driver's cached array); default derives it from the specs."""
        r = len(specs)
        if r == 0:
            raise ValueError("dispatch needs at least one row")
        # before _alloc/_check_net so a fired fault leaves the table unmutated
        faults.inject("frontier.step", rows=r)
        if self._check_net is not None:
            self._check_net(
                net_idx
                if net_idx is not None
                else np.fromiter((self._net_of[s.key] for s in specs), np.int32, r)
            )
        dest = [s.parent if s.var < 0 else self._alloc(s.key) for s in specs]
        parent = np.fromiter((s.parent for s in specs), np.int32, r)
        var = np.fromiter((s.var for s in specs), np.int32, r)
        val = np.fromiter((s.val for s in specs), np.int32, r)
        if net_idx is None:
            net_idx = np.fromiter((self._net_of[s.key] for s in specs), np.int32, r)
        dest_arr = np.asarray(dest, np.int32)
        if self._pad_rounds:
            r_p = next((w for w in self._widths if w >= r), None)
            if r_p is None:  # wider than anything compiled: a new pow2 width
                r_p = next_pow2(r)
                bisect.insort(self._widths, r_p)
        else:
            r_p = r
        # replicate the LAST row verbatim (dest included): identical inputs
        # write identical values, so the duplicate scatter is harmless and
        # the jitted step reuses already-compiled widths
        args = tuple(
            jax.device_put(a)
            for a in pad_round_rows(
                (parent, var, val, dest_arr, np.asarray(net_idx, np.int32)), r_p
            )
        )
        h2d = sum(int(a.nbytes) for a in args)
        self.h2d_bytes += h2d
        obs.REGISTRY.counter_add("frontier.h2d_bytes", h2d)
        self.rounds += 1
        self.rows_dispatched += r
        self.rows_padded += r_p
        self.rows_pow2 += next_pow2(r)
        # the launch span brackets the dispatch call: under the default async
        # timing it measures launch-side cost only; under timing="fenced" the
        # fence blocks on the round's metadata, so the span is the device
        # round itself (block_until_ready moves no data — the transfer-guard
        # audit stays clean, and verdicts are bit-identical either way)
        with obs.span("kernel.launch", cat="kernel", rows=r, padded=r_p,
                      fused=self.fused_fixpoint):
            faults.inject("kernel.launch", rows=r)
            with warnings.catch_warnings():
                warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
                self._buf, self._abuf, *meta = _frontier_step(
                    self._buf, self._abuf, self._networks(), *args, fix=self._fix,
                    want_alt=self._want_alt,
                )
            obs.fence(meta)
        obs.REGISTRY.gauge_set("frontier.rows_live", self.rows_live)
        obs.REGISTRY.gauge_set("frontier.capacity", self.capacity)
        return _PendingFrontierRound(self, tuple(meta), dest, [s.key for s in specs], r)


def frontier_capacity(n_searches: int, n_vars: int, dom_size: int,
                      cap: int = 8192) -> int:
    """Initial `FrontierTable` rows for ``n_searches`` concurrent searches of
    shape (n_vars, dom_size). A DFS level holds its node plus the unvisited
    sibling closures, so ~(n + d) rows per search bounds the common case;
    rows are n·d bools, so presizing is cheap while mid-run growth recompiles
    the fused step for every live round shape. Growth still works — this is a
    sizing heuristic, not a limit."""
    return max(64, min(cap, next_pow2(n_searches * (n_vars + dom_size + 2))))


def resolve_instance_idx(instance_idx, n_instances: int, n_rows: int) -> np.ndarray:
    """Normalize/validate the row→instance map of ``enforce_many``."""
    if instance_idx is None:
        if n_rows != n_instances:
            raise ValueError(
                f"enforce_many got {n_rows} domains for {n_instances} instances; "
                "pass instance_idx to map rows to instances"
            )
        return np.arange(n_instances, dtype=np.int32)
    idx = np.asarray(instance_idx, dtype=np.int32)
    if idx.shape != (n_rows,):
        raise ValueError(f"instance_idx shape {idx.shape} != ({n_rows},)")
    if idx.size and (idx.min() < 0 or idx.max() >= n_instances):
        raise ValueError(f"instance_idx out of range [0, {n_instances})")
    return idx


class Engine(abc.ABC):
    """One enforcement backend. Register concrete engines in `repro.engines`."""

    #: registry key (and the string accepted by ``mac_solve(engine=...)``)
    name: ClassVar[str]
    #: unit of ``EnforceResult.n_recurrences`` — "recurrences" for the tensor
    #: fixpoint backends (Table 1 #Recurrence), "revisions" for AC3
    #: (Table 1 #Revision). `SearchStats` files counts accordingly.
    count_unit: ClassVar[str] = "recurrences"
    #: whether ``enforce_batch`` is genuinely one parallel dispatch. Sequential
    #: host engines (AC3) set this False so MAC search enforces children
    #: lazily one at a time — eager batching would do strictly more work there
    #: and skew the per-assignment statistics.
    supports_batch: ClassVar[bool] = True
    #: whether ``enforce_many`` is one stacked device dispatch (jit-shaped on
    #: the row count, so callers benefit from padding rounds to reused shapes).
    #: False = the generic host-routing fallback, where padded rows would be
    #: real enforcement work thrown away.
    stacked_many: ClassVar[bool] = False
    #: whether ``open_slot_pool`` is backed by a device-resident stacked slot
    #: table (one gather+fixpoint dispatch per round). The service keys its
    #: per-bucket wiring (round padding, occupancy accounting) off this
    #: advertisement — engines declare the capability, callers never hardcode
    #: backend names. True requires ``_open_stacked_slot_pool``.
    slot_table: ClassVar[bool] = False
    #: whether this engine supplies the fused frontier dispatch (DESIGN.md §8):
    #: ``frontier_fix``/``frontier_networks`` back a device-resident
    #: `FrontierTable`, so lockstep rounds gather parents, assign, enforce and
    #: select on device and ship only O(R·d) metadata to the host. False =
    #: the search layer's host-side store (domains in numpy, as for AC3).
    device_frontier: ClassVar[bool] = False
    #: whether enforcement runs its whole recurrence inside ONE kernel launch
    #: (the fused in-kernel fixpoint). Engines with a runtime mode switch (the
    #: Pallas backends' ``fixpoint=`` knob) shadow this with an instance
    #: attribute; the frontier's launch accounting reads it either way.
    fused_fixpoint: ClassVar[bool] = False
    #: ceiling on how many frontier rows ONE request may speculatively occupy
    #: on this backend (tree-split siblings + portfolio members, DESIGN.md §9).
    #: An occupancy hint, not a semantic knob: wide stacked backends amortize
    #: extra rows almost for free, host loops pay per row. The service clamps
    #: its duplication budget by it at admission.
    speculative_rows_hint: ClassVar[int] = 32

    def network_nbytes(self, n_vars: int, dom_size: int) -> int:
        """Resident device bytes of ONE prepared network of caller shape
        (n_vars, dom_size) in THIS engine's representation — the unit the
        service's cache budget counts. The generic answer is the logical bool
        network (cons n²d² + mask n², one byte per element); engines with a
        padded or packed resident form (the Pallas backends) override with
        their true footprint, e.g. packed u32 words at 8× fewer bytes."""
        return n_vars * n_vars * dom_size * dom_size + n_vars * n_vars

    def prepare(self, csp: CSP) -> PreparedNetwork:
        """Compile the constraint network into this backend's resident form.
        Called once per CSP; everything O(n²d²) happens here."""
        return PreparedNetwork(self, csp, self._prepare_payload(csp))

    @abc.abstractmethod
    def _prepare_payload(self, csp: CSP) -> Any:
        ...

    @abc.abstractmethod
    def enforce(self, prepared: PreparedNetwork, dom, changed0: Changed = None) -> EnforceResult:
        ...

    def enforce_batch(self, prepared: PreparedNetwork, doms, changed0: Changed = None) -> EnforceResult:
        """Generic fallback: loop on the host and stack. Device backends
        override this with a single vmapped/sharded dispatch."""
        results = [
            self.enforce(prepared, doms[i], None if changed0 is None else changed0[i])
            for i in range(len(doms))
        ]
        return EnforceResult(
            dom=np.stack([np.asarray(r.dom) for r in results]),
            consistent=np.asarray([bool(r.consistent) for r in results]),
            n_recurrences=np.asarray([int(r.n_recurrences) for r in results]),
        )

    # --- multi-instance (one workload, many independent CSPs) ---------------

    def prepare_many(self, csps: Sequence[CSP]) -> PreparedMany:
        """Compile B constraint networks sharing (n, d) into one stacked
        resident form. Everything O(B·n²d²) happens here, once per workload."""
        csps = list(csps)
        if not csps:
            raise ValueError("prepare_many needs at least one CSP")
        n, d = csps[0].dom.shape
        for i, c in enumerate(csps):
            if tuple(c.dom.shape) != (n, d):
                raise ValueError(
                    f"prepare_many: instance {i} has shape {tuple(c.dom.shape)}, "
                    f"expected ({n}, {d}) — all instances must share (n_vars, dom_size)"
                )
        return PreparedMany(self, csps, self._prepare_many_payload(csps))

    def _prepare_many_payload(self, csps: List[CSP]) -> Any:
        """Generic fallback: per-instance `PreparedNetwork`s. Vmappable
        backends override this with genuinely stacked network tensors."""
        return [self.prepare(c) for c in csps]

    def enforce_many(
        self, prepared: PreparedMany, doms, changed0: Changed = None, instance_idx=None
    ) -> EnforceResult:
        """Generic fallback: route each row to its instance's prepared network
        on the host. Vmappable backends override this with ONE device dispatch
        over the stacked networks."""
        doms = np.asarray(doms)
        idx = resolve_instance_idx(instance_idx, prepared.n_instances, doms.shape[0])
        nets: List[PreparedNetwork] = prepared.payload
        return route_rows_on_host(
            lambda j, dom, ch: self.enforce(nets[j], dom, ch), doms, changed0, idx
        )

    # --- device-resident frontiers (DESIGN.md §8) ---------------------------

    def frontier_fix(self) -> Callable:
        """The fused assign+enforce core a `FrontierTable` round jits over:
        a *traceable* ``fix(networks, doms, var, val, net_idx)`` →
        `EnforceResult` applying the batched Alg. 2 assignment (``var < 0`` =
        root row, no assignment, all-changed seed) and the stacked fixpoint.
        MUST return a stable function object across calls — it keys the
        frontier step's jit cache."""
        raise NotImplementedError(
            f"{type(self).__name__} advertises device_frontier="
            f"{self.device_frontier} and does not implement frontier_fix"
        )

    def frontier_networks(self, prepared: PreparedMany) -> Any:
        """The jax pytree of stacked networks ``frontier_fix`` consumes, for a
        closed `prepare_many` workload (the open-world analogue is
        `StackedSlotPool.tables`)."""
        raise NotImplementedError

    def open_frontier(self, networks: Callable[[], Any], n_vars: int,
                      dom_size: int, capacity: int = 64,
                      check_net: Optional[Callable] = None) -> "FrontierTable":
        """A device-resident `FrontierTable` over this engine's fused frontier
        dispatch. ``networks`` is a zero-arg callable returning the live
        stacked-network pytree (re-read every round); ``check_net`` optionally
        validates each round's row→network routing (e.g. slot occupancy)."""
        return FrontierTable(n_vars, dom_size, networks, self.frontier_fix(),
                             capacity=capacity, check_net=check_net,
                             fused_fixpoint=self.fused_fixpoint)

    # --- open-world slots (continuous batching, DESIGN.md §7) ---------------

    def open_slot_pool(self, n_vars: int, dom_size: int, capacity: int) -> SlotPool:
        """A `SlotPool` of ``capacity`` resident network slots sharing one
        (n_vars, dom_size) bucket shape. Routed by the ``slot_table``
        advertisement: stacked engines get their device-resident table
        (`_open_stacked_slot_pool`), everything else the generic host-routing
        pool."""
        if self.slot_table:
            return self._open_stacked_slot_pool(n_vars, dom_size, capacity)
        return SlotPool(self, n_vars, dom_size, capacity)

    def _open_stacked_slot_pool(
        self, n_vars: int, dom_size: int, capacity: int
    ) -> StackedSlotPool:
        """Backend hook for ``slot_table = True`` engines: build the
        device-resident stacked pool (tables + encode + round dispatch)."""
        raise NotImplementedError(
            f"{type(self).__name__} advertises slot_table=True but does not "
            "implement _open_stacked_slot_pool"
        )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<{type(self).__name__} name={self.name!r}>"
