"""The Engine protocol — prepare-once, enforce-many arc consistency (DESIGN.md §3).

Every enforcement backend (einsum, paper-faithful full recompute, Pallas
kernels, sharded, AC3) satisfies one small contract:

    engine.prepare(csp)            -> PreparedNetwork       (expensive, once)
    prepared.enforce(dom, ch)      -> EnforceResult         (hot path)
    prepared.enforce_batch(doms, ch) -> EnforceResult       (B domains at once)
    engine.prepare_many(csps)      -> PreparedMany          (stacked workload)
    many.enforce_many(doms, ch, idx) -> EnforceResult       (R domains, each
                                                             vs its OWN network)

``prepare`` does everything that depends only on the *constraint network*:
padding the O(n²d²) constraint tensor to kernel tiles, bitpacking, reshaping,
device placement / sharding, and constructing the (jit-cache-stable) revise
closure. The per-call path touches only O(n·d) domain data. MAC search
(`core/search.py`) calls ``prepare`` exactly once per CSP and then enforces
thousands of candidate domains against the same prepared network — previously
the kernel paths re-padded and re-bitpacked the constraint tensor on every
single enforcement.

``enforce``/``enforce_batch`` accept domains in *caller* coordinates
(n, d) / (B, n, d); engines that pad internally (the Pallas backends) pad the
domain per call and un-pad the result, so callers never see padded shapes.

Padding contract (DESIGN.md §2): padded variables are unconstrained with a
non-empty domain ({value 0}), so they never change, never violate, and never
trip the wipeout check; padded values are absent from every domain and allowed
by no constraint. The AC closure over the original (n, d) slice is unchanged.
This module is the only place that implements that contract.
"""

from __future__ import annotations

import abc
import functools
import warnings
from typing import Any, Callable, ClassVar, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .csp import CSP
from .rtac import EnforceResult

Array = jax.Array
Changed = Optional[Union[Array, np.ndarray]]


# ---------------------------------------------------------------------------
# Padding contract — the ONE implementation (kernels and engines import these)
# ---------------------------------------------------------------------------


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def padded_shape(n: int, d: int, n_block: int, d_mult: int):
    """The kernel-tile shape `pad_network` pads (n, d) to. The ONE place the
    formula lives — engines that size slot tables without a CSP in hand
    (`_open_stacked_slot_pool`) must agree with `pad_network` by construction,
    not by convention."""
    return round_up(max(n, n_block), n_block), round_up(d, d_mult)


def pad_network(csp: CSP, n_block: int, d_mult: int):
    """Pad the *network* (cons, mask) to kernel tiles.

    Returns (cons, mask, n_p, d_p). Padded pairs are unconstrained
    (mask False, cons zero blocks) so they never produce a violation.
    """
    n, d = csp.dom.shape
    n_p, d_p = padded_shape(n, d, n_block, d_mult)
    cons = jnp.pad(csp.cons, ((0, n_p - n), (0, n_p - n), (0, d_p - d), (0, d_p - d)))
    mask = jnp.pad(csp.mask, ((0, n_p - n), (0, n_p - n)))
    return cons, mask, n_p, d_p


def pad_dom(dom: Array, n_p: int, d_p: int) -> Array:
    """Pad a domain tensor (..., n, d) -> (..., n_p, d_p).

    Padded variables get the singleton domain {0} (never empty → never trips
    the wipeout check); padded values are False everywhere.
    """
    *batch, n, d = dom.shape
    dom = jnp.pad(dom, [(0, 0)] * len(batch) + [(0, 0), (0, d_p - d)])
    pad_rows = jnp.zeros((*batch, n_p - n, d_p), jnp.bool_).at[..., :, 0].set(True)
    return jnp.concatenate([dom, pad_rows], axis=-2)


def pad_changed(changed0: Changed, n: int, n_p: int, batch: tuple = ()) -> Array:
    """Normalize+pad a changed seed (..., n) -> (..., n_p); None = all-changed.
    Padded variables are never marked changed (their domains never shrink)."""
    if changed0 is None:
        changed0 = jnp.ones((*batch, n), jnp.bool_)
    changed0 = jnp.asarray(changed0, dtype=jnp.bool_)
    return jnp.pad(changed0, [(0, 0)] * len(batch) + [(0, n_p - n)])


def as_changed(changed0: Changed) -> Optional[Array]:
    """Normalize a caller-supplied changed seed to a jax bool array (or None)."""
    if changed0 is None:
        return None
    return jnp.asarray(changed0, dtype=jnp.bool_)


# ---------------------------------------------------------------------------
# PreparedNetwork + Engine
# ---------------------------------------------------------------------------


class PreparedNetwork:
    """A CSP's constraint network compiled into one backend's resident form.

    Holds the engine that built it, the source CSP (for shapes and the root
    domain), and an opaque ``payload`` owned by the backend (padded/bitpacked
    tensors, revise closures, sharded jitted functions, host-side adjacency —
    whatever the backend's hot path needs so it never touches the raw CSP
    again).
    """

    __slots__ = ("engine", "csp", "payload")

    def __init__(self, engine: "Engine", csp: CSP, payload: Any):
        self.engine = engine
        self.csp = csp
        self.payload = payload

    @property
    def n_vars(self) -> int:
        return self.csp.dom.shape[0]

    @property
    def dom_size(self) -> int:
        return self.csp.dom.shape[1]

    def enforce(self, dom=None, changed0: Changed = None) -> EnforceResult:
        """Enforce AC on one domain (n, d); ``dom=None`` uses the CSP's root
        domain. ``changed0`` seeds the revision set (None = all variables)."""
        if dom is None:
            dom = self.csp.dom
        return self.engine.enforce(self, dom, changed0)

    def enforce_batch(self, doms, changed0: Changed = None) -> EnforceResult:
        """Enforce AC on B domains (B, n, d) in one dispatch; result fields
        carry a leading batch axis."""
        return self.engine.enforce_batch(self, doms, changed0)


class PreparedMany:
    """B constraint networks sharing (n, d), compiled into one backend's
    *stacked* resident form (DESIGN.md §6).

    Where `PreparedNetwork` amortizes preparation across the many enforcements
    of ONE search, `PreparedMany` amortizes the device across MANY independent
    instances: ``enforce_many`` resolves R domains, each against its own
    network, in one dispatch on backends that support it. ``payload`` is
    backend-owned — stacked tensors for the vmapped engines, a plain list of
    per-instance `PreparedNetwork`s for the generic fallback.
    """

    __slots__ = ("engine", "csps", "payload")

    def __init__(self, engine: "Engine", csps: Sequence[CSP], payload: Any):
        self.engine = engine
        self.csps = list(csps)
        self.payload = payload

    @property
    def n_instances(self) -> int:
        return len(self.csps)

    @property
    def n_vars(self) -> int:
        return self.csps[0].dom.shape[0]

    @property
    def dom_size(self) -> int:
        return self.csps[0].dom.shape[1]

    def enforce_many(
        self, doms, changed0: Changed = None, instance_idx=None
    ) -> EnforceResult:
        """Enforce AC on R domains (R, n, d), row i against the network of
        instance ``instance_idx[i]`` (default: ``arange(B)``, requiring R == B).
        Result fields carry the leading R axis."""
        return self.engine.enforce_many(self, doms, changed0, instance_idx)


def route_rows_on_host(enforce_row, doms, changed0: Changed, idx) -> EnforceResult:
    """The generic host-routing dispatch shared by `Engine.enforce_many` and
    `SlotPool.enforce_rows`: row i goes through ``enforce_row(idx[i], dom_i,
    changed_i)`` and the per-row results are stacked into one EnforceResult."""
    results = [
        enforce_row(int(j), doms[i], None if changed0 is None else changed0[i])
        for i, j in enumerate(idx)
    ]
    return EnforceResult(
        dom=np.stack([np.asarray(r.dom) for r in results]),
        consistent=np.asarray([bool(r.consistent) for r in results]),
        n_recurrences=np.asarray([int(r.n_recurrences) for r in results]),
    )


class SlotPool:
    """An *open-world* `PreparedMany`: a fixed-capacity table of resident
    network slots that searches join and leave mid-flight (DESIGN.md §7).

    Where `PreparedMany` stacks a closed batch of networks once, a `SlotPool`
    is the continuous-batching substrate of `repro.service`: ``install``
    compiles one network into a slot (the only O(n²d²) step, paid once per
    distinct network), ``enforce_rows`` resolves R domains — row i against
    slot ``slot_idx[i]`` — and ``release`` frees a slot for reuse when its
    last in-flight search retires. All slots share one (n_vars, dom_size)
    bucket shape, so every round reuses the same jitted program.

    This generic implementation keeps one `PreparedNetwork` per slot and
    routes rows on the host (works for every engine, including AC3). Engines
    that advertise ``slot_table = True`` get a device-resident `StackedSlotPool`
    instead — stacked tables, donated slot installs, one gather+fixpoint
    dispatch per round (`repro.engines.einsum`, `repro.engines.pallas`).
    """

    stacked: ClassVar[bool] = False

    def __init__(self, engine: "Engine", n_vars: int, dom_size: int, capacity: int):
        if capacity < 1:
            raise ValueError("SlotPool needs capacity >= 1")
        self.engine = engine
        self.n_vars = n_vars
        self.dom_size = dom_size
        self._nets: List[Optional[PreparedNetwork]] = [None] * capacity

    @property
    def capacity(self) -> int:
        return len(self._nets)

    def _check(self, slot: int, installing: bool) -> None:
        if not 0 <= slot < self.capacity:
            raise ValueError(f"slot {slot} out of range [0, {self.capacity})")
        if installing and self._nets[slot] is not None:
            raise ValueError(f"slot {slot} already installed; release it first")

    def install(self, slot: int, csp: CSP) -> None:
        """Compile ``csp``'s network into ``slot`` (must match the pool shape)."""
        self._check(slot, installing=True)
        if tuple(csp.dom.shape) != (self.n_vars, self.dom_size):
            raise ValueError(
                f"install: csp shape {tuple(csp.dom.shape)} != pool bucket "
                f"({self.n_vars}, {self.dom_size})"
            )
        self._nets[slot] = self._prepare_slot(slot, csp)

    def _prepare_slot(self, slot: int, csp: CSP):
        """Backend hook: build the slot's resident form. The generic pool keeps
        a `PreparedNetwork`; stacked pools write device tensors and return a
        truthy sentinel."""
        return self.engine.prepare(csp)

    def release(self, slot: int) -> None:
        """Free a slot (its network may be overwritten by a later install)."""
        self._check(slot, installing=False)
        self._nets[slot] = None

    def grow(self, capacity: int) -> None:
        """Enlarge the table (amortized doubling in the service layer)."""
        if capacity < self.capacity:
            raise ValueError("SlotPool.grow cannot shrink")
        self._nets.extend([None] * (capacity - self.capacity))

    def enforce_rows(self, doms, changed0: Changed = None, slot_idx=None):
        """Enforce R domains (R, n, d), row i against slot ``slot_idx[i]``."""
        doms = np.asarray(doms)
        idx = resolve_instance_idx(slot_idx, self.capacity, doms.shape[0])

        def enforce_row(j, dom, ch):
            net = self._nets[j]
            if net is None:
                raise ValueError(f"enforce_rows: slot {j} is empty")
            return net.enforce(dom, ch)

        return route_rows_on_host(enforce_row, doms, changed0, idx)

    @property
    def resident_nbytes(self) -> int:
        """Device bytes this pool's resident networks occupy, in the engine's
        OWN representation (`Engine.network_nbytes`) — packed words for the
        bitpacked backend, not logical cons bytes."""
        occupied = sum(net is not None for net in self._nets)
        return occupied * self.engine.network_nbytes(self.n_vars, self.dom_size)


@functools.partial(jax.jit, donate_argnums=(0,))
def _slot_write(table, slot, value):
    """In-place-ish slot update: with buffer donation XLA updates the resident
    table without a copy (TPU/GPU; CPU falls back to a copy and warns once)."""
    return table.at[slot].set(value)


class StackedSlotPool(SlotPool):
    """A device-resident `SlotPool`: the networks live in *stacked* device
    tensors (a pytree of ``(C, ...)`` tables), installs write one slot row via
    a donated ``.at[slot].set``, and ``enforce_rows`` is ONE dispatch that
    gathers each row's network from the tables — the open-world analogue of
    `PreparedMany`'s stacked dispatch (DESIGN.md §7).

    The backend supplies its representation as three pieces:

    - ``tables``: the initial (zeroed) slot tables — ``(C, n, n, d, d)`` bool
      cons for the einsum engines, ``(C, n_p·d_p, n_p·W)`` packed uint32 words
      for `pallas_packed`;
    - ``encode(csp)``: one network compiled into a matching pytree of slot
      rows (the only O(n²d²) step, paid once per install);
    - ``dispatch(tables, doms, changed0, idx)``: the jitted gather + fixpoint
      over the whole round.
    """

    stacked: ClassVar[bool] = True

    def __init__(
        self,
        engine: "Engine",
        n_vars: int,
        dom_size: int,
        capacity: int,
        tables,
        encode: Callable[[CSP], Any],
        dispatch,
    ):
        super().__init__(engine, n_vars, dom_size, capacity)
        self._tables = tables
        self._encode = encode
        self._dispatch = dispatch

    def _prepare_slot(self, slot: int, csp: CSP):
        row = self._encode(csp)
        s = jnp.int32(slot)
        with warnings.catch_warnings():
            # CPU backends can't honour donation; the copy fallback is correct.
            warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
            self._tables = jax.tree_util.tree_map(
                lambda t, v: _slot_write(t, s, jnp.asarray(v)), self._tables, row
            )
        return True  # occupancy sentinel; the network lives in the tables

    def grow(self, capacity: int) -> None:
        old = self.capacity
        super().grow(capacity)
        if capacity > old:
            self._tables = jax.tree_util.tree_map(
                lambda t: jnp.pad(
                    t, [(0, capacity - old)] + [(0, 0)] * (t.ndim - 1)
                ),
                self._tables,
            )

    def enforce_rows(self, doms, changed0: Changed = None, slot_idx=None):
        idx = resolve_instance_idx(slot_idx, self.capacity, np.shape(doms)[0])
        for j in np.unique(idx):
            if self._nets[int(j)] is None:
                raise ValueError(f"enforce_rows: slot {int(j)} is empty")
        return self._dispatch(self._tables, doms, changed0, idx)

    @property
    def resident_nbytes(self) -> int:
        """The actual footprint of the resident slot tables (all slots — the
        table is allocated whole, occupied or not)."""
        return sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree_util.tree_leaves(self._tables)
        )


def resolve_instance_idx(instance_idx, n_instances: int, n_rows: int) -> np.ndarray:
    """Normalize/validate the row→instance map of ``enforce_many``."""
    if instance_idx is None:
        if n_rows != n_instances:
            raise ValueError(
                f"enforce_many got {n_rows} domains for {n_instances} instances; "
                "pass instance_idx to map rows to instances"
            )
        return np.arange(n_instances, dtype=np.int32)
    idx = np.asarray(instance_idx, dtype=np.int32)
    if idx.shape != (n_rows,):
        raise ValueError(f"instance_idx shape {idx.shape} != ({n_rows},)")
    if idx.size and (idx.min() < 0 or idx.max() >= n_instances):
        raise ValueError(f"instance_idx out of range [0, {n_instances})")
    return idx


class Engine(abc.ABC):
    """One enforcement backend. Register concrete engines in `repro.engines`."""

    #: registry key (and the string accepted by ``mac_solve(engine=...)``)
    name: ClassVar[str]
    #: unit of ``EnforceResult.n_recurrences`` — "recurrences" for the tensor
    #: fixpoint backends (Table 1 #Recurrence), "revisions" for AC3
    #: (Table 1 #Revision). `SearchStats` files counts accordingly.
    count_unit: ClassVar[str] = "recurrences"
    #: whether ``enforce_batch`` is genuinely one parallel dispatch. Sequential
    #: host engines (AC3) set this False so MAC search enforces children
    #: lazily one at a time — eager batching would do strictly more work there
    #: and skew the per-assignment statistics.
    supports_batch: ClassVar[bool] = True
    #: whether ``enforce_many`` is one stacked device dispatch (jit-shaped on
    #: the row count, so callers benefit from padding rounds to reused shapes).
    #: False = the generic host-routing fallback, where padded rows would be
    #: real enforcement work thrown away.
    stacked_many: ClassVar[bool] = False
    #: whether ``open_slot_pool`` is backed by a device-resident stacked slot
    #: table (one gather+fixpoint dispatch per round). The service keys its
    #: per-bucket wiring (round padding, occupancy accounting) off this
    #: advertisement — engines declare the capability, callers never hardcode
    #: backend names. True requires ``_open_stacked_slot_pool``.
    slot_table: ClassVar[bool] = False

    def network_nbytes(self, n_vars: int, dom_size: int) -> int:
        """Resident device bytes of ONE prepared network of caller shape
        (n_vars, dom_size) in THIS engine's representation — the unit the
        service's cache budget counts. The generic answer is the logical bool
        network (cons n²d² + mask n², one byte per element); engines with a
        padded or packed resident form (the Pallas backends) override with
        their true footprint, e.g. packed u32 words at 8× fewer bytes."""
        return n_vars * n_vars * dom_size * dom_size + n_vars * n_vars

    def prepare(self, csp: CSP) -> PreparedNetwork:
        """Compile the constraint network into this backend's resident form.
        Called once per CSP; everything O(n²d²) happens here."""
        return PreparedNetwork(self, csp, self._prepare_payload(csp))

    @abc.abstractmethod
    def _prepare_payload(self, csp: CSP) -> Any:
        ...

    @abc.abstractmethod
    def enforce(self, prepared: PreparedNetwork, dom, changed0: Changed = None) -> EnforceResult:
        ...

    def enforce_batch(self, prepared: PreparedNetwork, doms, changed0: Changed = None) -> EnforceResult:
        """Generic fallback: loop on the host and stack. Device backends
        override this with a single vmapped/sharded dispatch."""
        results = [
            self.enforce(prepared, doms[i], None if changed0 is None else changed0[i])
            for i in range(len(doms))
        ]
        return EnforceResult(
            dom=np.stack([np.asarray(r.dom) for r in results]),
            consistent=np.asarray([bool(r.consistent) for r in results]),
            n_recurrences=np.asarray([int(r.n_recurrences) for r in results]),
        )

    # --- multi-instance (one workload, many independent CSPs) ---------------

    def prepare_many(self, csps: Sequence[CSP]) -> PreparedMany:
        """Compile B constraint networks sharing (n, d) into one stacked
        resident form. Everything O(B·n²d²) happens here, once per workload."""
        csps = list(csps)
        if not csps:
            raise ValueError("prepare_many needs at least one CSP")
        n, d = csps[0].dom.shape
        for i, c in enumerate(csps):
            if tuple(c.dom.shape) != (n, d):
                raise ValueError(
                    f"prepare_many: instance {i} has shape {tuple(c.dom.shape)}, "
                    f"expected ({n}, {d}) — all instances must share (n_vars, dom_size)"
                )
        return PreparedMany(self, csps, self._prepare_many_payload(csps))

    def _prepare_many_payload(self, csps: List[CSP]) -> Any:
        """Generic fallback: per-instance `PreparedNetwork`s. Vmappable
        backends override this with genuinely stacked network tensors."""
        return [self.prepare(c) for c in csps]

    def enforce_many(
        self, prepared: PreparedMany, doms, changed0: Changed = None, instance_idx=None
    ) -> EnforceResult:
        """Generic fallback: route each row to its instance's prepared network
        on the host. Vmappable backends override this with ONE device dispatch
        over the stacked networks."""
        doms = np.asarray(doms)
        idx = resolve_instance_idx(instance_idx, prepared.n_instances, doms.shape[0])
        nets: List[PreparedNetwork] = prepared.payload
        return route_rows_on_host(
            lambda j, dom, ch: self.enforce(nets[j], dom, ch), doms, changed0, idx
        )

    # --- open-world slots (continuous batching, DESIGN.md §7) ---------------

    def open_slot_pool(self, n_vars: int, dom_size: int, capacity: int) -> SlotPool:
        """A `SlotPool` of ``capacity`` resident network slots sharing one
        (n_vars, dom_size) bucket shape. Routed by the ``slot_table``
        advertisement: stacked engines get their device-resident table
        (`_open_stacked_slot_pool`), everything else the generic host-routing
        pool."""
        if self.slot_table:
            return self._open_stacked_slot_pool(n_vars, dom_size, capacity)
        return SlotPool(self, n_vars, dom_size, capacity)

    def _open_stacked_slot_pool(
        self, n_vars: int, dom_size: int, capacity: int
    ) -> StackedSlotPool:
        """Backend hook for ``slot_table = True`` engines: build the
        device-resident stacked pool (tables + encode + round dispatch)."""
        raise NotImplementedError(
            f"{type(self).__name__} advertises slot_table=True but does not "
            "implement _open_stacked_slot_pool"
        )

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<{type(self).__name__} name={self.name!r}>"
