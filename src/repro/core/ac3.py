"""AC3 — the sequential propagation baseline the paper compares against (§5.1).

Queue-based arc revision (Mackworth 1977), implemented with numpy row ops (the
paper used "Python + JIT"; vectorizing each `revise` over the domain is the
comparable treatment). Counts `#Revision` — the number of `revise` calls — which
is the quantity reported in paper Table 1.
"""

from __future__ import annotations

from collections import deque
from typing import NamedTuple, Optional

import numpy as np


class AC3Result(NamedTuple):
    dom: np.ndarray
    consistent: bool
    n_revisions: int


def build_neighbours(mask: np.ndarray) -> list:
    """Adjacency lists — the host-side 'prepared network' for AC3."""
    return [np.nonzero(mask[x])[0] for x in range(mask.shape[0])]


def enforce_ac3(
    cons: np.ndarray,  # (n, n, d, d) bool
    mask: np.ndarray,  # (n, n) bool
    dom: np.ndarray,  # (n, d) bool
    changed0: Optional[np.ndarray] = None,  # (n,) bool — seed vars (None = all)
    neighbours: Optional[list] = None,  # precomputed build_neighbours(mask)
) -> AC3Result:
    n = dom.shape[0]
    dom = dom.copy()
    if neighbours is None:
        neighbours = build_neighbours(mask)

    # Arc queue: (x, y) means "revise dom(x) against c_xy".
    queue: deque = deque()
    in_queue = np.zeros((n, n), dtype=bool)

    def push(x: int, y: int) -> None:
        if not in_queue[x, y]:
            in_queue[x, y] = True
            queue.append((x, y))

    # Seed: every arc pointing at a changed variable (all arcs for a fresh net).
    seeds = range(n) if changed0 is None else np.nonzero(changed0)[0]
    for y in seeds:
        for x in neighbours[y]:
            push(int(x), int(y))

    n_revisions = 0
    while queue:
        x, y = queue.popleft()
        in_queue[x, y] = False
        n_revisions += 1
        # revise: keep a in dom(x) iff some b in dom(y) with cons[x,y,a,b]
        supported = (cons[x, y] & dom[y][None, :]).any(axis=1)  # (d,)
        new_row = dom[x] & supported
        if new_row.sum() == 0:
            return AC3Result(dom, False, n_revisions)
        if (new_row != dom[x]).any():
            dom[x] = new_row
            for z in neighbours[x]:
                if z != y:
                    push(int(z), x)
    return AC3Result(dom, True, n_revisions)


def assign_np(dom: np.ndarray, var_idx: int, val_idx: int) -> np.ndarray:
    out = dom.copy()
    out[var_idx] = False
    out[var_idx, val_idx] = True
    return out
