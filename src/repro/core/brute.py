"""Independent brute-force oracles for tests.

``ac_closure_brute`` applies the *definition* of arc consistency directly with
plain Python loops (AC1-style sweep to fixpoint) — deliberately naive and
structurally unlike both RTAC and AC3, so agreement is meaningful.

``solve_brute`` enumerates complete assignments for end-to-end search tests.
"""

from __future__ import annotations

from itertools import product
from typing import List, Optional, Tuple

import numpy as np


def ac_closure_brute(
    cons: np.ndarray, mask: np.ndarray, dom: np.ndarray
) -> Tuple[np.ndarray, bool]:
    n, d = dom.shape
    dom = dom.copy()
    changed = True
    while changed:
        changed = False
        for x in range(n):
            for a in range(d):
                if not dom[x, a]:
                    continue
                for y in range(n):
                    if not mask[x, y]:
                        continue
                    has = False
                    for b in range(d):
                        if dom[y, b] and cons[x, y, a, b]:
                            has = True
                            break
                    if not has:
                        dom[x, a] = False
                        changed = True
                        break
    consistent = bool((dom.sum(axis=1) > 0).all())
    return dom, consistent


def solve_brute(
    cons: np.ndarray, mask: np.ndarray, dom: np.ndarray
) -> Optional[List[int]]:
    """First solution by exhaustive enumeration (tiny instances only)."""
    n, d = dom.shape
    choices = [list(np.nonzero(dom[x])[0]) for x in range(n)]
    for cand in product(*choices):
        ok = True
        for x in range(n):
            for y in range(x + 1, n):
                if mask[x, y] and not cons[x, y, cand[x], cand[y]]:
                    ok = False
                    break
            if not ok:
                break
        if ok:
            return list(cand)
    return None


def count_solutions(cons: np.ndarray, mask: np.ndarray, dom: np.ndarray) -> int:
    n, d = dom.shape
    choices = [list(np.nonzero(dom[x])[0]) for x in range(n)]
    count = 0
    for cand in product(*choices):
        ok = True
        for x in range(n):
            for y in range(x + 1, n):
                if mask[x, y] and not cons[x, y, cand[x], cand[y]]:
                    ok = False
                    break
            if not ok:
                break
        if ok:
            count += 1
    return count
