"""MAC backtrack search (paper Alg. 2) over any registered enforcement Engine.

``mac_solve`` prepares the constraint network ONCE (`Engine.prepare`) and then
maintains arc consistency after every assignment against the resident prepared
network, recording per-assignment statistics — exactly the quantities of paper
Table 1 (#Recurrence for the tensor engines / #Revision for AC3, averaged over
assignments, kept in separate fields) and Fig. 3 (time per assignment).

Beyond the paper, two batching axes (DESIGN.md §6) and a residency axis (§8):

- **Frontier batching** (within one search): all candidate values of the
  branching variable are enforced in one ``enforce_batch`` dispatch — one
  device round-trip per search *node* instead of per *child*. Pass
  ``batched_children=False`` for the classical one-child-at-a-time schedule.
  Engines with ``supports_batch=False`` (the sequential AC3 baseline, where
  eager batching is pure extra work) always use the classical schedule.
- **Instance batching** (across searches): ``solve_many`` runs B independent
  CSPs sharing (n, d) to completion. On batch-capable engines the searches
  advance in *lockstep*: each round resolves every active search's pending
  enforcement frontier in ONE dispatch, so a whole workload shares each device
  round-trip. Every search still takes exactly the decisions it would take
  alone — solutions and per-instance statistics are identical to sequential
  ``mac_solve`` (only wall-clock attribution differs).
- **Device residency** (DESIGN.md §8): on ``Engine.device_frontier`` backends
  the domains themselves never leave the device. The search coroutine speaks
  *row handles + decisions* — it never sees a domain tensor — and the lockstep
  round is one fused gather→assign→enforce→MRV dispatch against a
  `core.engine.FrontierTable`, shipping only O(R·d) metadata to the host
  (consistency bits, recurrence counts, the branching decision and its d-bit
  value row — domain sizes and assignment masks stay device-resident). Full
  domains cross the boundary exactly twice per search: the root upload at
  admission and the closure fetch at solution extraction. Engines without the
  capability (AC3, sharded) get `HostFrontierStore` — the same protocol with
  numpy-resident closures, bit-identical by construction.

The search logic itself is written once, as a coroutine that *yields*
enforcement requests and receives decision replies. `LockstepDriver`
multiplexes any number of coroutines over one `FrontierStore` in an **open
world**: searches are admitted between rounds (their root request simply rides
the next dispatch) and finished searches free their rows mid-flight — the
substrate of both the closed-batch ``solve_many`` portfolio and the
continuous-batching `repro.service.SolverService` (DESIGN.md §7). Rounds are
*pipelined*: ``round()`` launches the next dispatch asynchronously (JAX async
dispatch) and resolves it on the following call, so enforcement runs on device
while the host admits work, retires requests, and drives other buckets.
``engine`` accepts an `Engine` instance or a registry name
(`repro.engines.available_engines()`).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import time
import warnings
from typing import (
    Any,
    Dict,
    Generator,
    List,
    NamedTuple,
    Optional,
    Sequence,
    Tuple,
    Union,
)

import numpy as np

from repro import faults, obs

from .ac3 import assign_np
from .csp import CSP
from .engine import (
    Engine,
    FrontierRow,
    FrontierTable,
    RoundMeta,
    frontier_capacity,
    next_pow2 as _next_pow2,
    pad_round_rows,
)
from .rtac import EnforceResult


@dataclasses.dataclass
class SearchStats:
    n_assignments: int = 0
    n_backtracks: int = 0
    # Per-enforcement work counters, SEPARATED by unit (Table 1 honesty):
    # tensor-engine fixpoint recurrence counts vs AC3 revise-call counts.
    recurrences: List[int] = dataclasses.field(default_factory=list)
    revisions: List[int] = dataclasses.field(default_factory=list)
    enforce_seconds: List[float] = dataclasses.field(default_factory=list)
    #: kernel launches billed to this search's enforcement rounds (a fused
    #: in-kernel fixpoint bills 1 per round; the stepped path bills the
    #: round's max recurrence depth). Host engines leave it 0.
    launches: int = 0
    #: True iff the search stopped on its ``max_assignments`` budget — a
    #: (None, stats) result with ``exhausted=True`` is *inconclusive*, NOT a
    #: proof of unsatisfiability.
    exhausted: bool = False
    #: lockstep rounds this search's rows rode (1 dispatch each in
    #: ``mac_solve``; shared dispatches under `LockstepDriver`) — the
    #: per-instance rounds-to-solution the `solve_many` telemetry histograms.
    rounds: int = 0
    #: frontier rows dispatched on this search's behalf (== requests enforced
    #: solo; the group total under speculation — the service's
    #: ``rows_per_request`` metric).
    rows: int = 0
    #: speculative members this request occupied (owner + split siblings +
    #: portfolio racers, DESIGN.md §9). 1 = no speculation; the stats object
    #: is SHARED across a group, so every counter above is the group total.
    members: int = 1
    #: members cancelled when the group resolved (first SAT wins / UNSAT
    #: needs the whole cover) — speculative work thrown away.
    cancelled_members: int = 0
    #: non-None iff the round watchdog evicted this search mid-flight; the
    #: string names the breached bound. A quarantined ``(None, stats)`` result
    #: is a FAILURE verdict, never a proof of unsatisfiability — consumers
    #: must check this BEFORE reading ``None`` as UNSAT.
    quarantined: Optional[str] = None

    @property
    def mean_recurrences(self) -> float:
        return float(np.mean(self.recurrences)) if self.recurrences else 0.0

    @property
    def mean_revisions(self) -> float:
        return float(np.mean(self.revisions)) if self.revisions else 0.0

    @property
    def mean_enforce_ms(self) -> float:
        return 1e3 * float(np.mean(self.enforce_seconds)) if self.enforce_seconds else 0.0


class BudgetExceeded(Exception):
    pass


def _select_var(dom_np: np.ndarray, assigned: np.ndarray) -> int:
    """Minimum-remaining-values heuristic (paper leaves `heuristics()` open).
    The device frontier computes exactly this (first argmin over unassigned
    domain sizes) in `core.engine._frontier_step` — same ints, same ties."""
    sizes = dom_np.sum(axis=1).astype(np.int64)
    sizes[assigned] = np.iinfo(np.int64).max
    return int(np.argmin(sizes))


def _select_var_anti(dom_np: np.ndarray, assigned: np.ndarray) -> int:
    """Anti-MRV (largest remaining domain first) — a deliberately contrarian
    portfolio heuristic (DESIGN.md §9). The device frontier's ``want_alt``
    metadata computes exactly this (first argmax, assigned → -1 sentinel)."""
    sizes = dom_np.sum(axis=1).astype(np.int64)
    sizes[assigned] = -1
    return int(np.argmax(sizes))


class PortfolioSpec(NamedTuple):
    """One portfolio racer's decision policy: the branching-variable heuristic
    (``"mrv"`` | ``"anti"``) and the value ordering (``"lex"`` — the oracle's
    native order, ``"flip"`` — reversed, ``"shuffle"`` — seeded random)."""

    heuristic: str = "mrv"
    value_order: str = "lex"
    seed: int = 0


#: the diversity cycle `default_portfolio` deals racers from — maximally
#: different from the owner's (mrv, lex) policy first
_PORTFOLIO_CYCLE = (
    PortfolioSpec("mrv", "flip"),
    PortfolioSpec("anti", "lex"),
    PortfolioSpec("anti", "flip"),
    PortfolioSpec("mrv", "shuffle"),
    PortfolioSpec("anti", "shuffle"),
)


def default_portfolio(k: int, seed: int = 0) -> List[PortfolioSpec]:
    """``k`` racer policies, cycling the diversity deck with distinct seeds."""
    return [
        _PORTFOLIO_CYCLE[i % len(_PORTFOLIO_CYCLE)]._replace(seed=seed + i)
        for i in range(max(0, k))
    ]


def _value_order_fn(order: str, seed: int = 0):
    """The values-tuple transform of a `PortfolioSpec` (None = native order).
    The shuffle RNG is seeded once per member — deterministic for a given
    (spec, search path), which is all verdict parity needs."""
    if order == "lex":
        return None
    if order == "flip":
        return lambda values: tuple(reversed(values))
    if order == "shuffle":
        rng = np.random.default_rng(seed)

        def shuffle(values):
            vs = list(values)
            rng.shuffle(vs)
            return tuple(vs)

        return shuffle
    raise ValueError(f"unknown value_order {order!r}")


def resolve_engine(engine: Union[Engine, str], support_fn=None) -> Engine:
    """Engine instance passthrough, or registry lookup by name.
    ``support_fn`` is honoured by the einsum-contraction engines."""
    if isinstance(engine, Engine):
        if support_fn is not None:
            warnings.warn(
                "support_fn is ignored when an Engine instance is passed",
                stacklevel=3,
            )
        return engine
    from repro.engines import get_engine

    opts = {}
    if support_fn is not None and engine in ("einsum", "full"):
        opts["support_fn"] = support_fn
    return get_engine(engine, **opts)


# ---------------------------------------------------------------------------
# The MAC search coroutine — search logic decoupled from dispatch AND data.
# The coroutine never sees a domain tensor: it yields (parent handle, var,
# values) decisions and receives handles plus the on-store MRV selection.
# ---------------------------------------------------------------------------


class _Request(NamedTuple):
    """One pending enforcement: create and enforce the children of ``parent``
    obtained by assigning ``var := v`` for each v in ``values`` (``parent is
    None`` = the root propagation; exactly one implicit row). ``assigned`` is
    the (n,) bool assignment mask the children's own MRV selection must see."""

    parent: Optional[int]
    var: int
    values: Tuple[int, ...]
    assigned: np.ndarray


class _Reply(NamedTuple):
    """Per-child decision metadata — everything dfs needs at the next level.
    ``handles[i]`` is None where the child wiped out (its row was freed);
    ``branch_var``/``values`` are the MRV decision computed ON the closure
    (ignored for inconsistent or fully-assigned children). ``alt_var``/
    ``alt_values`` are the anti-MRV decision — present only when the store
    ships it (`enable_alt`), consumed only by anti-heuristic portfolio
    members."""

    handles: List[Optional[int]]
    consistent: np.ndarray  # (b,) bool
    branch_var: np.ndarray  # (b,) int
    values: List[Optional[Tuple[int, ...]]]
    alt_var: Optional[np.ndarray] = None  # (b,) int
    alt_values: Optional[List[Optional[Tuple[int, ...]]]] = None


_MacGen = Generator[_Request, _Reply, Optional[List[int]]]


def _mac_coroutine(
    csp: CSP,
    free_fn,
    extract_fn,
    supports_batch: bool,
    batched_children: bool,
    max_assignments: Optional[int],
    stats: SearchStats,
    n_active: Optional[int] = None,
    *,
    heuristic: str = "mrv",
    value_order=None,
    root_spec: Optional[Tuple[int, int, Tuple[int, ...]]] = None,
    assigned0: Optional[np.ndarray] = None,
    split_fn=None,
) -> _MacGen:
    """Alg. 2 as a coroutine: yields `_Request`s, receives `_Reply`s, returns
    the solution (or None). The coroutine owns every search decision and the
    assignment/backtrack counters; the driver owns dispatch, padding, timing
    and work-counter recording — so one search behaves identically whether it
    is driven alone (`mac_solve`) or multiplexed with others (`solve_many`),
    against host-resident closures or a device `FrontierTable`.

    ``free_fn(handle)`` releases a node the search will never revisit (a dead
    branch); ``extract_fn(handle)`` fetches a closure as a numpy (n, d) array —
    called exactly once, at solution extraction.

    ``n_active`` marks the first ``n_active`` variables as the real problem:
    variables beyond it (bucket padding under the §2 contract — unconstrained,
    singleton domain) start out assigned, are never branched on, and are
    excluded from the returned solution, so a padded search takes bit-identical
    decisions to the unpadded one.

    Speculation hooks (DESIGN.md §9; all default off — the oracle path above
    is byte-for-byte the classical search):

    - ``heuristic``: ``"mrv"`` (the oracle) or ``"anti"`` — branch on the
      reply's anti-MRV decision instead (requires the store's alt metadata).
    - ``value_order``: optional tuple transform applied to each node's value
      list (portfolio value diversity).
    - ``root_spec=(parent, var, values)``: start as a *split sibling* — the
      first request is a child-create against the (foreign, still-resident)
      ``parent`` row instead of a root propagation; ``assigned0`` is the
      assignment mask at the split node. The sibling touches the foreign row
      exactly once, at its first yield, which the driver dispatches while the
      owner still holds the row — after that every row it reads is its own.
    - ``split_fn(handle, var, values, assigned)``: called at every node with
      >1 values; returns the values THIS coroutine keeps and queues sibling
      spawns for the rest (the driver's group budget decides how many).
    """
    dom0 = np.asarray(csp.dom)
    n, _ = dom0.shape
    n_real = n if n_active is None else n_active

    if assigned0 is not None:
        assigned = np.array(assigned0, dtype=bool)
    else:
        assigned = np.zeros((n,), dtype=bool)
        assigned[n_real:] = True

    anti = heuristic == "anti"
    if heuristic not in ("mrv", "anti"):
        raise ValueError(f"unknown heuristic {heuristic!r}")

    def decide(reply: _Reply, i: int) -> Tuple[int, Optional[Tuple[int, ...]]]:
        if anti:
            if reply.alt_var is None:
                raise RuntimeError(
                    "anti-MRV member needs a store with alt metadata "
                    "(FrontierStore.enable_alt) — the driver enables it at "
                    "group admission"
                )
            return int(reply.alt_var[i]), reply.alt_values[i]
        return int(reply.branch_var[i]), reply.values[i]

    def solution_of(handle: int) -> List[int]:
        dom_np = extract_fn(handle)
        return [int(np.argmax(dom_np[x])) for x in range(n_real)]

    def dfs(handle: int, var: int, values: Tuple[int, ...]) -> _MacGen:
        if assigned.all():
            return solution_of(handle)

        if value_order is not None and len(values) > 1:
            values = tuple(value_order(values))
        if split_fn is not None and len(values) > 1:
            values = split_fn(handle, var, values, assigned)

        child_reply: Optional[_Reply] = None
        child_mask = assigned.copy()
        child_mask[var] = True
        if batched_children and supports_batch and len(values) > 1:
            child_reply = yield _Request(handle, var, values, child_mask)

        assigned[var] = True
        try:
            for i, val in enumerate(values):
                stats.n_assignments += 1
                if max_assignments and stats.n_assignments > max_assignments:
                    raise BudgetExceeded
                if child_reply is not None:
                    child, ok = child_reply.handles[i], bool(child_reply.consistent[i])
                    cvar, cvals = decide(child_reply, i)
                else:
                    r = yield _Request(handle, var, (val,), child_mask)
                    child, ok = r.handles[0], bool(r.consistent[0])
                    cvar, cvals = decide(r, 0)
                if ok:
                    sol = yield from dfs(child, cvar, cvals)
                    if sol is not None:
                        return sol
                    free_fn(child)  # dead branch: its row is reusable now
                stats.n_backtracks += 1
            return None
        finally:
            assigned[var] = False

    if root_spec is not None:
        parent_h, var0, values0 = root_spec
        return (yield from dfs(parent_h, var0, tuple(values0)))

    # Root propagation (Alg. 2 line 3).
    reply = yield _Request(None, -1, (), assigned.copy())
    if not bool(reply.consistent[0]):
        return None
    var0, values0 = decide(reply, 0)
    return (yield from dfs(reply.handles[0], var0, values0))




# ---------------------------------------------------------------------------
# HostFrontierStore — the host-resident FrontierStore (AC3 / sharded / oracle)
# ---------------------------------------------------------------------------


class _SyncRound:
    """A resolved-at-dispatch round (host stores have nothing in flight)."""

    def __init__(self, meta: RoundMeta):
        self._meta = meta

    def resolve(self) -> RoundMeta:
        return self._meta


class HostFrontierStore:
    """Host-side frontier store — same protocol as `core.engine.FrontierTable`
    with numpy-resident closures: child domains are materialized with
    ``assign_np`` and MRV runs through `_select_var`, exactly the pre-frontier
    dispatch path. This is both the fallback for engines without
    ``device_frontier`` (AC3, sharded) and the semantic oracle the device
    table must match bit-for-bit."""

    pipelined = False

    def __init__(self, n_vars: int, dispatch_rows, pad_rounds: bool = False):
        self._n = n_vars
        self._dispatch_rows = dispatch_rows  # (doms, chs, idx) -> EnforceResult
        self._pad_rounds = pad_rounds
        self._doms: Dict[int, np.ndarray] = {}
        self._of_key: Dict[Any, set] = {}
        self._net_of: Dict[Any, int] = {}
        self._handles = itertools.count()
        self._want_alt = False

    def enable_alt(self) -> None:
        """Ship the anti-MRV decision with every subsequent round (portfolio
        heuristic diversity — mirrors `FrontierTable.enable_alt`)."""
        self._want_alt = True

    def spare_rows(self) -> int:
        """Host closures are heap-allocated — occupancy never limits
        speculation here (admission clamps by the engine hint instead)."""
        return 1 << 20

    def _new_handle(self, key) -> int:
        h = next(self._handles)
        self._of_key[key].add(h)
        return h

    def register(self, key, net: int) -> None:
        """Register a search key with its network routing but no root closure
        — how a split sibling joins: its first request is a child-create
        against the owner's still-resident node."""
        if key in self._of_key:
            raise ValueError(f"search key {key!r} already registered")
        self._of_key[key] = set()
        self._net_of[key] = int(net)

    def begin(self, key, net: int, root_dom: np.ndarray, assigned=None) -> int:
        # ``assigned`` is part of the store protocol (the device table keeps
        # the mask resident); the host store reads it off each request instead
        del assigned
        self.register(key, net)
        h = self._new_handle(key)
        self._doms[h] = np.asarray(root_dom, dtype=bool)
        return h

    def free(self, key, handle: int) -> None:
        if handle in self._of_key.get(key, ()):
            self._of_key[key].discard(handle)
            self._doms.pop(handle, None)

    def release(self, key) -> None:
        for h in self._of_key.pop(key, ()):
            self._doms.pop(h, None)
        self._net_of.pop(key, None)

    def extract(self, key, handle: int) -> np.ndarray:
        return self._doms[handle]

    def _enforce_rows(self, doms, chs, idx, roots) -> EnforceResult:
        r = doms.shape[0]
        r_p = _next_pow2(r) if self._pad_rounds else r
        doms, chs, idx = pad_round_rows((doms, chs, idx), r_p)
        return self._dispatch_rows(doms, chs, idx)

    def dispatch(self, specs: Sequence[FrontierRow], net_idx=None) -> _SyncRound:
        r = len(specs)
        rows, roots = [], np.zeros((r,), dtype=bool)
        chs = np.zeros((r, self._n), dtype=bool)
        for i, s in enumerate(specs):
            parent_dom = self._doms[s.parent]
            if s.var < 0:
                rows.append(parent_dom)
                chs[i] = True
                roots[i] = True
            else:
                rows.append(assign_np(parent_dom, s.var, s.val))
                chs[i, s.var] = True
        doms = np.stack(rows)
        if net_idx is None:
            net_idx = np.fromiter((self._net_of[s.key] for s in specs), np.int32, r)
        # host stores block inside the dispatch (np.asarray below), so this
        # span IS the enforcement wall-clock, fenced or not
        with obs.span("kernel.launch", cat="kernel", rows=r):
            faults.inject("kernel.launch", rows=r)
            res = self._enforce_rows(doms, chs, np.asarray(net_idx, np.int32), roots)
            obs.fence(res.dom)
        dom_out = np.asarray(res.dom)[:r]
        cons = np.atleast_1d(np.asarray(res.consistent))[:r]
        k = np.atleast_1d(np.asarray(res.n_recurrences))[:r]

        d = dom_out.shape[-1]
        handles: List[Optional[int]] = []
        bvar = np.zeros((r,), np.int32)
        vrow = np.zeros((r, d), dtype=bool)
        avar = np.zeros((r,), np.int32) if self._want_alt else None
        arow = np.zeros((r, d), dtype=bool) if self._want_alt else None
        for i, s in enumerate(specs):
            if not bool(cons[i]):
                handles.append(None)
                continue
            h = s.parent if s.var < 0 else self._new_handle(s.key)
            self._doms[h] = dom_out[i]
            handles.append(h)
            bvar[i] = _select_var(dom_out[i], s.assigned)
            vrow[i] = dom_out[i][bvar[i]]
            if avar is not None:
                avar[i] = _select_var_anti(dom_out[i], s.assigned)
                arow[i] = dom_out[i][avar[i]]
        # host stores run the stepped recurrence: one enforcement dispatch per
        # iteration of the deepest row (same launch model as the stepped
        # device frontier — `core.engine._PendingFrontierRound.resolve`)
        launches = max(1, int(k.max())) if k.size else 1
        return _SyncRound(RoundMeta(handles, cons, k, bvar, vrow, launches,
                                    avar, arow))


class _SingleSearchStore(HostFrontierStore):
    """`mac_solve`'s store over ONE `PreparedNetwork`: single rows go through
    ``enforce`` (the root keeps the engine-native ``changed0=None`` seed),
    child frontiers through ``enforce_batch`` padded up to a power of two
    (repeating the last child — enforcement is idempotent per element) so the
    jitted batched fixpoint compiles O(log d) shapes instead of one per
    frontier size — exactly the pre-frontier dispatch schedule."""

    def __init__(self, prepared):
        super().__init__(prepared.n_vars, None, pad_rounds=False)
        self._prepared = prepared

    def _enforce_rows(self, doms, chs, idx, roots) -> EnforceResult:
        b = doms.shape[0]
        if b == 1:
            res = self._prepared.enforce(doms[0], None if roots[0] else chs[0])
            return EnforceResult(
                np.asarray(res.dom)[None],
                np.atleast_1d(np.asarray(res.consistent)),
                np.atleast_1d(np.asarray(res.n_recurrences)),
            )
        doms, chs = pad_round_rows((doms, chs), _next_pow2(b))
        res = self._prepared.enforce_batch(doms, chs)
        return EnforceResult(
            np.asarray(res.dom)[:b],
            np.asarray(res.consistent)[:b],
            np.asarray(res.n_recurrences)[:b],
        )


def _drive_single(store: HostFrontierStore, root: int, gen: _MacGen,
                  counts: List[int], stats: SearchStats,
                  collect_stats: bool) -> Optional[List[int]]:
    """Run one coroutine to completion against a single-search store."""
    try:
        req = gen.send(None)  # prime: runs to the first yield
        while True:
            if req.parent is None:
                specs = [FrontierRow(0, root, -1, 0, req.assigned, 0)]
            else:
                specs = [
                    FrontierRow(0, req.parent, req.var, v, req.assigned, 0)
                    for v in req.values
                ]
            t0 = time.perf_counter()
            with obs.span("driver.round", cat="driver", rows=len(specs)):
                with obs.span("frontier.step", cat="driver"):
                    res = store.dispatch(specs).resolve()
            obs.REGISTRY.counter_add("driver.rounds")
            obs.REGISTRY.counter_add("driver.rows", len(specs))
            obs.REGISTRY.counter_add("driver.launches", res.launches)
            stats.rounds += 1
            stats.rows += len(specs)
            if collect_stats:
                stats.enforce_seconds.append(time.perf_counter() - t0)
                counts.extend(int(v) for v in res.k)
                stats.launches += res.launches
            req = gen.send(_Reply(res.handles, res.consistent, res.branch_var,
                                  _value_lists(res.handles, res.value_row)))
    except StopIteration as stop:
        return stop.value


def _value_lists(handles: Sequence[Optional[int]],
                 rows: np.ndarray) -> List[Optional[Tuple[int, ...]]]:
    """Per-row live values of a selected variable (None where the row wiped
    out) — the host side of the d-bit value rows the round shipped back."""
    return [
        tuple(int(v) for v in np.nonzero(rows[i])[0])
        if handles[i] is not None
        else None
        for i in range(len(handles))
    ]


def mac_solve(
    csp: CSP,
    engine: Union[Engine, str] = "einsum",
    support_fn=None,
    max_assignments: Optional[int] = None,
    batched_children: bool = True,
    collect_stats: bool = True,
    split_budget: int = 0,
    portfolio: int = 0,
    portfolio_seed: int = 0,
) -> Tuple[Optional[List[int]], SearchStats]:
    """Returns (solution | None, stats). Raises nothing on budget exhaustion —
    stops and returns (None, stats) with ``stats.n_assignments`` at the cap.

    With ``split_budget > 0`` or ``portfolio > 0`` the single solve becomes a
    speculative *group* (DESIGN.md §9): up to ``split_budget`` tree-split
    siblings plus ``portfolio`` heuristic-diverse racers explore concurrently
    under a shared assignment budget; the first SAT wins, UNSAT needs the
    whole cover. Both default 0 so plain ``mac_solve`` stays the bit-identical
    sequential oracle the parity suite compares everything against. Verdicts
    (SAT/UNSAT) are identical to the oracle's; a budget stop remains
    inconclusive either way."""
    eng = resolve_engine(engine, support_fn)
    prepared = eng.prepare(csp)  # the ONLY preparation in the whole run
    if split_budget or portfolio:
        store = _SingleSearchStore(prepared)
        driver = LockstepDriver(store, prepared.n_vars, count_unit=eng.count_unit)
        stats = driver.admit_group(
            0, csp,
            split_budget=split_budget,
            portfolio=portfolio,
            portfolio_seed=portfolio_seed,
            supports_batch=eng.supports_batch,
            batched_children=batched_children,
            max_assignments=max_assignments,
            collect_stats=collect_stats,
        )
        sol = None
        while driver.has_work:
            for _k, (s, _st) in driver.round().items():
                sol = s
        return sol, stats
    stats = SearchStats()
    counts = stats.recurrences if eng.count_unit == "recurrences" else stats.revisions
    store = _SingleSearchStore(prepared)
    root = store.begin(0, 0, np.asarray(csp.dom))  # host store: mask per request
    gen = _mac_coroutine(
        csp,
        functools.partial(store.free, 0),
        functools.partial(store.extract, 0),
        eng.supports_batch,
        batched_children,
        max_assignments,
        stats,
    )
    try:
        sol = _drive_single(store, root, gen, counts, stats, collect_stats)
    except BudgetExceeded:
        stats.exhausted = True
        return None, stats
    finally:
        store.release(0)
    return sol, stats


# ---------------------------------------------------------------------------
# LockstepDriver — open-world lockstep multiplexing (DESIGN.md §6/§7/§8)
# ---------------------------------------------------------------------------


class RoundInfo(NamedTuple):
    """Telemetry of one RESOLVED lockstep round. ``seconds`` spans dispatch
    launch → metadata arrival: on a pipelined store that window deliberately
    overlaps host work done between ``round()`` calls (admissions, other
    buckets' dispatches), so it is an upper bound on the round's device time,
    not a pure enforcement measurement."""

    rows: int
    searches: int
    seconds: float
    launches: int = 1


class _MemberKey(NamedTuple):
    """Store/driver key of one speculative group member: ``(group key, member
    ordinal)``. Member 0 is the owner (the cover's first tile); higher
    ordinals are split siblings and portfolio racers in admission order."""

    group: Any
    m: int


def _sort_key(k):
    """Total order over mixed solo keys and `_MemberKey`s (a solo key sorts
    as member -1 of itself, so one group's members stay adjacent)."""
    return (k.group, k.m) if isinstance(k, _MemberKey) else (k, -1)


@dataclasses.dataclass
class _Group:
    """One speculative request: the members racing on its behalf and the
    resolution state (DESIGN.md §9). The verdict contract:

    - any member returning a solution resolves the group SAT (losers are
      cancelled — their rows free immediately);
    - the ``cover`` set (owner + split siblings, including queued spawns not
      yet admitted) tiles the search tree exactly once: when every cover
      member has returned None un-exhausted, the group is proven UNSAT;
    - a ``complete`` member (portfolio racer — its own full restart of the
      tree) returning None un-exhausted proves UNSAT by itself;
    - ``stats`` is ONE object shared by every member, so ``max_assignments``
      is a group-total budget and the merged counters come for free; any
      member tripping the budget resolves the whole group exhausted
      (inconclusive), eagerly."""

    key: Any
    csp: CSP
    idx: int
    stats: SearchStats
    split_budget: int
    supports_batch: bool
    batched_children: bool
    n_active: Optional[int]
    max_assignments: Optional[int]
    collect: bool
    split_fn: Any = None
    live: set = dataclasses.field(default_factory=set)
    cover: set = dataclasses.field(default_factory=set)
    complete: set = dataclasses.field(default_factory=set)
    done: bool = False
    result: Optional[List[int]] = None
    exhausted: bool = False
    next_m: int = 0


class LockstepDriver:
    """Multiplexes MAC-search coroutines over ONE `FrontierStore`, open-world.

    Each round gathers every live search's pending request into a single
    dispatch against the store — a device-resident `core.engine.FrontierTable`
    on ``device_frontier`` engines (domains never leave the device; only
    per-row metadata crosses the host boundary), a `HostFrontierStore`
    otherwise — scatters the decision replies back, and advances each search
    to its next request. Unlike the closed batch that ``solve_many``
    historically hard-coded, membership is dynamic:

    - ``admit`` joins a new search *between* rounds — its root propagation
      simply rides the next dispatch alongside everyone else's frontiers;
    - a search that finishes (solution, exhaustion, or budget) is reported by
      the ``round()`` that retired it and frees its rows immediately — the
      batch never drains to a stragglers-only tail before new work can enter;
    - ``cancel`` evicts a search mid-flight (deadline expiry in the service).

    Rounds are **pipelined** on stores that advertise ``pipelined=True``:
    ``round()`` resolves the previous dispatch (blocking only on its small
    metadata), advances the coroutines, then launches the next dispatch
    asynchronously and returns — enforcement for round *t+1* runs on device
    while the host retires requests, admits new work, and drives other
    buckets' rounds. Synchronous stores resolve within the same call.

    The driver owns dispatch, routing, timing, and work-counter filing; every
    search still takes exactly the decisions it would take alone (solutions
    and per-instance statistics are bit-identical to sequential `mac_solve` —
    only ``enforce_seconds`` attribution differs, splitting each round's
    wall-clock across participants proportionally to their row counts; the
    per-round attributions sum exactly to the round's measured seconds).
    """

    def __init__(
        self,
        store,
        n_vars: int,
        count_unit: str = "recurrences",
        round_wall_s: Optional[float] = None,
        round_recurrences: Optional[int] = None,
    ):
        self._store = store
        self._n = n_vars
        self._count_unit = count_unit
        # round watchdog bounds (None = unbounded, the solve_many default):
        # a resolved round breaching either evicts its deepest live search
        # via `_quarantine_offender` instead of letting one pathological
        # instance stall every search sharing the lockstep
        if round_wall_s is not None and round_wall_s <= 0:
            raise ValueError("round_wall_s must be positive (or None)")
        if round_recurrences is not None and round_recurrences < 1:
            raise ValueError("round_recurrences must be >= 1 (or None)")
        self._round_wall_s = round_wall_s
        self._round_recurrences = round_recurrences
        self.watchdog_trips = 0
        self._gens: Dict[object, _MacGen] = {}
        self._pending: Dict[object, _Request] = {}
        self._idx: Dict[object, int] = {}
        self._root: Dict[object, int] = {}
        self._stats: Dict[object, SearchStats] = {}
        self._collect: Dict[object, bool] = {}
        # speculative groups (DESIGN.md §9): group key -> _Group, member key
        # -> its group, and the sibling spawns queued by split_fn between
        # rounds (admitted at the top of the next round, while the parent row
        # they reference is guaranteed still live)
        self._groups: Dict[object, _Group] = {}
        self._group_of: Dict[object, _Group] = {}
        self._spawns: List[Tuple] = []
        self._inflight = None  # (layout, pending round, t0)
        # membership-stable caches: the sorted key order is rebuilt only when
        # membership changes, the np.repeat routing array only when the
        # per-search row counts differ from the previous round
        self._order: List = []
        self._order_dirty = False
        self._route_cache: Optional[Tuple[Tuple[int, ...], np.ndarray]] = None
        #: telemetry over resolved rounds
        self.last_round: Optional[RoundInfo] = None
        self.rounds = 0
        self.rows_dispatched = 0
        self.launches = 0  # kernel-launch bill across resolved rounds
        self.round_seconds: List[float] = []

    # --- membership --------------------------------------------------------

    def admit(
        self,
        key,
        csp: CSP,
        idx: int = 0,
        *,
        supports_batch: bool = True,
        batched_children: bool = True,
        n_active: Optional[int] = None,
        max_assignments: Optional[int] = None,
        collect_stats: bool = True,
    ) -> SearchStats:
        """Join a new search; it participates from the next dispatch on.
        ``idx`` routes the search's rows to its constraint network. Returns
        the live `SearchStats` (filled in as rounds run)."""
        if key in self._gens or key in self._groups:
            raise ValueError(f"search key {key!r} already admitted")
        stats = SearchStats()
        gen = _mac_coroutine(
            csp,
            functools.partial(self._store.free, key),
            functools.partial(self._store.extract, key),
            supports_batch,
            batched_children,
            max_assignments,
            stats,
            n_active=n_active,
        )
        req0 = gen.send(None)  # root request; always yields ≥ once
        root = self._store.begin(key, idx, np.asarray(csp.dom), req0.assigned)
        self._pending[key] = req0
        self._gens[key] = gen
        self._idx[key] = int(idx)
        self._root[key] = root
        self._stats[key] = stats
        self._collect[key] = collect_stats
        self._order_dirty = True
        return stats

    def admit_group(
        self,
        key,
        csp: CSP,
        idx: int = 0,
        *,
        split_budget: int = 0,
        portfolio: int = 0,
        portfolio_seed: int = 0,
        supports_batch: bool = True,
        batched_children: bool = True,
        n_active: Optional[int] = None,
        max_assignments: Optional[int] = None,
        collect_stats: bool = True,
    ) -> SearchStats:
        """Join one request as a speculative GROUP (DESIGN.md §9): an owner
        search that may scatter up to ``split_budget`` sibling subtrees onto
        spare rows as it branches, racing ``portfolio`` heuristic-diverse full
        restarts. ``round()`` reports the group under ``key`` exactly like a
        solo search — first SAT wins (the rest are cancelled), UNSAT needs
        the whole cover, ``max_assignments`` is a group-total budget. The
        returned `SearchStats` is shared by every member, so its counters are
        the request's totals. With both knobs 0 this IS ``admit``."""
        if split_budget <= 0 and portfolio <= 0:
            return self.admit(
                key, csp, idx,
                supports_batch=supports_batch,
                batched_children=batched_children,
                n_active=n_active,
                max_assignments=max_assignments,
                collect_stats=collect_stats,
            )
        if key in self._gens or key in self._groups:
            raise ValueError(f"search key {key!r} already admitted")
        g = _Group(
            key=key, csp=csp, idx=int(idx), stats=SearchStats(),
            split_budget=int(split_budget), supports_batch=supports_batch,
            batched_children=batched_children, n_active=n_active,
            max_assignments=max_assignments, collect=collect_stats,
        )
        self._groups[key] = g

        def split_fn(handle, var, values, assigned):
            if g.done or g.split_budget <= 0 or len(values) < 2:
                return values
            s = min(g.split_budget, len(values) - 1)
            g.split_budget -= s
            keep = values[: len(values) - s]
            for v in values[len(values) - s:]:
                mkey = _MemberKey(g.key, g.next_m)
                g.next_m += 1
                # in the cover from queue time: the subtree is spoken for even
                # before its sibling is admitted, so an emptying cover can't
                # mis-declare UNSAT while spawns are still queued
                g.cover.add(mkey)
                g.stats.members += 1
                self._spawns.append((g, mkey, handle, var, (v,), assigned.copy()))
            return keep

        if split_budget > 0:
            g.split_fn = split_fn

        owner = _MemberKey(key, g.next_m)
        g.next_m += 1
        g.cover.add(owner)
        self._admit_member(g, owner, heuristic="mrv", value_order=None,
                           split_fn=g.split_fn)
        for spec in default_portfolio(portfolio, portfolio_seed):
            mkey = _MemberKey(key, g.next_m)
            g.next_m += 1
            g.complete.add(mkey)
            g.stats.members += 1
            if spec.heuristic == "anti" and hasattr(self._store, "enable_alt"):
                self._store.enable_alt()
            self._admit_member(
                g, mkey, heuristic=spec.heuristic,
                value_order=_value_order_fn(spec.value_order, spec.seed),
                split_fn=None,
            )
        return g.stats

    def _admit_member(self, g: _Group, mkey, *, heuristic, value_order,
                      split_fn) -> None:
        """Admit one full-restart group member (owner or portfolio racer):
        its own root upload, the group's shared stats and budget."""
        gen = _mac_coroutine(
            g.csp,
            functools.partial(self._store.free, mkey),
            functools.partial(self._store.extract, mkey),
            g.supports_batch,
            g.batched_children,
            g.max_assignments,
            g.stats,
            n_active=g.n_active,
            heuristic=heuristic,
            value_order=value_order,
            split_fn=split_fn,
        )
        req0 = gen.send(None)  # root request; always yields ≥ once
        root = self._store.begin(mkey, g.idx, np.asarray(g.csp.dom), req0.assigned)
        self._pending[mkey] = req0
        self._gens[mkey] = gen
        self._idx[mkey] = g.idx
        self._root[mkey] = root
        self._stats[mkey] = g.stats
        self._collect[mkey] = g.collect
        self._group_of[mkey] = g
        g.live.add(mkey)
        self._order_dirty = True

    def _admit_spawns(self, finished: Dict) -> None:
        """Materialize the sibling spawns split_fn queued during the last
        ``_advance``: each joins with `FrontierStore.register` (no root
        upload — its first request is a child-create against the owner's
        still-live parent row) and rides the next dispatch."""
        while self._spawns:
            spawns, self._spawns = self._spawns, []
            for g, mkey, parent, var, values, mask in spawns:
                if g.done:
                    continue
                gen = _mac_coroutine(
                    g.csp,
                    functools.partial(self._store.free, mkey),
                    functools.partial(self._store.extract, mkey),
                    g.supports_batch,
                    g.batched_children,
                    g.max_assignments,
                    g.stats,
                    n_active=g.n_active,
                    root_spec=(parent, var, values),
                    assigned0=mask,
                    split_fn=g.split_fn,
                )
                try:
                    req0 = gen.send(None)
                except BudgetExceeded:
                    # the group-total budget tripped while priming: the whole
                    # group is exhausted — resolve it now (also drops this
                    # batch's remaining spawns for the group)
                    g.cover.discard(mkey)
                    self._resolve_group(g, None, True, finished)
                    continue
                self._store.register(mkey, g.idx)
                self._pending[mkey] = req0
                self._gens[mkey] = gen
                self._idx[mkey] = g.idx
                self._root[mkey] = parent
                self._stats[mkey] = g.stats
                self._collect[mkey] = g.collect
                self._group_of[mkey] = g
                g.live.add(mkey)
                self._order_dirty = True

    def _finish_key(self, k, sol, exhausted: bool, finished: Dict) -> None:
        """Route one coroutine's completion: solo searches report directly;
        group members feed the group's verdict logic."""
        stats = self._retire_key(k)
        g = self._group_of.pop(k, None)
        if g is None:
            if exhausted:
                stats.exhausted = True
            finished[k] = (sol, stats)
            return
        g.live.discard(k)
        complete = k in g.complete
        g.cover.discard(k)
        g.complete.discard(k)
        if g.done:
            return  # a straggler of an already-resolved group
        if sol is not None:
            self._resolve_group(g, sol, False, finished)
        elif exhausted:
            self._resolve_group(g, None, True, finished)
        elif complete or not g.cover:
            # a full restart came back UNSAT, or the cover tiles are all
            # exhausted-free and empty — either is a proof
            self._resolve_group(g, None, False, finished)

    def _resolve_group(self, g: _Group, sol, exhausted: bool,
                       finished: Dict) -> None:
        """Settle a group's verdict: cancel the losers (rows free now), drop
        its queued spawns, report it under the group key."""
        g.done = True
        g.result, g.exhausted = sol, exhausted
        self._cancel_members(g)
        if exhausted:
            g.stats.exhausted = True
        self._groups.pop(g.key, None)
        finished[g.key] = (sol, g.stats)

    def _retire_key(self, key) -> SearchStats:
        """Drop every piece of driver state for one search key and reclaim its
        store rows (safe mid-flight: the in-flight round's results for the key
        are dropped at resolution). Returns the search's stats."""
        self._gens.pop(key).close()
        self._pending.pop(key, None)  # absent while the search is in flight
        self._idx.pop(key, None)
        self._root.pop(key, None)
        self._collect.pop(key, None)
        self._store.release(key)
        self._order_dirty = True
        return self._stats.pop(key)

    def _cancel_members(self, g: _Group) -> None:
        """Retire every live member of ``g`` and drop its queued spawns,
        billing each as a cancelled member."""
        before = g.stats.cancelled_members
        with obs.span("group.cancel", cat="driver", n=len(g.live)):
            for k in list(g.live):
                if k in self._gens:
                    self._retire_key(k)
                    self._group_of.pop(k, None)
                    g.stats.cancelled_members += 1
            g.live.clear()
            kept = [s for s in self._spawns if s[0] is not g]
            g.stats.cancelled_members += len(self._spawns) - len(kept)
            self._spawns = kept
        obs.REGISTRY.counter_add(
            "driver.cancelled_members", g.stats.cancelled_members - before
        )

    def cancel(self, key) -> SearchStats:
        """Evict a live search or a whole speculative group (e.g. deadline
        expiry); frees its rows even if they are part of an in-flight round
        (the round's results are simply dropped at resolution)."""
        g = self._groups.pop(key, None)
        if g is not None:
            g.done = True
            self._cancel_members(g)
            return g.stats
        return self._retire_key(key)

    @property
    def active_keys(self) -> List:
        return sorted(self._gens, key=_sort_key)

    def is_active(self, key) -> bool:
        return key in self._gens or key in self._groups

    @property
    def has_work(self) -> bool:
        return (
            bool(self._pending)
            or bool(self._spawns)
            or self._inflight is not None
        )

    @property
    def n_pending_rows(self) -> int:
        return sum(max(1, len(req.values)) for req in self._pending.values())

    # --- one lockstep round -------------------------------------------------

    def round(self) -> Dict[object, Tuple[Optional[List[int]], SearchStats]]:
        """Resolve the in-flight dispatch (if any), advance its searches, then
        launch the next dispatch; returns ``{key: (solution | None, stats)}``
        for the searches that finished (their rows are freed). On pipelined
        stores the launch is asynchronous — it resolves on the NEXT call."""
        self.last_round = None
        finished: Dict[object, Tuple[Optional[List[int]], SearchStats]] = {}
        with obs.span("driver.round", cat="driver"):
            if self._inflight is not None:
                layout, pend, t0 = self._inflight
                self._inflight = None
                with obs.span("round.resolve", cat="driver", rows=sum(b for _, b in layout)):
                    finished = self._advance(layout, pend, t0)
            if self._spawns:
                # admit split siblings NOW, before the next dispatch: their
                # first request reads the parent row, whose owner is still
                # paused on a yield — the row cannot be freed before this
                # round resolves
                with obs.span("group.spawn", cat="driver", n=len(self._spawns)):
                    self._admit_spawns(finished)
            if self._pending:
                with obs.span("frontier.step", cat="driver") as _sp:
                    specs, layout, net_idx = self._collect_rows()
                    if _sp is not None:
                        _sp.args["rows"] = len(specs)
                    t0 = time.perf_counter()
                    pend = self._store.dispatch(specs, net_idx)
                    if getattr(self._store, "pipelined", False):
                        self._inflight = (layout, pend, t0)
                if self._inflight is None:
                    with obs.span("round.resolve", cat="driver", rows=len(specs)):
                        finished.update(self._advance(layout, pend, t0))
        return finished

    def _collect_rows(self):
        """Flatten every pending request into row specs, in cached sorted-key
        order, with the np.repeat routing array rebuilt only when the round
        shape actually changed."""
        if self._order_dirty:
            self._order = sorted(self._pending, key=_sort_key)
            self._order_dirty = False
            self._route_cache = None
        order = self._order
        sizes = tuple(
            1 if self._pending[k].parent is None else len(self._pending[k].values)
            for k in order
        )
        if self._route_cache is not None and self._route_cache[0] == sizes:
            net_idx = self._route_cache[1]
        else:
            per_key = np.asarray([self._idx[k] for k in order], np.int32)
            net_idx = np.repeat(per_key, sizes)
            self._route_cache = (sizes, net_idx)

        specs: List[FrontierRow] = []
        layout: List[Tuple[object, int]] = []
        for k, b in zip(order, sizes):
            req = self._pending.pop(k)
            if req.parent is None:
                specs.append(
                    FrontierRow(k, self._root[k], -1, 0, req.assigned, self._idx[k])
                )
            else:
                specs.extend(
                    FrontierRow(k, req.parent, req.var, v, req.assigned, self._idx[k])
                    for v in req.values
                )
            layout.append((k, b))
        return specs, layout, net_idx

    def _quarantine_offender(self, layout, res, reason: str, finished: Dict) -> None:
        """Watchdog eviction: retire the live search whose rows did the
        deepest work this round, reporting ``(None, stats)`` with
        ``stats.quarantined`` set (rows freed mid-flight through the normal
        `_retire_key` → ``store.release`` lifetime). Group members take their
        whole speculative group down with them — the group shares one verdict."""
        offender, depth = None, -1.0
        off = 0
        for k, b in layout:
            rows_k = res.k[off:off + b]
            off += b
            if k not in self._gens:
                continue
            d = float(np.max(rows_k)) if rows_k.size else 0.0
            if d > depth:
                offender, depth = k, d
        if offender is None:
            return
        self.watchdog_trips += 1
        obs.counter_add("watchdog.trips")
        g = self._group_of.get(offender)
        if g is not None and not g.done:
            self._resolve_group(g, None, False, finished)
            g.stats.quarantined = reason
        else:
            stats = self._retire_key(offender)
            self._group_of.pop(offender, None)
            stats.quarantined = reason
            finished[offender] = (None, stats)

    def _advance(self, layout, pend, t0) -> Dict:
        """Block on a round's metadata, file stats, advance every coroutine."""
        faults.inject("round.resolve", rows=sum(b for _, b in layout))
        res = pend.resolve()
        dt = time.perf_counter() - t0
        r = sum(b for _, b in layout)
        self.rounds += 1
        self.rows_dispatched += r
        self.round_seconds.append(dt)
        self.launches += res.launches
        self.last_round = RoundInfo(r, len(layout), dt, res.launches)
        obs.REGISTRY.counter_add("driver.rounds")
        obs.REGISTRY.counter_add("driver.rows", r)
        obs.REGISTRY.counter_add("driver.launches", res.launches)
        obs.REGISTRY.counter_add("driver.recurrences", int(np.sum(res.k)))
        values = _value_lists(res.handles, res.value_row)
        alt_values = (
            _value_lists(res.handles, res.alt_row)
            if res.alt_var is not None
            else None
        )

        finished: Dict[object, Tuple[Optional[List[int]], SearchStats]] = {}
        breach = None
        if self._round_wall_s is not None and dt > self._round_wall_s:
            breach = f"round wall-clock {dt:.3f}s > {self._round_wall_s:g}s"
        elif (
            self._round_recurrences is not None
            and res.k.size
            and int(np.max(res.k)) > self._round_recurrences
        ):
            breach = (
                f"round recurrence depth {int(np.max(res.k))} > "
                f"{self._round_recurrences}"
            )
        if breach is not None:
            # evict BEFORE advancing coroutines: the offender's results for
            # this round are dropped and the `k not in self._gens` guard below
            # skips its layout slice
            self._quarantine_offender(layout, res, breach, finished)

        off = 0
        # a speculative group's members share ONE stats object: per-REQUEST
        # round quantities (rounds ridden, the round's launch bill) must be
        # filed once per stats object, not once per member
        billed = set()
        for k, b in layout:
            rows = slice(off, off + b)
            off += b
            if k not in self._gens:  # cancelled while the round was in flight
                continue
            stats = self._stats[k]
            first = id(stats) not in billed
            billed.add(id(stats))
            if first:
                stats.rounds += 1
            stats.rows += b
            if self._collect[k]:
                # attribute the round's wall-clock over its REAL rows, so the
                # per-search attributions sum exactly to the measured seconds
                stats.enforce_seconds.append(dt * b / r)
                counts = (
                    stats.recurrences
                    if self._count_unit == "recurrences"
                    else stats.revisions
                )
                counts.extend(int(v) for v in res.k[rows])
                if first:
                    stats.launches += res.launches
            reply = _Reply(
                res.handles[rows], res.consistent[rows], res.branch_var[rows],
                values[rows],
                None if res.alt_var is None else res.alt_var[rows],
                None if alt_values is None else alt_values[rows],
            )
            try:
                self._pending[k] = self._gens[k].send(reply)
            except StopIteration as stop:
                self._finish_key(k, stop.value, False, finished)
            except BudgetExceeded:
                self._finish_key(k, None, True, finished)
        return finished


# ---------------------------------------------------------------------------
# solve_many — the portfolio entry point (one workload, many CSPs)
# ---------------------------------------------------------------------------


def solve_many(
    csps: Sequence[CSP],
    engine: Union[Engine, str] = "einsum",
    support_fn=None,
    max_assignments: Optional[int] = None,
    batched_children: bool = True,
    collect_stats: bool = True,
    telemetry: Optional[dict] = None,
    split_budget: int = 0,
    portfolio: int = 0,
    portfolio_seed: int = 0,
) -> Tuple[List[Optional[List[int]]], List[SearchStats]]:
    """Run B independent MAC searches (instances sharing (n, d)) to completion.

    On ``device_frontier`` engines the searches advance in lockstep against a
    device-resident `FrontierTable` over the `Engine.prepare_many` stacked
    networks: every round is ONE fused assign+enforce+MRV dispatch and only
    per-row metadata crosses the host boundary (DESIGN.md §8). Other
    batch-capable engines run the same lockstep through the host store.
    ``max_assignments`` is a *per-instance* budget. Solutions and per-instance
    search statistics are identical to sequential ``mac_solve``;
    ``enforce_seconds`` attributes each round's wall-clock to its participants
    proportionally to their row counts.

    Sequential engines (``supports_batch=False``, i.e. AC3) degrade to one
    ``mac_solve`` per instance — same results, no amortization.

    ``telemetry``, if a dict, is filled with round/transfer counters
    (``rounds``, ``rows_dispatched``, ``round_seconds_total`` and — on the
    device frontier — ``host_bytes_per_round`` vs the counterfactual
    ``domain_bytes_per_round``), plus the PER-INSTANCE rounds-to-solution
    distribution (``rounds_per_instance`` summary + log2-binned
    ``rounds_hist``) — batch totals hid exactly the stragglers this exists
    to expose; `benchmarks/bench_many.py` records these into the
    ``frontier`` section of BENCH_engines.json.

    ``split_budget``/``portfolio`` turn each instance into a speculative
    group (DESIGN.md §9; see `mac_solve`) — verdicts still match the
    sequential oracle, per-instance stats become group totals.

    Returns (solutions, stats) as same-length lists, index-aligned with
    ``csps``.
    """
    csps = list(csps)
    eng = resolve_engine(engine, support_fn)
    if not csps:
        return [], []

    if not eng.supports_batch:
        sols, stats = [], []
        for csp in csps:
            s, st = mac_solve(
                csp,
                engine=eng,
                max_assignments=max_assignments,
                batched_children=batched_children,
                collect_stats=collect_stats,
                split_budget=split_budget,
                portfolio=portfolio,
                portfolio_seed=portfolio_seed,
            )
            sols.append(s)
            stats.append(st)
        if telemetry is not None:
            _fill_rounds_histogram(telemetry, stats)
        return sols, stats

    prepared = eng.prepare_many(csps)  # the ONLY preparation in the whole run
    # speculative members multiply the worst-case live rows per instance
    n_eff = len(csps) * (1 + max(0, split_budget) + max(0, portfolio))
    if eng.device_frontier:
        networks = eng.frontier_networks(prepared)
        store = eng.open_frontier(
            lambda: networks, prepared.n_vars, prepared.dom_size,
            # presize for the worst case a DFS can hold live (every level keeps
            # its node + unvisited siblings): growth mid-run would recompile
            # the fused step for every round shape, and rows are n·d bools —
            # cheap enough that oversizing beats recompiling
            capacity=frontier_capacity(n_eff, prepared.n_vars, prepared.dom_size),
        )
    else:
        # host store over the stacked/host-routed enforce_many dispatch; pad
        # rounds only when the dispatch is one jit-shaped stacked program
        store = HostFrontierStore(
            prepared.n_vars, prepared.enforce_many, pad_rounds=eng.stacked_many
        )
    driver = LockstepDriver(store, prepared.n_vars, count_unit=eng.count_unit)
    all_stats = [
        driver.admit_group(
            i,
            csp,
            idx=i,
            split_budget=split_budget,
            portfolio=portfolio,
            portfolio_seed=portfolio_seed + i,
            supports_batch=eng.supports_batch,
            batched_children=batched_children,
            max_assignments=max_assignments,
            collect_stats=collect_stats,
        )
        for i, csp in enumerate(csps)
    ]
    sols: List[Optional[List[int]]] = [None] * len(csps)
    while driver.has_work:
        for i, (sol, _st) in driver.round().items():
            sols[i] = sol
    # per-instance distributions into the central registry (DESIGN.md §10):
    # this is where tracker history and the obs CLI read straggler spread
    # and the launches-per-solve claim from, tracing on or off
    obs.REGISTRY.counter_add("many.solves", len(csps))
    obs.REGISTRY.observe("many.launches_per_solve", driver.launches / len(csps))
    for st in all_stats:
        obs.REGISTRY.observe("many.rounds_per_instance", st.rounds)
    if telemetry is not None:
        telemetry.update(
            engine=eng.name,
            device_frontier=bool(eng.device_frontier),
            fused_fixpoint=bool(getattr(eng, "fused_fixpoint", False)),
            rounds=driver.rounds,
            rows_dispatched=driver.rows_dispatched,
            launches=driver.launches,
            launches_per_round=driver.launches / max(driver.rounds, 1),
            round_seconds_total=float(sum(driver.round_seconds)),
        )
        _fill_rounds_histogram(telemetry, all_stats)
        if isinstance(store, FrontierTable):
            telemetry.update(
                host_bytes_per_round=store.host_bytes_per_round,
                domain_bytes_per_round=store.domain_bytes_per_round,
                rows_padded=store.rows_padded,
                root_bytes=store.root_bytes,
                extract_bytes=store.extract_bytes,
            )
    return sols, all_stats


def _fill_rounds_histogram(telemetry: dict, all_stats: Sequence[SearchStats]) -> None:
    """Per-instance rounds-to-solution distribution: summary percentiles plus
    a log2-binned histogram (bin 0 counts instances that took 0 rounds; bin
    j ≥ 1 counts 2^(j-1) ≤ rounds < 2^j). Batch totals average the stragglers
    away — this is where a 4/32-solved workload becomes visible."""
    rp = np.asarray([st.rounds for st in all_stats], dtype=np.int64)
    if rp.size == 0:
        telemetry["rounds_per_instance"] = {}
        telemetry["rounds_hist"] = []
        return
    bins = np.bincount(
        np.where(rp > 0, np.floor(np.log2(np.maximum(rp, 1))).astype(np.int64) + 1, 0)
    )
    telemetry["rounds_per_instance"] = {
        "min": int(rp.min()),
        "p50": float(np.median(rp)),
        "p90": float(np.percentile(rp, 90)),
        "max": int(rp.max()),
    }
    telemetry["rounds_hist"] = [int(c) for c in bins]


def check_solution(csp: CSP, solution: List[int]) -> bool:
    """Verify a full assignment in O(n²) numpy (no Python pair loop): one
    gather checks every value is in-domain, one gather over the upper-triangle
    constrained pairs checks every binary constraint."""
    sol = np.asarray(solution, dtype=np.int64)
    n = sol.shape[0]
    dom = np.asarray(csp.dom)
    if not dom[np.arange(n), sol].all():
        return False
    mask = np.asarray(csp.mask)[:n, :n]
    cons = np.asarray(csp.cons)
    xs, ys = np.nonzero(np.triu(mask, 1))
    return bool(cons[xs, ys, sol[xs], sol[ys]].all())
