"""MAC backtrack search (paper Alg. 2) over any registered enforcement Engine.

``mac_solve`` prepares the constraint network ONCE (`Engine.prepare`) and then
maintains arc consistency after every assignment against the resident prepared
network, recording per-assignment statistics — exactly the quantities of paper
Table 1 (#Recurrence for the tensor engines / #Revision for AC3, averaged over
assignments, kept in separate fields) and Fig. 3 (time per assignment).

Beyond the paper: the per-child loop is *frontier-batched by default* — all
candidate values of the branching variable are enforced in one
``enforce_batch`` dispatch (one device round-trip per search *node* instead of
per *child*), which the sequential paradigm cannot express. Pass
``batched_children=False`` for the classical one-child-at-a-time schedule.
Engines with ``supports_batch=False`` (the sequential AC3 baseline, where
eager batching is pure extra work) always use the classical schedule.

``engine`` accepts an `Engine` instance or a registry name
(`repro.engines.available_engines()`); the pre-Engine strings "rtac" /
"rtac_full" still resolve (with a DeprecationWarning) for one release.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import List, Optional, Union

import numpy as np

from .ac3 import assign_np
from .csp import CSP
from .engine import Engine


@dataclasses.dataclass
class SearchStats:
    n_assignments: int = 0
    n_backtracks: int = 0
    # Per-enforcement work counters, SEPARATED by unit (Table 1 honesty):
    # tensor-engine fixpoint recurrence counts vs AC3 revise-call counts.
    recurrences: List[int] = dataclasses.field(default_factory=list)
    revisions: List[int] = dataclasses.field(default_factory=list)
    enforce_seconds: List[float] = dataclasses.field(default_factory=list)

    @property
    def mean_recurrences(self) -> float:
        return float(np.mean(self.recurrences)) if self.recurrences else 0.0

    @property
    def mean_revisions(self) -> float:
        return float(np.mean(self.revisions)) if self.revisions else 0.0

    @property
    def mean_enforce_ms(self) -> float:
        return 1e3 * float(np.mean(self.enforce_seconds)) if self.enforce_seconds else 0.0


class BudgetExceeded(Exception):
    pass


def _select_var(dom_np: np.ndarray, assigned: np.ndarray) -> int:
    """Minimum-remaining-values heuristic (paper leaves `heuristics()` open)."""
    sizes = dom_np.sum(axis=1).astype(np.int64)
    sizes[assigned] = np.iinfo(np.int64).max
    return int(np.argmin(sizes))


def resolve_engine(engine: Union[Engine, str], support_fn=None) -> Engine:
    """Engine instance passthrough, or registry lookup by name (legacy names
    warn). ``support_fn`` is honoured by the einsum-contraction engines."""
    if isinstance(engine, Engine):
        if support_fn is not None:
            warnings.warn(
                "support_fn is ignored when an Engine instance is passed",
                stacklevel=3,
            )
        return engine
    from repro.engines import get_engine

    opts = {}
    if support_fn is not None and engine in ("rtac", "rtac_full", "einsum", "full"):
        opts["support_fn"] = support_fn
    return get_engine(engine, **opts)


def mac_solve(
    csp: CSP,
    engine: Union[Engine, str] = "einsum",
    support_fn=None,
    max_assignments: Optional[int] = None,
    batched_children: bool = True,
    collect_stats: bool = True,
) -> tuple[Optional[List[int]], SearchStats]:
    """Returns (solution | None, stats). Raises nothing on budget exhaustion —
    stops and returns (None, stats) with ``stats.n_assignments`` at the cap."""
    eng = resolve_engine(engine, support_fn)
    prepared = eng.prepare(csp)  # the ONLY preparation in the whole run
    stats = SearchStats()
    n, _ = csp.dom.shape
    counts = stats.recurrences if eng.count_unit == "recurrences" else stats.revisions

    def record(t0: float, ks) -> None:
        if collect_stats:
            stats.enforce_seconds.append(time.perf_counter() - t0)
            counts.extend(int(k) for k in np.atleast_1d(ks))

    def enforce_one(dom_np: np.ndarray, changed_idx: Optional[int]):
        """-> (dom' np, consistent). One domain, one dispatch."""
        ch = None
        if changed_idx is not None:
            ch = np.zeros((n,), bool)
            ch[changed_idx] = True
        t0 = time.perf_counter()
        res = prepared.enforce(dom_np, ch)
        record(t0, res.n_recurrences)
        return np.asarray(res.dom), bool(res.consistent)

    # Root propagation (Alg. 2 line 3).
    dom0, ok = enforce_one(np.asarray(csp.dom), None)
    if not ok:
        return None, stats

    assigned = np.zeros((n,), dtype=bool)

    def dfs(dom_np: np.ndarray) -> Optional[List[int]]:
        if assigned.all():
            return [int(np.argmax(dom_np[x])) for x in range(n)]
        var = _select_var(dom_np, assigned)
        values = [int(v) for v in np.nonzero(dom_np[var])[0]]

        child_results = None
        if batched_children and eng.supports_batch and len(values) > 1:
            b = len(values)
            # bucket B up to a power of two (repeating the last child — the
            # fixpoint is idempotent per element) so the jitted batched
            # enforcement compiles O(log d) shapes instead of one per frontier
            # size; results are sliced back to the true frontier below.
            b_p = 1 << (b - 1).bit_length()
            doms = np.stack(
                [assign_np(dom_np, var, v) for v in values]
                + [assign_np(dom_np, var, values[-1])] * (b_p - b)
            )
            ch = np.zeros((b_p, n), bool)
            ch[:, var] = True
            t0 = time.perf_counter()
            res = prepared.enforce_batch(doms, ch)
            record(t0, np.asarray(res.n_recurrences)[:b])
            child_results = res

        assigned[var] = True
        try:
            for i, val in enumerate(values):
                stats.n_assignments += 1
                if max_assignments and stats.n_assignments > max_assignments:
                    raise BudgetExceeded
                if child_results is not None:
                    ok_i = bool(child_results.consistent[i])
                    dom_i = np.asarray(child_results.dom[i])
                else:
                    dom_i, ok_i = enforce_one(assign_np(dom_np, var, val), var)
                if ok_i:
                    sol = dfs(dom_i)
                    if sol is not None:
                        return sol
                stats.n_backtracks += 1
            return None
        finally:
            assigned[var] = False

    try:
        sol = dfs(dom0)
    except BudgetExceeded:
        return None, stats
    return sol, stats


def check_solution(csp: CSP, solution: List[int]) -> bool:
    cons = np.asarray(csp.cons)
    mask = np.asarray(csp.mask)
    dom = np.asarray(csp.dom)
    n = len(solution)
    for x in range(n):
        if not dom[x, solution[x]]:
            return False
        for y in range(x + 1, n):
            if mask[x, y] and not cons[x, y, solution[x], solution[y]]:
                return False
    return True
