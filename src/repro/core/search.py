"""MAC backtrack search (paper Alg. 2) over any registered enforcement Engine.

``mac_solve`` prepares the constraint network ONCE (`Engine.prepare`) and then
maintains arc consistency after every assignment against the resident prepared
network, recording per-assignment statistics — exactly the quantities of paper
Table 1 (#Recurrence for the tensor engines / #Revision for AC3, averaged over
assignments, kept in separate fields) and Fig. 3 (time per assignment).

Beyond the paper, two batching axes (DESIGN.md §6) and a residency axis (§8):

- **Frontier batching** (within one search): all candidate values of the
  branching variable are enforced in one ``enforce_batch`` dispatch — one
  device round-trip per search *node* instead of per *child*. Pass
  ``batched_children=False`` for the classical one-child-at-a-time schedule.
  Engines with ``supports_batch=False`` (the sequential AC3 baseline, where
  eager batching is pure extra work) always use the classical schedule.
- **Instance batching** (across searches): ``solve_many`` runs B independent
  CSPs sharing (n, d) to completion. On batch-capable engines the searches
  advance in *lockstep*: each round resolves every active search's pending
  enforcement frontier in ONE dispatch, so a whole workload shares each device
  round-trip. Every search still takes exactly the decisions it would take
  alone — solutions and per-instance statistics are identical to sequential
  ``mac_solve`` (only wall-clock attribution differs).
- **Device residency** (DESIGN.md §8): on ``Engine.device_frontier`` backends
  the domains themselves never leave the device. The search coroutine speaks
  *row handles + decisions* — it never sees a domain tensor — and the lockstep
  round is one fused gather→assign→enforce→MRV dispatch against a
  `core.engine.FrontierTable`, shipping only O(R·d) metadata to the host
  (consistency bits, recurrence counts, the branching decision and its d-bit
  value row — domain sizes and assignment masks stay device-resident). Full
  domains cross the boundary exactly twice per search: the root upload at
  admission and the closure fetch at solution extraction. Engines without the
  capability (AC3, sharded) get `HostFrontierStore` — the same protocol with
  numpy-resident closures, bit-identical by construction.

The search logic itself is written once, as a coroutine that *yields*
enforcement requests and receives decision replies. `LockstepDriver`
multiplexes any number of coroutines over one `FrontierStore` in an **open
world**: searches are admitted between rounds (their root request simply rides
the next dispatch) and finished searches free their rows mid-flight — the
substrate of both the closed-batch ``solve_many`` portfolio and the
continuous-batching `repro.service.SolverService` (DESIGN.md §7). Rounds are
*pipelined*: ``round()`` launches the next dispatch asynchronously (JAX async
dispatch) and resolves it on the following call, so enforcement runs on device
while the host admits work, retires requests, and drives other buckets.
``engine`` accepts an `Engine` instance or a registry name
(`repro.engines.available_engines()`).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import time
import warnings
from typing import (
    Any,
    Dict,
    Generator,
    List,
    NamedTuple,
    Optional,
    Sequence,
    Tuple,
    Union,
)

import numpy as np

from .ac3 import assign_np
from .csp import CSP
from .engine import (
    Engine,
    FrontierRow,
    FrontierTable,
    RoundMeta,
    frontier_capacity,
    next_pow2 as _next_pow2,
    pad_round_rows,
)
from .rtac import EnforceResult


@dataclasses.dataclass
class SearchStats:
    n_assignments: int = 0
    n_backtracks: int = 0
    # Per-enforcement work counters, SEPARATED by unit (Table 1 honesty):
    # tensor-engine fixpoint recurrence counts vs AC3 revise-call counts.
    recurrences: List[int] = dataclasses.field(default_factory=list)
    revisions: List[int] = dataclasses.field(default_factory=list)
    enforce_seconds: List[float] = dataclasses.field(default_factory=list)
    #: kernel launches billed to this search's enforcement rounds (a fused
    #: in-kernel fixpoint bills 1 per round; the stepped path bills the
    #: round's max recurrence depth). Host engines leave it 0.
    launches: int = 0
    #: True iff the search stopped on its ``max_assignments`` budget — a
    #: (None, stats) result with ``exhausted=True`` is *inconclusive*, NOT a
    #: proof of unsatisfiability.
    exhausted: bool = False

    @property
    def mean_recurrences(self) -> float:
        return float(np.mean(self.recurrences)) if self.recurrences else 0.0

    @property
    def mean_revisions(self) -> float:
        return float(np.mean(self.revisions)) if self.revisions else 0.0

    @property
    def mean_enforce_ms(self) -> float:
        return 1e3 * float(np.mean(self.enforce_seconds)) if self.enforce_seconds else 0.0


class BudgetExceeded(Exception):
    pass


def _select_var(dom_np: np.ndarray, assigned: np.ndarray) -> int:
    """Minimum-remaining-values heuristic (paper leaves `heuristics()` open).
    The device frontier computes exactly this (first argmin over unassigned
    domain sizes) in `core.engine._frontier_step` — same ints, same ties."""
    sizes = dom_np.sum(axis=1).astype(np.int64)
    sizes[assigned] = np.iinfo(np.int64).max
    return int(np.argmin(sizes))


def resolve_engine(engine: Union[Engine, str], support_fn=None) -> Engine:
    """Engine instance passthrough, or registry lookup by name.
    ``support_fn`` is honoured by the einsum-contraction engines."""
    if isinstance(engine, Engine):
        if support_fn is not None:
            warnings.warn(
                "support_fn is ignored when an Engine instance is passed",
                stacklevel=3,
            )
        return engine
    from repro.engines import get_engine

    opts = {}
    if support_fn is not None and engine in ("einsum", "full"):
        opts["support_fn"] = support_fn
    return get_engine(engine, **opts)


# ---------------------------------------------------------------------------
# The MAC search coroutine — search logic decoupled from dispatch AND data.
# The coroutine never sees a domain tensor: it yields (parent handle, var,
# values) decisions and receives handles plus the on-store MRV selection.
# ---------------------------------------------------------------------------


class _Request(NamedTuple):
    """One pending enforcement: create and enforce the children of ``parent``
    obtained by assigning ``var := v`` for each v in ``values`` (``parent is
    None`` = the root propagation; exactly one implicit row). ``assigned`` is
    the (n,) bool assignment mask the children's own MRV selection must see."""

    parent: Optional[int]
    var: int
    values: Tuple[int, ...]
    assigned: np.ndarray


class _Reply(NamedTuple):
    """Per-child decision metadata — everything dfs needs at the next level.
    ``handles[i]`` is None where the child wiped out (its row was freed);
    ``branch_var``/``values`` are the MRV decision computed ON the closure
    (ignored for inconsistent or fully-assigned children)."""

    handles: List[Optional[int]]
    consistent: np.ndarray  # (b,) bool
    branch_var: np.ndarray  # (b,) int
    values: List[Optional[Tuple[int, ...]]]


_MacGen = Generator[_Request, _Reply, Optional[List[int]]]


def _mac_coroutine(
    csp: CSP,
    free_fn,
    extract_fn,
    supports_batch: bool,
    batched_children: bool,
    max_assignments: Optional[int],
    stats: SearchStats,
    n_active: Optional[int] = None,
) -> _MacGen:
    """Alg. 2 as a coroutine: yields `_Request`s, receives `_Reply`s, returns
    the solution (or None). The coroutine owns every search decision and the
    assignment/backtrack counters; the driver owns dispatch, padding, timing
    and work-counter recording — so one search behaves identically whether it
    is driven alone (`mac_solve`) or multiplexed with others (`solve_many`),
    against host-resident closures or a device `FrontierTable`.

    ``free_fn(handle)`` releases a node the search will never revisit (a dead
    branch); ``extract_fn(handle)`` fetches a closure as a numpy (n, d) array —
    called exactly once, at solution extraction.

    ``n_active`` marks the first ``n_active`` variables as the real problem:
    variables beyond it (bucket padding under the §2 contract — unconstrained,
    singleton domain) start out assigned, are never branched on, and are
    excluded from the returned solution, so a padded search takes bit-identical
    decisions to the unpadded one."""
    dom0 = np.asarray(csp.dom)
    n, _ = dom0.shape
    n_real = n if n_active is None else n_active

    assigned = np.zeros((n,), dtype=bool)
    assigned[n_real:] = True

    # Root propagation (Alg. 2 line 3).
    reply = yield _Request(None, -1, (), assigned.copy())
    if not bool(reply.consistent[0]):
        return None

    def solution_of(handle: int) -> List[int]:
        dom_np = extract_fn(handle)
        return [int(np.argmax(dom_np[x])) for x in range(n_real)]

    def dfs(handle: int, var: int, values: Tuple[int, ...]) -> _MacGen:
        if assigned.all():
            return solution_of(handle)

        child_reply: Optional[_Reply] = None
        child_mask = assigned.copy()
        child_mask[var] = True
        if batched_children and supports_batch and len(values) > 1:
            child_reply = yield _Request(handle, var, values, child_mask)

        assigned[var] = True
        try:
            for i, val in enumerate(values):
                stats.n_assignments += 1
                if max_assignments and stats.n_assignments > max_assignments:
                    raise BudgetExceeded
                if child_reply is not None:
                    child, ok = child_reply.handles[i], bool(child_reply.consistent[i])
                    cvar, cvals = int(child_reply.branch_var[i]), child_reply.values[i]
                else:
                    r = yield _Request(handle, var, (val,), child_mask)
                    child, ok = r.handles[0], bool(r.consistent[0])
                    cvar, cvals = int(r.branch_var[0]), r.values[0]
                if ok:
                    sol = yield from dfs(child, cvar, cvals)
                    if sol is not None:
                        return sol
                    free_fn(child)  # dead branch: its row is reusable now
                stats.n_backtracks += 1
            return None
        finally:
            assigned[var] = False

    return (yield from dfs(reply.handles[0], int(reply.branch_var[0]), reply.values[0]))




# ---------------------------------------------------------------------------
# HostFrontierStore — the host-resident FrontierStore (AC3 / sharded / oracle)
# ---------------------------------------------------------------------------


class _SyncRound:
    """A resolved-at-dispatch round (host stores have nothing in flight)."""

    def __init__(self, meta: RoundMeta):
        self._meta = meta

    def resolve(self) -> RoundMeta:
        return self._meta


class HostFrontierStore:
    """Host-side frontier store — same protocol as `core.engine.FrontierTable`
    with numpy-resident closures: child domains are materialized with
    ``assign_np`` and MRV runs through `_select_var`, exactly the pre-frontier
    dispatch path. This is both the fallback for engines without
    ``device_frontier`` (AC3, sharded) and the semantic oracle the device
    table must match bit-for-bit."""

    pipelined = False

    def __init__(self, n_vars: int, dispatch_rows, pad_rounds: bool = False):
        self._n = n_vars
        self._dispatch_rows = dispatch_rows  # (doms, chs, idx) -> EnforceResult
        self._pad_rounds = pad_rounds
        self._doms: Dict[int, np.ndarray] = {}
        self._of_key: Dict[Any, set] = {}
        self._net_of: Dict[Any, int] = {}
        self._handles = itertools.count()

    def _new_handle(self, key) -> int:
        h = next(self._handles)
        self._of_key[key].add(h)
        return h

    def begin(self, key, net: int, root_dom: np.ndarray, assigned=None) -> int:
        # ``assigned`` is part of the store protocol (the device table keeps
        # the mask resident); the host store reads it off each request instead
        del assigned
        if key in self._of_key:
            raise ValueError(f"search key {key!r} already registered")
        self._of_key[key] = set()
        self._net_of[key] = int(net)
        h = self._new_handle(key)
        self._doms[h] = np.asarray(root_dom, dtype=bool)
        return h

    def free(self, key, handle: int) -> None:
        if handle in self._of_key.get(key, ()):
            self._of_key[key].discard(handle)
            self._doms.pop(handle, None)

    def release(self, key) -> None:
        for h in self._of_key.pop(key, ()):
            self._doms.pop(h, None)
        self._net_of.pop(key, None)

    def extract(self, key, handle: int) -> np.ndarray:
        return self._doms[handle]

    def _enforce_rows(self, doms, chs, idx, roots) -> EnforceResult:
        r = doms.shape[0]
        r_p = _next_pow2(r) if self._pad_rounds else r
        doms, chs, idx = pad_round_rows((doms, chs, idx), r_p)
        return self._dispatch_rows(doms, chs, idx)

    def dispatch(self, specs: Sequence[FrontierRow], net_idx=None) -> _SyncRound:
        r = len(specs)
        rows, roots = [], np.zeros((r,), dtype=bool)
        chs = np.zeros((r, self._n), dtype=bool)
        for i, s in enumerate(specs):
            parent_dom = self._doms[s.parent]
            if s.var < 0:
                rows.append(parent_dom)
                chs[i] = True
                roots[i] = True
            else:
                rows.append(assign_np(parent_dom, s.var, s.val))
                chs[i, s.var] = True
        doms = np.stack(rows)
        if net_idx is None:
            net_idx = np.fromiter((self._net_of[s.key] for s in specs), np.int32, r)
        res = self._enforce_rows(doms, chs, np.asarray(net_idx, np.int32), roots)
        dom_out = np.asarray(res.dom)[:r]
        cons = np.atleast_1d(np.asarray(res.consistent))[:r]
        k = np.atleast_1d(np.asarray(res.n_recurrences))[:r]

        d = dom_out.shape[-1]
        handles: List[Optional[int]] = []
        bvar = np.zeros((r,), np.int32)
        vrow = np.zeros((r, d), dtype=bool)
        for i, s in enumerate(specs):
            if not bool(cons[i]):
                handles.append(None)
                continue
            h = s.parent if s.var < 0 else self._new_handle(s.key)
            self._doms[h] = dom_out[i]
            handles.append(h)
            bvar[i] = _select_var(dom_out[i], s.assigned)
            vrow[i] = dom_out[i][bvar[i]]
        # host stores run the stepped recurrence: one enforcement dispatch per
        # iteration of the deepest row (same launch model as the stepped
        # device frontier — `core.engine._PendingFrontierRound.resolve`)
        launches = max(1, int(k.max())) if k.size else 1
        return _SyncRound(RoundMeta(handles, cons, k, bvar, vrow, launches))


class _SingleSearchStore(HostFrontierStore):
    """`mac_solve`'s store over ONE `PreparedNetwork`: single rows go through
    ``enforce`` (the root keeps the engine-native ``changed0=None`` seed),
    child frontiers through ``enforce_batch`` padded up to a power of two
    (repeating the last child — enforcement is idempotent per element) so the
    jitted batched fixpoint compiles O(log d) shapes instead of one per
    frontier size — exactly the pre-frontier dispatch schedule."""

    def __init__(self, prepared):
        super().__init__(prepared.n_vars, None, pad_rounds=False)
        self._prepared = prepared

    def _enforce_rows(self, doms, chs, idx, roots) -> EnforceResult:
        b = doms.shape[0]
        if b == 1:
            res = self._prepared.enforce(doms[0], None if roots[0] else chs[0])
            return EnforceResult(
                np.asarray(res.dom)[None],
                np.atleast_1d(np.asarray(res.consistent)),
                np.atleast_1d(np.asarray(res.n_recurrences)),
            )
        doms, chs = pad_round_rows((doms, chs), _next_pow2(b))
        res = self._prepared.enforce_batch(doms, chs)
        return EnforceResult(
            np.asarray(res.dom)[:b],
            np.asarray(res.consistent)[:b],
            np.asarray(res.n_recurrences)[:b],
        )


def _drive_single(store: HostFrontierStore, root: int, gen: _MacGen,
                  counts: List[int], stats: SearchStats,
                  collect_stats: bool) -> Optional[List[int]]:
    """Run one coroutine to completion against a single-search store."""
    try:
        req = gen.send(None)  # prime: runs to the first yield
        while True:
            if req.parent is None:
                specs = [FrontierRow(0, root, -1, 0, req.assigned, 0)]
            else:
                specs = [
                    FrontierRow(0, req.parent, req.var, v, req.assigned, 0)
                    for v in req.values
                ]
            t0 = time.perf_counter()
            res = store.dispatch(specs).resolve()
            if collect_stats:
                stats.enforce_seconds.append(time.perf_counter() - t0)
                counts.extend(int(v) for v in res.k)
                stats.launches += res.launches
            req = gen.send(_Reply(res.handles, res.consistent, res.branch_var,
                                  _value_lists(res)))
    except StopIteration as stop:
        return stop.value


def _value_lists(res: RoundMeta) -> List[Optional[Tuple[int, ...]]]:
    """Per-row live values of the branching variable (None where the row wiped
    out) — the host side of the d-bit value row the round shipped back."""
    return [
        tuple(int(v) for v in np.nonzero(res.value_row[i])[0])
        if res.handles[i] is not None
        else None
        for i in range(len(res.handles))
    ]


def mac_solve(
    csp: CSP,
    engine: Union[Engine, str] = "einsum",
    support_fn=None,
    max_assignments: Optional[int] = None,
    batched_children: bool = True,
    collect_stats: bool = True,
) -> Tuple[Optional[List[int]], SearchStats]:
    """Returns (solution | None, stats). Raises nothing on budget exhaustion —
    stops and returns (None, stats) with ``stats.n_assignments`` at the cap."""
    eng = resolve_engine(engine, support_fn)
    prepared = eng.prepare(csp)  # the ONLY preparation in the whole run
    stats = SearchStats()
    counts = stats.recurrences if eng.count_unit == "recurrences" else stats.revisions
    store = _SingleSearchStore(prepared)
    root = store.begin(0, 0, np.asarray(csp.dom))  # host store: mask per request
    gen = _mac_coroutine(
        csp,
        functools.partial(store.free, 0),
        functools.partial(store.extract, 0),
        eng.supports_batch,
        batched_children,
        max_assignments,
        stats,
    )
    try:
        sol = _drive_single(store, root, gen, counts, stats, collect_stats)
    except BudgetExceeded:
        stats.exhausted = True
        return None, stats
    finally:
        store.release(0)
    return sol, stats


# ---------------------------------------------------------------------------
# LockstepDriver — open-world lockstep multiplexing (DESIGN.md §6/§7/§8)
# ---------------------------------------------------------------------------


class RoundInfo(NamedTuple):
    """Telemetry of one RESOLVED lockstep round. ``seconds`` spans dispatch
    launch → metadata arrival: on a pipelined store that window deliberately
    overlaps host work done between ``round()`` calls (admissions, other
    buckets' dispatches), so it is an upper bound on the round's device time,
    not a pure enforcement measurement."""

    rows: int
    searches: int
    seconds: float
    launches: int = 1


class LockstepDriver:
    """Multiplexes MAC-search coroutines over ONE `FrontierStore`, open-world.

    Each round gathers every live search's pending request into a single
    dispatch against the store — a device-resident `core.engine.FrontierTable`
    on ``device_frontier`` engines (domains never leave the device; only
    per-row metadata crosses the host boundary), a `HostFrontierStore`
    otherwise — scatters the decision replies back, and advances each search
    to its next request. Unlike the closed batch that ``solve_many``
    historically hard-coded, membership is dynamic:

    - ``admit`` joins a new search *between* rounds — its root propagation
      simply rides the next dispatch alongside everyone else's frontiers;
    - a search that finishes (solution, exhaustion, or budget) is reported by
      the ``round()`` that retired it and frees its rows immediately — the
      batch never drains to a stragglers-only tail before new work can enter;
    - ``cancel`` evicts a search mid-flight (deadline expiry in the service).

    Rounds are **pipelined** on stores that advertise ``pipelined=True``:
    ``round()`` resolves the previous dispatch (blocking only on its small
    metadata), advances the coroutines, then launches the next dispatch
    asynchronously and returns — enforcement for round *t+1* runs on device
    while the host retires requests, admits new work, and drives other
    buckets' rounds. Synchronous stores resolve within the same call.

    The driver owns dispatch, routing, timing, and work-counter filing; every
    search still takes exactly the decisions it would take alone (solutions
    and per-instance statistics are bit-identical to sequential `mac_solve` —
    only ``enforce_seconds`` attribution differs, splitting each round's
    wall-clock across participants proportionally to their row counts; the
    per-round attributions sum exactly to the round's measured seconds).
    """

    def __init__(
        self,
        store,
        n_vars: int,
        count_unit: str = "recurrences",
    ):
        self._store = store
        self._n = n_vars
        self._count_unit = count_unit
        self._gens: Dict[object, _MacGen] = {}
        self._pending: Dict[object, _Request] = {}
        self._idx: Dict[object, int] = {}
        self._root: Dict[object, int] = {}
        self._stats: Dict[object, SearchStats] = {}
        self._collect: Dict[object, bool] = {}
        self._inflight = None  # (layout, pending round, t0)
        # membership-stable caches: the sorted key order is rebuilt only when
        # membership changes, the np.repeat routing array only when the
        # per-search row counts differ from the previous round
        self._order: List = []
        self._order_dirty = False
        self._route_cache: Optional[Tuple[Tuple[int, ...], np.ndarray]] = None
        #: telemetry over resolved rounds
        self.last_round: Optional[RoundInfo] = None
        self.rounds = 0
        self.rows_dispatched = 0
        self.launches = 0  # kernel-launch bill across resolved rounds
        self.round_seconds: List[float] = []

    # --- membership --------------------------------------------------------

    def admit(
        self,
        key,
        csp: CSP,
        idx: int = 0,
        *,
        supports_batch: bool = True,
        batched_children: bool = True,
        n_active: Optional[int] = None,
        max_assignments: Optional[int] = None,
        collect_stats: bool = True,
    ) -> SearchStats:
        """Join a new search; it participates from the next dispatch on.
        ``idx`` routes the search's rows to its constraint network. Returns
        the live `SearchStats` (filled in as rounds run)."""
        if key in self._gens:
            raise ValueError(f"search key {key!r} already admitted")
        stats = SearchStats()
        gen = _mac_coroutine(
            csp,
            functools.partial(self._store.free, key),
            functools.partial(self._store.extract, key),
            supports_batch,
            batched_children,
            max_assignments,
            stats,
            n_active=n_active,
        )
        req0 = gen.send(None)  # root request; always yields ≥ once
        root = self._store.begin(key, idx, np.asarray(csp.dom), req0.assigned)
        self._pending[key] = req0
        self._gens[key] = gen
        self._idx[key] = int(idx)
        self._root[key] = root
        self._stats[key] = stats
        self._collect[key] = collect_stats
        self._order_dirty = True
        return stats

    def cancel(self, key) -> SearchStats:
        """Evict a live search (e.g. deadline expiry); frees its rows even if
        they are part of an in-flight round (the round's results for this
        search are simply dropped at resolution)."""
        self._gens.pop(key).close()
        self._pending.pop(key, None)  # absent while the search is in flight
        self._idx.pop(key)
        self._root.pop(key)
        self._collect.pop(key)
        self._store.release(key)
        self._order_dirty = True
        return self._stats.pop(key)

    @property
    def active_keys(self) -> List:
        return sorted(self._gens)

    def is_active(self, key) -> bool:
        return key in self._gens

    @property
    def has_work(self) -> bool:
        return bool(self._pending) or self._inflight is not None

    @property
    def n_pending_rows(self) -> int:
        return sum(max(1, len(req.values)) for req in self._pending.values())

    # --- one lockstep round -------------------------------------------------

    def round(self) -> Dict[object, Tuple[Optional[List[int]], SearchStats]]:
        """Resolve the in-flight dispatch (if any), advance its searches, then
        launch the next dispatch; returns ``{key: (solution | None, stats)}``
        for the searches that finished (their rows are freed). On pipelined
        stores the launch is asynchronous — it resolves on the NEXT call."""
        self.last_round = None
        finished: Dict[object, Tuple[Optional[List[int]], SearchStats]] = {}
        if self._inflight is not None:
            layout, pend, t0 = self._inflight
            self._inflight = None
            finished = self._advance(layout, pend, t0)
        if self._pending:
            specs, layout, net_idx = self._collect_rows()
            t0 = time.perf_counter()
            pend = self._store.dispatch(specs, net_idx)
            if getattr(self._store, "pipelined", False):
                self._inflight = (layout, pend, t0)
            else:
                finished.update(self._advance(layout, pend, t0))
        return finished

    def _collect_rows(self):
        """Flatten every pending request into row specs, in cached sorted-key
        order, with the np.repeat routing array rebuilt only when the round
        shape actually changed."""
        if self._order_dirty:
            self._order = sorted(self._pending)
            self._order_dirty = False
            self._route_cache = None
        order = self._order
        sizes = tuple(
            1 if self._pending[k].parent is None else len(self._pending[k].values)
            for k in order
        )
        if self._route_cache is not None and self._route_cache[0] == sizes:
            net_idx = self._route_cache[1]
        else:
            per_key = np.asarray([self._idx[k] for k in order], np.int32)
            net_idx = np.repeat(per_key, sizes)
            self._route_cache = (sizes, net_idx)

        specs: List[FrontierRow] = []
        layout: List[Tuple[object, int]] = []
        for k, b in zip(order, sizes):
            req = self._pending.pop(k)
            if req.parent is None:
                specs.append(
                    FrontierRow(k, self._root[k], -1, 0, req.assigned, self._idx[k])
                )
            else:
                specs.extend(
                    FrontierRow(k, req.parent, req.var, v, req.assigned, self._idx[k])
                    for v in req.values
                )
            layout.append((k, b))
        return specs, layout, net_idx

    def _advance(self, layout, pend, t0) -> Dict:
        """Block on a round's metadata, file stats, advance every coroutine."""
        res = pend.resolve()
        dt = time.perf_counter() - t0
        r = sum(b for _, b in layout)
        self.rounds += 1
        self.rows_dispatched += r
        self.round_seconds.append(dt)
        self.launches += res.launches
        self.last_round = RoundInfo(r, len(layout), dt, res.launches)
        values = _value_lists(res)

        off = 0
        finished: Dict[object, Tuple[Optional[List[int]], SearchStats]] = {}
        for k, b in layout:
            rows = slice(off, off + b)
            off += b
            if k not in self._gens:  # cancelled while the round was in flight
                continue
            stats = self._stats[k]
            if self._collect[k]:
                # attribute the round's wall-clock over its REAL rows, so the
                # per-search attributions sum exactly to the measured seconds
                stats.enforce_seconds.append(dt * b / r)
                counts = (
                    stats.recurrences
                    if self._count_unit == "recurrences"
                    else stats.revisions
                )
                counts.extend(int(v) for v in res.k[rows])
                stats.launches += res.launches
            reply = _Reply(
                res.handles[rows], res.consistent[rows], res.branch_var[rows],
                values[rows],
            )
            try:
                self._pending[k] = self._gens[k].send(reply)
            except StopIteration as stop:
                finished[k] = (stop.value, stats)
            except BudgetExceeded:
                stats.exhausted = True
                finished[k] = (None, stats)
        for k in finished:
            del self._gens[k], self._idx[k], self._root[k]
            del self._stats[k], self._collect[k]
            self._pending.pop(k, None)
            self._store.release(k)
            self._order_dirty = True
        return finished


# ---------------------------------------------------------------------------
# solve_many — the portfolio entry point (one workload, many CSPs)
# ---------------------------------------------------------------------------


def solve_many(
    csps: Sequence[CSP],
    engine: Union[Engine, str] = "einsum",
    support_fn=None,
    max_assignments: Optional[int] = None,
    batched_children: bool = True,
    collect_stats: bool = True,
    telemetry: Optional[dict] = None,
) -> Tuple[List[Optional[List[int]]], List[SearchStats]]:
    """Run B independent MAC searches (instances sharing (n, d)) to completion.

    On ``device_frontier`` engines the searches advance in lockstep against a
    device-resident `FrontierTable` over the `Engine.prepare_many` stacked
    networks: every round is ONE fused assign+enforce+MRV dispatch and only
    per-row metadata crosses the host boundary (DESIGN.md §8). Other
    batch-capable engines run the same lockstep through the host store.
    ``max_assignments`` is a *per-instance* budget. Solutions and per-instance
    search statistics are identical to sequential ``mac_solve``;
    ``enforce_seconds`` attributes each round's wall-clock to its participants
    proportionally to their row counts.

    Sequential engines (``supports_batch=False``, i.e. AC3) degrade to one
    ``mac_solve`` per instance — same results, no amortization.

    ``telemetry``, if a dict, is filled with round/transfer counters
    (``rounds``, ``rows_dispatched``, ``round_seconds_total`` and — on the
    device frontier — ``host_bytes_per_round`` vs the counterfactual
    ``domain_bytes_per_round``); `benchmarks/bench_many.py` records these
    into the ``frontier`` section of BENCH_engines.json.

    Returns (solutions, stats) as same-length lists, index-aligned with
    ``csps``.
    """
    csps = list(csps)
    eng = resolve_engine(engine, support_fn)
    if not csps:
        return [], []

    if not eng.supports_batch:
        sols, stats = [], []
        for csp in csps:
            s, st = mac_solve(
                csp,
                engine=eng,
                max_assignments=max_assignments,
                batched_children=batched_children,
                collect_stats=collect_stats,
            )
            sols.append(s)
            stats.append(st)
        return sols, stats

    prepared = eng.prepare_many(csps)  # the ONLY preparation in the whole run
    if eng.device_frontier:
        networks = eng.frontier_networks(prepared)
        store = eng.open_frontier(
            lambda: networks, prepared.n_vars, prepared.dom_size,
            # presize for the worst case a DFS can hold live (every level keeps
            # its node + unvisited siblings): growth mid-run would recompile
            # the fused step for every round shape, and rows are n·d bools —
            # cheap enough that oversizing beats recompiling
            capacity=frontier_capacity(len(csps), prepared.n_vars, prepared.dom_size),
        )
    else:
        # host store over the stacked/host-routed enforce_many dispatch; pad
        # rounds only when the dispatch is one jit-shaped stacked program
        store = HostFrontierStore(
            prepared.n_vars, prepared.enforce_many, pad_rounds=eng.stacked_many
        )
    driver = LockstepDriver(store, prepared.n_vars, count_unit=eng.count_unit)
    all_stats = [
        driver.admit(
            i,
            csp,
            idx=i,
            batched_children=batched_children,
            max_assignments=max_assignments,
            collect_stats=collect_stats,
        )
        for i, csp in enumerate(csps)
    ]
    sols: List[Optional[List[int]]] = [None] * len(csps)
    while driver.has_work:
        for i, (sol, _st) in driver.round().items():
            sols[i] = sol
    if telemetry is not None:
        telemetry.update(
            engine=eng.name,
            device_frontier=bool(eng.device_frontier),
            fused_fixpoint=bool(getattr(eng, "fused_fixpoint", False)),
            rounds=driver.rounds,
            rows_dispatched=driver.rows_dispatched,
            launches=driver.launches,
            launches_per_round=driver.launches / max(driver.rounds, 1),
            round_seconds_total=float(sum(driver.round_seconds)),
        )
        if isinstance(store, FrontierTable):
            telemetry.update(
                host_bytes_per_round=store.host_bytes_per_round,
                domain_bytes_per_round=store.domain_bytes_per_round,
                rows_padded=store.rows_padded,
                root_bytes=store.root_bytes,
                extract_bytes=store.extract_bytes,
            )
    return sols, all_stats


def check_solution(csp: CSP, solution: List[int]) -> bool:
    """Verify a full assignment in O(n²) numpy (no Python pair loop): one
    gather checks every value is in-domain, one gather over the upper-triangle
    constrained pairs checks every binary constraint."""
    sol = np.asarray(solution, dtype=np.int64)
    n = sol.shape[0]
    dom = np.asarray(csp.dom)
    if not dom[np.arange(n), sol].all():
        return False
    mask = np.asarray(csp.mask)[:n, :n]
    cons = np.asarray(csp.cons)
    xs, ys = np.nonzero(np.triu(mask, 1))
    return bool(cons[xs, ys, sol[xs], sol[ys]].all())
