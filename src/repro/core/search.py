"""MAC backtrack search (paper Alg. 2) over any registered enforcement Engine.

``mac_solve`` prepares the constraint network ONCE (`Engine.prepare`) and then
maintains arc consistency after every assignment against the resident prepared
network, recording per-assignment statistics — exactly the quantities of paper
Table 1 (#Recurrence for the tensor engines / #Revision for AC3, averaged over
assignments, kept in separate fields) and Fig. 3 (time per assignment).

Beyond the paper, two batching axes (DESIGN.md §6):

- **Frontier batching** (within one search): all candidate values of the
  branching variable are enforced in one ``enforce_batch`` dispatch — one
  device round-trip per search *node* instead of per *child*. Pass
  ``batched_children=False`` for the classical one-child-at-a-time schedule.
  Engines with ``supports_batch=False`` (the sequential AC3 baseline, where
  eager batching is pure extra work) always use the classical schedule.
- **Instance batching** (across searches): ``solve_many`` runs B independent
  CSPs sharing (n, d) to completion. On batch-capable engines the searches
  advance in *lockstep*: each round gathers every active search's pending
  enforcement frontier into ONE ``enforce_many`` dispatch against the stacked
  prepared networks (`Engine.prepare_many`), so a whole workload shares each
  device round-trip. Every search still takes exactly the decisions it would
  take alone — solutions and per-instance statistics are identical to
  sequential ``mac_solve`` (only wall-clock attribution differs).

The search logic itself is written once, as a coroutine that *yields*
enforcement requests and receives results. `LockstepDriver` multiplexes any
number of coroutines over one row-dispatch function in an **open world**:
searches are admitted between rounds (their root request simply joins the next
dispatch) and finished searches free their rows mid-flight — the substrate of
both the closed-batch ``solve_many`` portfolio and the continuous-batching
`repro.service.SolverService` (DESIGN.md §7). ``engine`` accepts an `Engine`
instance or a registry name (`repro.engines.available_engines()`).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, Generator, List, NamedTuple, Optional, Sequence, Tuple, Union

import numpy as np

from .ac3 import assign_np
from .csp import CSP
from .engine import Engine


@dataclasses.dataclass
class SearchStats:
    n_assignments: int = 0
    n_backtracks: int = 0
    # Per-enforcement work counters, SEPARATED by unit (Table 1 honesty):
    # tensor-engine fixpoint recurrence counts vs AC3 revise-call counts.
    recurrences: List[int] = dataclasses.field(default_factory=list)
    revisions: List[int] = dataclasses.field(default_factory=list)
    enforce_seconds: List[float] = dataclasses.field(default_factory=list)
    #: True iff the search stopped on its ``max_assignments`` budget — a
    #: (None, stats) result with ``exhausted=True`` is *inconclusive*, NOT a
    #: proof of unsatisfiability.
    exhausted: bool = False

    @property
    def mean_recurrences(self) -> float:
        return float(np.mean(self.recurrences)) if self.recurrences else 0.0

    @property
    def mean_revisions(self) -> float:
        return float(np.mean(self.revisions)) if self.revisions else 0.0

    @property
    def mean_enforce_ms(self) -> float:
        return 1e3 * float(np.mean(self.enforce_seconds)) if self.enforce_seconds else 0.0


class BudgetExceeded(Exception):
    pass


def _select_var(dom_np: np.ndarray, assigned: np.ndarray) -> int:
    """Minimum-remaining-values heuristic (paper leaves `heuristics()` open)."""
    sizes = dom_np.sum(axis=1).astype(np.int64)
    sizes[assigned] = np.iinfo(np.int64).max
    return int(np.argmin(sizes))


def resolve_engine(engine: Union[Engine, str], support_fn=None) -> Engine:
    """Engine instance passthrough, or registry lookup by name.
    ``support_fn`` is honoured by the einsum-contraction engines."""
    if isinstance(engine, Engine):
        if support_fn is not None:
            warnings.warn(
                "support_fn is ignored when an Engine instance is passed",
                stacklevel=3,
            )
        return engine
    from repro.engines import get_engine

    opts = {}
    if support_fn is not None and engine in ("einsum", "full"):
        opts["support_fn"] = support_fn
    return get_engine(engine, **opts)


# ---------------------------------------------------------------------------
# The MAC search coroutine — search logic decoupled from dispatch
# ---------------------------------------------------------------------------


class _Request(NamedTuple):
    """One pending enforcement: b candidate domains, all rows live."""

    doms: np.ndarray  # (b, n, d) bool
    changed: Optional[np.ndarray]  # (b, n) bool, or None = all variables


class _Reply(NamedTuple):
    doms: np.ndarray  # (b, n, d) bool — AC closures
    consistent: np.ndarray  # (b,) bool


_MacGen = Generator[_Request, _Reply, Optional[List[int]]]


def _mac_coroutine(
    csp: CSP,
    supports_batch: bool,
    batched_children: bool,
    max_assignments: Optional[int],
    stats: SearchStats,
    n_active: Optional[int] = None,
) -> _MacGen:
    """Alg. 2 as a coroutine: yields `_Request`s, receives `_Reply`s, returns
    the solution (or None). The coroutine owns every search decision and the
    assignment/backtrack counters; the driver owns dispatch, padding, timing
    and work-counter recording — so one search behaves identically whether it
    is driven alone (`mac_solve`) or multiplexed with others (`solve_many`).

    ``n_active`` marks the first ``n_active`` variables as the real problem:
    variables beyond it (bucket padding under the §2 contract — unconstrained,
    singleton domain) start out assigned, are never branched on, and are
    excluded from the returned solution, so a padded search takes bit-identical
    decisions to the unpadded one."""
    dom0 = np.asarray(csp.dom)
    n, _ = dom0.shape
    n_real = n if n_active is None else n_active

    # Root propagation (Alg. 2 line 3).
    reply = yield _Request(dom0[None], None)
    if not bool(reply.consistent[0]):
        return None

    assigned = np.zeros((n,), dtype=bool)
    assigned[n_real:] = True

    def dfs(dom_np: np.ndarray) -> _MacGen:
        if assigned.all():
            return [int(np.argmax(dom_np[x])) for x in range(n_real)]
        var = _select_var(dom_np, assigned)
        values = [int(v) for v in np.nonzero(dom_np[var])[0]]

        child_results: Optional[_Reply] = None
        if batched_children and supports_batch and len(values) > 1:
            doms = np.stack([assign_np(dom_np, var, v) for v in values])
            ch = np.zeros((len(values), n), bool)
            ch[:, var] = True
            child_results = yield _Request(doms, ch)

        assigned[var] = True
        try:
            for i, val in enumerate(values):
                stats.n_assignments += 1
                if max_assignments and stats.n_assignments > max_assignments:
                    raise BudgetExceeded
                if child_results is not None:
                    dom_i = child_results.doms[i]
                    ok_i = bool(child_results.consistent[i])
                else:
                    ch = np.zeros((1, n), bool)
                    ch[0, var] = True
                    r = yield _Request(assign_np(dom_np, var, val)[None], ch)
                    dom_i, ok_i = r.doms[0], bool(r.consistent[0])
                if ok_i:
                    sol = yield from dfs(dom_i)
                    if sol is not None:
                        return sol
                stats.n_backtracks += 1
            return None
        finally:
            assigned[var] = False

    return (yield from dfs(reply.doms[0]))


def _next_pow2(b: int) -> int:
    return 1 << (b - 1).bit_length()


def _drive_single(prepared, gen: _MacGen, counts: List[int], stats: SearchStats,
                  collect_stats: bool) -> Optional[List[int]]:
    """Run one coroutine against one `PreparedNetwork`. Single-row requests go
    through ``enforce``; frontiers through ``enforce_batch``, padded up to a
    power of two (repeating the last child — enforcement is idempotent per
    element) so the jitted batched fixpoint compiles O(log d) shapes instead
    of one per frontier size."""
    try:
        req = gen.send(None)  # prime: runs to the first yield
        while True:
            b = req.doms.shape[0]
            t0 = time.perf_counter()
            if b == 1:
                res = prepared.enforce(
                    req.doms[0], None if req.changed is None else req.changed[0]
                )
                doms_out = np.asarray(res.dom)[None]
                cons_out = np.atleast_1d(np.asarray(res.consistent))
                ks = np.atleast_1d(np.asarray(res.n_recurrences))
            else:
                b_p = _next_pow2(b)
                doms, ch = req.doms, req.changed
                if b_p != b:
                    doms = np.concatenate([doms, np.repeat(doms[-1:], b_p - b, axis=0)])
                    ch = np.concatenate([ch, np.repeat(ch[-1:], b_p - b, axis=0)])
                res = prepared.enforce_batch(doms, ch)
                doms_out = np.asarray(res.dom)[:b]
                cons_out = np.asarray(res.consistent)[:b]
                ks = np.asarray(res.n_recurrences)[:b]
            if collect_stats:
                stats.enforce_seconds.append(time.perf_counter() - t0)
                counts.extend(int(k) for k in ks)
            req = gen.send(_Reply(doms_out, cons_out))
    except StopIteration as stop:
        return stop.value


def mac_solve(
    csp: CSP,
    engine: Union[Engine, str] = "einsum",
    support_fn=None,
    max_assignments: Optional[int] = None,
    batched_children: bool = True,
    collect_stats: bool = True,
) -> Tuple[Optional[List[int]], SearchStats]:
    """Returns (solution | None, stats). Raises nothing on budget exhaustion —
    stops and returns (None, stats) with ``stats.n_assignments`` at the cap."""
    eng = resolve_engine(engine, support_fn)
    prepared = eng.prepare(csp)  # the ONLY preparation in the whole run
    stats = SearchStats()
    counts = stats.recurrences if eng.count_unit == "recurrences" else stats.revisions
    gen = _mac_coroutine(csp, eng.supports_batch, batched_children, max_assignments, stats)
    try:
        sol = _drive_single(prepared, gen, counts, stats, collect_stats)
    except BudgetExceeded:
        stats.exhausted = True
        return None, stats
    return sol, stats


# ---------------------------------------------------------------------------
# LockstepDriver — open-world lockstep multiplexing (DESIGN.md §6/§7)
# ---------------------------------------------------------------------------


#: row dispatcher: (doms (R, n, d), changed (R, n), idx (R,) int32) -> EnforceResult.
#: ``idx[i]`` routes row i to its own constraint network — a `PreparedMany`
#: instance index for the closed-batch portfolio, a `SlotPool` slot for the
#: open-world service.
RowDispatch = Callable[[np.ndarray, np.ndarray, np.ndarray], "object"]


class LockstepDriver:
    """Multiplexes MAC-search coroutines over ONE row dispatcher, open-world.

    Each ``round()`` concatenates every live search's pending enforcement
    frontier into a single dispatch, scatters the replies back, and advances
    each search to its next request. Unlike the closed batch that
    ``solve_many`` historically hard-coded, membership is dynamic:

    - ``admit`` joins a new search *between* rounds — its root propagation
      simply rides the next dispatch alongside everyone else's frontiers;
    - a search that finishes (solution, exhaustion, or budget) is reported by
      the ``round()`` that retired it and frees its rows immediately — the
      batch never drains to a stragglers-only tail before new work can enter;
    - ``cancel`` evicts a search mid-flight (deadline expiry in the service).

    The driver owns dispatch, padding, timing, and work-counter filing; every
    search still takes exactly the decisions it would take alone (solutions
    and per-instance statistics are bit-identical to sequential `mac_solve` —
    only ``enforce_seconds`` attribution differs, splitting each round's
    wall-clock across participants proportionally to their row counts).
    """

    def __init__(
        self,
        dispatch: RowDispatch,
        n_vars: int,
        count_unit: str = "recurrences",
        pad_rounds: bool = True,
    ):
        self._dispatch = dispatch
        self._n = n_vars
        self._count_unit = count_unit
        self._pad_rounds = pad_rounds
        self._gens: Dict[object, _MacGen] = {}
        self._pending: Dict[object, _Request] = {}
        self._idx: Dict[object, int] = {}
        self._stats: Dict[object, SearchStats] = {}
        self._collect: Dict[object, bool] = {}

    # --- membership --------------------------------------------------------

    def admit(
        self,
        key,
        csp: CSP,
        idx: int = 0,
        *,
        supports_batch: bool = True,
        batched_children: bool = True,
        n_active: Optional[int] = None,
        max_assignments: Optional[int] = None,
        collect_stats: bool = True,
    ) -> SearchStats:
        """Join a new search; it participates from the next ``round()`` on.
        ``idx`` routes the search's rows to its constraint network. Returns
        the live `SearchStats` (filled in as rounds run)."""
        if key in self._gens:
            raise ValueError(f"search key {key!r} already admitted")
        stats = SearchStats()
        gen = _mac_coroutine(
            csp, supports_batch, batched_children, max_assignments, stats,
            n_active=n_active,
        )
        self._pending[key] = gen.send(None)  # root request; always yields ≥ once
        self._gens[key] = gen
        self._idx[key] = int(idx)
        self._stats[key] = stats
        self._collect[key] = collect_stats
        return stats

    def cancel(self, key) -> SearchStats:
        """Evict a live search (e.g. deadline expiry); frees its rows."""
        self._gens.pop(key).close()
        self._pending.pop(key)
        self._idx.pop(key)
        self._collect.pop(key)
        return self._stats.pop(key)

    @property
    def active_keys(self) -> List:
        return sorted(self._pending)

    def is_active(self, key) -> bool:
        return key in self._gens

    @property
    def has_work(self) -> bool:
        return bool(self._pending)

    @property
    def n_pending_rows(self) -> int:
        return sum(req.doms.shape[0] for req in self._pending.values())

    # --- one lockstep round -------------------------------------------------

    def round(self) -> Dict[object, Tuple[Optional[List[int]], SearchStats]]:
        """Dispatch every live search's pending frontier as ONE call, advance
        each search, and return ``{key: (solution | None, stats)}`` for the
        searches that finished this round (their rows are freed)."""
        if not self._pending:
            return {}
        order = sorted(self._pending)
        sizes = [self._pending[k].doms.shape[0] for k in order]
        doms = np.concatenate([self._pending[k].doms for k in order])
        chs = np.concatenate(
            [
                self._pending[k].changed
                if self._pending[k].changed is not None
                else np.ones((self._pending[k].doms.shape[0], self._n), bool)
                for k in order
            ]
        )
        idx = np.repeat(np.asarray([self._idx[k] for k in order], np.int32), sizes)
        r = len(idx)
        # Pad the round up to a power of two only for stacked-dispatch engines
        # (jit-shape reuse, as in the single-search frontier path); on the
        # host-routing fallback padded rows would be real work thrown away.
        r_p = _next_pow2(r) if self._pad_rounds else r
        if r_p != r:
            doms = np.concatenate([doms, np.repeat(doms[-1:], r_p - r, axis=0)])
            chs = np.concatenate([chs, np.repeat(chs[-1:], r_p - r, axis=0)])
            idx = np.concatenate([idx, np.repeat(idx[-1:], r_p - r)])

        t0 = time.perf_counter()
        res = self._dispatch(doms, chs, idx)
        doms_out = np.asarray(res.dom)
        cons_out = np.asarray(res.consistent)
        ks = np.asarray(res.n_recurrences)
        dt = time.perf_counter() - t0

        off = 0
        finished: Dict[object, Tuple[Optional[List[int]], SearchStats]] = {}
        for k, b in zip(order, sizes):
            rows = slice(off, off + b)
            off += b
            stats = self._stats[k]
            if self._collect[k]:
                stats.enforce_seconds.append(dt * b / r_p)
                counts = (
                    stats.recurrences
                    if self._count_unit == "recurrences"
                    else stats.revisions
                )
                counts.extend(int(v) for v in ks[rows])
            try:
                self._pending[k] = self._gens[k].send(
                    _Reply(doms_out[rows], cons_out[rows])
                )
            except StopIteration as stop:
                finished[k] = (stop.value, stats)
            except BudgetExceeded:
                stats.exhausted = True
                finished[k] = (None, stats)
        for k in finished:
            del self._gens[k], self._pending[k], self._idx[k]
            del self._stats[k], self._collect[k]
        return finished


# ---------------------------------------------------------------------------
# solve_many — the portfolio entry point (one workload, many CSPs)
# ---------------------------------------------------------------------------


def solve_many(
    csps: Sequence[CSP],
    engine: Union[Engine, str] = "einsum",
    support_fn=None,
    max_assignments: Optional[int] = None,
    batched_children: bool = True,
    collect_stats: bool = True,
) -> Tuple[List[Optional[List[int]]], List[SearchStats]]:
    """Run B independent MAC searches (instances sharing (n, d)) to completion.

    On batch-capable engines the searches advance in lockstep: every round
    concatenates each active search's pending frontier into one
    ``enforce_many`` dispatch against the `Engine.prepare_many` stacked
    networks (the round is padded up to a power of two for jit-shape reuse).
    ``max_assignments`` is a *per-instance* budget. Solutions and per-instance
    search statistics are identical to sequential ``mac_solve``;
    ``enforce_seconds`` attributes each round's wall-clock to its participants
    proportionally to their row counts.

    Sequential engines (``supports_batch=False``, i.e. AC3) degrade to one
    ``mac_solve`` per instance — same results, no amortization.

    Returns (solutions, stats) as same-length lists, index-aligned with
    ``csps``.
    """
    csps = list(csps)
    eng = resolve_engine(engine, support_fn)
    if not csps:
        return [], []

    if not eng.supports_batch:
        sols, stats = [], []
        for csp in csps:
            s, st = mac_solve(
                csp,
                engine=eng,
                max_assignments=max_assignments,
                batched_children=batched_children,
                collect_stats=collect_stats,
            )
            sols.append(s)
            stats.append(st)
        return sols, stats

    prepared = eng.prepare_many(csps)  # the ONLY preparation in the whole run
    driver = LockstepDriver(
        prepared.enforce_many,
        prepared.n_vars,
        count_unit=eng.count_unit,
        # capability advertisement, not a backend-name check: every stacked
        # engine (einsum/full and the Pallas stacked kernels) pads rounds for
        # jit-shape reuse; host-routing engines would pay for padded rows
        pad_rounds=eng.stacked_many,
    )
    all_stats = [
        driver.admit(
            i,
            csp,
            idx=i,
            batched_children=batched_children,
            max_assignments=max_assignments,
            collect_stats=collect_stats,
        )
        for i, csp in enumerate(csps)
    ]
    sols: List[Optional[List[int]]] = [None] * len(csps)
    while driver.has_work:
        for i, (sol, _st) in driver.round().items():
            sols[i] = sol
    return sols, all_stats


def check_solution(csp: CSP, solution: List[int]) -> bool:
    cons = np.asarray(csp.cons)
    mask = np.asarray(csp.mask)
    dom = np.asarray(csp.dom)
    n = len(solution)
    for x in range(n):
        if not dom[x, solution[x]]:
            return False
        for y in range(x + 1, n):
            if mask[x, y] and not cons[x, y, solution[x], solution[y]]:
                return False
    return True
