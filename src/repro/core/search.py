"""MAC backtrack search (paper Alg. 2) over either enforcement engine.

``mac_solve`` maintains arc consistency with RTAC (device-resident fixpoint) or
AC3 (host baseline) after every assignment, recording per-assignment statistics —
exactly the quantities of paper Table 1 (#Recurrence / #Revision averaged over
assignments) and Fig. 3 (time per assignment).

Beyond the paper: ``batched_children=True`` enforces ALL candidate values of the
branching variable in one ``vmap``-batched fixpoint (one device dispatch per
*node* instead of per *child*), which the sequential paradigm cannot express.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ac3 as _ac3
from . import rtac as _rtac
from .csp import CSP


@dataclasses.dataclass
class SearchStats:
    n_assignments: int = 0
    n_backtracks: int = 0
    recurrences: List[int] = dataclasses.field(default_factory=list)  # per enforcement
    enforce_seconds: List[float] = dataclasses.field(default_factory=list)

    @property
    def mean_recurrences(self) -> float:
        return float(np.mean(self.recurrences)) if self.recurrences else 0.0

    @property
    def mean_enforce_ms(self) -> float:
        return 1e3 * float(np.mean(self.enforce_seconds)) if self.enforce_seconds else 0.0


class BudgetExceeded(Exception):
    pass


def _select_var(dom_np: np.ndarray, assigned: np.ndarray) -> int:
    """Minimum-remaining-values heuristic (paper leaves `heuristics()` open)."""
    sizes = dom_np.sum(axis=1).astype(np.int64)
    sizes[assigned] = np.iinfo(np.int64).max
    return int(np.argmin(sizes))


def mac_solve(
    csp: CSP,
    engine: str = "rtac",  # "rtac" | "rtac_full" | "ac3"
    support_fn=_rtac.einsum_support,
    max_assignments: Optional[int] = None,
    batched_children: bool = False,
    collect_stats: bool = True,
) -> tuple[Optional[List[int]], SearchStats]:
    """Returns (solution | None, stats). Raises nothing on budget exhaustion —
    stops and returns (None, stats) with ``stats.n_assignments`` at the cap."""
    stats = SearchStats()
    n, d = csp.dom.shape
    cons_np = np.asarray(csp.cons)
    mask_np = np.asarray(csp.mask)

    use_ac3 = engine == "ac3"
    if engine == "rtac":
        enf = lambda dom, ch: _rtac.enforce(csp.cons, csp.mask, dom, ch, support_fn=support_fn)
    elif engine == "rtac_full":
        enf = lambda dom, ch: _rtac.enforce_full(csp.cons, csp.mask, dom, support_fn=support_fn)
    elif engine != "ac3":
        raise ValueError(f"unknown engine {engine!r}")

    def enforce_from(dom, changed_idx: Optional[int]):
        """Run enforcement; returns (dom', consistent, count)."""
        t0 = time.perf_counter()
        if use_ac3:
            ch = None
            if changed_idx is not None:
                ch = np.zeros((n,), bool)
                ch[changed_idx] = True
            res = _ac3.enforce_ac3(cons_np, mask_np, np.asarray(dom), ch)
            out = (res.dom, res.consistent, res.n_revisions)
        else:
            ch = None
            if changed_idx is not None:
                ch = jnp.zeros((n,), jnp.bool_).at[changed_idx].set(True)
            res = enf(dom, ch)
            out = (res.dom, bool(res.consistent), int(res.n_recurrences))
        if collect_stats:
            stats.enforce_seconds.append(time.perf_counter() - t0)
            stats.recurrences.append(out[2])
        return out

    # Root propagation (Alg. 2 line 3).
    dom0, ok, _ = enforce_from(csp.dom, None)
    if not ok:
        return None, stats

    assigned = np.zeros((n,), dtype=bool)

    def dfs(dom) -> Optional[List[int]]:
        dom_np = np.asarray(dom)
        if assigned.all():
            return [int(np.argmax(dom_np[x])) for x in range(n)]
        var = _select_var(dom_np, assigned)
        values = [int(v) for v in np.nonzero(dom_np[var])[0]]

        child_results = None
        if batched_children and not use_ac3 and len(values) > 1:
            doms = jnp.stack(
                [_rtac.assign(jnp.asarray(dom), var, v) for v in values]
            )
            ch = jnp.zeros((len(values), n), jnp.bool_).at[:, var].set(True)
            t0 = time.perf_counter()
            res = _rtac.enforce_batch(csp.cons, csp.mask, doms, ch, support_fn=support_fn)
            if collect_stats:
                stats.enforce_seconds.append(time.perf_counter() - t0)
                stats.recurrences.extend(int(k) for k in res.n_recurrences)
            child_results = res

        assigned[var] = True
        try:
            for i, val in enumerate(values):
                stats.n_assignments += 1
                if max_assignments and stats.n_assignments > max_assignments:
                    raise BudgetExceeded
                if child_results is not None:
                    ok_i = bool(child_results.consistent[i])
                    dom_i = child_results.dom[i]
                else:
                    if use_ac3:
                        dom_a = _ac3.assign_np(dom_np, var, val)
                    else:
                        dom_a = _rtac.assign(jnp.asarray(dom), var, val)
                    dom_i, ok_i, _ = enforce_from(dom_a, var)
                if ok_i:
                    sol = dfs(dom_i)
                    if sol is not None:
                        return sol
                stats.n_backtracks += 1
            return None
        finally:
            assigned[var] = False

    try:
        sol = dfs(dom0)
    except BudgetExceeded:
        return None, stats
    return sol, stats


def check_solution(csp: CSP, solution: List[int]) -> bool:
    cons = np.asarray(csp.cons)
    mask = np.asarray(csp.mask)
    dom = np.asarray(csp.dom)
    n = len(solution)
    for x in range(n):
        if not dom[x, solution[x]]:
            return False
        for y in range(x + 1, n):
            if mask[x, y] and not cons[x, y, solution[x], solution[y]]:
                return False
    return True
