"""RTAC core — the paper's contribution as a composable JAX module."""

from .csp import (
    CSP,
    CSPBenchSpec,
    PAPER_GRID,
    coloring_csp,
    make_csp,
    nqueens_csp,
    pad_domains,
    random_csp,
    sudoku_csp,
    to_paper_cons,
)
from .rtac import (
    EnforceResult,
    assign,
    einsum_support,
    enforce,
    enforce_batch,
    enforce_full,
    enforce_full_batch,
)
from .ac3 import AC3Result, build_neighbours, enforce_ac3, assign_np
from .brute import ac_closure_brute, count_solutions, solve_brute
from .engine import Engine, FrontierTable, PreparedMany, PreparedNetwork, SlotPool
from .search import (
    LockstepDriver,
    SearchStats,
    check_solution,
    mac_solve,
    resolve_engine,
    solve_many,
)

__all__ = [
    "CSP",
    "CSPBenchSpec",
    "PAPER_GRID",
    "coloring_csp",
    "make_csp",
    "nqueens_csp",
    "pad_domains",
    "random_csp",
    "sudoku_csp",
    "to_paper_cons",
    "EnforceResult",
    "assign",
    "einsum_support",
    "enforce",
    "enforce_batch",
    "enforce_full",
    "enforce_full_batch",
    "AC3Result",
    "build_neighbours",
    "enforce_ac3",
    "assign_np",
    "ac_closure_brute",
    "count_solutions",
    "solve_brute",
    "Engine",
    "FrontierTable",
    "PreparedMany",
    "PreparedNetwork",
    "SlotPool",
    "LockstepDriver",
    "SearchStats",
    "check_solution",
    "mac_solve",
    "resolve_engine",
    "solve_many",
]
