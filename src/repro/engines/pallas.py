"""Pallas kernel engines — dense uint8 and bitpacked uint32 revise (DESIGN.md §4).

``prepare`` pays the O(n²d²) padding / transpose / bitpack of the constraint
tensor exactly once per CSP; the hot path pads only the O(n·d) domain (and
changed seed) into kernel coordinates and un-pads the result, so callers never
see padded shapes. The revise closures come from the ``lru_cache``-d factories
in `repro.kernels.ops`, so their identity is stable and the RTAC fixpoint
compiles once per (shape, blocks) — including under ``vmap`` for
``enforce_batch`` (Pallas interpret and compiled modes both batch).

Workload/service paths are fully device-resident (no host routing):

- ``prepare_many`` stacks the per-instance prepared networks into
  ``(B, n_p·d_p, cols)`` tables (packed uint32 words for `pallas_packed`) and
  ``enforce_many`` runs ONE stacked fixpoint (`rtac.enforce_rows_generic`)
  whose revise is the stacked kernel — the grid carries the instance axis.
- ``open_slot_pool`` backs the service with a `StackedSlotPool` over the same
  tables: installs are donated ``.at[slot].set`` row writes into the
  ``(C, n_p·d_p, n_p·W)`` packed slot table, and every round is one jitted
  gather + stacked-kernel dispatch. Results are bit-identical to the einsum
  slot path by construction (same coroutine, same per-row fixpoint semantics).

``network_nbytes`` reports the engine's TRUE resident footprint — padded u8
bytes for `pallas_dense`, packed u32 words (8× less) for `pallas_packed` — so
the service cache budget admits proportionally more packed networks.
"""

from __future__ import annotations

import os

import jax.numpy as jnp

from repro.core import rtac
from repro.core.csp import CSP
from repro.core.engine import (
    Engine,
    PreparedMany,
    PreparedNetwork,
    StackedSlotPool,
    as_changed,
    pad_changed,
    pad_dom,
    padded_shape,
    resolve_instance_idx,
)
from repro.core.rtac import EnforceResult, enforce_batch_generic, enforce_generic
from repro.kernels import ops
from . import register


class _PallasEngine(Engine):
    """Shared prepare/enforce plumbing; subclasses pick the kernel binding.

    Subclass hooks (``dims`` is the kernel-coordinate tuple — (n_p, d_p) for
    dense, (n_p, d_p, w) for packed):

    - ``_prepare_net(csp) -> (network, dims)`` — the memoized padded/packed
      resident form;
    - ``_dims(n, d)`` — kernel dims for a caller shape (no CSP needed; must
      agree with ``_prepare_net`` for that shape);
    - ``_revise_fn(dims)`` / ``_rows_fn(dims)`` — the single and stacked
      revise closures;
    - ``_empty_tables(dims, capacity)`` — zeroed slot tables for the pool.
    """

    stacked_many = True
    slot_table = True
    device_frontier = True
    # stacked kernel rows are near-free up to the tile width
    speculative_rows_hint = 64

    def __init__(
        self,
        block_rx: int = 8,
        block_ry: int = 8,
        interpret: bool = True,
        fixpoint: str | None = None,
    ):
        self.block_rx = block_rx
        self.block_ry = block_ry
        self.interpret = interpret
        # Recurrence placement: "fused" runs the whole fixpoint inside ONE
        # kernel launch (domains pinned in VMEM, SMEM convergence flag);
        # "stepped" is the original XLA while_loop around per-iteration revise
        # launches — kept as the fallback and the parity oracle. Bit-identical
        # by construction (tests/test_fused.py sweeps both).
        if fixpoint is None:
            fixpoint = os.environ.get("REPRO_PALLAS_FIXPOINT", "fused")
        if fixpoint not in ("fused", "stepped"):
            raise ValueError(
                f"fixpoint must be 'fused' or 'stepped', got {fixpoint!r}"
            )
        self.fixpoint = fixpoint
        self.fused_fixpoint = fixpoint == "fused"

    def _pad_shape(self, n: int, d: int):
        """The §2 padding the kernel shims apply for this engine's blocks —
        same `padded_shape` formula, same `ops.D_MULT`, agreement by
        construction."""
        return padded_shape(n, d, max(self.block_rx, self.block_ry), ops.D_MULT)

    # --- single-network path (one search, many domains) ---------------------

    def _prepare_payload(self, csp: CSP):
        network, dims = self._prepare_net(csp)
        return network, dims, self._revise_fn(dims)

    def enforce(self, prepared: PreparedNetwork, dom, changed0=None) -> EnforceResult:
        network, dims, revise_fn = prepared.payload
        n_p, d_p = dims[0], dims[1]
        n, d = prepared.n_vars, prepared.dom_size
        dom_p = pad_dom(jnp.asarray(dom), n_p, d_p)
        ch_p = pad_changed(changed0, n, n_p)
        res = enforce_generic(network, dom_p, ch_p, revise_fn=revise_fn)
        return EnforceResult(res.dom[:n, :d], res.consistent, res.n_recurrences)

    def enforce_batch(self, prepared: PreparedNetwork, doms, changed0=None) -> EnforceResult:
        network, dims, revise_fn = prepared.payload
        n_p, d_p = dims[0], dims[1]
        n, d = prepared.n_vars, prepared.dom_size
        doms = jnp.asarray(doms)
        dom_p = pad_dom(doms, n_p, d_p)
        ch_p = pad_changed(changed0, n, n_p, batch=doms.shape[:-2])
        res = enforce_batch_generic(network, dom_p, ch_p, revise_fn=revise_fn)
        return EnforceResult(res.dom[:, :n, :d], res.consistent, res.n_recurrences)

    # --- stacked workload path (R rows, each against its OWN network) -------

    def _prepare_many_payload(self, csps):
        nets = [self._prepare_net(c) for c in csps]
        dims = nets[0][1]
        tables = (
            jnp.stack([net[0][0] for net in nets]),
            jnp.stack([net[0][1] for net in nets]),
        )
        return tables, dims, self._rows_fn(dims)

    def _rows_dispatch(self, tables, dims, rows_fn, n, d, doms, changed0, idx):
        """Pad R caller-coordinate rows into kernel coordinates, run the ONE
        stacked gather+kernel fixpoint, un-pad. Shared by `enforce_many` and
        the slot pool."""
        n_p, d_p = dims[0], dims[1]
        doms = jnp.asarray(doms)
        dom_p = pad_dom(doms, n_p, d_p)
        ch_p = pad_changed(as_changed(changed0), n, n_p, batch=doms.shape[:-2])
        if self.fused_fixpoint:
            self._maybe_autotune(dims, dom_p.shape[0])
            res = ops.enforce_rows_fused(
                tables, dom_p, ch_p, jnp.asarray(idx),
                fixpoint_rows_fn=self._fixpoint_rows_fn(dims),
            )
        else:
            res = rtac.enforce_rows_generic(
                tables, dom_p, ch_p, jnp.asarray(idx), revise_rows_fn=rows_fn
            )
        return EnforceResult(res.dom[:, :n, :d], res.consistent, res.n_recurrences)

    def _maybe_autotune(self, dims, r: int) -> None:
        """Eager, env-gated (``REPRO_AUTOTUNE=1``) tune-on-first-use for the
        bucket about to be dispatched — runs BEFORE the jitted fused program
        traces, so the schedule it bakes is the tuned one."""
        from repro.kernels import autotune

        w = dims[2] if len(dims) > 2 else 0
        autotune.maybe_tune(self._fixpoint_kind, dims[0], dims[1], w, r)

    def enforce_many(
        self, prepared: PreparedMany, doms, changed0=None, instance_idx=None
    ) -> EnforceResult:
        tables, dims, rows_fn = prepared.payload
        idx = resolve_instance_idx(
            instance_idx, prepared.n_instances, len(doms)
        )
        return self._rows_dispatch(
            tables, dims, rows_fn,
            prepared.n_vars, prepared.dom_size, doms, changed0, idx,
        )

    def _open_stacked_slot_pool(self, n_vars, dom_size, capacity) -> StackedSlotPool:
        dims = self._dims(n_vars, dom_size)
        rows_fn = self._rows_fn(dims)

        def dispatch(tables, doms, changed0, idx):
            return self._rows_dispatch(
                tables, dims, rows_fn, n_vars, dom_size, doms, changed0, idx
            )

        return StackedSlotPool(
            self, n_vars, dom_size, capacity,
            self._empty_tables(dims, capacity),
            encode=lambda csp: self._prepare_net(csp)[0],
            dispatch=dispatch,
        )

    # --- device-resident frontiers (DESIGN.md §8) ---------------------------

    def frontier_fix(self):
        """The `lru_cache`-d fused assign+revise entry from `kernels.ops`
        (stable identity per (kernel, blocks, interpret) — keys the frontier
        step's jit cache); kernel dims derive from the row shapes at trace
        time, so one fix object serves every bucket. In fused mode the whole
        round's recurrence is one kernel launch."""
        fn = self._frontier_fused_fn if self.fused_fixpoint else self._frontier_fn
        return fn(self.block_rx, self.block_ry, self.interpret)

    def frontier_networks(self, prepared: PreparedMany):
        return prepared.payload[0]


@register
class PallasDenseEngine(_PallasEngine):
    """Incremental RTAC with the dense uint8 Pallas revise kernel."""

    name = "pallas_dense"
    _frontier_fn = staticmethod(ops._dense_frontier_fn)
    _frontier_fused_fn = staticmethod(ops._dense_frontier_fused_fn)
    _fixpoint_kind = "dense"

    def _prepare_net(self, csp: CSP):
        network, _, (n_p, d_p) = ops.prepare_dense(csp, self.block_rx, self.block_ry)
        return network, (n_p, d_p)

    def _dims(self, n: int, d: int):
        return self._pad_shape(n, d)

    def _revise_fn(self, dims):
        n_p, d_p = dims
        return ops._dense_revise_fn(n_p, d_p, self.block_rx, self.block_ry, self.interpret)

    def _rows_fn(self, dims):
        n_p, d_p = dims
        return ops._dense_rows_fn(n_p, d_p, self.block_rx, self.block_ry, self.interpret)

    def _fixpoint_rows_fn(self, dims):
        n_p, d_p = dims
        return ops._dense_fixpoint_rows_fn(
            n_p, d_p, self.block_rx, self.block_ry, self.interpret
        )

    def _empty_tables(self, dims, capacity: int):
        n_p, d_p = dims
        return (
            jnp.zeros((capacity, n_p * d_p, n_p * d_p), jnp.uint8),
            jnp.zeros((capacity, n_p, n_p), jnp.uint8),
        )

    def network_nbytes(self, n_vars: int, dom_size: int) -> int:
        n_p, d_p = self._pad_shape(n_vars, dom_size)
        return n_p * d_p * n_p * d_p + n_p * n_p  # u8 cons2 + u8 mask


@register
class PallasPackedEngine(_PallasEngine):
    """Incremental RTAC with the bitpacked uint32 Pallas revise kernel
    (8× less constraint traffic than uint8, 16× than bf16)."""

    name = "pallas_packed"
    _frontier_fn = staticmethod(ops._packed_frontier_fn)
    _frontier_fused_fn = staticmethod(ops._packed_frontier_fused_fn)
    _fixpoint_kind = "packed"

    def _prepare_net(self, csp: CSP):
        network, _, (n_p, d_p, w) = ops.prepare_packed(csp, self.block_rx, self.block_ry)
        return network, (n_p, d_p, w)

    def _dims(self, n: int, d: int):
        n_p, d_p = self._pad_shape(n, d)
        return n_p, d_p, -(-d_p // 32)

    def _revise_fn(self, dims):
        n_p, d_p, w = dims
        return ops._packed_revise_fn(
            n_p, d_p, w, self.block_rx, self.block_ry, self.interpret
        )

    def _rows_fn(self, dims):
        n_p, d_p, w = dims
        return ops._packed_rows_fn(
            n_p, d_p, w, self.block_rx, self.block_ry, self.interpret
        )

    def _fixpoint_rows_fn(self, dims):
        n_p, d_p, w = dims
        return ops._packed_fixpoint_rows_fn(
            n_p, d_p, w, self.block_rx, self.block_ry, self.interpret
        )

    def _empty_tables(self, dims, capacity: int):
        n_p, d_p, w = dims
        return (
            jnp.zeros((capacity, n_p * d_p, n_p * w), jnp.uint32),
            jnp.zeros((capacity, n_p, n_p), jnp.uint8),
        )

    def network_nbytes(self, n_vars: int, dom_size: int) -> int:
        n_p, d_p, w = self._dims(n_vars, dom_size)
        return n_p * d_p * n_p * w * 4 + n_p * n_p  # u32 packed words + u8 mask
