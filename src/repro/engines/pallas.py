"""Pallas kernel engines — dense uint8 and bitpacked uint32 revise (DESIGN.md §4).

``prepare`` pays the O(n²d²) padding / transpose / bitpack of the constraint
tensor exactly once per CSP; the hot path pads only the O(n·d) domain (and
changed seed) into kernel coordinates and un-pads the result, so callers never
see padded shapes. The revise closures come from the ``lru_cache``-d factories
in `repro.kernels.ops`, so their identity is stable and the RTAC fixpoint
compiles once per (shape, blocks) — including under ``vmap`` for
``enforce_batch`` (Pallas interpret and compiled modes both batch).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.csp import CSP
from repro.core.engine import Engine, PreparedNetwork, pad_changed, pad_dom
from repro.core.rtac import EnforceResult, enforce_batch_generic, enforce_generic
from repro.kernels import ops
from . import register


class _PallasEngine(Engine):
    """Shared prepare/enforce plumbing; subclasses pick the kernel binding.

    ``prepare_many``/``enforce_many`` use the generic per-instance fallback:
    vmapping a `pallas_call` over the *constraint* operand would re-trace the
    kernel per instance anyway in interpret mode, so the workload path keeps
    one prepared (padded + bitpacked) network per instance and routes rows on
    the host. Each instance still pays its O(n²d²) preparation exactly once.
    """

    def __init__(self, block_rx: int = 8, block_ry: int = 8, interpret: bool = True):
        self.block_rx = block_rx
        self.block_ry = block_ry
        self.interpret = interpret

    # subclasses: _build(csp) -> (network, (n_p, d_p), revise_fn)

    def _prepare_payload(self, csp: CSP):
        return self._build(csp)

    def enforce(self, prepared: PreparedNetwork, dom, changed0=None) -> EnforceResult:
        network, (n_p, d_p), revise_fn = prepared.payload
        n, d = prepared.n_vars, prepared.dom_size
        dom_p = pad_dom(jnp.asarray(dom), n_p, d_p)
        ch_p = pad_changed(changed0, n, n_p)
        res = enforce_generic(network, dom_p, ch_p, revise_fn=revise_fn)
        return EnforceResult(res.dom[:n, :d], res.consistent, res.n_recurrences)

    def enforce_batch(self, prepared: PreparedNetwork, doms, changed0=None) -> EnforceResult:
        network, (n_p, d_p), revise_fn = prepared.payload
        n, d = prepared.n_vars, prepared.dom_size
        doms = jnp.asarray(doms)
        dom_p = pad_dom(doms, n_p, d_p)
        ch_p = pad_changed(changed0, n, n_p, batch=doms.shape[:-2])
        res = enforce_batch_generic(network, dom_p, ch_p, revise_fn=revise_fn)
        return EnforceResult(res.dom[:, :n, :d], res.consistent, res.n_recurrences)


@register
class PallasDenseEngine(_PallasEngine):
    """Incremental RTAC with the dense uint8 Pallas revise kernel."""

    name = "pallas_dense"

    def _build(self, csp: CSP):
        network, _, (n_p, d_p) = ops.prepare_dense(csp, self.block_rx, self.block_ry)
        revise_fn = ops._dense_revise_fn(
            n_p, d_p, self.block_rx, self.block_ry, self.interpret
        )
        return network, (n_p, d_p), revise_fn


@register
class PallasPackedEngine(_PallasEngine):
    """Incremental RTAC with the bitpacked uint32 Pallas revise kernel
    (8× less constraint traffic than uint8, 16× than bf16)."""

    name = "pallas_packed"

    def _build(self, csp: CSP):
        network, _, (n_p, d_p, w) = ops.prepare_packed(csp, self.block_rx, self.block_ry)
        revise_fn = ops._packed_revise_fn(
            n_p, d_p, w, self.block_rx, self.block_ry, self.interpret
        )
        return network, (n_p, d_p), revise_fn
