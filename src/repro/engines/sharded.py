"""Sharded engine — the shard_map fixpoint of `core/sharded.py` behind the
Engine protocol (DESIGN.md §5).

``prepare`` builds the jitted sharded enforcer once per (mesh, impl, dtype),
device_puts the constraint x-rows onto the 'model' axis, and — for
``impl="bitpacked"`` — packs the b-axis into uint32 words. The hot path only
shards the O(B·n·d) domain batch over the batch axes. ``enforce`` is a batch
of one; ``enforce_batch`` pads B up to a multiple of the batch-axis extent
(repeating the last domain — enforcement is idempotent per element) and
slices the result back.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.csp import CSP
from repro.core.engine import Engine, PreparedNetwork
from repro.core.rtac import EnforceResult
from repro.core.sharded import make_sharded_enforcer
from . import register


def _default_mesh() -> Mesh:
    """All host devices on the 'model' axis (constraint rows sharded; batch
    replicated) — the right default for a one-process run."""
    from repro.launch.mesh import make_mesh

    return make_mesh((1, jax.device_count()), ("data", "model"))


@register
class ShardedEngine(Engine):
    name = "sharded"
    # no frontier fabric yet (host-side store): duplication pays per row
    speculative_rows_hint = 16

    def __init__(
        self,
        mesh: Optional[Mesh] = None,
        model_axis: str = "model",
        batch_axes: Tuple[str, ...] = ("data",),
        dtype=jnp.bfloat16,
        impl: str = "einsum",  # "einsum" | "bitpacked"
    ):
        self.mesh = mesh if mesh is not None else _default_mesh()
        self.model_axis = model_axis
        self.batch_axes = batch_axes
        self.dtype = dtype
        self.impl = impl
        self._batch_extent = math.prod(
            dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[a]
            for a in batch_axes
        )

    def build_enforcer(self):
        """The raw jitted (cons, mask, dom_batch, changed_batch) -> EnforceResult;
        exposed for AOT lowering (launch/dryrun_rtac.py)."""
        return make_sharded_enforcer(
            self.mesh,
            model_axis=self.model_axis,
            batch_axes=self.batch_axes,
            dtype=self.dtype,
            impl=self.impl,
        )

    def _prepare_payload(self, csp: CSP):
        cons = csp.cons
        if self.impl == "bitpacked":
            from repro.kernels.ref import pack_bits_ref

            cons = pack_bits_ref(cons)  # (n, n, d, W) uint32
        model_sh = NamedSharding(self.mesh, P(self.model_axis))
        cons_s = jax.device_put(cons, model_sh)
        mask_s = jax.device_put(csp.mask, model_sh)
        return self.build_enforcer(), cons_s, mask_s

    def _run(self, prepared: PreparedNetwork, doms: jax.Array, changed0) -> EnforceResult:
        enf, cons_s, mask_s = prepared.payload
        b, n = doms.shape[0], doms.shape[1]
        if changed0 is None:
            changed0 = jnp.ones((b, n), jnp.bool_)
        changed0 = jnp.asarray(changed0, jnp.bool_)
        # pad B to the batch-axis extent (shard_map needs even shards)
        b_p = -(-b // self._batch_extent) * self._batch_extent
        if b_p != b:
            reps = [doms[-1:]] * (b_p - b)
            doms = jnp.concatenate([doms] + reps, axis=0)
            changed0 = jnp.concatenate([changed0] + [changed0[-1:]] * (b_p - b), axis=0)
        batch_sh = NamedSharding(self.mesh, P(self.batch_axes))
        doms = jax.device_put(doms, batch_sh)
        changed0 = jax.device_put(changed0, batch_sh)
        res = enf(cons_s, mask_s, doms, changed0)
        if b_p != b:
            res = EnforceResult(res.dom[:b], res.consistent[:b], res.n_recurrences[:b])
        return res

    def enforce(self, prepared: PreparedNetwork, dom, changed0=None) -> EnforceResult:
        dom = jnp.asarray(dom)
        if changed0 is not None:
            changed0 = jnp.asarray(changed0, jnp.bool_)[None]
        res = self._run(prepared, dom[None], changed0)
        return EnforceResult(res.dom[0], res.consistent[0], res.n_recurrences[0])

    def enforce_batch(self, prepared: PreparedNetwork, doms, changed0=None) -> EnforceResult:
        return self._run(prepared, jnp.asarray(doms), changed0)

    # prepare_many / enforce_many: generic per-instance fallback. The sharded
    # fixpoint replicates ONE constraint network's x-rows across the 'model'
    # axis; stacking B different networks would multiply the dominant O(n²d²)
    # residency by B per shard, which is exactly what this engine exists to
    # avoid. Workloads of small instances belong on `einsum`/`full`.
