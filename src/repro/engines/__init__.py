"""Engine registry — every enforcement backend behind one protocol (DESIGN.md §3).

    from repro.engines import get_engine
    eng = get_engine("pallas_packed")
    prepared = eng.prepare(csp)            # pad + bitpack + place, ONCE
    res = prepared.enforce(dom, changed0)  # hot path: O(n·d) host work

Registered backends:

    einsum        incremental RTAC (Prop. 2), XLA einsum contraction
    full          paper-faithful dense recurrence (Eq. 1, no incrementality)
    pallas_dense  incremental RTAC, dense uint8 Pallas revise kernel
    pallas_packed incremental RTAC, bitpacked uint32 Pallas revise kernel
    sharded       shard_map fixpoint over a device mesh (cons x-rows on
                  'model', domain batch on 'data')
    ac3           queue-based host baseline (paper §5.1); counts revisions

The pre-Engine legacy names ("rtac", "rtac_full") were removed after their
one deprecation release; use "einsum" / "full".
"""

from __future__ import annotations

from typing import Dict, List, Type

from repro.core.engine import Engine, PreparedNetwork

_REGISTRY: Dict[str, Type[Engine]] = {}


def register(cls: Type[Engine]) -> Type[Engine]:
    """Class decorator: register an Engine subclass under ``cls.name``."""
    _REGISTRY[cls.name] = cls
    return cls


def available_engines() -> List[str]:
    return sorted(_REGISTRY)


def get_engine(name: str, **opts) -> Engine:
    """Instantiate a registered engine by name (``opts`` go to its __init__)."""
    if name not in _REGISTRY:
        raise ValueError(f"unknown engine {name!r}; available: {available_engines()}")
    return _REGISTRY[name](**opts)


# Import for side effect: each module registers its engines.
from . import einsum as _einsum  # noqa: E402
from . import pallas as _pallas  # noqa: E402
from . import sharded as _sharded  # noqa: E402
from . import ac3 as _ac3  # noqa: E402

EinsumEngine = _einsum.EinsumEngine
FullEngine = _einsum.FullEngine
PallasDenseEngine = _pallas.PallasDenseEngine
PallasPackedEngine = _pallas.PallasPackedEngine
ShardedEngine = _sharded.ShardedEngine
AC3Engine = _ac3.AC3Engine

__all__ = [
    "Engine",
    "PreparedNetwork",
    "register",
    "get_engine",
    "available_engines",
    "EinsumEngine",
    "FullEngine",
    "PallasDenseEngine",
    "PallasPackedEngine",
    "ShardedEngine",
    "AC3Engine",
]
