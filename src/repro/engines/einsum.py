"""Einsum engines — the XLA-contraction RTAC backends (no Pallas, no padding).

``einsum`` is the incremental fixpoint of Prop. 2 (the default engine);
``full`` is the paper-faithful bare recurrence of Eq. 1, recomputing the
support test for every (x, a) each step — kept as the fidelity baseline.
"""

from __future__ import annotations

import functools
import warnings
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rtac
from repro.core.csp import CSP
from repro.core.engine import (
    Engine,
    PreparedMany,
    PreparedNetwork,
    SlotPool,
    as_changed,
    resolve_instance_idx,
)
from repro.core.rtac import EnforceResult, SupportFn, einsum_support
from . import register


def _stack_networks(csps: List[CSP]):
    """(B, n, n, d, d) cons + (B, n, n) mask — the stacked workload form."""
    return (
        jnp.stack([c.cons for c in csps]),
        jnp.stack([c.mask for c in csps]),
    )


@functools.partial(jax.jit, donate_argnums=(0,))
def _slot_write(stack, slot, value):
    """In-place-ish slot update: with buffer donation XLA updates the resident
    stack without a copy (TPU/GPU; CPU falls back to a copy and warns once)."""
    return stack.at[slot].set(value)


class _StackedSlotPool(SlotPool):
    """Device-resident slot table for the vmappable engines: installs write
    one network into the stacked (C, n, n, d, d) / (C, n, n) tensors, and
    ``enforce_rows`` is ONE jitted gather+vmap fixpoint over the whole round —
    the open-world analogue of `PreparedMany`'s stacked dispatch."""

    stacked = True

    def __init__(self, engine, n_vars, dom_size, capacity, dispatch):
        super().__init__(engine, n_vars, dom_size, capacity)
        self._round_dispatch = dispatch
        n, d = n_vars, dom_size
        self._cons = jnp.zeros((capacity, n, n, d, d), jnp.bool_)
        self._mask = jnp.zeros((capacity, n, n), jnp.bool_)

    def _prepare_slot(self, slot: int, csp: CSP):
        with warnings.catch_warnings():
            # CPU backends can't honour donation; the copy fallback is correct.
            warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
            self._cons = _slot_write(self._cons, jnp.int32(slot), jnp.asarray(csp.cons))
            self._mask = _slot_write(self._mask, jnp.int32(slot), jnp.asarray(csp.mask))
        return True  # occupancy sentinel; the network lives in the stacks

    def grow(self, capacity: int) -> None:
        old = self.capacity
        super().grow(capacity)
        if capacity > old:
            pad = [(0, capacity - old)] + [(0, 0)] * (self._cons.ndim - 1)
            self._cons = jnp.pad(self._cons, pad)
            self._mask = jnp.pad(self._mask, pad[:3])

    def enforce_rows(self, doms, changed0=None, slot_idx=None):
        doms = jnp.asarray(doms)
        idx = resolve_instance_idx(slot_idx, self.capacity, doms.shape[0])
        for j in np.unique(idx):
            if self._nets[int(j)] is None:
                raise ValueError(f"enforce_rows: slot {int(j)} is empty")
        return self._round_dispatch(
            (self._cons, self._mask), doms, as_changed(changed0), jnp.asarray(idx)
        )


def _revise_for(support_fn: SupportFn):
    """Module-level-stable revise closure (keys `enforce_generic`'s jit cache)."""
    if support_fn is einsum_support:
        return rtac._EINSUM_REVISE
    return rtac._REVISE_CACHE.setdefault(support_fn, rtac.make_einsum_revise(support_fn))


@register
class EinsumEngine(Engine):
    """Incremental RTAC (Prop. 2) with the einsum support contraction."""

    name = "einsum"
    stacked_many = True

    def __init__(self, support_fn: SupportFn = einsum_support):
        self.support_fn = support_fn
        self._revise_fn = _revise_for(support_fn)

    def _prepare_payload(self, csp: CSP):
        return (csp.cons, csp.mask)

    def enforce(self, prepared: PreparedNetwork, dom, changed0=None) -> EnforceResult:
        return rtac.enforce_generic(
            prepared.payload, jnp.asarray(dom), as_changed(changed0),
            revise_fn=self._revise_fn,
        )

    def enforce_batch(self, prepared: PreparedNetwork, doms, changed0=None) -> EnforceResult:
        return rtac.enforce_batch_generic(
            prepared.payload, jnp.asarray(doms), as_changed(changed0),
            revise_fn=self._revise_fn,
        )

    def _prepare_many_payload(self, csps: List[CSP]):
        return _stack_networks(csps)

    def enforce_many(self, prepared: PreparedMany, doms, changed0=None, instance_idx=None) -> EnforceResult:
        doms = jnp.asarray(doms)
        idx = resolve_instance_idx(instance_idx, prepared.n_instances, doms.shape[0])
        return rtac.enforce_many_generic(
            prepared.payload, doms, as_changed(changed0), jnp.asarray(idx),
            revise_fn=self._revise_fn,
        )

    def open_slot_pool(self, n_vars: int, dom_size: int, capacity: int) -> SlotPool:
        def dispatch(networks, doms, changed0, idx):
            return rtac.enforce_many_generic(
                networks, doms, changed0, idx, revise_fn=self._revise_fn
            )

        return _StackedSlotPool(self, n_vars, dom_size, capacity, dispatch)


@register
class FullEngine(Engine):
    """Paper-faithful dense recurrence (Eq. 1). Ignores ``changed0`` — every
    step re-tests all (x, a) pairs, exactly as published."""

    name = "full"
    stacked_many = True

    def __init__(self, support_fn: SupportFn = einsum_support):
        self.support_fn = support_fn

    def _prepare_payload(self, csp: CSP):
        return (csp.cons, csp.mask)

    def enforce(self, prepared: PreparedNetwork, dom, changed0=None) -> EnforceResult:
        cons, mask = prepared.payload
        return rtac.enforce_full(cons, mask, jnp.asarray(dom), support_fn=self.support_fn)

    def enforce_batch(self, prepared: PreparedNetwork, doms, changed0=None) -> EnforceResult:
        cons, mask = prepared.payload
        return rtac.enforce_full_batch(cons, mask, jnp.asarray(doms), support_fn=self.support_fn)

    def _prepare_many_payload(self, csps: List[CSP]):
        return _stack_networks(csps)

    def enforce_many(self, prepared: PreparedMany, doms, changed0=None, instance_idx=None) -> EnforceResult:
        doms = jnp.asarray(doms)
        idx = resolve_instance_idx(instance_idx, prepared.n_instances, doms.shape[0])
        cons, mask = prepared.payload
        return rtac.enforce_full_many(
            cons, mask, doms, jnp.asarray(idx), support_fn=self.support_fn
        )

    def open_slot_pool(self, n_vars: int, dom_size: int, capacity: int) -> SlotPool:
        def dispatch(networks, doms, changed0, idx):
            cons, mask = networks
            del changed0  # the paper-faithful recurrence re-tests everything
            return rtac.enforce_full_many(cons, mask, doms, idx, support_fn=self.support_fn)

        return _StackedSlotPool(self, n_vars, dom_size, capacity, dispatch)
