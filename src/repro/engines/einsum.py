"""Einsum engines — the XLA-contraction RTAC backends (no Pallas, no padding).

``einsum`` is the incremental fixpoint of Prop. 2 (the default engine);
``full`` is the paper-faithful bare recurrence of Eq. 1, recomputing the
support test for every (x, a) each step — kept as the fidelity baseline.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import rtac
from repro.core.csp import CSP
from repro.core.engine import Engine, PreparedNetwork, as_changed
from repro.core.rtac import EnforceResult, SupportFn, einsum_support
from . import register


def _revise_for(support_fn: SupportFn):
    """Module-level-stable revise closure (keys `enforce_generic`'s jit cache)."""
    if support_fn is einsum_support:
        return rtac._EINSUM_REVISE
    return rtac._REVISE_CACHE.setdefault(support_fn, rtac.make_einsum_revise(support_fn))


@register
class EinsumEngine(Engine):
    """Incremental RTAC (Prop. 2) with the einsum support contraction."""

    name = "einsum"

    def __init__(self, support_fn: SupportFn = einsum_support):
        self.support_fn = support_fn
        self._revise_fn = _revise_for(support_fn)

    def _prepare_payload(self, csp: CSP):
        return (csp.cons, csp.mask)

    def enforce(self, prepared: PreparedNetwork, dom, changed0=None) -> EnforceResult:
        return rtac.enforce_generic(
            prepared.payload, jnp.asarray(dom), as_changed(changed0),
            revise_fn=self._revise_fn,
        )

    def enforce_batch(self, prepared: PreparedNetwork, doms, changed0=None) -> EnforceResult:
        return rtac.enforce_batch_generic(
            prepared.payload, jnp.asarray(doms), as_changed(changed0),
            revise_fn=self._revise_fn,
        )


@register
class FullEngine(Engine):
    """Paper-faithful dense recurrence (Eq. 1). Ignores ``changed0`` — every
    step re-tests all (x, a) pairs, exactly as published."""

    name = "full"

    def __init__(self, support_fn: SupportFn = einsum_support):
        self.support_fn = support_fn

    def _prepare_payload(self, csp: CSP):
        return (csp.cons, csp.mask)

    def enforce(self, prepared: PreparedNetwork, dom, changed0=None) -> EnforceResult:
        cons, mask = prepared.payload
        return rtac.enforce_full(cons, mask, jnp.asarray(dom), support_fn=self.support_fn)

    def enforce_batch(self, prepared: PreparedNetwork, doms, changed0=None) -> EnforceResult:
        cons, mask = prepared.payload
        return rtac.enforce_full_batch(cons, mask, jnp.asarray(doms), support_fn=self.support_fn)
