"""Einsum engines — the XLA-contraction RTAC backends (no Pallas, no padding).

``einsum`` is the incremental fixpoint of Prop. 2 (the default engine);
``full`` is the paper-faithful bare recurrence of Eq. 1, recomputing the
support test for every (x, a) each step — kept as the fidelity baseline.
"""

from __future__ import annotations

import functools
from typing import List

import jax.numpy as jnp

from repro.core import rtac
from repro.core.csp import CSP
from repro.core.engine import (
    Engine,
    PreparedMany,
    PreparedNetwork,
    StackedSlotPool,
    as_changed,
    resolve_instance_idx,
)
from repro.core.rtac import EnforceResult, SupportFn, einsum_support
from . import register


@functools.lru_cache(maxsize=None)
def _einsum_frontier_fix(revise_fn):
    """Stable-identity fused frontier core (keys the frontier step's jit
    cache): batched assign + seed + the gather/vmap incremental fixpoint."""

    def fix(networks, doms, var, val, net_idx):
        return rtac.assign_enforce_many(networks, doms, var, val, net_idx,
                                        revise_fn=revise_fn)

    return fix


@functools.lru_cache(maxsize=None)
def _full_frontier_fix(support_fn):
    def fix(networks, doms, var, val, net_idx):
        cons, mask = networks
        return rtac.assign_enforce_full_many(cons, mask, doms, var, val, net_idx,
                                             support_fn=support_fn)

    return fix


def _stack_networks(csps: List[CSP]):
    """(B, n, n, d, d) cons + (B, n, n) mask — the stacked workload form."""
    return (
        jnp.stack([c.cons for c in csps]),
        jnp.stack([c.mask for c in csps]),
    )


def _open_einsum_pool(engine, n_vars, dom_size, capacity, round_dispatch):
    """Shared einsum/full slot pool: unpadded bool (C, n, n, d, d) / (C, n, n)
    tables; the round dispatch is the same jitted gather+vmap fixpoint as
    `enforce_many`."""
    n, d = n_vars, dom_size
    tables = (
        jnp.zeros((capacity, n, n, d, d), jnp.bool_),
        jnp.zeros((capacity, n, n), jnp.bool_),
    )

    def dispatch(tables, doms, changed0, idx):
        return round_dispatch(
            tables, jnp.asarray(doms), as_changed(changed0), jnp.asarray(idx)
        )

    return StackedSlotPool(
        engine, n_vars, dom_size, capacity,
        tables, encode=lambda csp: (csp.cons, csp.mask), dispatch=dispatch,
    )


def _revise_for(support_fn: SupportFn):
    """Module-level-stable revise closure (keys `enforce_generic`'s jit cache)."""
    if support_fn is einsum_support:
        return rtac._EINSUM_REVISE
    return rtac._REVISE_CACHE.setdefault(support_fn, rtac.make_einsum_revise(support_fn))


@register
class EinsumEngine(Engine):
    """Incremental RTAC (Prop. 2) with the einsum support contraction."""

    name = "einsum"
    stacked_many = True
    slot_table = True
    device_frontier = True
    # stacked frontier rounds amortize extra rows — speculation is cheap here
    speculative_rows_hint = 64

    def __init__(self, support_fn: SupportFn = einsum_support):
        self.support_fn = support_fn
        self._revise_fn = _revise_for(support_fn)

    def _prepare_payload(self, csp: CSP):
        return (csp.cons, csp.mask)

    def enforce(self, prepared: PreparedNetwork, dom, changed0=None) -> EnforceResult:
        return rtac.enforce_generic(
            prepared.payload, jnp.asarray(dom), as_changed(changed0),
            revise_fn=self._revise_fn,
        )

    def enforce_batch(self, prepared: PreparedNetwork, doms, changed0=None) -> EnforceResult:
        return rtac.enforce_batch_generic(
            prepared.payload, jnp.asarray(doms), as_changed(changed0),
            revise_fn=self._revise_fn,
        )

    def _prepare_many_payload(self, csps: List[CSP]):
        return _stack_networks(csps)

    def enforce_many(self, prepared: PreparedMany, doms, changed0=None, instance_idx=None) -> EnforceResult:
        doms = jnp.asarray(doms)
        idx = resolve_instance_idx(instance_idx, prepared.n_instances, doms.shape[0])
        return rtac.enforce_many_generic(
            prepared.payload, doms, as_changed(changed0), jnp.asarray(idx),
            revise_fn=self._revise_fn,
        )

    def _open_stacked_slot_pool(self, n_vars, dom_size, capacity) -> StackedSlotPool:
        def dispatch(networks, doms, changed0, idx):
            return rtac.enforce_many_generic(
                networks, doms, changed0, idx, revise_fn=self._revise_fn
            )

        return _open_einsum_pool(self, n_vars, dom_size, capacity, dispatch)

    def frontier_fix(self):
        return _einsum_frontier_fix(self._revise_fn)

    def frontier_networks(self, prepared: PreparedMany):
        return prepared.payload


@register
class FullEngine(Engine):
    """Paper-faithful dense recurrence (Eq. 1). Ignores ``changed0`` — every
    step re-tests all (x, a) pairs, exactly as published."""

    name = "full"
    stacked_many = True
    slot_table = True
    device_frontier = True
    speculative_rows_hint = 64

    def __init__(self, support_fn: SupportFn = einsum_support):
        self.support_fn = support_fn

    def _prepare_payload(self, csp: CSP):
        return (csp.cons, csp.mask)

    def enforce(self, prepared: PreparedNetwork, dom, changed0=None) -> EnforceResult:
        cons, mask = prepared.payload
        return rtac.enforce_full(cons, mask, jnp.asarray(dom), support_fn=self.support_fn)

    def enforce_batch(self, prepared: PreparedNetwork, doms, changed0=None) -> EnforceResult:
        cons, mask = prepared.payload
        return rtac.enforce_full_batch(cons, mask, jnp.asarray(doms), support_fn=self.support_fn)

    def _prepare_many_payload(self, csps: List[CSP]):
        return _stack_networks(csps)

    def enforce_many(self, prepared: PreparedMany, doms, changed0=None, instance_idx=None) -> EnforceResult:
        doms = jnp.asarray(doms)
        idx = resolve_instance_idx(instance_idx, prepared.n_instances, doms.shape[0])
        cons, mask = prepared.payload
        return rtac.enforce_full_many(
            cons, mask, doms, jnp.asarray(idx), support_fn=self.support_fn
        )

    def _open_stacked_slot_pool(self, n_vars, dom_size, capacity) -> StackedSlotPool:
        def dispatch(networks, doms, changed0, idx):
            cons, mask = networks
            del changed0  # the paper-faithful recurrence re-tests everything
            return rtac.enforce_full_many(cons, mask, doms, idx, support_fn=self.support_fn)

        return _open_einsum_pool(self, n_vars, dom_size, capacity, dispatch)

    def frontier_fix(self):
        return _full_frontier_fix(self.support_fn)

    def frontier_networks(self, prepared: PreparedMany):
        return prepared.payload
