"""AC3 engine — the sequential host baseline (paper §5.1) behind the Engine
protocol. ``prepare`` converts the constraint tensors to numpy and builds the
adjacency lists once; ``count_unit`` is "revisions" (paper Table 1 #Revision),
which `SearchStats` files separately from the tensor engines' recurrences.
"""

from __future__ import annotations

import numpy as np

from repro.core import ac3 as _ac3
from repro.core.csp import CSP
from repro.core.engine import Engine, PreparedNetwork
from repro.core.rtac import EnforceResult
from . import register


@register
class AC3Engine(Engine):
    name = "ac3"
    count_unit = "revisions"
    # sequential baseline: a "batch" is just a host loop, so eager frontier
    # batching in search would waste work — enforce children lazily instead
    supports_batch = False
    # every speculative row is a full host enforcement — keep duplication low
    speculative_rows_hint = 8

    def _prepare_payload(self, csp: CSP):
        cons = np.asarray(csp.cons)
        mask = np.asarray(csp.mask)
        return cons, mask, _ac3.build_neighbours(mask)

    def enforce(self, prepared: PreparedNetwork, dom, changed0=None) -> EnforceResult:
        cons, mask, neighbours = prepared.payload
        if changed0 is not None:
            changed0 = np.asarray(changed0, dtype=bool)
        res = _ac3.enforce_ac3(
            cons, mask, np.asarray(dom), changed0, neighbours=neighbours
        )
        # n_recurrences carries this engine's native unit: revisions.
        return EnforceResult(res.dom, res.consistent, res.n_revisions)

    # enforce_batch / enforce_many: the generic host-loop fallbacks in Engine
    # are already the right (only) semantics for a sequential baseline —
    # `solve_many` likewise degrades to one search at a time on this engine.
