"""Span tracer — nested wall-clock spans in a bounded in-memory ring.

The tracing contract (DESIGN.md §10):

- **Zero overhead when off.** The module-level tracer is ``None`` until
  `enable()` (or ``REPRO_TRACE=1`` at import); `span()` then returns one
  shared null context manager — the off-path cost of an instrumentation
  point is a global read plus a no-op ``with``. No span objects, no clock
  reads, no ring writes.
- **Bounded memory.** Spans land in a ``deque(maxlen=capacity)`` ring; a
  long-lived service overwrites its oldest spans instead of growing, and
  the ``dropped`` counter says how many rolled off.
- **No semantic footprint.** Spans never touch device buffers. The one
  exception is opt-in: ``timing="fenced"`` makes `fence()` call
  ``jax.block_until_ready`` on the traced value so a span brackets real
  device time instead of an async launch — ``block_until_ready`` performs
  no transfer (``jax.transfer_guard("disallow")`` stays clean) and never
  changes values, so verdicts are bit-identical in every mode. The default
  ``timing="async"`` leaves JAX's async dispatch completely untouched.

Span hierarchy is positional: a span opened while another is open is its
child (one implicit stack per tracer — the whole repo is single-threaded
by design, see `service.SolverService`). Request-lifetime spans that
bracket other work (``service.request``) are filed as pre-timed *complete*
events via `record_complete` instead of nesting.
"""

from __future__ import annotations

import itertools
import os
import time
from collections import deque
from typing import Any, Dict, List, Optional

#: ``REPRO_TRACE=1`` enables tracing at import of `repro.obs`
TRACE_ENV = "REPRO_TRACE"
#: ``REPRO_TRACE_TIMING=fenced`` selects fenced timing when env-enabled
TIMING_ENV = "REPRO_TRACE_TIMING"
#: ``REPRO_TRACE_RING=<n>`` overrides the ring capacity when env-enabled
RING_ENV = "REPRO_TRACE_RING"
DEFAULT_RING = 65_536
TIMING_MODES = ("async", "fenced")


class Span:
    """One recorded interval. ``t0`` is tracer-clock seconds; ``dur`` is
    seconds (set at close; -1 while open). ``parent`` is the enclosing
    span's ``sid`` (0 = top-level). ``track`` groups spans into Perfetto
    rows (threads)."""

    __slots__ = ("sid", "parent", "name", "cat", "track", "t0", "dur", "args")

    def __init__(self, sid: int, parent: int, name: str, cat: str, track: str,
                 t0: float, dur: float, args: Dict[str, Any]):
        self.sid = sid
        self.parent = parent
        self.name = name
        self.cat = cat
        self.track = track
        self.t0 = t0
        self.dur = dur
        self.args = args

    def to_dict(self) -> Dict[str, Any]:
        return {
            "sid": self.sid, "parent": self.parent, "name": self.name,
            "cat": self.cat, "track": self.track, "t0": self.t0,
            "dur": self.dur, "args": self.args,
        }

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<Span {self.name} {self.dur * 1e3:.3f}ms args={self.args}>"


class Tracer:
    """The recording core: a span stack (nesting) + a bounded ring (storage).

    ``timing`` is "async" (default — record launch-side wall-clock, never
    synchronize) or "fenced" (`fence()` blocks on traced values so spans
    measure completed device work)."""

    def __init__(self, capacity: int = DEFAULT_RING, timing: str = "async",
                 clock=time.perf_counter):
        if timing not in TIMING_MODES:
            raise ValueError(f"timing must be one of {TIMING_MODES}, got {timing!r}")
        if capacity < 1:
            raise ValueError("tracer ring capacity must be >= 1")
        self.capacity = int(capacity)
        self.timing = timing
        self._clock = clock
        self.origin = clock()  # export rebases timestamps onto this
        self.spans: deque = deque(maxlen=self.capacity)
        self.dropped = 0  # spans that rolled off the ring
        self.force_closed = 0  # mismatched exits repaired by `end`
        self._stack: List[Span] = []
        self._ids = itertools.count(1)

    def now(self) -> float:
        return self._clock()

    # --- recording ----------------------------------------------------------

    def begin(self, name: str, cat: str = "repro", track: str = "main",
              args: Optional[Dict[str, Any]] = None) -> Span:
        parent = self._stack[-1].sid if self._stack else 0
        s = Span(next(self._ids), parent, name, cat, track,
                 self.now(), -1.0, args if args is not None else {})
        self._stack.append(s)
        return s

    def end(self, span: Span) -> None:
        """Close ``span``. Tolerates mismatched nesting (an exception that
        skipped an inner exit): any span still open above ``span`` is
        force-closed at the same instant rather than left to corrupt the
        stack — integrity over precision."""
        t1 = self.now()
        while self._stack:
            top = self._stack.pop()
            if top is span:
                break
            top.dur = t1 - top.t0
            self._record(top)
            self.force_closed += 1
        span.dur = t1 - span.t0
        self._record(span)

    def record_complete(self, name: str, t0: float, t1: float,
                        cat: str = "repro", track: str = "main",
                        args: Optional[Dict[str, Any]] = None) -> Span:
        """File a pre-timed span (e.g. a request's submit → retire lifetime,
        measured around other spans rather than nested inside them)."""
        s = Span(next(self._ids), 0, name, cat, track, t0,
                 max(t1 - t0, 0.0), args if args is not None else {})
        self._record(s)
        return s

    def _record(self, span: Span) -> None:
        if len(self.spans) == self.capacity:
            self.dropped += 1
        self.spans.append(span)

    # --- introspection ------------------------------------------------------

    @property
    def open_spans(self) -> int:
        return len(self._stack)

    def snapshot_spans(self) -> List[Dict[str, Any]]:
        """The ring as plain dicts (JSON-ready), oldest first."""
        return [s.to_dict() for s in self.spans]


class _NullSpan:
    """The disabled-path context manager: one shared instance, no state."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _SpanCtx:
    """Context manager binding one `span()` call to the live tracer. Enter
    returns the `Span` so call sites can attach result args
    (``s.args["hit"] = True``) before exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_track", "_args", "_span")

    def __init__(self, tracer: Tracer, name: str, cat: str, track: str,
                 args: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._track = track
        self._args = args
        self._span: Optional[Span] = None

    def __enter__(self) -> Span:
        self._span = self._tracer.begin(self._name, self._cat, self._track, self._args)
        return self._span

    def __exit__(self, *exc):
        # close against the tracer live at enter — a disable() mid-span
        # must not strand the stack
        if self._span is not None:
            self._tracer.end(self._span)
        return False


# --- the module-level tracer (what the instrumentation points talk to) ------

_TRACER: Optional[Tracer] = None


def enabled() -> bool:
    return _TRACER is not None


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def enable(capacity: int = DEFAULT_RING, timing: str = "async") -> Tracer:
    """Install a fresh tracer (replacing any prior one) and return it."""
    global _TRACER
    _TRACER = Tracer(capacity=capacity, timing=timing)
    return _TRACER


def disable() -> Optional[Tracer]:
    """Remove the tracer; returns it (spans intact) for late export."""
    global _TRACER
    t, _TRACER = _TRACER, None
    return t


def enable_from_env(environ=os.environ) -> bool:
    """``REPRO_TRACE=1`` (anything but ""/"0"/"false"/"off") enables tracing
    with ``REPRO_TRACE_TIMING`` / ``REPRO_TRACE_RING`` knobs. Called once at
    `repro.obs` import; safe to re-call."""
    flag = environ.get(TRACE_ENV, "").strip().lower()
    if not flag or flag in ("0", "false", "off"):
        return False
    timing = environ.get(TIMING_ENV, "async").strip().lower() or "async"
    capacity = int(environ.get(RING_ENV, DEFAULT_RING))
    enable(capacity=capacity, timing=timing)
    return True


def span(name: str, cat: str = "repro", track: str = "main", **args):
    """The one instrumentation macro: ``with obs.span("driver.round"): ...``.
    Returns the shared null context manager when tracing is off."""
    t = _TRACER
    if t is None:
        return _NULL_SPAN
    return _SpanCtx(t, name, cat, track, args)


def record_complete(name: str, t0: float, t1: float, cat: str = "repro",
                    track: str = "main", **args) -> None:
    t = _TRACER
    if t is not None:
        t.record_complete(name, t0, t1, cat, track, args)


def now() -> float:
    """Tracer-clock timestamp for `record_complete` pairs; 0.0 when off (the
    pair is never filed then, so the value is inert)."""
    t = _TRACER
    return t.now() if t is not None else 0.0


def fence(value):
    """Block until ``value``'s device computation completes — ONLY under
    ``timing="fenced"`` with tracing on; a no-op (and zero-cost modulo one
    global read) otherwise. ``block_until_ready`` moves no data, so the
    frontier's ``jax.transfer_guard("disallow")`` audit stays clean, and it
    never changes values, so verdicts are bit-identical in every mode."""
    t = _TRACER
    if t is not None and t.timing == "fenced":
        import jax  # deferred: obs must import without jax

        jax.block_until_ready(value)
    return value
