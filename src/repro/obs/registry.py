"""Central metric registry — named counters / gauges / histograms.

Where the tracer (`obs.tracing`) answers "where did the wall-clock go",
the registry answers "how much of everything happened": every subsystem
publishes into ONE process-wide table under dotted names
(``driver.rounds``, ``cache.hits``, ``speculation.split_granted``,
``kernels.fn_builds``, ...) and `snapshot()` reduces it to one JSON-ready
dict with the stable schema ``repro-obs/v1`` that the benchmarks, the
tracker history, and the CLI all consume.

The robustness fabric (DESIGN.md §12) publishes here too:
``faults.injected`` / ``faults.injected.<site>`` (fired injections),
``faults.round_recoveries`` (driver rebuilds after a faulted round),
``service.shed`` / ``service.retries`` / ``service.failed``,
``fallback.demotions`` / ``fallback.breaker_trips``, and
``watchdog.trips`` (round watchdog evictions).

Unlike the tracer the registry is ALWAYS on: publishing is a plain dict
int-add (no clock reads, no allocation on the hot path beyond a deque
append for histogram samples), cheap enough that the default path carries
it — benchmarks read the snapshot with tracing off.

This module also owns the shared reduction helpers (`percentile`,
`summarize`) that `service.metrics.ServiceMetrics` routes its per-field
reductions through — one implementation, with the empty-window → zeros
guarantee made in one place instead of per call site.
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Iterable, List

import numpy as np

#: the snapshot wire schema — bump on any breaking key change
SCHEMA = "repro-obs/v1"

#: histogram sample percentiles reported by `summarize`
SUMMARY_PCTS = (50, 90, 95, 99)


def percentile(samples: Iterable[float], pct: float) -> float:
    """One percentile over a sample iterable; 0.0 on an empty window (never
    NaN — the shared guarantee every metrics snapshot leans on)."""
    arr = np.fromiter(samples, dtype=float)
    if arr.size == 0:
        return 0.0
    return float(np.percentile(arr, pct))


def mean(samples: Iterable[float]) -> float:
    """Mean with the same empty → 0.0 guarantee."""
    arr = np.fromiter(samples, dtype=float)
    return float(arr.mean()) if arr.size else 0.0


def summarize(samples: Iterable[float], pcts=SUMMARY_PCTS) -> Dict[str, float]:
    """count/mean/min/max + percentiles of a sample window; all-zeros (and
    NaN-free) on an empty window."""
    arr = np.fromiter(samples, dtype=float)
    if arr.size == 0:
        out = {"count": 0, "mean": 0.0, "min": 0.0, "max": 0.0}
        out.update({f"p{int(p)}": 0.0 for p in pcts})
        return out
    out = {
        "count": int(arr.size),
        "mean": float(arr.mean()),
        "min": float(arr.min()),
        "max": float(arr.max()),
    }
    out.update({f"p{int(p)}": float(np.percentile(arr, p)) for p in pcts})
    return out


class Registry:
    """Named counters (monotonic), gauges (last value), histograms (bounded
    sample windows). Names are dotted strings; one flat namespace."""

    def __init__(self, window: int = 65_536):
        if window < 1:
            raise ValueError("registry histogram window must be >= 1")
        self.window = window
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, Deque[float]] = {}

    # --- publishing (the hot path: keep these dict-op cheap) ----------------

    def counter_add(self, name: str, value: float = 1) -> None:
        self._counters[name] = self._counters.get(name, 0) + value

    def gauge_set(self, name: str, value: float) -> None:
        self._gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = deque(maxlen=self.window)
        h.append(value)

    # --- reading ------------------------------------------------------------

    def counter(self, name: str) -> float:
        return self._counters.get(name, 0)

    def gauge(self, name: str) -> float:
        return self._gauges.get(name, 0.0)

    def samples(self, name: str) -> Deque[float]:
        return self._hists.get(name, deque())

    def snapshot(self) -> Dict[str, object]:
        """The whole table as one JSON-ready dict, schema ``repro-obs/v1``.
        Histograms reduce to their `summarize` dicts (the raw windows stay
        in memory)."""
        return {
            "schema": SCHEMA,
            "counters": {k: self._counters[k] for k in sorted(self._counters)},
            "gauges": {k: self._gauges[k] for k in sorted(self._gauges)},
            "histograms": {k: summarize(self._hists[k]) for k in sorted(self._hists)},
        }

    def reset(self) -> None:
        """Drop every metric (benchmark scoping, tests)."""
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()

    def scope(self) -> "RegistryScope":
        """A delta view over this registry: ``with REGISTRY.scope() as sc:``
        marks the current counter values and histogram positions, and
        ``sc.delta()`` afterwards reduces ONLY what was published inside the
        block. Publishing stays global and always-on — a scope never mutates
        or pauses the registry, it just remembers where it stood — so scopes
        nest freely and cost two dict copies each.

        `repro.sweeps` wraps every sweep cell in one, so per-cell records
        carry exactly that cell's rounds/launches/speculation figures instead
        of the whole process history."""
        return RegistryScope(self)


class RegistryScope:
    """Per-block registry delta (see `Registry.scope`).

    Caveat: histogram windows are bounded deques, so a scope that outlives
    ``registry.window`` samples of one histogram under-reports that
    histogram's early samples (never its late ones). Sweep cells publish a
    few dozen samples each — far inside the default 65k window.
    """

    def __init__(self, registry: Registry):
        self._r = registry
        self._counters0: Dict[str, float] = {}
        self._hist0: Dict[str, int] = {}

    def __enter__(self) -> "RegistryScope":
        self._counters0 = dict(self._r._counters)
        self._hist0 = {k: len(v) for k, v in self._r._hists.items()}
        return self

    def __exit__(self, *exc) -> None:
        return None

    def counters(self) -> Dict[str, float]:
        """Counter increments since scope entry (zero-delta keys dropped)."""
        out = {}
        for k, v in self._r._counters.items():
            d = v - self._counters0.get(k, 0)
            if d:
                out[k] = d
        return out

    def samples(self, name: str) -> List[float]:
        """Histogram samples published under ``name`` since scope entry."""
        h = self._r._hists.get(name)
        if h is None:
            return []
        new = len(h) - self._hist0.get(name, 0)
        if new <= 0:
            return []
        return list(h)[-new:]

    def delta(self) -> Dict[str, object]:
        """JSON-ready reduction of everything published inside the scope:
        counter deltas plus `summarize` over each histogram's new samples
        (histograms with no new samples are dropped). Schema ``repro-obs/v1``
        like the full `Registry.snapshot`."""
        hists = {}
        for name in sorted(self._r._hists):
            new = self.samples(name)
            if new:
                hists[name] = summarize(new)
        return {
            "schema": SCHEMA,
            "counters": self.counters(),
            "histograms": hists,
        }


#: the process-wide registry every subsystem publishes into
REGISTRY = Registry()


def counter_add(name: str, value: float = 1) -> None:
    REGISTRY.counter_add(name, value)


def gauge_set(name: str, value: float) -> None:
    REGISTRY.gauge_set(name, value)


def observe(name: str, value: float) -> None:
    REGISTRY.observe(name, value)


def snapshot() -> Dict[str, object]:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()
