"""CLI: ``python -m repro.obs summarize|export <run.json>``.

``summarize`` prints the per-phase time breakdown (by span name), the
``driver.round`` child-coverage figure, counter-derived per-solve rates
(rounds / launches / recurrences per completed solve), and speculation
outcomes. ``export`` writes ``trace.perfetto.json`` — open it at
https://ui.perfetto.dev.

Run dumps (``repro-obs/v1`` JSON: registry snapshot + tracer spans) come
from any entry point that calls `repro.obs.dump_run` under ``REPRO_TRACE=1``
— e.g. ``python -m repro.launch.serve --trace-out run.json`` or the
bench-smoke service benchmark; sweep cells record per-cell registry deltas
(`Registry.scope`) into their ``cells.jsonl`` instead.
"""

from __future__ import annotations

import argparse
import json
from collections import defaultdict
from pathlib import Path
from typing import List, Optional

from .export import child_coverage, export_run, load_run


def _phase_table(spans: List[dict]) -> List[tuple]:
    agg = defaultdict(lambda: [0, 0.0])  # name -> [count, total_s]
    for s in spans:
        agg[s["name"]][0] += 1
        agg[s["name"]][1] += max(s["dur"], 0.0)
    return sorted(
        ((name, n, tot) for name, (n, tot) in agg.items()),
        key=lambda row: -row[2],
    )


def _per_solve(counters: dict) -> List[str]:
    solved = counters.get("service.completed", 0) or counters.get("many.solves", 0)
    lines = []
    if solved:
        for metric in ("driver.rounds", "driver.launches", "driver.recurrences"):
            v = counters.get(metric)
            if v is not None:
                lines.append(f"  {metric.split('.')[1]}/solve {v / solved:10.2f}")
    return lines


def summarize(run: dict) -> str:
    out = []
    spans = run.get("spans", [])
    snap = run.get("snapshot", {})
    counters = snap.get("counters", {})
    tracer = run.get("tracer")

    out.append(f"schema {run.get('schema')}")
    if tracer:
        out.append(
            f"tracer timing={tracer.get('timing')} spans={len(spans)} "
            f"dropped={tracer.get('dropped', 0)} "
            f"force_closed={tracer.get('force_closed', 0)}"
        )
    if spans:
        out.append("")
        out.append(f"{'span':24s} {'count':>8s} {'total_ms':>12s} {'mean_ms':>10s}")
        for name, n, tot in _phase_table(spans):
            out.append(f"{name:24s} {n:8d} {tot * 1e3:12.3f} {tot * 1e3 / n:10.3f}")
        cov = child_coverage(spans, "driver.round")
        out.append("")
        out.append(f"driver.round child coverage: {cov * 100:.1f}%")

    if counters:
        out.append("")
        out.append("counters:")
        for k in sorted(counters):
            out.append(f"  {k:32s} {counters[k]:>12g}")
        per_solve = _per_solve(counters)
        if per_solve:
            out.append("per-solve:")
            out.extend(per_solve)
        granted = counters.get("speculation.split_granted", 0) + counters.get(
            "speculation.portfolio_granted", 0
        )
        denied = counters.get("speculation.denied", 0)
        cancelled = counters.get("driver.cancelled_members", 0)
        if granted or denied or cancelled:
            out.append(
                f"speculation: {granted:g} member(s) granted, {denied:g} "
                f"request(s) denied, {cancelled:g} member(s) cancelled"
            )
    hists = snap.get("histograms", {})
    if hists:
        out.append("histograms:")
        for k in sorted(hists):
            h = hists[k]
            out.append(
                f"  {k:32s} n={h.get('count', 0):<7d} "
                f"p50={h.get('p50', 0.0):<10.3f} p90={h.get('p90', 0.0):<10.3f} "
                f"max={h.get('max', 0.0):.3f}"
            )
    return "\n".join(out)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs", description=__doc__
    )
    sub = ap.add_subparsers(dest="cmd", required=True)
    p_sum = sub.add_parser("summarize", help="print a run dump's breakdown")
    p_sum.add_argument("run", type=Path, help="run dump (repro-obs/v1 JSON)")
    p_exp = sub.add_parser("export", help="write a Perfetto-loadable trace")
    p_exp.add_argument("run", type=Path)
    p_exp.add_argument("-o", "--out", type=Path, default=None,
                       help="output path (default: <run dir>/trace.perfetto.json)")
    args = ap.parse_args(argv)

    run = load_run(args.run)
    if args.cmd == "summarize":
        print(summarize(run))
        return 0
    out = args.out if args.out is not None else args.run.parent / "trace.perfetto.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    doc = export_run(run)
    out.write_text(json.dumps(doc))
    print(f"wrote {out} ({len(doc['traceEvents'])} events) — load at ui.perfetto.dev")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
