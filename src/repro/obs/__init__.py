"""`repro.obs` — structured observability: spans, metrics, Perfetto export.

One import surface for the three pieces (DESIGN.md §10):

- **tracer** (`obs.span` / `obs.fence`, `obs.tracing`): nested wall-clock
  spans (``service.request`` → ``driver.round`` → ``frontier.step`` →
  ``kernel.launch``) in a bounded ring. OFF by default — zero overhead —
  enabled by `enable()` or ``REPRO_TRACE=1``; ``timing="fenced"``
  (``REPRO_TRACE_TIMING=fenced``) opts into `jax.block_until_ready`
  fencing so spans measure device completion instead of async launch.
- **registry** (`obs.REGISTRY`, `obs.counter_add` / `gauge_set` /
  `observe`): always-on named counters/gauges/histograms every subsystem
  publishes into; `snapshot()` is the one ``repro-obs/v1`` dict the
  benchmarks and tracker consume.
- **export** (`obs.dump_run` / `write_trace`, ``python -m repro.obs``):
  run dumps and Chrome-trace/Perfetto timelines.

This package imports only the standard library + numpy (jax is deferred
inside `fence`), so instrumented core modules can import it without cycles
or import-time cost.
"""

from . import export, registry, tracing  # noqa: F401  (submodule access)
from .export import child_coverage, chrome_trace, dump_run, load_run, run_payload, write_trace
from .registry import (
    REGISTRY,
    SCHEMA,
    Registry,
    RegistryScope,
    counter_add,
    gauge_set,
    mean,
    observe,
    percentile,
    snapshot,
    summarize,
)
from .tracing import (
    Span,
    Tracer,
    disable,
    enable,
    enable_from_env,
    enabled,
    fence,
    get_tracer,
    now,
    record_complete,
    span,
)

__all__ = [
    "REGISTRY", "SCHEMA", "Registry", "RegistryScope", "Span", "Tracer",
    "child_coverage", "chrome_trace", "counter_add", "disable", "dump_run",
    "enable", "enable_from_env", "enabled", "fence", "gauge_set",
    "get_tracer", "load_run", "mean", "now", "observe", "percentile",
    "record_complete", "run_payload", "snapshot", "span", "summarize",
    "write_trace",
]

# honour REPRO_TRACE=1 at first import, wherever that import happens
enable_from_env()
