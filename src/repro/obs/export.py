"""Export — run dumps (``repro-obs/v1`` JSON) and Chrome-trace/Perfetto JSON.

Two artifacts per traced run:

- **run dump** (`dump_run`): ``{"schema": "repro-obs/v1", "snapshot": ...,
  "spans": [...], "tracer": {...}}`` — the registry snapshot plus the span
  ring as neutral dicts. This is what ``python -m repro.obs`` consumes.
- **timeline** (`chrome_trace` / `write_trace`): the Chrome trace-event
  format (https://ui.perfetto.dev loads it directly): one ``"X"``
  (complete) event per span with microsecond ``ts``/``dur`` rebased to the
  tracer origin, integer ``pid``/``tid``, and ``"M"`` metadata events
  naming the process and one thread per span track.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from . import registry, tracing

RUN_SCHEMA = registry.SCHEMA  # one schema governs snapshot and run dump
PID = 1


def spans_payload(tracer: tracing.Tracer) -> Dict[str, object]:
    """The tracer's state as JSON-ready dicts (ring oldest-first)."""
    return {
        "origin": tracer.origin,
        "timing": tracer.timing,
        "capacity": tracer.capacity,
        "dropped": tracer.dropped,
        "force_closed": tracer.force_closed,
        "spans": tracer.snapshot_spans(),
    }


def chrome_trace(spans: Sequence[dict], origin: float = 0.0) -> Dict[str, object]:
    """Spans (as `Span.to_dict` dicts) → a Chrome trace-event JSON object.

    Tracks map to synthetic thread ids in first-seen order; ``"M"``
    thread_name/process_name metadata events label them for Perfetto's
    track list. Timestamps/durations are microseconds (the format's unit),
    rebased to ``origin`` so traces start near t=0."""
    tids: Dict[str, int] = {}
    events: List[dict] = [{
        "name": "process_name", "ph": "M", "pid": PID, "tid": 0,
        "args": {"name": "repro"},
    }]
    body: List[dict] = []
    for s in spans:
        track = s.get("track", "main")
        tid = tids.get(track)
        if tid is None:
            tid = tids[track] = len(tids) + 1
        body.append({
            "name": s["name"],
            "cat": s.get("cat", "repro"),
            "ph": "X",
            "ts": (s["t0"] - origin) * 1e6,
            "dur": max(s["dur"], 0.0) * 1e6,
            "pid": PID,
            "tid": tid,
            "args": s.get("args", {}),
        })
    events.extend({
        "name": "thread_name", "ph": "M", "pid": PID, "tid": tid,
        "args": {"name": track},
    } for track, tid in tids.items())
    events.extend(body)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def child_coverage(spans: Sequence[dict], name: str = "driver.round") -> float:
    """Of the total wall-clock spent inside spans named ``name``, the
    fraction covered by their DIRECT children — the acceptance figure for
    "a round's time decomposes into its phases". 1.0 when no such spans
    were recorded (nothing to decompose)."""
    by_sid = {s["sid"]: s for s in spans}
    total = child = 0.0
    for s in spans:
        if s["name"] == name and s["dur"] > 0:
            total += s["dur"]
    if total <= 0.0:
        return 1.0
    for s in spans:
        p = by_sid.get(s["parent"])
        if p is not None and p["name"] == name and s["dur"] > 0:
            child += s["dur"]
    return child / total


def run_payload(tracer: Optional[tracing.Tracer] = None,
                extra: Optional[dict] = None) -> Dict[str, object]:
    """One run dump: registry snapshot + (if tracing) the span ring."""
    tracer = tracer if tracer is not None else tracing.get_tracer()
    payload: Dict[str, object] = {
        "schema": RUN_SCHEMA,
        "snapshot": registry.snapshot(),
    }
    if tracer is not None:
        tp = spans_payload(tracer)
        payload["spans"] = tp.pop("spans")
        payload["tracer"] = tp
    else:
        payload["spans"] = []
        payload["tracer"] = None
    if extra:
        payload.update(extra)
    return payload


def dump_run(path, tracer: Optional[tracing.Tracer] = None,
             extra: Optional[dict] = None) -> Dict[str, object]:
    """Write the run dump to ``path``; returns the payload."""
    payload = run_payload(tracer, extra)
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload, indent=1))
    return payload


def write_trace(path, tracer: Optional[tracing.Tracer] = None) -> Path:
    """Write the live tracer's ring as a Perfetto-loadable trace file."""
    tracer = tracer if tracer is not None else tracing.get_tracer()
    if tracer is None:
        raise RuntimeError("write_trace: tracing is not enabled")
    doc = chrome_trace(tracer.snapshot_spans(), origin=tracer.origin)
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(doc))
    return p


def load_run(path) -> Dict[str, object]:
    payload = json.loads(Path(path).read_text())
    schema = payload.get("schema")
    if schema != RUN_SCHEMA:
        raise ValueError(f"unknown run schema {schema!r} (expected {RUN_SCHEMA!r})")
    return payload


def export_run(run: Dict[str, object]) -> Dict[str, object]:
    """A loaded run dump → its Chrome-trace document."""
    tracer_meta = run.get("tracer") or {}
    origin = float(tracer_meta.get("origin", 0.0))
    return chrome_trace(run.get("spans", []), origin=origin)
