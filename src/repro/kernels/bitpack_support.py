"""Bitpacked RTAC revise kernel — beyond-paper bandwidth optimization.

The dense kernel streams one byte per constraint bit; since the revise pass only
needs "∃ support", the value axis b packs into uint32 words (Lecoutre & Vion'08
bitwise AC, fused into the paper's tensor recurrence). Constraint-tensor traffic
drops 8× vs uint8 (32× vs the paper's fp32 matmul operands) — and the pass is
memory-bound, so this is a direct roofline win (EXPERIMENTS.md §Perf).

Layout mirrors rtac_support.py with the b-axis packed:

  cons_p2[(x·d + a), (y·W + w)]  uint32,  W = ceil(d/32)
  grid (i over x-row-blocks, j over y-col-blocks), j sequential-reduce
  support test:  has[x,a,y] = any_w( cons_word & dom_word ) != 0

Two bindings of the same body:

- :func:`packed_revise` — one network, one domain (the single-search hot path);
- :func:`packed_revise_stacked` — the workload/service form (DESIGN.md §6/§7):
  the grid grows a leading *instance* axis ``r`` and every operand carries a
  matching leading row axis, so R rows — each already gathered from the
  ``(C, n·d, n·W)`` packed slot table by the dispatch — revise against their
  OWN packed network in one kernel launch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _revise_packed_kernel(cons_ref, dom_ref, changed_ref, mask_ref, out_ref, *, w: int, d: int):
    j = pl.program_id(1)

    br = cons_ref.shape[0]  # RX * d
    rx = mask_ref.shape[0]
    ry = mask_ref.shape[1]

    c = cons_ref[...]  # (BR, RY*W) uint32
    dw = dom_ref[...]  # (1, RY*W) uint32
    anded = c & dw  # word-wise AND
    has_any = jnp.any(anded.reshape(br, ry, w) != 0, axis=-1)  # (BR, RY)
    m = mask_ref[...].astype(jnp.bool_)
    m_rows = jnp.broadcast_to(m[:, None, :], (rx, d, ry)).reshape(br, ry)
    has = has_any | ~m_rows
    ch = changed_ref[...].astype(jnp.bool_)  # (1, RY)
    viol = jnp.any(ch & ~has, axis=-1)  # (BR,)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = out_ref[...] | viol[None, :].astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("d", "w", "block_rx", "block_ry", "interpret")
)
def packed_revise(
    cons_p2: Array,  # (n*d, n*W) uint32
    dom_p: Array,  # (1, n*W) uint32
    changed: Array,  # (1, n) uint8
    mask: Array,  # (n, n) uint8
    *,
    d: int,
    w: int,
    block_rx: int = 8,
    block_ry: int = 8,
    interpret: bool = True,
) -> Array:
    nd = cons_p2.shape[0]
    n = nd // d
    assert cons_p2.shape[1] == n * w
    assert n % block_rx == 0 and n % block_ry == 0, (n, block_rx, block_ry)
    br, bcw = block_rx * d, block_ry * w
    grid = (n // block_rx, n // block_ry)

    return pl.pallas_call(
        functools.partial(_revise_packed_kernel, w=w, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bcw), lambda i, j: (i, j)),
            pl.BlockSpec((1, bcw), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_ry), lambda i, j: (0, j)),
            pl.BlockSpec((block_rx, block_ry), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, br), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, nd), jnp.uint8),
        interpret=interpret,
    )(cons_p2, dom_p, changed, mask)


def _revise_packed_stacked_kernel(
    cons_ref, dom_ref, changed_ref, mask_ref, out_ref, *, w: int, d: int
):
    """Same body as `_revise_packed_kernel`, with a leading instance axis:
    grid (r, i, j), every block a (1, ...) slice of row r's operands."""
    j = pl.program_id(2)

    br = cons_ref.shape[1]  # RX * d
    rx = mask_ref.shape[1]
    ry = mask_ref.shape[2]

    c = cons_ref[0]  # (BR, RY*W) uint32
    dw = dom_ref[0]  # (1, RY*W) uint32
    anded = c & dw
    has_any = jnp.any(anded.reshape(br, ry, w) != 0, axis=-1)  # (BR, RY)
    m = mask_ref[0].astype(jnp.bool_)
    m_rows = jnp.broadcast_to(m[:, None, :], (rx, d, ry)).reshape(br, ry)
    has = has_any | ~m_rows
    ch = changed_ref[0].astype(jnp.bool_)  # (1, RY)
    viol = jnp.any(ch & ~has, axis=-1)  # (BR,)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = out_ref[...] | viol[None, None, :].astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("d", "w", "block_rx", "block_ry", "interpret")
)
def packed_revise_stacked(
    cons_g: Array,  # (R, n*d, n*W) uint32 — row r's network, slot-table gathered
    dom_p: Array,  # (R, 1, n*W) uint32
    changed: Array,  # (R, 1, n) uint8
    mask: Array,  # (R, n, n) uint8
    *,
    d: int,
    w: int,
    block_rx: int = 8,
    block_ry: int = 8,
    interpret: bool = True,
) -> Array:
    """R simultaneous packed revisions, each against its own network: the grid
    carries the instance axis (r, i, j); j is the sequential reduction."""
    r, nd = cons_g.shape[0], cons_g.shape[1]
    n = nd // d
    assert cons_g.shape[2] == n * w
    assert n % block_rx == 0 and n % block_ry == 0, (n, block_rx, block_ry)
    br, bcw = block_rx * d, block_ry * w
    grid = (r, n // block_rx, n // block_ry)

    return pl.pallas_call(
        functools.partial(_revise_packed_stacked_kernel, w=w, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, br, bcw), lambda r, i, j: (r, i, j)),
            pl.BlockSpec((1, 1, bcw), lambda r, i, j: (r, 0, j)),
            pl.BlockSpec((1, 1, block_ry), lambda r, i, j: (r, 0, j)),
            pl.BlockSpec((1, block_rx, block_ry), lambda r, i, j: (r, i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, br), lambda r, i, j: (r, 0, i)),
        out_shape=jax.ShapeDtypeStruct((r, 1, nd), jnp.uint8),
        interpret=interpret,
    )(cons_g, dom_p, changed, mask)
