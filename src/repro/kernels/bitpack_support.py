"""Bitpacked RTAC revise kernel — beyond-paper bandwidth optimization.

The dense kernel streams one byte per constraint bit; since the revise pass only
needs "∃ support", the value axis b packs into uint32 words (Lecoutre & Vion'08
bitwise AC, fused into the paper's tensor recurrence). Constraint-tensor traffic
drops 8× vs uint8 (32× vs the paper's fp32 matmul operands) — and the pass is
memory-bound, so this is a direct roofline win (EXPERIMENTS.md §Perf).

Layout mirrors rtac_support.py with the b-axis packed:

  cons_p2[(x·d + a), (y·W + w)]  uint32,  W = ceil(d/32)
  grid (i over x-row-blocks, j over y-col-blocks), j sequential-reduce
  support test:  has[x,a,y] = any_w( cons_word & dom_word ) != 0

Two bindings of the same body:

- :func:`packed_revise` — one network, one domain (the single-search hot path);
- :func:`packed_revise_stacked` — the workload/service form (DESIGN.md §6/§7):
  the grid grows a leading *instance* axis ``r`` and every operand carries a
  matching leading row axis, so R rows — each already gathered from the
  ``(C, n·d, n·W)`` packed slot table by the dispatch — revise against their
  OWN packed network in one kernel launch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _revise_packed_kernel(cons_ref, dom_ref, changed_ref, mask_ref, out_ref, *, w: int, d: int):
    j = pl.program_id(1)

    br = cons_ref.shape[0]  # RX * d
    rx = mask_ref.shape[0]
    ry = mask_ref.shape[1]

    c = cons_ref[...]  # (BR, RY*W) uint32
    dw = dom_ref[...]  # (1, RY*W) uint32
    anded = c & dw  # word-wise AND
    has_any = jnp.any(anded.reshape(br, ry, w) != 0, axis=-1)  # (BR, RY)
    m = mask_ref[...].astype(jnp.bool_)
    m_rows = jnp.broadcast_to(m[:, None, :], (rx, d, ry)).reshape(br, ry)
    has = has_any | ~m_rows
    ch = changed_ref[...].astype(jnp.bool_)  # (1, RY)
    viol = jnp.any(ch & ~has, axis=-1)  # (BR,)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = out_ref[...] | viol[None, :].astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("d", "w", "block_rx", "block_ry", "interpret")
)
def packed_revise(
    cons_p2: Array,  # (n*d, n*W) uint32
    dom_p: Array,  # (1, n*W) uint32
    changed: Array,  # (1, n) uint8
    mask: Array,  # (n, n) uint8
    *,
    d: int,
    w: int,
    block_rx: int = 8,
    block_ry: int = 8,
    interpret: bool = True,
) -> Array:
    nd = cons_p2.shape[0]
    n = nd // d
    assert cons_p2.shape[1] == n * w
    assert n % block_rx == 0 and n % block_ry == 0, (n, block_rx, block_ry)
    br, bcw = block_rx * d, block_ry * w
    grid = (n // block_rx, n // block_ry)

    return pl.pallas_call(
        functools.partial(_revise_packed_kernel, w=w, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bcw), lambda i, j: (i, j)),
            pl.BlockSpec((1, bcw), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_ry), lambda i, j: (0, j)),
            pl.BlockSpec((block_rx, block_ry), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, br), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, nd), jnp.uint8),
        interpret=interpret,
    )(cons_p2, dom_p, changed, mask)


def _revise_packed_stacked_kernel(
    cons_ref, dom_ref, changed_ref, mask_ref, out_ref, *, w: int, d: int
):
    """Same body as `_revise_packed_kernel`, with a leading instance axis:
    grid (r, i, j), every block a (1, ...) slice of row r's operands."""
    j = pl.program_id(2)

    br = cons_ref.shape[1]  # RX * d
    rx = mask_ref.shape[1]
    ry = mask_ref.shape[2]

    c = cons_ref[0]  # (BR, RY*W) uint32
    dw = dom_ref[0]  # (1, RY*W) uint32
    anded = c & dw
    has_any = jnp.any(anded.reshape(br, ry, w) != 0, axis=-1)  # (BR, RY)
    m = mask_ref[0].astype(jnp.bool_)
    m_rows = jnp.broadcast_to(m[:, None, :], (rx, d, ry)).reshape(br, ry)
    has = has_any | ~m_rows
    ch = changed_ref[0].astype(jnp.bool_)  # (1, RY)
    viol = jnp.any(ch & ~has, axis=-1)  # (BR,)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = out_ref[...] | viol[None, None, :].astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("d", "w", "block_rx", "block_ry", "interpret")
)
def packed_revise_stacked(
    cons_g: Array,  # (R, n*d, n*W) uint32 — row r's network, slot-table gathered
    dom_p: Array,  # (R, 1, n*W) uint32
    changed: Array,  # (R, 1, n) uint8
    mask: Array,  # (R, n, n) uint8
    *,
    d: int,
    w: int,
    block_rx: int = 8,
    block_ry: int = 8,
    interpret: bool = True,
) -> Array:
    """R simultaneous packed revisions, each against its own network: the grid
    carries the instance axis (r, i, j); j is the sequential reduction."""
    r, nd = cons_g.shape[0], cons_g.shape[1]
    n = nd // d
    assert cons_g.shape[2] == n * w
    assert n % block_rx == 0 and n % block_ry == 0, (n, block_rx, block_ry)
    br, bcw = block_rx * d, block_ry * w
    grid = (r, n // block_rx, n // block_ry)

    return pl.pallas_call(
        functools.partial(_revise_packed_stacked_kernel, w=w, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, br, bcw), lambda r, i, j: (r, i, j)),
            pl.BlockSpec((1, 1, bcw), lambda r, i, j: (r, 0, j)),
            pl.BlockSpec((1, 1, block_ry), lambda r, i, j: (r, 0, j)),
            pl.BlockSpec((1, block_rx, block_ry), lambda r, i, j: (r, i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, br), lambda r, i, j: (r, 0, i)),
        out_shape=jax.ShapeDtypeStruct((r, 1, nd), jnp.uint8),
        interpret=interpret,
    )(cons_g, dom_p, changed, mask)


# ---------------------------------------------------------------------------
# Fused in-kernel fixpoint, packed form (DESIGN.md §4): the recurrence loops
# inside one pallas_call over the (n, W) uint32 domain WORDS — packing happens
# once per iteration in VMEM, never in HBM, and the launch emits final
# (unpacked) domains + per-row verdicts + recurrence counts.
# ---------------------------------------------------------------------------


def _fixpoint_packed_stacked_kernel(
    cons_ref, dom_ref, changed_ref, mask_ref,
    dom_out_ref, cons_out_ref, k_out_ref, flags_ref,
    *, w: int, d: int, block_rx: int, block_ry: int, sweep: str,
):
    """Packed analogue of `rtac_support._fixpoint_stacked_kernel`: the loop
    state is the (B, n·W) uint32 word planes; each sweep word-ANDs constraint
    tiles against the domain words (support test = any word nonzero), packs
    the violated bits back into words, and updates the words in place in VMEM.
    ``flags_ref`` is the SMEM convergence flag + sweep counter; per-row
    semantics are bit-identical to `rtac.enforce_rows_generic`."""
    b = cons_ref.shape[0]
    nd = cons_ref.shape[1]
    n = nd // d
    nx = n // block_rx
    ny = n // block_ry
    brd = block_rx * d
    bcw = block_ry * w

    m = mask_ref[...].astype(jnp.bool_)  # (B, n, n)
    # little-endian bit weights, 2-D iota per the TPU lowering rules
    bit = jax.lax.broadcasted_iota(jnp.uint32, (1, 32), 1).reshape(32)
    weights = (jnp.uint32(1) << bit)  # (32,)

    words0 = dom_ref[...].reshape(b, n * w)  # (B, n*W) uint32
    ch0 = changed_ref[...].reshape(b, n).astype(jnp.bool_)
    alive0 = jnp.any(words0.reshape(b, n, w) != 0, axis=-1)  # (B, n)
    consistent0 = jnp.all(alive0, axis=-1)  # (B,)

    flags_ref[0] = jnp.int32(1)  # convergence flag: 1 while any row active
    flags_ref[1] = jnp.int32(0)  # in-kernel sweep counter

    def tile(ix, iy, words, seed, acc):
        """OR one (brd × bcw) tile's violations into the x-slab ``acc``."""
        cs = pl.load(
            cons_ref, (slice(None), pl.ds(ix * brd, brd), pl.ds(iy * bcw, bcw))
        )  # (B, brd, bcw) uint32
        dw = jax.lax.dynamic_slice(words, (0, iy * bcw), (b, bcw))
        anded = cs & dw[:, None, :]
        has_any = jnp.any(anded.reshape(b, brd, block_ry, w) != 0, axis=-1)
        ms = jax.lax.dynamic_slice(
            m, (0, ix * block_rx, iy * block_ry), (b, block_rx, block_ry)
        )
        m_rows = jnp.broadcast_to(
            ms[:, :, None, :], (b, block_rx, d, block_ry)
        ).reshape(b, brd, block_ry)
        has = has_any | ~m_rows
        sd = jax.lax.dynamic_slice(seed, (0, iy * block_ry), (b, block_ry))
        return acc | jnp.any(sd[:, None, :] & ~has, axis=-1)  # (B, brd)

    def revise(words, seed):
        """Full blocked sweep -> violated (B, nd) bool (Jacobi: reads only the
        pre-sweep word planes, so sweep order never changes results)."""
        viol = jnp.zeros((b, nd), jnp.bool_)
        if sweep == "xy":
            def x_body(ix, v):
                slab = jax.lax.fori_loop(
                    0, ny, lambda iy, a: tile(ix, iy, words, seed, a),
                    jnp.zeros((b, brd), jnp.bool_),
                )
                return jax.lax.dynamic_update_slice(v, slab, (0, ix * brd))

            viol = jax.lax.fori_loop(0, nx, x_body, viol)
        else:  # "yx"
            def y_body(iy, v):
                def x_body(ix, vv):
                    old = jax.lax.dynamic_slice(vv, (0, ix * brd), (b, brd))
                    return jax.lax.dynamic_update_slice(
                        vv, tile(ix, iy, words, seed, old), (0, ix * brd)
                    )

                return jax.lax.fori_loop(0, nx, x_body, v)

            viol = jax.lax.fori_loop(0, ny, y_body, viol)
        return viol

    def pack(bits):
        """(B, nd) bool -> (B, n*W) uint32, little-endian (`ref.pack_bits_ref`)."""
        padded = jnp.pad(bits.reshape(b, n, d), ((0, 0), (0, 0), (0, w * 32 - d)))
        lanes = padded.reshape(b, n, w, 32).astype(jnp.uint32)
        return jnp.sum(lanes * weights, axis=-1, dtype=jnp.uint32).reshape(b, n * w)

    def cond(s):
        words, ch, ok, k = s
        return jnp.any(ok & jnp.any(ch, axis=-1))

    def body(s):
        words, ch, ok, k = s
        active = ok & jnp.any(ch, axis=-1)  # (B,)
        seed = ch & active[:, None]
        viol_words = pack(revise(words, seed))
        new_words = words & ~viol_words
        changed = jnp.any(
            (new_words != words).reshape(b, n, w), axis=-1
        )  # (B, n)
        ok2 = ok & jnp.all(
            jnp.any(new_words.reshape(b, n, w) != 0, axis=-1), axis=-1
        )
        flags_ref[0] = jnp.any(ok2 & jnp.any(changed, axis=-1)).astype(jnp.int32)
        flags_ref[1] = flags_ref[1] + 1
        return (new_words, changed, ok2, k + active.astype(jnp.int32))

    state = (
        words0,
        ch0 & consistent0[:, None],
        consistent0,
        jnp.zeros((b,), jnp.int32),
    )
    words_f, _, cons_f, k_f = jax.lax.while_loop(cond, body, state)
    # unpack once, at the very end — callers get dense (B, nd) uint8 domains
    bits = ((words_f.reshape(b, n, w)[..., None] >> bit) & 1).astype(jnp.uint8)
    dom_out_ref[...] = bits.reshape(b, n, w * 32)[:, :, :d].reshape(b, 1, nd)
    cons_out_ref[...] = cons_f[:, None].astype(jnp.uint8)
    k_out_ref[...] = k_f[:, None]


@functools.partial(
    jax.jit,
    static_argnames=(
        "d", "w", "block_r", "block_rx", "block_ry", "sweep", "interpret"
    ),
)
def packed_fixpoint_stacked(
    cons_g: Array,  # (R, n*d, n*W) uint32 — row r's network, slot-table gathered
    dom_words: Array,  # (R, 1, n*W) uint32 — packed, assignment already applied
    changed: Array,  # (R, 1, n) uint8 — the Prop. 2 revision seed
    mask: Array,  # (R, n, n) uint8
    *,
    d: int,
    w: int,
    block_r: int = 8,
    block_rx: int = 8,
    block_ry: int = 8,
    sweep: str = "xy",
    interpret: bool = True,
):
    """R packed fixpoints in ONE launch: grid over instance blocks of
    ``block_r`` rows, the whole recurrence over uint32 word planes inside each
    cell. Returns (dom (R, 1, n·d) u8 — unpacked, consistent (R, 1) u8,
    k (R, 1) i32) — per-row bit-identical to the stepped path."""
    r, nd = cons_g.shape[0], cons_g.shape[1]
    n = nd // d
    assert cons_g.shape[2] == n * w
    assert r % block_r == 0, (r, block_r)
    assert n % block_rx == 0 and n % block_ry == 0, (n, block_rx, block_ry)
    assert sweep in ("xy", "yx"), sweep
    grid = (r // block_r,)

    return pl.pallas_call(
        functools.partial(
            _fixpoint_packed_stacked_kernel,
            w=w, d=d, block_rx=block_rx, block_ry=block_ry, sweep=sweep,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, nd, n * w), lambda g: (g, 0, 0)),
            pl.BlockSpec((block_r, 1, n * w), lambda g: (g, 0, 0)),
            pl.BlockSpec((block_r, 1, n), lambda g: (g, 0, 0)),
            pl.BlockSpec((block_r, n, n), lambda g: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_r, 1, nd), lambda g: (g, 0, 0)),
            pl.BlockSpec((block_r, 1), lambda g: (g, 0)),
            pl.BlockSpec((block_r, 1), lambda g: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, 1, nd), jnp.uint8),
            jax.ShapeDtypeStruct((r, 1), jnp.uint8),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((2,), jnp.int32)],
        interpret=interpret,
    )(cons_g, dom_words, changed, mask)
