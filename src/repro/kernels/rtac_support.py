"""Dense RTAC revise kernel — fused support-count + clamp + changed-masked AND-reduce.

TPU adaptation of the paper's Alg. 1 lines 14-16 (see DESIGN.md §2). The GPU
implementation is a cuBLAS matmul producing the (n, n, d) support-count tensor in
HBM, followed by separate clamp/sum/where kernels. The contraction has arithmetic
intensity ~2 FLOP per constraint byte — memory-bound — so on TPU the correct
shape is a single streaming pass over the constraint tensor on the VPU with
everything fused, never materializing the (n, n, d) intermediate.

Layout: the 4-D constraint tensor is viewed as a 2-D matrix
``cons2[(x·d + a), (y·d + b)]`` so VMEM tiles are plain 2-D blocks:

  grid (i over x-row-blocks, j over y-col-blocks)   — j is the reduction dim
  cons2 block   (BR, BC) uint8   BR = RX·d rows, BC = RY·d cols
  dom block     (1, BC)   uint8  (flattened domains of the RY vars)
  changed block (1, RY)   uint8
  mask block    (RX, RY)  uint8
  out block     (1, BR)   uint8  — violated, indexed by i only: revisited across
                                   j with OR-accumulation (sequential TPU grid)

In-kernel: sup = cons2 * dom (VPU int8), per-y counts by (BR, RY, d) reshape-sum,
has = cnt>0 | ~mask, partial violated = any_y(changed & ~has) OR-ed into out.

Block sizes are multiples of (8, 128) sublane×lane tiles when d permits; ops.py
pads n and d so every grid cell is full (padding is inert: padded vars are
unconstrained, never in a domain, never changed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _revise_kernel(cons_ref, dom_ref, changed_ref, mask_ref, out_ref, *, d: int):
    j = pl.program_id(1)

    br = cons_ref.shape[0]
    bc = cons_ref.shape[1]
    rx = mask_ref.shape[0]
    ry = mask_ref.shape[1]

    c = cons_ref[...]  # (BR, BC) uint8
    dval = dom_ref[...]  # (1, BC) uint8
    sup = (c & dval).astype(jnp.int32)  # 0/1 — AND == product for bits
    # per-y support counts: (BR, RY, d) -> (BR, RY)
    cnt = jnp.sum(sup.reshape(br, ry, d), axis=-1)
    # expand mask rows var->values: (RX, RY) -> (BR, RY) via broadcast+reshape
    m = mask_ref[...].astype(jnp.bool_)  # (RX, RY)
    m_rows = jnp.broadcast_to(m[:, None, :], (rx, d, ry)).reshape(br, ry)
    has = (cnt > 0) | ~m_rows  # (BR, RY)
    ch = changed_ref[...].astype(jnp.bool_)  # (1, RY)
    viol = jnp.any(ch & ~has, axis=-1)  # (BR,)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = out_ref[...] | viol[None, :].astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("d", "block_rx", "block_ry", "interpret")
)
def dense_revise(
    cons2: Array,  # (n*d, n*d) uint8 — flattened [x,a],[y,b]
    dom_flat: Array,  # (1, n*d) uint8
    changed: Array,  # (1, n) uint8
    mask: Array,  # (n, n) uint8
    *,
    d: int,
    block_rx: int = 8,  # x-vars per row block
    block_ry: int = 8,  # y-vars per col block
    interpret: bool = True,
) -> Array:
    """Returns violated (1, n*d) uint8. Shapes must be pre-padded so that
    ``block_rx | n`` and ``block_ry | n``."""
    nd = cons2.shape[0]
    n = nd // d
    assert n % block_rx == 0 and n % block_ry == 0, (n, block_rx, block_ry)
    br, bc = block_rx * d, block_ry * d
    grid = (n // block_rx, n // block_ry)

    return pl.pallas_call(
        functools.partial(_revise_kernel, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_ry), lambda i, j: (0, j)),
            pl.BlockSpec((block_rx, block_ry), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, br), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, nd), jnp.uint8),
        interpret=interpret,
    )(cons2, dom_flat, changed, mask)


def assign_padded_rows(dom_p: Array, var: Array, val: Array) -> Array:
    """Batched Alg. 2 ``assign`` in kernel (padded) coordinates — the fused
    front half of a frontier dispatch (DESIGN.md §8): row i's ``dom(var[i])``
    collapses to ``{val[i]}`` before the stacked revise fixpoint runs, all in
    one traced program, so a search round never materializes assigned domains
    on the host. ``var[i] < 0`` marks a root row, left untouched. ``var``/
    ``val`` index *caller* coordinates (< n, < d), so the padded tail — absent
    values, unconstrained singleton variables — is preserved by construction.
    """
    r, _, d_p = dom_p.shape
    safe_var = jnp.maximum(var, 0)
    onehot = (jnp.arange(d_p, dtype=var.dtype)[None, :] == val[:, None]).astype(dom_p.dtype)
    assigned = dom_p.at[jnp.arange(r), safe_var].set(onehot)
    return jnp.where((var < 0)[:, None, None], dom_p, assigned)


def _revise_stacked_kernel(cons_ref, dom_ref, changed_ref, mask_ref, out_ref, *, d: int):
    """Same body as `_revise_kernel`, with a leading instance axis: grid
    (r, i, j), every block a (1, ...) slice of row r's operands."""
    j = pl.program_id(2)

    br = cons_ref.shape[1]
    rx = mask_ref.shape[1]
    ry = mask_ref.shape[2]

    c = cons_ref[0]  # (BR, BC) uint8
    dval = dom_ref[0]  # (1, BC) uint8
    sup = (c & dval).astype(jnp.int32)
    cnt = jnp.sum(sup.reshape(br, ry, d), axis=-1)
    m = mask_ref[0].astype(jnp.bool_)  # (RX, RY)
    m_rows = jnp.broadcast_to(m[:, None, :], (rx, d, ry)).reshape(br, ry)
    has = (cnt > 0) | ~m_rows
    ch = changed_ref[0].astype(jnp.bool_)  # (1, RY)
    viol = jnp.any(ch & ~has, axis=-1)  # (BR,)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = out_ref[...] | viol[None, None, :].astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("d", "block_rx", "block_ry", "interpret")
)
def dense_revise_stacked(
    cons_g: Array,  # (R, n*d, n*d) uint8 — row r's network, slot-table gathered
    dom_flat: Array,  # (R, 1, n*d) uint8
    changed: Array,  # (R, 1, n) uint8
    mask: Array,  # (R, n, n) uint8
    *,
    d: int,
    block_rx: int = 8,
    block_ry: int = 8,
    interpret: bool = True,
) -> Array:
    """R simultaneous dense revisions, each against its own network: the grid
    carries the instance axis (r, i, j); j is the sequential reduction.
    Returns violated (R, 1, n*d) uint8."""
    r, nd = cons_g.shape[0], cons_g.shape[1]
    n = nd // d
    assert n % block_rx == 0 and n % block_ry == 0, (n, block_rx, block_ry)
    br, bc = block_rx * d, block_ry * d
    grid = (r, n // block_rx, n // block_ry)

    return pl.pallas_call(
        functools.partial(_revise_stacked_kernel, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, br, bc), lambda r, i, j: (r, i, j)),
            pl.BlockSpec((1, 1, bc), lambda r, i, j: (r, 0, j)),
            pl.BlockSpec((1, 1, block_ry), lambda r, i, j: (r, 0, j)),
            pl.BlockSpec((1, block_rx, block_ry), lambda r, i, j: (r, i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, br), lambda r, i, j: (r, 0, i)),
        out_shape=jax.ShapeDtypeStruct((r, 1, nd), jnp.uint8),
        interpret=interpret,
    )(cons_g, dom_flat, changed, mask)


# ---------------------------------------------------------------------------
# Fused in-kernel fixpoint (DESIGN.md §4): the WHOLE AC recurrence runs inside
# one pallas_call — the (n, d) domain planes stay pinned in VMEM across
# iterations instead of round-tripping HBM once per recurrence.
# ---------------------------------------------------------------------------


def _fixpoint_stacked_kernel(
    cons_ref, dom_ref, changed_ref, mask_ref,
    dom_out_ref, cons_out_ref, k_out_ref, flags_ref,
    *, d: int, block_rx: int, block_ry: int, sweep: str,
):
    """One grid cell = ``block_r`` instances run to their AC fixpoint.

    The recurrence is a `jax.lax.while_loop` INSIDE the kernel body carrying
    (dom, changed, consistent, k); per-row semantics are bit-identical to
    `rtac.enforce_rows_generic` (active masking freezes converged/wiped-out
    rows, ``k`` counts only active steps). Each revise sweep walks the
    constraint block in (block_rx·d × block_ry·d) tiles; ``sweep`` picks the
    loop-nest order ("xy" = x-outer, "yx" = y-outer). Both orders OR into the
    same violated accumulator against the PRE-sweep domain (Jacobi), so the
    schedule knob never changes results — only VMEM access order.

    ``flags_ref`` is SMEM scalar memory: [0] the convergence flag (1 while any
    row in the cell is still active), [1] the sweep counter. The per-row
    verdicts and recurrence counts are emitted as kernel outputs.
    """
    b = cons_ref.shape[0]
    nd = cons_ref.shape[1]
    n = nd // d
    nx = n // block_rx
    ny = n // block_ry
    brd = block_rx * d
    bcd = block_ry * d

    m = mask_ref[...].astype(jnp.bool_)  # (B, n, n)

    dom0 = dom_ref[...].reshape(b, nd)  # (B, nd) uint8
    ch0 = changed_ref[...].reshape(b, n).astype(jnp.bool_)
    consistent0 = ~jnp.any(
        jnp.sum(dom0.reshape(b, n, d).astype(jnp.int32), axis=-1) == 0, axis=-1
    )  # (B,)

    flags_ref[0] = jnp.int32(1)  # convergence flag: 1 while any row active
    flags_ref[1] = jnp.int32(0)  # in-kernel sweep counter

    def tile(ix, iy, dom, seed, acc):
        """OR one (brd × bcd) tile's violations into the x-slab ``acc``."""
        cs = pl.load(
            cons_ref, (slice(None), pl.ds(ix * brd, brd), pl.ds(iy * bcd, bcd))
        )  # (B, brd, bcd)
        dv = jax.lax.dynamic_slice(dom, (0, iy * bcd), (b, bcd))
        sup = (cs & dv[:, None, :]).astype(jnp.int32)
        cnt = jnp.sum(sup.reshape(b, brd, block_ry, d), axis=-1)  # (B, brd, RY)
        ms = jax.lax.dynamic_slice(
            m, (0, ix * block_rx, iy * block_ry), (b, block_rx, block_ry)
        )
        m_rows = jnp.broadcast_to(
            ms[:, :, None, :], (b, block_rx, d, block_ry)
        ).reshape(b, brd, block_ry)
        has = (cnt > 0) | ~m_rows
        sd = jax.lax.dynamic_slice(seed, (0, iy * block_ry), (b, block_ry))
        return acc | jnp.any(sd[:, None, :] & ~has, axis=-1)  # (B, brd)

    def revise(dom, seed):
        """Full blocked sweep -> violated (B, nd) bool (Jacobi: reads only the
        pre-sweep ``dom``, so "xy" and "yx" orders are bit-identical)."""
        viol = jnp.zeros((b, nd), jnp.bool_)
        if sweep == "xy":
            def x_body(ix, v):
                slab = jax.lax.fori_loop(
                    0, ny, lambda iy, a: tile(ix, iy, dom, seed, a),
                    jnp.zeros((b, brd), jnp.bool_),
                )
                return jax.lax.dynamic_update_slice(v, slab, (0, ix * brd))

            viol = jax.lax.fori_loop(0, nx, x_body, viol)
        else:  # "yx"
            def y_body(iy, v):
                def x_body(ix, vv):
                    old = jax.lax.dynamic_slice(vv, (0, ix * brd), (b, brd))
                    return jax.lax.dynamic_update_slice(
                        vv, tile(ix, iy, dom, seed, old), (0, ix * brd)
                    )

                return jax.lax.fori_loop(0, nx, x_body, v)

            viol = jax.lax.fori_loop(0, ny, y_body, viol)
        return viol

    def cond(s):
        dom, ch, ok, k = s
        return jnp.any(ok & jnp.any(ch, axis=-1))

    def body(s):
        dom, ch, ok, k = s
        active = ok & jnp.any(ch, axis=-1)  # (B,)
        seed = ch & active[:, None]
        viol = revise(dom, seed)
        new_dom = dom & ~viol.astype(jnp.uint8)
        changed = jnp.any((new_dom != dom).reshape(b, n, d), axis=-1)
        ok2 = ok & ~jnp.any(
            jnp.sum(new_dom.reshape(b, n, d).astype(jnp.int32), axis=-1) == 0,
            axis=-1,
        )
        flags_ref[0] = jnp.any(ok2 & jnp.any(changed, axis=-1)).astype(jnp.int32)
        flags_ref[1] = flags_ref[1] + 1
        return (new_dom, changed, ok2, k + active.astype(jnp.int32))

    state = (
        dom0,
        ch0 & consistent0[:, None],
        consistent0,
        jnp.zeros((b,), jnp.int32),
    )
    dom_f, _, cons_f, k_f = jax.lax.while_loop(cond, body, state)
    dom_out_ref[...] = dom_f.reshape(b, 1, nd)
    cons_out_ref[...] = cons_f[:, None].astype(jnp.uint8)
    k_out_ref[...] = k_f[:, None]


@functools.partial(
    jax.jit,
    static_argnames=("d", "block_r", "block_rx", "block_ry", "sweep", "interpret"),
)
def dense_fixpoint_stacked(
    cons_g: Array,  # (R, n*d, n*d) uint8 — row r's network, slot-table gathered
    dom_flat: Array,  # (R, 1, n*d) uint8 — assignment already applied
    changed: Array,  # (R, 1, n) uint8 — the Prop. 2 revision seed
    mask: Array,  # (R, n, n) uint8
    *,
    d: int,
    block_r: int = 8,
    block_rx: int = 8,
    block_ry: int = 8,
    sweep: str = "xy",
    interpret: bool = True,
):
    """R dense fixpoints in ONE launch: grid over instance blocks of
    ``block_r`` rows, the whole recurrence inside each cell. Returns
    (dom (R, 1, n·d) u8, consistent (R, 1) u8, k (R, 1) i32) — per-row
    bit-identical to the stepped `rtac.enforce_rows_generic` path."""
    r, nd = cons_g.shape[0], cons_g.shape[1]
    n = nd // d
    assert r % block_r == 0, (r, block_r)
    assert n % block_rx == 0 and n % block_ry == 0, (n, block_rx, block_ry)
    assert sweep in ("xy", "yx"), sweep
    grid = (r // block_r,)

    return pl.pallas_call(
        functools.partial(
            _fixpoint_stacked_kernel,
            d=d, block_rx=block_rx, block_ry=block_ry, sweep=sweep,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, nd, nd), lambda g: (g, 0, 0)),
            pl.BlockSpec((block_r, 1, nd), lambda g: (g, 0, 0)),
            pl.BlockSpec((block_r, 1, n), lambda g: (g, 0, 0)),
            pl.BlockSpec((block_r, n, n), lambda g: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_r, 1, nd), lambda g: (g, 0, 0)),
            pl.BlockSpec((block_r, 1), lambda g: (g, 0)),
            pl.BlockSpec((block_r, 1), lambda g: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, 1, nd), jnp.uint8),
            jax.ShapeDtypeStruct((r, 1), jnp.uint8),
            jax.ShapeDtypeStruct((r, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((2,), jnp.int32)],
        interpret=interpret,
    )(cons_g, dom_flat, changed, mask)
