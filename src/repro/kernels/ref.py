"""Pure-jnp oracles for the RTAC kernels.

``revise_ref`` is the ground truth for one recurrence of Eq. 1 (incremental,
Prop. 2 masked form): violated[x, a] == some *changed* neighbour y gives (x, a)
no support. Both Pallas kernels (dense uint8 and bitpacked uint32) must match it
bit-exactly over shape/dtype sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def support_counts_ref(cons: Array, dom: Array, dtype=jnp.float32) -> Array:
    """counts[x, y, a] = |{b in dom(y) : cons[x,y,a,b]}| — Alg. 1 line 14."""
    return jnp.einsum(
        "xyab,yb->xya",
        cons.astype(dtype),
        dom.astype(dtype),
        preferred_element_type=jnp.float32,
    )


def has_support_ref(cons: Array, mask: Array, dom: Array) -> Array:
    """has[x, y, a] — support exists, or (x, y) unconstrained."""
    cnt = support_counts_ref(cons, dom)
    return (cnt > 0) | ~mask[:, :, None]


def revise_ref(cons: Array, mask: Array, dom: Array, changed: Array) -> Array:
    """violated[x, a] (n, d) bool — the fused quantity both kernels produce."""
    has = has_support_ref(cons, mask, dom)
    return jnp.any(changed[None, :, None] & ~has, axis=1)


def pack_bits_ref(bits: Array) -> Array:
    """Pack a trailing bool axis into uint32 words (little-endian bit order).

    (..., d) bool -> (..., ceil(d/32)) uint32
    """
    d = bits.shape[-1]
    w = -(-d // 32)
    pad = w * 32 - d
    b = jnp.pad(bits.astype(jnp.uint32), [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    b = b.reshape(*bits.shape[:-1], w, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(b * weights, axis=-1, dtype=jnp.uint32)


def revise_packed_ref(
    cons_packed: Array,  # (n, n, d, W) uint32 — b-axis packed
    mask: Array,  # (n, n) bool
    dom_packed: Array,  # (n, W) uint32
    changed: Array,  # (n,) bool
) -> Array:
    """Bitpacked oracle: support test is AND over words, nonzero anywhere."""
    anded = cons_packed & dom_packed[None, :, None, :]  # (n, n, d, W)
    has = jnp.any(anded != 0, axis=-1) | ~mask[:, :, None]  # (n, n, d)
    return jnp.any(changed[None, :, None] & ~has, axis=1)  # (n, d)
