"""Autotuned block shapes for the fused fixpoint kernels (DESIGN.md §4).

The fused kernels expose a *schedule* — instance-axis tiling ``block_r``,
revise-sweep tiles ``block_rx``/``block_ry``, and the in-kernel loop-nest
order ``sweep`` ("xy" / "yx") — that never changes results (every candidate
is a Jacobi sweep OR-ing into one violated accumulator against the pre-sweep
domain), only VMEM access order and grid shape. This module picks the fastest
schedule per shape bucket, once, and persists the choice.

Mechanics:

- Buckets are ``kind/n{n_p}/d{d_p}/w{W}/r{pow2(R)}`` — padded kernel dims are
  already quantized, and the round width R is pow2-bucketed exactly like the
  frontier's ratcheted widths, so a handful of buckets covers a run.
- ``tune``/``ensure_tuned`` time each candidate EAGERLY (block_until_ready on
  a seeded synthetic workload of real `random_csp` networks at the bucket
  shape) and store the winner. Timing never happens at jit-trace time.
- The winners persist in a versioned JSON cache (``REPRO_AUTOTUNE_CACHE``
  overrides the path). ``get_config`` — the only call sites of which are the
  trace-time schedule lookups in `kernels.ops` — READS the in-memory table
  (loaded from disk once) and falls back to defaults for untuned buckets; it
  never times anything. Tune before first dispatch of a shape (the jitted
  program bakes the schedule it saw): the benchmarks and the CI smoke invoke
  ``python -m repro.kernels.autotune`` explicitly, and engines opt in via the
  ``REPRO_AUTOTUNE=1`` environment gate.

Cache format (``repro-autotune/v1``)::

    {"schema": "repro-autotune/v1",
     "configs": {"packed/n16/d8/w1/r8":
                 {"block_r": 8, "block_rx": 8, "block_ry": 8, "sweep": "xy"}}}
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Dict, List, Optional

from repro import obs
from repro.core.engine import next_pow2

SCHEMA = "repro-autotune/v1"
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
TUNE_ENV = "REPRO_AUTOTUNE"
SWEEPS = ("xy", "yx")


@dataclasses.dataclass(frozen=True)
class TuneConfig:
    """One fused-kernel schedule. Every field is parity-neutral by
    construction (see module docstring) — tuning can never change results."""

    block_r: int
    block_rx: int
    block_ry: int
    sweep: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TuneConfig":
        return cls(
            block_r=int(d["block_r"]),
            block_rx=int(d["block_rx"]),
            block_ry=int(d["block_ry"]),
            sweep=str(d["sweep"]),
        )


#: in-memory config table, keyed by bucket string; populated by `load_cache`
#: (lazily, once) and by `tune`
_CONFIGS: Dict[str, TuneConfig] = {}
_LOADED: Optional[str] = None  # path the table was loaded from, or None


def cache_path() -> Path:
    override = os.environ.get(CACHE_ENV)
    if override:
        return Path(override)
    return Path.home() / ".cache" / "repro" / "autotune.json"


def bucket_key(kind: str, n_p: int, d_p: int, w: int, r: int) -> str:
    """Bucket id: kernel dims are already padded/quantized; the row count R is
    pow2-bucketed (the same quantization the frontier's ratcheted widths and
    the service's round padding apply)."""
    return f"{kind}/n{n_p}/d{d_p}/w{w}/r{next_pow2(max(int(r), 1))}"


def load_cache(path: Optional[Path] = None, force: bool = False) -> int:
    """Merge the on-disk cache into the in-memory table (idempotent; corrupt
    or missing files load zero entries). Returns the number of entries."""
    global _LOADED
    p = Path(path) if path is not None else cache_path()
    if _LOADED == str(p) and not force:
        return len(_CONFIGS)
    try:
        payload = json.loads(p.read_text())
        if payload.get("schema") != SCHEMA:
            raise ValueError(f"unknown autotune schema {payload.get('schema')!r}")
        for key, cfg in payload.get("configs", {}).items():
            _CONFIGS[key] = TuneConfig.from_dict(cfg)
    except (OSError, ValueError, KeyError, json.JSONDecodeError):
        pass
    _LOADED = str(p)
    return len(_CONFIGS)


def save_cache(path: Optional[Path] = None) -> Path:
    p = Path(path) if path is not None else cache_path()
    p.parent.mkdir(parents=True, exist_ok=True)
    payload = {
        "schema": SCHEMA,
        "configs": {k: c.to_dict() for k, c in sorted(_CONFIGS.items())},
    }
    p.write_text(json.dumps(payload, indent=2) + "\n")
    return p


def reset(clear_loaded: bool = True) -> None:
    """Drop the in-memory table (tests)."""
    global _LOADED
    _CONFIGS.clear()
    if clear_loaded:
        _LOADED = None


def effective_block_r(block_r: int, r: int) -> int:
    """Largest divisor of ``r`` not exceeding ``block_r`` (the grid needs
    ``block_r | R``; round widths are mostly pow2, so this is usually exact)."""
    br = max(1, min(int(block_r), int(r)))
    while r % br:
        br -= 1
    return br


def _sanitize(cfg: TuneConfig, n_p: int, block_rx: int, block_ry: int) -> TuneConfig:
    """A cached schedule must still tile this shape (the cache may predate a
    layout change): sweep tiles must divide n_p, else fall back per-field."""
    brx = cfg.block_rx if n_p % cfg.block_rx == 0 else block_rx
    bry = cfg.block_ry if n_p % cfg.block_ry == 0 else block_ry
    sweep = cfg.sweep if cfg.sweep in SWEEPS else "xy"
    return TuneConfig(max(1, cfg.block_r), brx, bry, sweep)


def get_config(
    kind: str, n_p: int, d_p: int, w: int, r: int, block_rx: int, block_ry: int
) -> TuneConfig:
    """Trace-time schedule lookup — a pure read. Untuned buckets get the
    engine defaults (block_r=8, the engine's sweep tiles, "xy")."""
    if _LOADED is None:
        load_cache()
    cfg = _CONFIGS.get(bucket_key(kind, n_p, d_p, w, r))
    if cfg is None:
        return TuneConfig(8, block_rx, block_ry, "xy")
    return _sanitize(cfg, n_p, block_rx, block_ry)


# ---------------------------------------------------------------------------
# The search — eager timing only, never at trace time
# ---------------------------------------------------------------------------


def candidate_configs(n_p: int, r: int) -> List[TuneConfig]:
    """A deliberately small grid: ≤ 2 instance tilings × ≤ 2 sweep-tile sizes
    per axis × both sweep orders (≤ 16 kernels per bucket)."""
    tiles = [v for v in (8, 16) if n_p % v == 0] or [n_p]
    tiles = tiles[-2:]
    row_tiles = sorted({effective_block_r(v, r) for v in (1, 8)})
    return [
        TuneConfig(br, brx, bry, sweep)
        for br in row_tiles
        for brx in tiles
        for bry in tiles
        for sweep in SWEEPS
    ]


def _tune_workload(kind: str, n_p: int, d_p: int, r: int, interpret: bool):
    """A seeded synthetic bucket workload: 3 real `random_csp` networks at
    exactly the padded shape (n_p, d_p are tile multiples, so preparation is
    shape-preserving), r root rows round-robined across them."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import random_csp
    from repro.core.engine import pad_changed, pad_dom
    from repro.kernels import ops

    csps = [random_csp(n_p, d_p, 0.6, 0.5, seed=1000 + i) for i in range(3)]
    prepare = ops.prepare_packed if kind == "packed" else ops.prepare_dense
    prepared = [prepare(c, 8, 8) for c in csps]
    dims = prepared[0][2]
    if (dims[0], dims[1]) != (n_p, d_p):  # pragma: no cover - guarded by callers
        raise ValueError(f"bucket ({n_p}, {d_p}) is not a padded shape: got {dims}")
    idx = np.arange(r, dtype=np.int32) % len(csps)
    cons_g = jnp.stack([prepared[j][0][0] for j in idx])
    mask_g = jnp.stack([prepared[j][0][1] for j in idx])
    doms = jnp.stack([prepared[j][1] for j in idx])
    changed = pad_changed(None, n_p, n_p, batch=(r,))
    return dims, (cons_g, mask_g), pad_dom(doms, n_p, d_p), changed


def _time_candidate(
    kind: str, dims, net_g, dom_p, ch_p, cfg: TuneConfig,
    interpret: bool, repeats: int,
) -> float:
    import jax
    import jax.numpy as jnp

    from repro.kernels import bitpack_support, ref, rtac_support

    r = dom_p.shape[0]
    br = effective_block_r(cfg.block_r, r)
    cons_g, mask_g = net_g

    if kind == "packed":
        n_p, d_p, w = dims
        dom_pk = ref.pack_bits_ref(dom_p).reshape(r, 1, n_p * w)

        def run():
            return bitpack_support.packed_fixpoint_stacked(
                cons_g, dom_pk,
                ch_p.astype(jnp.uint8).reshape(r, 1, n_p), mask_g,
                d=d_p, w=w, block_r=br, block_rx=cfg.block_rx,
                block_ry=cfg.block_ry, sweep=cfg.sweep, interpret=interpret,
            )
    else:
        n_p, d_p = dims[0], dims[1]

        def run():
            return rtac_support.dense_fixpoint_stacked(
                cons_g,
                dom_p.astype(jnp.uint8).reshape(r, 1, n_p * d_p),
                ch_p.astype(jnp.uint8).reshape(r, 1, n_p), mask_g,
                d=d_p, block_r=br, block_rx=cfg.block_rx,
                block_ry=cfg.block_ry, sweep=cfg.sweep, interpret=interpret,
            )

    jax.block_until_ready(run())  # compile/warm outside the timed window
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        best = min(best, time.perf_counter() - t0)
    return best


def tune(
    kind: str,
    n_p: int,
    d_p: int,
    r: int = 8,
    *,
    interpret: bool = True,
    repeats: int = 2,
    save: bool = True,
    path: Optional[Path] = None,
) -> TuneConfig:
    """Time every candidate schedule for one bucket (eagerly — never call from
    a traced context), record the winner, persist the cache. Returns it."""
    if kind not in ("dense", "packed"):
        raise ValueError(f"unknown kernel kind {kind!r}")
    r = next_pow2(max(int(r), 1))
    t_search0 = time.perf_counter()
    with obs.span("autotune.search", cat="autotune", kind=kind,
                  n=n_p, d=d_p, r=r) as _sp:
        dims, net_g, dom_p, ch_p = _tune_workload(kind, n_p, d_p, r, interpret)
        w = dims[2] if kind == "packed" else 0
        best_cfg, best_t = None, float("inf")
        candidates = candidate_configs(n_p, r)
        for cfg in candidates:
            t = _time_candidate(kind, dims, net_g, dom_p, ch_p, cfg, interpret, repeats)
            if t < best_t:
                best_cfg, best_t = cfg, t
        if _sp is not None:
            _sp.args["candidates"] = len(candidates)
    obs.counter_add("autotune.tuned_buckets")
    obs.observe("autotune.search_seconds", time.perf_counter() - t_search0)
    _CONFIGS[bucket_key(kind, n_p, d_p, w, r)] = best_cfg
    if save:
        save_cache(path)
    return best_cfg


def ensure_tuned(
    kind: str, n_p: int, d_p: int, w: int, r: int, **tune_kwargs
) -> TuneConfig:
    """Tune the bucket only if the (loaded) cache has no entry for it."""
    if _LOADED is None:
        load_cache(tune_kwargs.get("path"))
    hit = _CONFIGS.get(bucket_key(kind, n_p, d_p, w, r))
    if hit is not None:
        return hit
    return tune(kind, n_p, d_p, r, **tune_kwargs)


def maybe_tune(kind: str, n_p: int, d_p: int, w: int, r: int) -> Optional[TuneConfig]:
    """Engine hook: tune-on-first-use, gated by ``REPRO_AUTOTUNE=1`` (timing
    a bucket in interpret mode is not free, so it is opt-in)."""
    if not os.environ.get(TUNE_ENV):
        return None
    return ensure_tuned(kind, n_p, d_p, w, r)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Tune fused-fixpoint block shapes for one bucket"
    )
    ap.add_argument("--kind", choices=("dense", "packed"), default="packed")
    ap.add_argument("--n", type=int, default=16, help="padded var count n_p")
    ap.add_argument("--d", type=int, default=8, help="padded domain size d_p")
    ap.add_argument("--rows", type=int, default=8, help="round width R")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--cache", type=Path, default=None,
                    help=f"cache file (default: ${CACHE_ENV} or "
                         f"~/.cache/repro/autotune.json)")
    args = ap.parse_args(argv)
    if args.cache is not None:
        os.environ[CACHE_ENV] = str(args.cache)
    load_cache(args.cache)
    cfg = tune(args.kind, args.n, args.d, args.rows,
               repeats=args.repeats, path=args.cache)
    w = -(-args.d // 32) if args.kind == "packed" else 0
    key = bucket_key(args.kind, args.n, args.d, w, args.rows)
    print(json.dumps({"bucket": key, "config": cfg.to_dict(),
                      "cache": str(args.cache or cache_path())}))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
