"""Jitted wrappers binding the Pallas revise kernels into the RTAC fixpoint.

Handles the shape contract between the algorithm (n vars × d values, any sizes)
and the kernels (padded, flattened, optionally bitpacked). The padding contract
itself lives in `repro.core.engine` (DESIGN.md §2) — this module only reshapes
and bitpacks the padded tensors into the kernels' layouts:

- revise_fn factories are ``lru_cache``-d on (shapes, blocks) so the returned
  function object is stable and keys `enforce_generic`'s jit cache correctly.
- network preparation (padding + transpose + bitpack of the O(n²d²) constraint
  tensor) is memoized per CSP identity, so repeated preparation of the same
  network is free. The Engine layer (`repro.engines.pallas`) calls
  ``prepare_dense``/``prepare_packed`` once per CSP by construction — the
  deprecated one-shot ``enforce_*_kernel`` entry points are gone; go through
  ``repro.engines.get_engine("pallas_dense" | "pallas_packed")``.

On this CPU container the kernels run in ``interpret=True`` (Pallas executes
the kernel body in Python); on a real TPU pass ``interpret=False``.
"""

from __future__ import annotations

import functools
import weakref
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import faults, obs
from repro.core import rtac
from repro.core.csp import CSP
from repro.core.engine import pad_dom, pad_network, padded_shape
from . import autotune, bitpack_support, ref, rtac_support

Array = jax.Array


def _count_build(name: str) -> None:
    """Registry tick for one kernel-closure construction. The factories are
    ``lru_cache``-d, so this fires once per distinct (shape, blocks, mode)
    program family — the compiled-program census the obs CLI reports."""
    obs.counter_add("kernels.fn_builds")
    obs.counter_add(f"kernels.fn_builds.{name}")

#: value-axis tile multiple both kernels pad d to (the one place it is set —
#: engines sizing slot tables without a CSP import this)
D_MULT = 8

# (kind, blocks, id(cons), id(mask)) -> (wref(cons), wref(mask), (network, dims)).
# Keyed by the identity of BOTH network tensors — the prepared form embeds the
# mask, so a CSP sharing `cons` but carrying a different `mask` must miss. The
# weakrefs guard against id() reuse after gc, and their callbacks evict the
# entry when either tensor is collected.
_NETWORK_CACHE: dict = {}


def _cached(kind: str, csp: CSP, block_rx: int, block_ry: int, build):
    key = (kind, block_rx, block_ry, id(csp.cons), id(csp.mask))
    hit = _NETWORK_CACHE.get(key)
    if hit is not None and hit[0]() is csp.cons and hit[1]() is csp.mask:
        return hit[2]
    value = build()
    evict = lambda _ref: _NETWORK_CACHE.pop(key, None)
    try:
        rc = weakref.ref(csp.cons, evict)
        rm = weakref.ref(csp.mask, evict)
    except TypeError:  # non-weakrefable leaf; just skip caching
        return value
    _NETWORK_CACHE[key] = (rc, rm, value)
    return value


# ---------------------------------------------------------------------------
# Dense uint8 kernel
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _dense_revise_fn(n_p: int, d_p: int, block_rx: int, block_ry: int, interpret: bool):
    _count_build("dense_revise")
    def revise_fn(net, dom, changed):
        cons2, mask_u8 = net
        viol = rtac_support.dense_revise(
            cons2,
            dom.astype(jnp.uint8).reshape(1, n_p * d_p),
            changed.astype(jnp.uint8).reshape(1, n_p),
            mask_u8,
            d=d_p,
            block_rx=block_rx,
            block_ry=block_ry,
            interpret=interpret,
        )
        return viol.reshape(n_p, d_p).astype(jnp.bool_)

    return revise_fn


def prepare_dense(csp: CSP, block_rx: int = 8, block_ry: int = 8):
    """-> (network, dom_padded, (n_p, d_p)). network = (cons2 u8, mask u8).

    The network half is memoized per CSP; the domain is padded fresh (O(n·d))."""
    faults.inject("kernel.launch", kernel="dense")

    def build():
        cons, mask, n_p, d_p = pad_network(csp, max(block_rx, block_ry), D_MULT)
        cons2 = (
            jnp.transpose(cons, (0, 2, 1, 3))
            .reshape(n_p * d_p, n_p * d_p)
            .astype(jnp.uint8)
        )
        return (cons2, mask.astype(jnp.uint8)), (n_p, d_p)

    network, (n_p, d_p) = _cached("dense", csp, block_rx, block_ry, build)
    return network, pad_dom(csp.dom, n_p, d_p), (n_p, d_p)


@functools.lru_cache(maxsize=None)
def _dense_rows_fn(n_p: int, d_p: int, block_rx: int, block_ry: int, interpret: bool):
    """Stacked revise-rows closure (rtac.ReviseRowsFn) for the dense u8 kernel:
    ``net_g`` leaves carry a leading row axis (gathered from the slot table)."""
    _count_build("dense_rows")

    def revise_rows(net_g, doms, changed):
        cons_g, mask_g = net_g  # (R, n_p*d_p, n_p*d_p) u8, (R, n_p, n_p) u8
        r = doms.shape[0]
        viol = rtac_support.dense_revise_stacked(
            cons_g,
            doms.astype(jnp.uint8).reshape(r, 1, n_p * d_p),
            changed.astype(jnp.uint8).reshape(r, 1, n_p),
            mask_g,
            d=d_p,
            block_rx=block_rx,
            block_ry=block_ry,
            interpret=interpret,
        )
        return viol.reshape(r, n_p, d_p).astype(jnp.bool_)

    return revise_rows


# ---------------------------------------------------------------------------
# Bitpacked uint32 kernel
# ---------------------------------------------------------------------------


def pack_network(cons: Array, n_p: int, d_p: int) -> Tuple[Array, int]:
    """(n_p,n_p,d_p,d_p) bool -> ((n_p*d_p, n_p*W) uint32, W)."""
    packed = ref.pack_bits_ref(cons)  # (n_p, n_p, d_p, W)
    w = packed.shape[-1]
    return jnp.transpose(packed, (0, 2, 1, 3)).reshape(n_p * d_p, n_p * w), w


@functools.lru_cache(maxsize=None)
def _packed_revise_fn(
    n_p: int, d_p: int, w: int, block_rx: int, block_ry: int, interpret: bool
):
    _count_build("packed_revise")
    def revise_fn(net, dom, changed):
        cons_p2, mask_u8 = net
        dom_pk = ref.pack_bits_ref(dom).reshape(1, n_p * w)
        viol = bitpack_support.packed_revise(
            cons_p2,
            dom_pk,
            changed.astype(jnp.uint8).reshape(1, n_p),
            mask_u8,
            d=d_p,
            w=w,
            block_rx=block_rx,
            block_ry=block_ry,
            interpret=interpret,
        )
        return viol.reshape(n_p, d_p).astype(jnp.bool_)

    return revise_fn


def prepare_packed(csp: CSP, block_rx: int = 8, block_ry: int = 8):
    """-> (network, dom_padded, (n_p, d_p, w)); network memoized per CSP."""
    faults.inject("kernel.launch", kernel="packed")

    def build():
        cons, mask, n_p, d_p = pad_network(csp, max(block_rx, block_ry), D_MULT)
        cons_p2, w = pack_network(cons, n_p, d_p)
        return (cons_p2, mask.astype(jnp.uint8)), (n_p, d_p, w)

    network, (n_p, d_p, w) = _cached("packed", csp, block_rx, block_ry, build)
    return network, pad_dom(csp.dom, n_p, d_p), (n_p, d_p, w)


# ---------------------------------------------------------------------------
# Fused assign + revise frontier entries (DESIGN.md §8)
# ---------------------------------------------------------------------------


def _padded_seed(var, n: int, n_p: int):
    """The Prop. 2 revision seed in padded coordinates: ``one_hot(var)`` for
    assigned rows, all real variables for root rows (``var < 0``); padded
    variables are never seeded (their domains never shrink). Identical to
    `pad_changed` applied to the caller-coordinate seed."""
    ar = jnp.arange(n_p, dtype=var.dtype)[None, :]
    is_root = (var < 0)[:, None]
    return jnp.where(is_root, ar < n, ar == jnp.maximum(var, 0)[:, None])


@functools.lru_cache(maxsize=None)
def _dense_frontier_fn(block_rx: int, block_ry: int, interpret: bool):
    """Fused assign+revise frontier dispatch for the dense u8 kernel: one
    traced program pads R parent closures into kernel coordinates, applies the
    batched Alg. 2 assignment (`rtac_support.assign_padded_rows`), and runs
    the stacked-kernel fixpoint — the device never sees a host-built domain."""
    _count_build("dense_frontier")

    def assign_enforce_rows(net_g, doms, var, val, idx):
        r, n, d = doms.shape
        n_p, d_p = padded_shape(n, d, max(block_rx, block_ry), D_MULT)
        rows_fn = _dense_rows_fn(n_p, d_p, block_rx, block_ry, interpret)
        dom_p = rtac_support.assign_padded_rows(pad_dom(doms, n_p, d_p), var, val)
        ch_p = _padded_seed(var, n, n_p)
        res = rtac.enforce_rows_generic(net_g, dom_p, ch_p, idx, revise_rows_fn=rows_fn)
        return rtac.EnforceResult(res.dom[:, :n, :d], res.consistent, res.n_recurrences)

    return assign_enforce_rows


@functools.lru_cache(maxsize=None)
def _packed_frontier_fn(block_rx: int, block_ry: int, interpret: bool):
    """Fused assign+revise frontier dispatch for the bitpacked u32 kernel
    (same shape as `_dense_frontier_fn`; the fixpoint packs row domains fresh
    each recurrence, the networks ride gathered from the packed slot table)."""
    _count_build("packed_frontier")

    def assign_enforce_rows(net_g, doms, var, val, idx):
        r, n, d = doms.shape
        n_p, d_p = padded_shape(n, d, max(block_rx, block_ry), D_MULT)
        w = -(-d_p // 32)
        rows_fn = _packed_rows_fn(n_p, d_p, w, block_rx, block_ry, interpret)
        dom_p = rtac_support.assign_padded_rows(pad_dom(doms, n_p, d_p), var, val)
        ch_p = _padded_seed(var, n, n_p)
        res = rtac.enforce_rows_generic(net_g, dom_p, ch_p, idx, revise_rows_fn=rows_fn)
        return rtac.EnforceResult(res.dom[:, :n, :d], res.consistent, res.n_recurrences)

    return assign_enforce_rows


@functools.lru_cache(maxsize=None)
def _packed_rows_fn(
    n_p: int, d_p: int, w: int, block_rx: int, block_ry: int, interpret: bool
):
    """Stacked revise-rows closure (rtac.ReviseRowsFn) for the bitpacked u32
    kernel: row domains are packed fresh (O(R·n·d)); the packed networks ride
    gathered from the (C, n·d, n·W) slot table."""
    _count_build("packed_rows")

    def revise_rows(net_g, doms, changed):
        cons_g, mask_g = net_g  # (R, n_p*d_p, n_p*w) u32, (R, n_p, n_p) u8
        r = doms.shape[0]
        dom_pk = ref.pack_bits_ref(doms).reshape(r, 1, n_p * w)
        viol = bitpack_support.packed_revise_stacked(
            cons_g,
            dom_pk,
            changed.astype(jnp.uint8).reshape(r, 1, n_p),
            mask_g,
            d=d_p,
            w=w,
            block_rx=block_rx,
            block_ry=block_ry,
            interpret=interpret,
        )
        return viol.reshape(r, n_p, d_p).astype(jnp.bool_)

    return revise_rows


# ---------------------------------------------------------------------------
# Fused in-kernel fixpoint (one launch per round; DESIGN.md §4)
# ---------------------------------------------------------------------------


def _fixpoint_schedule(
    kind: str, n_p: int, d_p: int, w: int, r: int, block_rx: int, block_ry: int
):
    """Resolve the fused-kernel schedule at trace time. R is static inside a
    traced program, so this is a plain in-memory lookup (`autotune.get_config`
    never times anything); untuned buckets run the engine defaults. The jitted
    program bakes the schedule it sees — tune before first dispatch."""
    cfg = autotune.get_config(kind, n_p, d_p, w, r, block_rx, block_ry)
    return autotune.TuneConfig(
        autotune.effective_block_r(cfg.block_r, r),
        cfg.block_rx, cfg.block_ry, cfg.sweep,
    )


@functools.lru_cache(maxsize=None)
def _dense_fixpoint_rows_fn(
    n_p: int, d_p: int, block_rx: int, block_ry: int, interpret: bool
):
    """Stacked one-launch fixpoint for the dense u8 kernel. Same signature as
    `rtac.enforce_rows_generic` (net_g, dom_p, ch_p -> EnforceResult in padded
    coordinates) so engines can swap it for the stepped path wholesale."""
    _count_build("dense_fixpoint_rows")

    def fixpoint_rows(net_g, doms, changed):
        cons_g, mask_g = net_g
        r = doms.shape[0]
        cfg = _fixpoint_schedule("dense", n_p, d_p, 0, r, block_rx, block_ry)
        dom_f, cons_f, k_f = rtac_support.dense_fixpoint_stacked(
            cons_g,
            doms.astype(jnp.uint8).reshape(r, 1, n_p * d_p),
            changed.astype(jnp.uint8).reshape(r, 1, n_p),
            mask_g,
            d=d_p,
            block_r=cfg.block_r,
            block_rx=cfg.block_rx,
            block_ry=cfg.block_ry,
            sweep=cfg.sweep,
            interpret=interpret,
        )
        return rtac.EnforceResult(
            dom_f.reshape(r, n_p, d_p).astype(jnp.bool_),
            cons_f[:, 0].astype(jnp.bool_),
            k_f[:, 0],
        )

    return fixpoint_rows


@functools.lru_cache(maxsize=None)
def _packed_fixpoint_rows_fn(
    n_p: int, d_p: int, w: int, block_rx: int, block_ry: int, interpret: bool
):
    """Stacked one-launch fixpoint for the bitpacked u32 kernel: row domains
    are packed ONCE on entry and stay (n, W) u32 words in VMEM across every
    in-kernel recurrence (the stepped path re-packs each iteration)."""
    _count_build("packed_fixpoint_rows")

    def fixpoint_rows(net_g, doms, changed):
        cons_g, mask_g = net_g
        r = doms.shape[0]
        cfg = _fixpoint_schedule("packed", n_p, d_p, w, r, block_rx, block_ry)
        dom_pk = ref.pack_bits_ref(doms).reshape(r, 1, n_p * w)
        dom_f, cons_f, k_f = bitpack_support.packed_fixpoint_stacked(
            cons_g,
            dom_pk,
            changed.astype(jnp.uint8).reshape(r, 1, n_p),
            mask_g,
            d=d_p,
            w=w,
            block_r=cfg.block_r,
            block_rx=cfg.block_rx,
            block_ry=cfg.block_ry,
            sweep=cfg.sweep,
            interpret=interpret,
        )
        return rtac.EnforceResult(
            dom_f.reshape(r, n_p, d_p).astype(jnp.bool_),
            cons_f[:, 0].astype(jnp.bool_),
            k_f[:, 0],
        )

    return fixpoint_rows


@functools.partial(jax.jit, static_argnames=("fixpoint_rows_fn",))
def enforce_rows_fused(networks, dom, changed0, instance_idx, fixpoint_rows_fn):
    """Fused-kernel counterpart of `rtac.enforce_rows_generic`: gather each
    row's network from the stacked tables, then ONE kernel launch runs the
    whole recurrence. Inputs/outputs match `enforce_rows_generic` exactly so
    `engines.pallas` routes between them with a flag."""
    net_g = jax.tree_util.tree_map(lambda t: t[instance_idx], networks)
    return fixpoint_rows_fn(net_g, dom, changed0)


@functools.lru_cache(maxsize=None)
def _dense_frontier_fused_fn(block_rx: int, block_ry: int, interpret: bool):
    """One-launch-per-round frontier dispatch for the dense u8 kernel: pad,
    batched Alg. 2 assignment, seed — then a single fused fixpoint launch in
    place of `_dense_frontier_fn`'s stepped while_loop."""
    _count_build("dense_frontier_fused")

    def assign_enforce_rows(net_g, doms, var, val, idx):
        r, n, d = doms.shape
        n_p, d_p = padded_shape(n, d, max(block_rx, block_ry), D_MULT)
        rows_fn = _dense_fixpoint_rows_fn(n_p, d_p, block_rx, block_ry, interpret)
        dom_p = rtac_support.assign_padded_rows(pad_dom(doms, n_p, d_p), var, val)
        ch_p = _padded_seed(var, n, n_p)
        net_rows = jax.tree_util.tree_map(lambda t: t[idx], net_g)
        res = rows_fn(net_rows, dom_p, ch_p)
        return rtac.EnforceResult(res.dom[:, :n, :d], res.consistent, res.n_recurrences)

    return assign_enforce_rows


@functools.lru_cache(maxsize=None)
def _packed_frontier_fused_fn(block_rx: int, block_ry: int, interpret: bool):
    """One-launch-per-round frontier dispatch for the bitpacked u32 kernel
    (shape-identical to `_packed_frontier_fn`; domains pack once on entry and
    the recurrence runs on u32 word planes pinned in VMEM)."""
    _count_build("packed_frontier_fused")

    def assign_enforce_rows(net_g, doms, var, val, idx):
        r, n, d = doms.shape
        n_p, d_p = padded_shape(n, d, max(block_rx, block_ry), D_MULT)
        w = -(-d_p // 32)
        rows_fn = _packed_fixpoint_rows_fn(n_p, d_p, w, block_rx, block_ry, interpret)
        dom_p = rtac_support.assign_padded_rows(pad_dom(doms, n_p, d_p), var, val)
        ch_p = _padded_seed(var, n, n_p)
        net_rows = jax.tree_util.tree_map(lambda t: t[idx], net_g)
        res = rows_fn(net_rows, dom_p, ch_p)
        return rtac.EnforceResult(res.dom[:, :n, :d], res.consistent, res.n_recurrences)

    return assign_enforce_rows
