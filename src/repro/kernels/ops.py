"""Jitted wrappers binding the Pallas revise kernels into the RTAC fixpoint.

Handles the shape contract between the algorithm (n vars × d values, any sizes)
and the kernels (padded, flattened, optionally bitpacked):

- n is padded to the block multiple; padded variables are *unconstrained with
  full domains*, so they never change, never violate, and never trip the
  wipeout check. Padded values (d-axis) are absent from every domain and
  allowed by no constraint. The closure over the original slice is unchanged.
- revise_fn factories are ``lru_cache``-d on (shapes, blocks) so the returned
  function object is stable and keys `enforce_generic`'s jit cache correctly.

On this CPU container the kernels run in ``interpret=True`` (Pallas executes
the kernel body in Python); on a real TPU pass ``interpret=False``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.csp import CSP
from repro.core.rtac import EnforceResult, enforce_generic
from . import bitpack_support, ref, rtac_support

Array = jax.Array


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_csp(csp: CSP, n_block: int, d_mult: int):
    """Returns (cons, mask, dom, n_p, d_p) padded as described above."""
    n, d = csp.dom.shape
    n_p = _round_up(max(n, n_block), n_block)
    d_p = _round_up(d, d_mult)
    cons = jnp.pad(
        csp.cons, ((0, n_p - n), (0, n_p - n), (0, d_p - d), (0, d_p - d))
    )
    mask = jnp.pad(csp.mask, ((0, n_p - n), (0, n_p - n)))
    dom = jnp.pad(csp.dom, ((0, 0), (0, d_p - d)))
    pad_rows = jnp.zeros((n_p - n, d_p), jnp.bool_).at[:, 0].set(True)
    dom = jnp.concatenate([dom, pad_rows], axis=0)
    return cons, mask, dom, n_p, d_p


def _pad_changed(changed0: Optional[Array], n: int, n_p: int) -> Array:
    if changed0 is None:
        changed0 = jnp.ones((n,), jnp.bool_)
    return jnp.pad(changed0, (0, n_p - n))


# ---------------------------------------------------------------------------
# Dense uint8 kernel
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _dense_revise_fn(n_p: int, d_p: int, block_rx: int, block_ry: int, interpret: bool):
    def revise_fn(net, dom, changed):
        cons2, mask_u8 = net
        viol = rtac_support.dense_revise(
            cons2,
            dom.astype(jnp.uint8).reshape(1, n_p * d_p),
            changed.astype(jnp.uint8).reshape(1, n_p),
            mask_u8,
            d=d_p,
            block_rx=block_rx,
            block_ry=block_ry,
            interpret=interpret,
        )
        return viol.reshape(n_p, d_p).astype(jnp.bool_)

    return revise_fn


def prepare_dense(csp: CSP, block_rx: int = 8, block_ry: int = 8):
    """-> (network, dom_padded, (n_p, d_p)). network = (cons2 u8, mask u8)."""
    cons, mask, dom_p, n_p, d_p = _pad_csp(csp, max(block_rx, block_ry), 8)
    cons2 = (
        jnp.transpose(cons, (0, 2, 1, 3))
        .reshape(n_p * d_p, n_p * d_p)
        .astype(jnp.uint8)
    )
    return (cons2, mask.astype(jnp.uint8)), dom_p, (n_p, d_p)


def enforce_dense_kernel(
    csp: CSP,
    changed0: Optional[Array] = None,
    block_rx: int = 8,
    block_ry: int = 8,
    interpret: bool = True,
) -> EnforceResult:
    """End-to-end RTAC with the dense Pallas revise."""
    network, dom_p, (n_p, d_p) = prepare_dense(csp, block_rx, block_ry)
    n, d = csp.dom.shape
    revise_fn = _dense_revise_fn(n_p, d_p, block_rx, block_ry, interpret)
    res = enforce_generic(network, dom_p, _pad_changed(changed0, n, n_p), revise_fn=revise_fn)
    return EnforceResult(res.dom[:n, :d], res.consistent, res.n_recurrences)


# ---------------------------------------------------------------------------
# Bitpacked uint32 kernel
# ---------------------------------------------------------------------------


def pack_network(cons: Array, n_p: int, d_p: int) -> Tuple[Array, int]:
    """(n_p,n_p,d_p,d_p) bool -> ((n_p*d_p, n_p*W) uint32, W)."""
    packed = ref.pack_bits_ref(cons)  # (n_p, n_p, d_p, W)
    w = packed.shape[-1]
    return jnp.transpose(packed, (0, 2, 1, 3)).reshape(n_p * d_p, n_p * w), w


@functools.lru_cache(maxsize=None)
def _packed_revise_fn(
    n_p: int, d_p: int, w: int, block_rx: int, block_ry: int, interpret: bool
):
    def revise_fn(net, dom, changed):
        cons_p2, mask_u8 = net
        dom_pk = ref.pack_bits_ref(dom).reshape(1, n_p * w)
        viol = bitpack_support.packed_revise(
            cons_p2,
            dom_pk,
            changed.astype(jnp.uint8).reshape(1, n_p),
            mask_u8,
            d=d_p,
            w=w,
            block_rx=block_rx,
            block_ry=block_ry,
            interpret=interpret,
        )
        return viol.reshape(n_p, d_p).astype(jnp.bool_)

    return revise_fn


def prepare_packed(csp: CSP, block_rx: int = 8, block_ry: int = 8):
    cons, mask, dom_p, n_p, d_p = _pad_csp(csp, max(block_rx, block_ry), 8)
    cons_p2, w = pack_network(cons, n_p, d_p)
    return (cons_p2, mask.astype(jnp.uint8)), dom_p, (n_p, d_p, w)


def enforce_packed_kernel(
    csp: CSP,
    changed0: Optional[Array] = None,
    block_rx: int = 8,
    block_ry: int = 8,
    interpret: bool = True,
) -> EnforceResult:
    """End-to-end RTAC with the bitpacked Pallas revise (8× less cons traffic)."""
    network, dom_p, (n_p, d_p, w) = prepare_packed(csp, block_rx, block_ry)
    n, d = csp.dom.shape
    revise_fn = _packed_revise_fn(n_p, d_p, w, block_rx, block_ry, interpret)
    res = enforce_generic(network, dom_p, _pad_changed(changed0, n, n_p), revise_fn=revise_fn)
    return EnforceResult(res.dom[:n, :d], res.consistent, res.n_recurrences)
