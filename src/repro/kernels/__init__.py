"""Pallas TPU kernels for the paper's compute hot spot (the revise contraction).

rtac_support   dense uint8 fused support-count+clamp+AND-reduce (VPU streaming)
bitpack_support  uint32 bitpacked variant (beyond paper: 16x less traffic)
ops            jit'd wrappers + padding/packing + prepare_* network builders
ref            pure-jnp oracles the kernels are validated against
"""

from . import bitpack_support, ops, ref, rtac_support

__all__ = ["bitpack_support", "ops", "ref", "rtac_support"]
