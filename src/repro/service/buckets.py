"""Shape-bucketed admission (DESIGN.md §7).

jit compiles one program per shape, so a service facing heterogeneous requests
must either force one global (worst-case) shape or compile per exact shape —
both lose. Buckets split the difference: each request's ``(n_vars, dom_size)``
is rounded up to the next power of two (with a small floor), the CSP is padded
into that bucket under the §2 padding contract, and every request in a bucket
shares the same jitted fixpoint, slot pool, and lockstep rounds. O(log n ·
log d) distinct programs cover every shape.

Padding preserves search semantics exactly: padded variables are unconstrained
with singleton domain {0} (never change, never violate, never trip wipeout),
padded values are absent everywhere, and `core.search._mac_coroutine` takes
``n_active`` so padded variables are born assigned and never branched on — a
padded search takes bit-identical decisions to the unpadded one.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro import obs
from repro.core.csp import CSP
from repro.core.engine import next_pow2, pad_dom


@dataclasses.dataclass(frozen=True, order=True)
class Bucket:
    """One shared compilation shape: requests with n ≤ n_p, d ≤ d_p land here."""

    n_p: int
    d_p: int

    def contains(self, n: int, d: int) -> bool:
        return n <= self.n_p and d <= self.d_p

    @property
    def network_nbytes(self) -> int:
        """Resident bytes of ONE prepared network in this bucket (bool cons
        O(n_p²·d_p²) + bool mask O(n_p²)) — the cache's accounting unit."""
        return self.n_p * self.n_p * self.d_p * self.d_p + self.n_p * self.n_p

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"({self.n_p}x{self.d_p})"


def _round_up_pow2(x: int, floor: int) -> int:
    return next_pow2(max(x, floor))


def bucket_for(n: int, d: int, n_floor: int = 8, d_floor: int = 4) -> Bucket:
    """The admission bucket for a request of shape (n, d): each axis rounds up
    to the next power of two, floored so tiny requests coalesce. Idempotent on
    its own output (``bucket_for(n_p, d_p) == Bucket(n_p, d_p)``)."""
    if n < 1 or d < 1:
        raise ValueError(f"bucket_for: need n, d >= 1, got ({n}, {d})")
    return Bucket(_round_up_pow2(n, n_floor), _round_up_pow2(d, d_floor))


def speculative_budget(
    split: int,
    portfolio: int,
    queue_depth: int,
    spare_rows: int,
    queue_limit: int,
) -> tuple:
    """Size one request's speculative duplication against live load
    (DESIGN.md §9): speculation fills SLACK — it must never starve queued
    requests of rows or admission throughput.

    - At or beyond ``queue_limit`` queued requests (or with ≤ 1 spare row),
      speculation is off entirely: ``(0, 0)``.
    - Otherwise the request may claim ``spare_rows // (1 + queue_depth) - 1``
      extra rows (its own row is not speculative) — an even hypothetical
      share of the slack against everyone waiting, split-first (subtree
      siblings reuse resident parent rows; portfolio racers re-upload roots).

    Returns ``(split_eff, portfolio_eff)`` clamped budgets. Grant/deny
    outcomes publish into the obs registry (``speculation.*``) — the
    feedback signal the ROADMAP's adaptive-speculation item reads."""
    wanted = max(0, split) + max(0, portfolio)
    if queue_depth >= queue_limit or spare_rows <= 1:
        if wanted:
            obs.counter_add("speculation.denied")
        return 0, 0
    allowed = max(0, spare_rows // (1 + queue_depth) - 1)
    split_eff = min(max(0, split), allowed)
    portfolio_eff = min(max(0, portfolio), allowed - split_eff)
    if wanted:
        granted = split_eff + portfolio_eff
        if granted == 0:
            obs.counter_add("speculation.denied")
        else:
            obs.counter_add("speculation.split_granted", split_eff)
            obs.counter_add("speculation.portfolio_granted", portfolio_eff)
            if granted < wanted:
                obs.counter_add("speculation.clamped")
    return split_eff, portfolio_eff


def pad_csp(csp: CSP, bucket: Bucket) -> CSP:
    """Pad a CSP into its bucket shape under the §2 contract. The AC closure
    and the MAC search restricted to the original (n, d) slice are unchanged."""
    n, d = csp.dom.shape
    if not bucket.contains(n, d):
        raise ValueError(f"csp shape ({n}, {d}) does not fit bucket {bucket}")
    dn, dd = bucket.n_p - n, bucket.d_p - d
    if dn == 0 and dd == 0:
        return csp
    return CSP(
        cons=jnp.pad(csp.cons, ((0, dn), (0, dn), (0, dd), (0, dd))),
        mask=jnp.pad(csp.mask, ((0, dn), (0, dn))),
        dom=pad_dom(jnp.asarray(csp.dom), bucket.n_p, bucket.d_p),
    )
