"""Service metrics: throughput, tail latency, queue depth, dispatch occupancy.

Everything the ROADMAP's "serves heavy traffic" north star needs a number for:
request latency percentiles (p50/p95/p99, submit → finish), sustained
instances/second, queue depth over time, and rows-per-dispatch — the
continuous-batching occupancy figure that says whether rounds actually ride
full batches or the device is dispatching single rows.

Memory is bounded for a long-lived service: totals (request counts, rows
dispatched, span) are exact O(1) counters, while the per-sample series
(latencies, queue depths, per-round rows/seconds) live in sliding windows of
the most recent ``window`` samples — percentiles and means are therefore
*recent-window* figures, which is what an operator watches anyway.

All reductions route through the shared `repro.obs.registry` helpers
(`percentile` / `mean`), which guarantee empty-window → 0.0 (never NaN) in
ONE place; ``window=1`` degenerates to last-sample metrics but stays finite.
Every ``record_*`` call also publishes into the central obs registry
(``service.*`` counters/histograms), so a process-wide `obs.snapshot()`
carries the same figures without holding a service reference.
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Optional

from repro import obs
from repro.obs.registry import mean as _mean
from repro.obs.registry import percentile as _percentile


class ServiceMetrics:
    """Counters + sliding-window samples; ``snapshot`` reduces to one dict.

    The snapshot schema is stable and NaN-free: on a freshly constructed
    instance (or any empty window) every value is an exact zero."""

    def __init__(self, window: int = 100_000) -> None:
        if window < 1:
            raise ValueError("metrics window must be >= 1")
        self.window = window
        # exact totals
        self.n_submitted = 0
        self.n_completed = 0
        self.n_timed_out = 0
        self.n_cancelled = 0
        # robustness outcomes (DESIGN.md §12): shed at/before admission,
        # failed after exhausting the fallback ladder (or quarantined), plus
        # the recovery work done on the way — retries, engine demotions,
        # circuit-breaker trips
        self.n_shed = 0
        self.n_failed = 0
        self.n_retries = 0
        self.n_demotions = 0
        self.n_breaker_trips = 0
        self.n_rounds = 0
        self.rows_dispatched = 0
        self.launches = 0
        self.first_submit_t: Optional[float] = None
        self.last_finish_t: Optional[float] = None
        # bounded recent-window samples
        self.latencies_s: Deque[float] = deque(maxlen=window)
        self.queue_depths: Deque[int] = deque(maxlen=window)
        self.round_rows: Deque[int] = deque(maxlen=window)
        self.round_searches: Deque[int] = deque(maxlen=window)
        self.round_seconds: Deque[float] = deque(maxlen=window)
        self.round_launches: Deque[int] = deque(maxlen=window)
        # speculation (DESIGN.md §9): rows each request consumed over its
        # lifetime, and how many speculative members were spawned / cancelled
        self.rows_per_request: Deque[int] = deque(maxlen=window)
        self.speculative_members_total = 0
        self.speculative_cancels_total = 0

    # --- recording ----------------------------------------------------------

    def record_submit(self, t: float) -> None:
        self.n_submitted += 1
        if self.first_submit_t is None:
            self.first_submit_t = t
        obs.counter_add("service.submitted")

    def record_finish(self, t: float, latency_s: float, status: str) -> None:
        if status == "done":
            self.n_completed += 1
            self.latencies_s.append(latency_s)
            obs.counter_add("service.completed")
            obs.observe("service.latency_ms", 1e3 * latency_s)
        elif status == "timed_out":
            self.n_timed_out += 1
            obs.counter_add("service.timed_out")
        elif status == "shed":
            self.n_shed += 1
            obs.counter_add("service.shed")
        elif status == "failed":
            self.n_failed += 1
            obs.counter_add("service.failed")
        else:
            self.n_cancelled += 1
            obs.counter_add("service.cancelled")
        self.last_finish_t = t

    def record_retry(self) -> None:
        """One faulted request re-queued for another attempt (same engine)."""
        self.n_retries += 1
        obs.counter_add("service.retries")

    def record_demotion(self) -> None:
        """One request demoted a rung down the engine fallback ladder."""
        self.n_demotions += 1
        obs.counter_add("fallback.demotions")

    def record_breaker_trip(self) -> None:
        """One bucket's circuit breaker opened (floor raised to a fallback)."""
        self.n_breaker_trips += 1
        obs.counter_add("fallback.breaker_trips")

    def record_queue_depth(self, depth: int) -> None:
        self.queue_depths.append(depth)
        obs.gauge_set("service.queue_depth", depth)

    def record_round(
        self, rows: int, searches: int, seconds: float, launches: int = 1
    ) -> None:
        self.n_rounds += 1
        self.rows_dispatched += rows
        self.launches += launches
        self.round_rows.append(rows)
        self.round_searches.append(searches)
        self.round_seconds.append(seconds)
        self.round_launches.append(launches)
        obs.counter_add("service.rounds")
        obs.counter_add("service.rows_dispatched", rows)
        obs.observe("service.round_ms", 1e3 * seconds)

    def record_request_rows(self, rows: int, members: int, cancelled: int) -> None:
        """File one retired request's lifetime row consumption and speculation
        outcome: ``members`` counts every search that ran for it (1 = no
        speculation), ``cancelled`` the members killed when a sibling won."""
        self.rows_per_request.append(rows)
        self.speculative_members_total += max(0, members - 1)
        self.speculative_cancels_total += cancelled
        obs.observe("service.rows_per_request", rows)

    # --- reduction ----------------------------------------------------------

    def latency_ms(self, pct: float) -> float:
        """Latency percentile over the recent window, in milliseconds;
        0.0 (never NaN) on an empty window."""
        return 1e3 * _percentile(self.latencies_s, pct)

    @property
    def span_s(self) -> float:
        """First submit → last finish (the sustained-throughput denominator)."""
        if self.first_submit_t is None or self.last_finish_t is None:
            return 0.0
        return max(self.last_finish_t - self.first_submit_t, 0.0)

    @property
    def throughput_rps(self) -> float:
        span = self.span_s
        return self.n_completed / span if span > 0 else 0.0

    def snapshot(self) -> Dict[str, float]:
        return {
            "submitted": self.n_submitted,
            "completed": self.n_completed,
            "timed_out": self.n_timed_out,
            "cancelled": self.n_cancelled,
            "shed": self.n_shed,
            "failed": self.n_failed,
            "retries": self.n_retries,
            "demotions": self.n_demotions,
            "breaker_trips": self.n_breaker_trips,
            "span_s": round(self.span_s, 4),
            "throughput_rps": round(self.throughput_rps, 3),
            "p50_ms": round(self.latency_ms(50), 3),
            "p95_ms": round(self.latency_ms(95), 3),
            "p99_ms": round(self.latency_ms(99), 3),
            "rounds": self.n_rounds,
            "rows_dispatched": self.rows_dispatched,
            "mean_rows_per_dispatch": round(
                self.rows_dispatched / self.n_rounds if self.n_rounds else 0.0, 3
            ),
            "launches": self.launches,
            "mean_launches_per_round": round(_mean(self.round_launches), 3),
            "mean_searches_per_round": round(_mean(self.round_searches), 3),
            "mean_queue_depth": round(_mean(self.queue_depths), 3),
            "max_queue_depth": int(max(self.queue_depths, default=0)),
            "median_rows_per_request": round(
                _percentile(self.rows_per_request, 50), 3
            ),
            "speculative_members": self.speculative_members_total,
            "speculative_cancel_rate": round(
                self.speculative_cancels_total / self.speculative_members_total
                if self.speculative_members_total
                else 0.0,
                3,
            ),
        }
