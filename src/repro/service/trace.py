"""Arrival traces: seeded workloads that hit the service *over time*.

`poisson_trace` draws a reproducible Poisson process (exponential
inter-arrivals at ``rate`` requests/second) over the `repro.problems`
registry: each event picks a family and a size variant, so a replay exercises
shape-bucketed admission with genuinely heterogeneous requests. Instance i is
seeded ``(seed, i)`` — the trace is deterministic and events are stable under
rate/duration changes of later events.

`replay` feeds a trace through a `SolverService` against a `FastForwardClock`:
arrivals are admitted when the service clock reaches their timestamp; while
requests are in flight the clock advances at wall speed (queueing delay is
real compute), and when the service goes idle the clock jumps to the next
arrival — a 20-second trace replays in however long the solving actually
takes, never sleeping.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.csp import CSP
from repro.problems import generate
from .service import SolveRequest, SolverService

#: per-family size variants, deliberately CPU-small and shape-diverse so a
#: default trace spans several admission buckets
DEFAULT_VARIANTS: Dict[str, List[dict]] = {
    "model_rb": [
        {"n": 8, "hardness": 0.9},
        {"n": 10, "hardness": 1.0},
        {"n": 12, "hardness": 0.9},
    ],
    "coloring_random": [
        {"n": 12, "edge_prob": 0.25, "k": 3},
        {"n": 16, "edge_prob": 0.2, "k": 3},
    ],
    "random_binary": [
        {"n": 10, "d": 5, "density": 0.4, "tightness": 0.35},
    ],
    "coloring_kneser": [{"m": 5, "j": 2, "excess": 0}],
    "nqueens": [{"n": 8}, {"n": 10}],
    "pigeonhole": [{"n": 5}],
    "sudoku": [{"givens": 40}],
}


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One arrival: at time ``t``, submit family instance ``seed`` with knobs."""

    t: float
    family: str
    knobs: dict
    seed: tuple

    def build(self) -> CSP:
        return generate(self.family, seed=self.seed, **self.knobs)


def poisson_trace(
    families: Sequence[str],
    rate: float,
    duration: float,
    seed: int = 0,
    variants: Optional[Dict[str, List[dict]]] = None,
) -> List[TraceEvent]:
    """A seeded Poisson arrival process over the given problem families."""
    if rate <= 0 or duration <= 0:
        raise ValueError("poisson_trace needs rate > 0 and duration > 0")
    unknown = [f for f in families if f not in (variants or DEFAULT_VARIANTS)]
    if unknown:
        raise ValueError(
            f"no size variants for families {unknown}; "
            f"known: {sorted((variants or DEFAULT_VARIANTS))}"
        )
    vmap = variants or DEFAULT_VARIANTS
    rng = np.random.default_rng(seed)
    events: List[TraceEvent] = []
    t = 0.0
    for i in range(10**9):  # bounded by duration, not by count
        t += float(rng.exponential(1.0 / rate))
        if t >= duration:
            break
        family = families[int(rng.integers(len(families)))]
        knobs = vmap[family][int(rng.integers(len(vmap[family])))]
        events.append(TraceEvent(t=t, family=family, knobs=dict(knobs), seed=(seed, i)))
    return events


def dedup_trace(
    families: Sequence[str],
    rate: float,
    duration: float,
    seed: int = 0,
    pool_size: int = 4,
    variants: Optional[Dict[str, List[dict]]] = None,
) -> List[TraceEvent]:
    """A Poisson arrival process over a SMALL pool of recurring instances.

    `poisson_trace` seeds every event uniquely (``(seed, i)``), so no two
    requests ever share a constraint fingerprint and the service's
    prepared-network LRU never hits. Real traffic is nothing like that —
    the same problem instance arrives again and again. This trace models it:
    arrival times and family/variant picks are drawn exactly like
    `poisson_trace`, but each event's instance seed is drawn from a pool of
    ``pool_size`` seeds per (family, variant), so repeated events rebuild
    byte-identical CSPs and the cache's ``hits`` counter actually moves
    (`bench_service.py` records the resulting hit-rate)."""
    if pool_size < 1:
        raise ValueError("dedup_trace needs pool_size >= 1")
    base = poisson_trace(families, rate, duration, seed=seed, variants=variants)
    rng = np.random.default_rng((seed, pool_size))
    # seeds must stay int tuples (they feed numpy.random.default_rng), so the
    # per-(family, variant) pool is keyed by a variant ordinal, not by name
    ordinals: Dict[tuple, int] = {}
    out = []
    for ev in base:
        key = (ev.family, tuple(sorted(ev.knobs.items())))
        v = ordinals.setdefault(key, len(ordinals))
        out.append(
            dataclasses.replace(ev, seed=(seed, v, int(rng.integers(pool_size))))
        )
    return out


class FastForwardClock:
    """Monotonic clock that advances at wall speed but can jump forward over
    idle gaps — trace replays complete as fast as the compute allows while
    queueing delay under load stays real."""

    def __init__(self) -> None:
        self._t0 = time.perf_counter()
        self._offset = 0.0

    def __call__(self) -> float:
        return time.perf_counter() - self._t0 + self._offset

    def advance_to(self, t: float) -> None:
        now = self()
        if t > now:
            self._offset += t - now


def replay_rate_cell(
    engine: str,
    families: Sequence[str],
    rate: float,
    duration: float,
    seed: int = 0,
    kind: str = "poisson",
    pool_size: int = 3,
    warmup: bool = False,
    service_kwargs: Optional[dict] = None,
    submit_kwargs: Optional[dict] = None,
    variants: Optional[Dict[str, List[dict]]] = None,
) -> dict:
    """ONE capacity-ramp cell: a fresh `SolverService` fed a seeded arrival
    trace at ``rate`` req/s for ``duration`` trace-seconds, replayed to
    completion on a `FastForwardClock`. Returns a flat JSON-ready record —
    offered vs achieved rate, p50/p95/p99 latency, dispatch occupancy, cache
    hit-rate, speculation occupancy — for the caller to judge against an SLO.

    This is the driver hook behind capacity studies: `repro.sweeps`'s
    ``service`` mode calls it once per grid cell (sweeping ``rate`` for the
    offered-rate ramp, ``pool_size`` with ``kind="dedup"`` for the cache
    hit-rate ramp), and `benchmarks.bench_service` records the same rows into
    BENCH_engines.json. ``kind`` selects `poisson_trace` (every instance
    unique — the cold-cache worst case) or `dedup_trace` (instances recur from
    a ``pool_size`` pool per variant, so the prepared-network LRU serves real
    hits). The trace is a pure function of (families, rate, duration, seed),
    never of the engine or the service knobs.

    ``warmup=True`` first replays the same trace through a THROWAWAY service
    and discards it: jit-compiled bucket kernels are process-global, so the
    measured replay starts compile-warm and its latencies are queueing +
    solving, not XLA compilation. Capacity studies want this on (a cold p95
    is dominated by per-bucket compiles at low rates); single-shot
    benchmarking of cold-start behavior leaves it off."""
    if kind == "dedup":
        events = dedup_trace(families, rate=rate, duration=duration,
                             seed=seed, pool_size=pool_size, variants=variants)
    elif kind == "poisson":
        events = poisson_trace(families, rate=rate, duration=duration,
                               seed=seed, variants=variants)
    else:
        raise ValueError(f"unknown trace kind {kind!r} (poisson | dedup)")
    if warmup:
        wclock = FastForwardClock()
        wsvc = SolverService(engine=engine, clock=wclock,
                             **(service_kwargs or {}))
        replay(wsvc, events, wclock, **(submit_kwargs or {}))
    clock = FastForwardClock()
    svc = SolverService(engine=engine, clock=clock, **(service_kwargs or {}))
    t0 = time.perf_counter()
    requests = replay(svc, events, clock, **(submit_kwargs or {}))
    wall_s = time.perf_counter() - t0
    snap = svc.snapshot()
    cache = snap["cache"]
    lookups = cache.get("hits", 0) + cache.get("misses", 0)
    return {
        "engine": engine,
        "kind": kind,
        "families": list(families),
        "rate": rate,
        "duration": duration,
        "pool_size": pool_size if kind == "dedup" else None,
        "requests": len(requests),
        "completed": snap["completed"],
        "n_solved": sum(r.solution is not None for r in requests),
        # robustness outcomes (all zero on a fault-free replay): every future
        # must land in exactly one terminal bin — ``unresolved`` staying 0 is
        # the chaos acceptance gate
        "timed_out": snap["timed_out"],
        "shed": snap["shed"],
        "failed": snap["failed"],
        "retries": snap["retries"],
        "demotions": snap["demotions"],
        "breaker_trips": snap["breaker_trips"],
        "recovered": sum(
            r.status.value == "done" and (r.retries > 0 or r.engine_level > 0)
            for r in requests
        ),
        "unresolved": sum(not r.done() for r in requests),
        "wall_s": round(wall_s, 3),
        "throughput_rps": snap["throughput_rps"],
        "p50_ms": snap["p50_ms"],
        "p95_ms": snap["p95_ms"],
        "p99_ms": snap["p99_ms"],
        "mean_rows_per_dispatch": snap["mean_rows_per_dispatch"],
        "rounds": snap["rounds"],
        "launches": snap["launches"],
        "mean_launches_per_round": snap["mean_launches_per_round"],
        "cache": cache,
        "cache_hit_rate": (
            round(cache.get("hits", 0) / lookups, 4) if lookups else 0.0
        ),
        "median_rows_per_request": snap["median_rows_per_request"],
        "speculative_members": snap["speculative_members"],
        "speculative_cancel_rate": snap["speculative_cancel_rate"],
    }


def replay(
    service: SolverService,
    events: Sequence[TraceEvent],
    clock: FastForwardClock,
    **submit_kwargs,
) -> List[SolveRequest]:
    """Feed ``events`` through ``service`` (which must share ``clock``) and
    drive it to completion. ``submit_kwargs`` (deadline_s, max_assignments)
    apply to every request. Returns the requests in arrival order."""
    events = sorted(events, key=lambda e: e.t)
    requests: List[SolveRequest] = []
    i = 0
    while i < len(events) or service.has_work:
        now = clock()
        while i < len(events) and events[i].t <= now:
            requests.append(service.submit(events[i].build(), **submit_kwargs))
            i += 1
        if service.has_work:
            # if the service is only waiting on fault-retry backoff gates,
            # jump the clock to the earlier of the next gate / next arrival
            # instead of busy-stepping through the wait
            wake = service.next_wakeup()
            if wake is not None:
                if i < len(events):
                    wake = min(wake, events[i].t)
                clock.advance_to(wake)
            service.step()
        elif i < len(events):
            clock.advance_to(events[i].t)
    return requests
