"""SolverService — continuous-batching CSP solving over time (DESIGN.md §7).

`solve_many` takes a *closed* batch: every instance known up front, one
lockstep run to completion. A service faces an *open world* — requests arrive
over time, finish at different times, and must not wait for a batch to drain.
`SolverService` keeps the device saturated anyway:

- **submit** returns a futures-style `SolveRequest` immediately; the CSP is
  routed to its shape bucket (`buckets.bucket_for`) and queued;
- **admission** pads the CSP into its bucket, fingerprints the constraint
  network, and pins it in the prepared-network cache (`cache`) — a cache hit
  reuses an already-resident slot, a miss installs into a free slot of the
  bucket's `SlotPool` (growing by doubling when full);
- **step** runs ONE lockstep round per bucket with work: newly admitted
  searches' root propagations ride the same dispatch as everyone else's
  frontiers, and searches that finish free their rows (and their cache pins)
  mid-flight — continuous batching, one device dispatch per bucket round;
- per-request **deadlines** (checked between rounds) and **assignment
  budgets** bound work; `metrics.ServiceMetrics` tracks throughput, tail
  latency, queue depth, and rows-per-dispatch occupancy.

Single-threaded by design: ``step()`` is the event loop body, so tests and
trace replay drive the service deterministically (``request.result()`` just
steps until its request retires). Results and per-request `SearchStats` are
bit-identical to sequential `mac_solve` on the unpadded CSP — asserted by
`tests/test_service.py`.
"""

from __future__ import annotations

import itertools
import time
from collections import deque
from enum import Enum
from typing import Callable, Deque, Dict, List, Optional, Tuple, Union

from repro import obs
from repro.core.csp import CSP
from repro.core.engine import (
    Engine,
    FrontierTable,
    SlotPool,
    StackedSlotPool,
    frontier_capacity,
)
from repro.core.search import HostFrontierStore, LockstepDriver, SearchStats, resolve_engine
from .buckets import Bucket, bucket_for, pad_csp, speculative_budget
from .cache import CacheEntry, PreparedNetworkCache, network_fingerprint
from .metrics import ServiceMetrics


class RequestStatus(Enum):
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    TIMED_OUT = "timed_out"
    CANCELLED = "cancelled"


_TERMINAL = (RequestStatus.DONE, RequestStatus.TIMED_OUT, RequestStatus.CANCELLED)


class SolveRequest:
    """Futures-style handle for one submitted CSP."""

    __slots__ = (
        "id", "csp", "n_vars", "dom_size", "bucket", "fingerprint",
        "deadline", "max_assignments", "status", "solution", "stats",
        "split_budget", "portfolio",
        "submitted_at", "admitted_at", "finished_at", "_service",
        "_trace_t0",
    )

    def __init__(self, req_id: int, csp: CSP, bucket: Bucket, fingerprint: str,
                 submitted_at: float, deadline: Optional[float],
                 max_assignments: Optional[int], service: "SolverService",
                 split_budget: Optional[int] = None,
                 portfolio: Optional[int] = None):
        self.id = req_id
        self.csp = csp
        self.n_vars, self.dom_size = csp.dom.shape
        self.bucket = bucket
        self.fingerprint = fingerprint
        self.submitted_at = submitted_at
        self.deadline = deadline
        self.max_assignments = max_assignments
        # requested speculation ceilings (None = service defaults); admission
        # clamps them against live load via buckets.speculative_budget
        self.split_budget = split_budget
        self.portfolio = portfolio
        self.status = RequestStatus.QUEUED
        self.solution: Optional[List[int]] = None
        self.stats: Optional[SearchStats] = None
        self.admitted_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self._service = service
        # tracer-clock submit stamp for the request-lifetime span; the service
        # clock may be a FastForwardClock, so the tracer keeps its own timebase
        self._trace_t0 = obs.now()

    def done(self) -> bool:
        return self.status in _TERMINAL

    def result(self) -> Tuple[Optional[List[int]], Optional[SearchStats]]:
        """(solution | None, stats). Drives the service's event loop until this
        request retires (single-threaded future). ``(None, stats)`` is only a
        proof of UNSAT when ``status is DONE`` and ``stats.exhausted`` is
        False — a timed-out/cancelled request (check ``status``) or one that
        hit its assignment budget (``stats.exhausted``) is inconclusive."""
        while not self.done():
            self._service.step()
        return self.solution, self.stats

    @property
    def latency_s(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"<SolveRequest #{self.id} {self.status.value} "
                f"({self.n_vars}x{self.dom_size})->{self.bucket}>")


class _BucketRuntime:
    """One bucket's live state: slot pool, lockstep driver, slot free-list,
    and the in-flight requests (with their cache pins)."""

    def __init__(self, bucket: Bucket, pool: SlotPool, driver: LockstepDriver, store):
        self.bucket = bucket
        self.pool = pool
        self.driver = driver
        self.store = store  # FrontierTable | HostFrontierStore
        self.free_slots: List[int] = list(range(pool.capacity))
        self.active: Dict[int, Tuple[SolveRequest, CacheEntry]] = {}

    def take_slot(self) -> int:
        if not self.free_slots:
            old = self.pool.capacity
            self.pool.grow(old * 2)
            self.free_slots.extend(range(old, old * 2))
        return self.free_slots.pop()


class SolverService:
    """Continuous-batching solver service over any registered Engine."""

    def __init__(
        self,
        engine: Union[Engine, str] = "einsum",
        *,
        cache_bytes: int = 256 << 20,
        initial_slots: int = 8,
        max_active: Optional[int] = None,
        batched_children: bool = True,
        collect_stats: bool = True,
        split_budget: int = 0,
        portfolio: int = 0,
        portfolio_seed: int = 0,
        speculation_queue_limit: int = 4,
        n_floor: int = 8,
        d_floor: int = 4,
        clock: Optional[Callable[[], float]] = None,
        metrics_window: int = 100_000,
    ):
        self.engine = resolve_engine(engine)
        if initial_slots < 1:
            raise ValueError("initial_slots must be >= 1")
        if max_active is not None and max_active < 1:
            raise ValueError("max_active must be >= 1 (or None)")
        self._initial_slots = initial_slots
        self._max_active = max_active
        self._batched_children = batched_children
        self._collect_stats = collect_stats
        if split_budget < 0 or portfolio < 0:
            raise ValueError("split_budget / portfolio must be >= 0")
        if speculation_queue_limit < 1:
            raise ValueError("speculation_queue_limit must be >= 1")
        self._split_budget = split_budget
        self._portfolio = portfolio
        self._portfolio_seed = portfolio_seed
        self._speculation_queue_limit = speculation_queue_limit
        self._n_floor = n_floor
        self._d_floor = d_floor
        self._clock = clock if clock is not None else time.monotonic
        self._buckets: Dict[Bucket, _BucketRuntime] = {}
        self._queue: Deque[SolveRequest] = deque()
        self._ids = itertools.count()
        self.cache = PreparedNetworkCache(cache_bytes, self._free_slot)
        self.metrics = ServiceMetrics(window=metrics_window)

    # --- submission ---------------------------------------------------------

    def submit(
        self,
        csp: CSP,
        *,
        deadline_s: Optional[float] = None,
        max_assignments: Optional[int] = None,
        split_budget: Optional[int] = None,
        portfolio: Optional[int] = None,
    ) -> SolveRequest:
        """Queue one CSP; returns immediately with a `SolveRequest` future.

        Per-request knobs (exposed as ``[service]`` keys in `repro.sweeps`
        service-mode specs, and as ``submit_kwargs`` of
        `repro.service.replay_rate_cell`):

        - ``deadline_s``: relative to submission; an in-flight request whose
          deadline passes is cancelled at the next round boundary. Bounds
          *latency* (queue wait included).
        - ``max_assignments``: search-budget cap — the request completes
          unsolved once its MAC search has tried this many assignments.
          Bounds *compute* per request without touching queueing, which is
          why capacity studies set it: p95 then measures load, not the solve
          time of one pathologically hard instance.
        - ``split_budget`` / ``portfolio``: override the service's
          speculation defaults for this request (ceilings — admission still
          clamps them against queue depth and spare frontier rows; the
          verdict is unchanged either way, speculation only spends slack
          rows to finish sooner)."""
        now = self._clock()
        bucket = bucket_for(*csp.dom.shape, n_floor=self._n_floor, d_floor=self._d_floor)
        req = SolveRequest(
            next(self._ids), csp, bucket, network_fingerprint(csp),
            submitted_at=now,
            deadline=None if deadline_s is None else now + deadline_s,
            max_assignments=max_assignments,
            service=self,
            split_budget=split_budget,
            portfolio=portfolio,
        )
        self._queue.append(req)
        self.metrics.record_submit(now)
        return req

    def cancel(self, req: SolveRequest) -> bool:
        """Cancel a queued or running request; False if already terminal."""
        if req.done():
            return False
        self._retire(req, None, RequestStatus.CANCELLED)
        return True

    # --- event loop ---------------------------------------------------------

    @property
    def n_active(self) -> int:
        return sum(len(rt.active) for rt in self._buckets.values())

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(
            rt.driver.has_work for rt in self._buckets.values()
        )

    def step(self) -> int:
        """One event-loop beat: expire deadlines, admit from the queue, then
        run ONE lockstep round per bucket with pending work. Returns the
        number of requests that reached a terminal state."""
        now = self._clock()
        with obs.span("service.step", cat="service"):
            retired = self._expire(now)
            self._admit()
            for rt in list(self._buckets.values()):
                if not rt.driver.has_work:
                    continue
                finished = rt.driver.round()
                # rounds are pipelined: record the round the driver RESOLVED
                # this step (if any) — its row count and dispatch-to-metadata
                # seconds — not the one it just launched asynchronously
                info = rt.driver.last_round
                if info is not None:
                    self.metrics.record_round(
                        info.rows, info.searches, info.seconds, info.launches
                    )
                for req_id, (sol, _stats) in finished.items():
                    req, _entry = rt.active[req_id]
                    self._retire(req, sol, RequestStatus.DONE)
                    retired += 1
            self.metrics.record_queue_depth(len(self._queue))
        return retired

    def run_until_idle(self, max_steps: int = 1_000_000) -> None:
        for _ in range(max_steps):
            if not self.has_work:
                return
            self.step()
        raise RuntimeError(f"service still busy after {max_steps} steps")

    # --- internals ----------------------------------------------------------

    def _runtime(self, bucket: Bucket) -> _BucketRuntime:
        rt = self._buckets.get(bucket)
        if rt is None:
            pool = self.engine.open_slot_pool(bucket.n_p, bucket.d_p, self._initial_slots)
            # Engines ADVERTISE their capabilities (Engine.device_frontier /
            # slot_table); the bucket wiring follows the advertisement, never
            # backend names. Device-frontier engines dispatch every round
            # against a resident FrontierTable fed by the pool's live slot
            # tables (installs and growth between rounds are picked up);
            # everything else routes through the host store over the pool.
            if self.engine.device_frontier and isinstance(pool, StackedSlotPool):
                store = self.engine.open_frontier(
                    lambda: pool.tables, bucket.n_p, bucket.d_p,
                    capacity=frontier_capacity(
                        self._initial_slots, bucket.n_p, bucket.d_p
                    ),
                    check_net=pool.require_installed,
                )
            else:
                store = HostFrontierStore(
                    bucket.n_p, pool.enforce_rows, pad_rounds=self.engine.slot_table
                )
            driver = LockstepDriver(store, bucket.n_p, count_unit=self.engine.count_unit)
            rt = self._buckets[bucket] = _BucketRuntime(bucket, pool, driver, store)
        return rt

    def _free_slot(self, entry: CacheEntry) -> None:
        """Cache eviction callback: return the slot to its bucket's free list."""
        rt = self._buckets[entry.bucket]
        rt.pool.release(entry.slot)
        rt.free_slots.append(entry.slot)

    def _admit(self) -> None:
        while self._queue:
            if self._max_active is not None and self.n_active >= self._max_active:
                return
            req = self._queue.popleft()
            with obs.span("service.admit", cat="service", req=req.id,
                          bucket=str(req.bucket)):
                self._admit_one(req)

    def _admit_one(self, req: SolveRequest) -> None:
        rt = self._runtime(req.bucket)
        padded = pad_csp(req.csp, req.bucket)

        def install() -> int:
            slot = rt.take_slot()
            rt.pool.install(slot, padded)
            return slot

        # The cache budget counts the ENGINE's resident bytes for this
        # bucket shape — packed u32 words on pallas_packed (≈8× fewer
        # bytes than the logical bool network), padded u8 on pallas_dense,
        # the logical network elsewhere — so the same budget legally holds
        # proportionally more packed networks.
        entry, _hit = self.cache.acquire(
            req.bucket,
            req.fingerprint,
            self.engine.network_nbytes(req.bucket.n_p, req.bucket.d_p),
            install,
        )
        # Size this request's speculation against live load: the spare-row
        # pool is what the store ACTUALLY has free, clamped by the engine's
        # advertised appetite, shared fairly with everyone still queued.
        # Under pressure (deep queue / no slack) this degrades to plain
        # admission — admit_group with (0, 0) is byte-identical to admit.
        want_split = req.split_budget if req.split_budget is not None else self._split_budget
        want_port = req.portfolio if req.portfolio is not None else self._portfolio
        split_eff, port_eff = speculative_budget(
            want_split,
            want_port,
            queue_depth=len(self._queue),
            spare_rows=min(
                rt.store.spare_rows(), self.engine.speculative_rows_hint
            ),
            queue_limit=self._speculation_queue_limit,
        )
        req.stats = rt.driver.admit_group(
            req.id,
            padded,
            idx=entry.slot,
            split_budget=split_eff,
            portfolio=port_eff,
            portfolio_seed=self._portfolio_seed + req.id,
            supports_batch=self.engine.supports_batch,
            batched_children=self._batched_children,
            n_active=req.n_vars,
            max_assignments=req.max_assignments,
            collect_stats=self._collect_stats,
        )
        rt.active[req.id] = (req, entry)
        req.status = RequestStatus.RUNNING
        req.admitted_at = self._clock()

    def _expire(self, now: float) -> int:
        """Retire queued/running requests whose deadline has passed."""
        expired = [
            req for req in self._queue
            if req.deadline is not None and now >= req.deadline
        ]
        for rt in self._buckets.values():
            expired.extend(
                req for req, _e in rt.active.values()
                if req.deadline is not None and now >= req.deadline
            )
        for req in expired:
            self._retire(req, None, RequestStatus.TIMED_OUT)
        return len(expired)

    def _retire(self, req: SolveRequest, solution, status: RequestStatus) -> None:
        if req.status is RequestStatus.QUEUED:
            self._queue.remove(req)
        elif req.status is RequestStatus.RUNNING:
            rt = self._buckets[req.bucket]
            _req, entry = rt.active.pop(req.id)
            if rt.driver.is_active(req.id):  # still mid-flight (deadline/cancel)
                rt.driver.cancel(req.id)
            self.cache.release(entry)
        req.solution = solution
        req.status = status
        req.finished_at = self._clock()
        self.metrics.record_finish(
            req.finished_at, req.finished_at - req.submitted_at, status.value
        )
        # request-lifetime span on its own Perfetto track, in the TRACER's
        # timebase (the service clock may fast-forward); only when the stamp
        # was taken with tracing already on, so the pair shares one origin
        if obs.enabled() and req._trace_t0 > 0.0:
            obs.record_complete(
                "service.request", req._trace_t0, obs.now(),
                cat="service", track="requests",
                id=req.id, status=status.value, bucket=str(req.bucket),
            )
        if req.stats is not None:  # was admitted: file lifetime row consumption
            self.metrics.record_request_rows(
                req.stats.rows, req.stats.members, req.stats.cancelled_members
            )

    # --- introspection ------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        """Service-wide metrics + cache + per-bucket occupancy (JSON-ready)."""
        snap = self.metrics.snapshot()
        snap["cache"] = self.cache.stats()
        snap["buckets"] = {
            str(b): {
                "capacity": rt.pool.capacity,
                "free_slots": len(rt.free_slots),
                "active": len(rt.active),
                "resident_nbytes": rt.pool.resident_nbytes,
                **(
                    {
                        "device_frontier": True,
                        "frontier_rows": rt.store.capacity,
                        "frontier_rows_live": rt.store.rows_live,
                        "host_bytes_per_round": rt.store.host_bytes_per_round,
                    }
                    if isinstance(rt.store, FrontierTable)
                    else {"device_frontier": False}
                ),
            }
            for b, rt in sorted(self._buckets.items())
        }
        return snap
