"""SolverService — continuous-batching CSP solving over time (DESIGN.md §7).

`solve_many` takes a *closed* batch: every instance known up front, one
lockstep run to completion. A service faces an *open world* — requests arrive
over time, finish at different times, and must not wait for a batch to drain.
`SolverService` keeps the device saturated anyway:

- **submit** returns a futures-style `SolveRequest` immediately; the CSP is
  routed to its shape bucket (`buckets.bucket_for`) and queued;
- **admission** pads the CSP into its bucket, fingerprints the constraint
  network, and pins it in the prepared-network cache (`cache`) — a cache hit
  reuses an already-resident slot, a miss installs into a free slot of the
  bucket's `SlotPool` (growing by doubling when full);
- **step** runs ONE lockstep round per bucket with work: newly admitted
  searches' root propagations ride the same dispatch as everyone else's
  frontiers, and searches that finish free their rows (and their cache pins)
  mid-flight — continuous batching, one device dispatch per bucket round;
- per-request **deadlines** (checked between rounds) and **assignment
  budgets** bound work; `metrics.ServiceMetrics` tracks throughput, tail
  latency, queue depth, and rows-per-dispatch occupancy.

Single-threaded by design: ``step()`` is the event loop body, so tests and
trace replay drive the service deterministically (``request.result()`` just
steps until its request retires). Results and per-request `SearchStats` are
bit-identical to sequential `mac_solve` on the unpadded CSP — asserted by
`tests/test_service.py`.

Failure handling (DESIGN.md §12): every `repro.faults.FaultError` escaping
admission or a lockstep round is absorbed by the service, never the caller.
A faulted request is retried with capped exponential backoff, then demoted
down the engine fallback ladder (fused → stepped → einsum) with its rows
re-rooted on the fallback runtime, and only FAILED once the ladder is
exhausted. A faulted *round* rebuilds the bucket's driver + frontier store
from scratch (the slot pool and its resident networks survive) and requeues
every in-flight request; K consecutive faulted rounds trip the bucket's
circuit breaker, flooring all future admissions of that bucket at the next
ladder rung. Queue-depth and deadline-aware load shedding reject requests
with a typed `Overloaded` error before padding work is spent on them.
"""

from __future__ import annotations

import itertools
import math
import time
from collections import deque
from enum import Enum
from typing import Callable, Deque, Dict, List, Optional, Tuple, Union

from repro import faults, obs
from repro.core.csp import CSP
from repro.core.engine import (
    Engine,
    FrontierTable,
    SlotPool,
    StackedSlotPool,
    frontier_capacity,
)
from repro.core.search import HostFrontierStore, LockstepDriver, SearchStats, resolve_engine
from .buckets import Bucket, bucket_for, pad_csp, speculative_budget
from .cache import CacheEntry, PreparedNetworkCache, network_fingerprint
from .metrics import ServiceMetrics


class RequestStatus(Enum):
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    TIMED_OUT = "timed_out"
    CANCELLED = "cancelled"
    #: rejected by load shedding before (or at) admission; ``req.error`` is
    #: the `repro.faults.Overloaded` carrying the retry-after hint
    SHED = "shed"
    #: gave up after exhausting retries + the whole engine fallback ladder,
    #: or evicted by the round watchdog; ``req.error`` is the last fault
    FAILED = "failed"


_TERMINAL = (
    RequestStatus.DONE,
    RequestStatus.TIMED_OUT,
    RequestStatus.CANCELLED,
    RequestStatus.SHED,
    RequestStatus.FAILED,
)


class InvalidRequest(ValueError):
    """A submit-time argument is unusable (non-positive deadline, absurd
    budget, malformed domain shape). Raised eagerly at `SolverService.submit`
    so a bad request fails in the caller's stack frame, not rounds later
    inside the lockstep."""


class SolveRequest:
    """Futures-style handle for one submitted CSP."""

    __slots__ = (
        "id", "csp", "n_vars", "dom_size", "bucket", "fingerprint",
        "deadline", "max_assignments", "status", "solution", "stats",
        "split_budget", "portfolio",
        "submitted_at", "admitted_at", "finished_at", "_service",
        "_trace_t0",
        # robustness state: the terminal error (Overloaded / FaultError),
        # retries burned at the current ladder level, the current fallback
        # level, the backoff gate (admission skips this request until then),
        # and the runtime key it is active on
        "error", "retries", "engine_level", "not_before", "_rt_key",
    )

    def __init__(self, req_id: int, csp: CSP, bucket: Bucket, fingerprint: str,
                 submitted_at: float, deadline: Optional[float],
                 max_assignments: Optional[int], service: "SolverService",
                 split_budget: Optional[int] = None,
                 portfolio: Optional[int] = None):
        self.id = req_id
        self.csp = csp
        self.n_vars, self.dom_size = csp.dom.shape
        self.bucket = bucket
        self.fingerprint = fingerprint
        self.submitted_at = submitted_at
        self.deadline = deadline
        self.max_assignments = max_assignments
        # requested speculation ceilings (None = service defaults); admission
        # clamps them against live load via buckets.speculative_budget
        self.split_budget = split_budget
        self.portfolio = portfolio
        self.status = RequestStatus.QUEUED
        self.solution: Optional[List[int]] = None
        self.stats: Optional[SearchStats] = None
        self.error: Optional[BaseException] = None
        self.retries = 0
        self.engine_level = 0
        self.not_before = 0.0
        self._rt_key = None
        self.admitted_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        self._service = service
        # tracer-clock submit stamp for the request-lifetime span; the service
        # clock may be a FastForwardClock, so the tracer keeps its own timebase
        self._trace_t0 = obs.now()

    def done(self) -> bool:
        return self.status in _TERMINAL

    def result(self) -> Tuple[Optional[List[int]], Optional[SearchStats]]:
        """(solution | None, stats). Drives the service's event loop until this
        request retires (single-threaded future). ``(None, stats)`` is only a
        proof of UNSAT when ``status is DONE`` and ``stats.exhausted`` is
        False — a timed-out/cancelled/shed/failed request (check ``status``;
        SHED and FAILED carry the reason in ``error``) or one that hit its
        assignment budget (``stats.exhausted``) is inconclusive."""
        while not self.done():
            self._service.step()
        return self.solution, self.stats

    @property
    def latency_s(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"<SolveRequest #{self.id} {self.status.value} "
                f"({self.n_vars}x{self.dom_size})->{self.bucket}>")


class _BucketRuntime:
    """One (bucket, fallback level)'s live state: engine, slot pool, lockstep
    driver, slot free-list, and the in-flight requests (with their cache
    pins). A faulted round replaces ``driver``/``store`` in place — the pool
    (and every network the cache holds resident in it) survives the rebuild."""

    def __init__(self, bucket: Bucket, engine: Engine, level: int,
                 pool: SlotPool, driver: LockstepDriver, store):
        self.bucket = bucket
        self.engine = engine
        self.level = level
        self.pool = pool
        self.driver = driver
        self.store = store  # FrontierTable | HostFrontierStore
        self.free_slots: List[int] = list(range(pool.capacity))
        self.active: Dict[int, Tuple[SolveRequest, CacheEntry]] = {}
        #: consecutive faulted rounds — the circuit breaker's trip counter,
        #: reset by any cleanly resolved round
        self.consecutive_faults = 0

    def take_slot(self) -> int:
        if not self.free_slots:
            old = self.pool.capacity
            self.pool.grow(old * 2)
            self.free_slots.extend(range(old, old * 2))
        return self.free_slots.pop()


class SolverService:
    """Continuous-batching solver service over any registered Engine."""

    def __init__(
        self,
        engine: Union[Engine, str] = "einsum",
        *,
        cache_bytes: int = 256 << 20,
        initial_slots: int = 8,
        max_active: Optional[int] = None,
        batched_children: bool = True,
        collect_stats: bool = True,
        split_budget: int = 0,
        portfolio: int = 0,
        portfolio_seed: int = 0,
        speculation_queue_limit: int = 4,
        n_floor: int = 8,
        d_floor: int = 4,
        clock: Optional[Callable[[], float]] = None,
        metrics_window: int = 100_000,
        retry_cap: int = 2,
        backoff_base_s: float = 0.05,
        backoff_cap_s: float = 1.0,
        breaker_threshold: int = 3,
        round_wall_s: Optional[float] = None,
        round_recurrences: Optional[int] = None,
        shed_queue_depth: Optional[int] = None,
        shed_deadline_factor: Optional[float] = None,
    ):
        self.engine = resolve_engine(engine)
        if initial_slots < 1:
            raise ValueError("initial_slots must be >= 1")
        if max_active is not None and max_active < 1:
            raise ValueError("max_active must be >= 1 (or None)")
        self._initial_slots = initial_slots
        self._max_active = max_active
        self._batched_children = batched_children
        self._collect_stats = collect_stats
        if split_budget < 0 or portfolio < 0:
            raise ValueError("split_budget / portfolio must be >= 0")
        if speculation_queue_limit < 1:
            raise ValueError("speculation_queue_limit must be >= 1")
        self._split_budget = split_budget
        self._portfolio = portfolio
        self._portfolio_seed = portfolio_seed
        self._speculation_queue_limit = speculation_queue_limit
        self._n_floor = n_floor
        self._d_floor = d_floor
        self._clock = clock if clock is not None else time.monotonic
        if retry_cap < 0:
            raise ValueError("retry_cap must be >= 0")
        if backoff_base_s < 0 or backoff_cap_s < 0:
            raise ValueError("backoff_base_s / backoff_cap_s must be >= 0")
        if breaker_threshold < 1:
            raise ValueError("breaker_threshold must be >= 1")
        # fail at construction, not at the first admitted round
        if round_wall_s is not None and round_wall_s <= 0:
            raise ValueError("round_wall_s must be > 0 (or None)")
        if round_recurrences is not None and round_recurrences < 1:
            raise ValueError("round_recurrences must be >= 1 (or None)")
        if shed_queue_depth is not None and shed_queue_depth < 1:
            raise ValueError("shed_queue_depth must be >= 1 (or None)")
        if shed_deadline_factor is not None and shed_deadline_factor <= 0:
            raise ValueError("shed_deadline_factor must be > 0 (or None)")
        self._retry_cap = retry_cap
        self._backoff_base_s = backoff_base_s
        self._backoff_cap_s = backoff_cap_s
        self._breaker_threshold = breaker_threshold
        self._round_wall_s = round_wall_s
        self._round_recurrences = round_recurrences
        self._shed_queue_depth = shed_queue_depth
        self._shed_deadline_factor = shed_deadline_factor
        # the engine fallback ladder (level 0 = the primary engine); a
        # bucket's circuit breaker floors its admissions at _bucket_floor
        self._ladder: List[Engine] = self._build_ladder(self.engine)
        self._bucket_floor: Dict[Bucket, int] = {}
        # runtimes are keyed (Bucket, ladder level): a demoted request's rows
        # re-root on the fallback engine's own pool/frontier, never mixing
        # engines within one lockstep
        self._buckets: Dict[Tuple[Bucket, int], _BucketRuntime] = {}
        self._queue: Deque[SolveRequest] = deque()
        self._ids = itertools.count()
        self.cache = PreparedNetworkCache(cache_bytes, self._free_slot)
        self.metrics = ServiceMetrics(window=metrics_window)

    @staticmethod
    def _build_ladder(primary: Engine) -> List[Engine]:
        """fused → stepped → einsum, starting from whatever was configured.
        Each rung is strictly more conservative than the last; the final rung
        is the reference einsum engine whose verdicts the parity oracles pin,
        so a demotion never changes a result — only how it is computed."""
        ladder = [primary]
        name = getattr(primary, "name", None)
        from repro.engines import get_engine

        if name and getattr(primary, "fused_fixpoint", False):
            try:
                ladder.append(get_engine(name, fixpoint="stepped"))
            except (KeyError, TypeError, ValueError):
                pass
        if name != "einsum":
            ladder.append(get_engine("einsum"))
        return ladder

    # --- submission ---------------------------------------------------------

    def submit(
        self,
        csp: CSP,
        *,
        deadline_s: Optional[float] = None,
        max_assignments: Optional[int] = None,
        split_budget: Optional[int] = None,
        portfolio: Optional[int] = None,
    ) -> SolveRequest:
        """Queue one CSP; returns immediately with a `SolveRequest` future.

        Per-request knobs (exposed as ``[service]`` keys in `repro.sweeps`
        service-mode specs, and as ``submit_kwargs`` of
        `repro.service.replay_rate_cell`):

        - ``deadline_s``: relative to submission; an in-flight request whose
          deadline passes is cancelled at the next round boundary. Bounds
          *latency* (queue wait included).
        - ``max_assignments``: search-budget cap — the request completes
          unsolved once its MAC search has tried this many assignments.
          Bounds *compute* per request without touching queueing, which is
          why capacity studies set it: p95 then measures load, not the solve
          time of one pathologically hard instance.
        - ``split_budget`` / ``portfolio``: override the service's
          speculation defaults for this request (ceilings — admission still
          clamps them against queue depth and spare frontier rows; the
          verdict is unchanged either way, speculation only spends slack
          rows to finish sooner).

        Raises `InvalidRequest` eagerly on unusable arguments. With
        ``shed_queue_depth`` configured and the queue at/over it, the request
        is SHED immediately: its future resolves with
        ``error = faults.Overloaded`` (retry-after hint included) instead of
        joining a queue it would only time out in."""
        self._validate_submit(csp, deadline_s, max_assignments,
                              split_budget, portfolio)
        now = self._clock()
        bucket = bucket_for(*csp.dom.shape, n_floor=self._n_floor, d_floor=self._d_floor)
        req = SolveRequest(
            next(self._ids), csp, bucket, network_fingerprint(csp),
            submitted_at=now,
            deadline=None if deadline_s is None else now + deadline_s,
            max_assignments=max_assignments,
            service=self,
            split_budget=split_budget,
            portfolio=portfolio,
        )
        self._queue.append(req)
        self.metrics.record_submit(now)
        if (
            self._shed_queue_depth is not None
            and len(self._queue) > self._shed_queue_depth
        ):
            self._shed(req, f"queue depth {len(self._queue)} > "
                            f"{self._shed_queue_depth}")
        return req

    def _validate_submit(self, csp: CSP, deadline_s, max_assignments,
                         split_budget, portfolio) -> None:
        dom = getattr(csp, "dom", None)
        if dom is None or getattr(dom, "ndim", 0) != 2 or min(dom.shape) < 1:
            raise InvalidRequest(
                "csp.dom must be a 2-D (n_vars, dom_size) array with both "
                f"dimensions >= 1, got {None if dom is None else dom.shape}"
            )
        if deadline_s is not None and not (
            math.isfinite(deadline_s) and 0 <= deadline_s < 1e7
        ):
            # zero is legal (expire at the next beat — a probe pattern the
            # deadline tests use); negative or absurd magnitudes are not
            raise InvalidRequest(
                f"deadline_s must be a finite number of seconds in [0, 1e7), "
                f"got {deadline_s!r}"
            )
        if max_assignments is not None and not (
            isinstance(max_assignments, int) and 1 <= max_assignments <= 10**9
        ):
            raise InvalidRequest(
                f"max_assignments must be an int in [1, 1e9], "
                f"got {max_assignments!r}"
            )
        for label, v in (("split_budget", split_budget), ("portfolio", portfolio)):
            if v is not None and (not isinstance(v, int) or v < 0):
                raise InvalidRequest(f"{label} must be an int >= 0, got {v!r}")

    def _shed(self, req: SolveRequest, why: str) -> None:
        """Reject ``req`` with a typed `Overloaded` (terminal SHED status).
        The retry-after hint is the recent mean latency scaled by how many
        requests stand in line per admission slot — rough, but it gives a
        well-behaved client a sensible pause instead of a stampede."""
        lat = self.metrics.latency_ms(50) / 1e3
        slots = self._max_active if self._max_active is not None else max(
            1, self.n_active
        )
        hint = max(0.05, lat * (1 + len(self._queue) / max(1, slots)))
        req.error = faults.Overloaded(hint, why)
        self._retire(req, None, RequestStatus.SHED)

    def cancel(self, req: SolveRequest) -> bool:
        """Cancel a queued or running request; False if already terminal."""
        if req.done():
            return False
        self._retire(req, None, RequestStatus.CANCELLED)
        return True

    # --- event loop ---------------------------------------------------------

    @property
    def n_active(self) -> int:
        return sum(len(rt.active) for rt in self._buckets.values())

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(
            rt.driver.has_work for rt in self._buckets.values()
        )

    def next_wakeup(self) -> Optional[float]:
        """Earliest backoff gate among queued requests, IF backoff timers are
        the only thing the service is waiting on (no live driver work, nothing
        admittable now) — else None. Replay loops use this to fast-forward
        their clock over a pure backoff wait instead of busy-spinning."""
        if not self._queue or any(
            rt.driver.has_work for rt in self._buckets.values()
        ):
            return None
        gates = [r.not_before for r in self._queue]
        if min(gates) <= self._clock():
            return None
        return min(gates)

    def step(self) -> int:
        """One event-loop beat: expire deadlines, admit from the queue, then
        run ONE lockstep round per bucket with pending work. Returns the
        number of requests that reached a terminal state.

        A `faults.FaultError` escaping a round never escapes here: the
        runtime is recovered (driver + store rebuilt on the surviving pool)
        and its in-flight requests re-enter the queue through the
        retry/demote ladder."""
        now = self._clock()
        with obs.span("service.step", cat="service"):
            retired = self._expire(now)
            self._admit()
            for key, rt in list(self._buckets.items()):
                if not rt.driver.has_work:
                    continue
                try:
                    finished = rt.driver.round()
                except faults.FaultError as err:
                    self._recover_runtime(key, rt, err, now)
                    continue
                # rounds are pipelined: record the round the driver RESOLVED
                # this step (if any) — its row count and dispatch-to-metadata
                # seconds — not the one it just launched asynchronously. The
                # breaker counter resets only on a RESOLVED round: launch-only
                # rounds always succeed between faults and would otherwise
                # keep the count forever at 1
                info = rt.driver.last_round
                if info is not None:
                    rt.consecutive_faults = 0
                    self.metrics.record_round(
                        info.rows, info.searches, info.seconds, info.launches
                    )
                for req_id, (sol, stats) in finished.items():
                    req, _entry = rt.active[req_id]
                    # a watchdog quarantine is a FAILURE verdict — it must
                    # never read as UNSAT, so the check precedes (None, stats)
                    if stats is not None and stats.quarantined:
                        req.error = faults.FaultError(
                            "round.watchdog", stats.quarantined
                        )
                        self._retire(req, None, RequestStatus.FAILED)
                    else:
                        self._retire(req, sol, RequestStatus.DONE)
                    retired += 1
            self.metrics.record_queue_depth(len(self._queue))
        return retired

    def run_until_idle(self, max_steps: int = 1_000_000) -> None:
        for _ in range(max_steps):
            if not self.has_work:
                return
            wake = self.next_wakeup()
            if wake is not None:
                # the only work left is behind backoff gates — yield instead
                # of burning the step budget busy-spinning on the clock
                time.sleep(min(0.01, max(0.0, wake - self._clock())))
            self.step()
        raise RuntimeError(f"service still busy after {max_steps} steps")

    # --- internals ----------------------------------------------------------

    def _runtime(self, bucket: Bucket, level: int = 0) -> _BucketRuntime:
        key = (bucket, level)
        rt = self._buckets.get(key)
        if rt is None:
            engine = self._ladder[level]
            pool = engine.open_slot_pool(bucket.n_p, bucket.d_p, self._initial_slots)
            driver, store = self._build_driver(engine, bucket, pool)
            rt = self._buckets[key] = _BucketRuntime(
                bucket, engine, level, pool, driver, store
            )
        return rt

    def _build_driver(self, engine: Engine, bucket: Bucket, pool: SlotPool):
        """Wire a fresh driver + frontier store over ``pool`` — used both at
        runtime creation and to rebuild a runtime whose round faulted (the
        pool, holding every cache-resident network, is reused as-is).

        Engines ADVERTISE their capabilities (Engine.device_frontier /
        slot_table); the bucket wiring follows the advertisement, never
        backend names. Device-frontier engines dispatch every round against
        a resident FrontierTable fed by the pool's live slot tables (installs
        and growth between rounds are picked up); everything else routes
        through the host store over the pool."""
        if engine.device_frontier and isinstance(pool, StackedSlotPool):
            store = engine.open_frontier(
                lambda: pool.tables, bucket.n_p, bucket.d_p,
                capacity=frontier_capacity(
                    self._initial_slots, bucket.n_p, bucket.d_p
                ),
                check_net=pool.require_installed,
            )
        else:
            store = HostFrontierStore(
                bucket.n_p, pool.enforce_rows, pad_rounds=engine.slot_table
            )
        driver = LockstepDriver(
            store, bucket.n_p, count_unit=engine.count_unit,
            round_wall_s=self._round_wall_s,
            round_recurrences=self._round_recurrences,
        )
        return driver, store

    def _recover_runtime(self, key, rt: _BucketRuntime,
                         err: faults.FaultError, now: float) -> None:
        """A lockstep round faulted somewhere between dispatch and resolve —
        the driver/store state is unknowable, so rebuild both from scratch on
        the surviving slot pool and route every in-flight request back through
        the queue (retry → demote → FAILED ladder). K consecutive faulted
        rounds trip the bucket's circuit breaker: future admissions of this
        bucket floor at the next ladder rung instead of flapping."""
        rt.consecutive_faults += 1
        obs.counter_add("faults.round_recoveries")
        with obs.span("service.recover", cat="service", bucket=str(rt.bucket),
                      level=rt.level, site=err.site,
                      n_requeued=len(rt.active)):
            actives = list(rt.active.values())
            rt.active.clear()
            for req, entry in actives:
                self.cache.release(entry)
                self._fault_requeue(req, err, now)
            rt.driver, rt.store = self._build_driver(
                rt.engine, rt.bucket, rt.pool
            )
        if (
            rt.consecutive_faults >= self._breaker_threshold
            and rt.level + 1 < len(self._ladder)
            and self._bucket_floor.get(rt.bucket, 0) <= rt.level
        ):
            self._bucket_floor[rt.bucket] = rt.level + 1
            self.metrics.record_breaker_trip()
            rt.consecutive_faults = 0

    def _fault_requeue(self, req: SolveRequest, err: faults.FaultError,
                       now: float) -> None:
        """Route one faulted request: capped-exponential-backoff retry at its
        current ladder level, demotion to the next level once retries are
        spent, terminal FAILED once the ladder is exhausted."""
        req.error = err
        req.status = RequestStatus.QUEUED
        req._rt_key = None
        req.stats = None
        if req.retries < self._retry_cap:
            req.retries += 1
            req.not_before = now + min(
                self._backoff_base_s * (2 ** (req.retries - 1)),
                self._backoff_cap_s,
            )
            self.metrics.record_retry()
            self._queue.append(req)
            return
        if req.engine_level + 1 < len(self._ladder):
            req.engine_level += 1
            req.retries = 0
            req.not_before = now
            self.metrics.record_demotion()
            self._queue.append(req)
            return
        # ladder exhausted: requeue-then-retire so the one _retire path
        # handles bookkeeping (it pops QUEUED requests from the queue)
        self._queue.append(req)
        self._retire(req, None, RequestStatus.FAILED)

    def _free_slot(self, entry: CacheEntry) -> None:
        """Cache eviction callback: return the slot to its runtime's free
        list. Level-0 entries carry a bare Bucket key, fallback entries the
        (bucket, level) composite — normalize to the runtime key."""
        key = entry.bucket if isinstance(entry.bucket, tuple) else (entry.bucket, 0)
        rt = self._buckets[key]
        rt.pool.release(entry.slot)
        rt.free_slots.append(entry.slot)

    def _admit(self) -> None:
        now = self._clock()
        deferred: List[SolveRequest] = []
        try:
            while self._queue:
                if self._max_active is not None and self.n_active >= self._max_active:
                    return
                req = self._queue.popleft()
                if req.not_before > now:
                    deferred.append(req)  # backoff gate still closed
                    continue
                with obs.span("service.admit", cat="service", req=req.id,
                              bucket=str(req.bucket)):
                    try:
                        self._admit_one(req, now)
                    except faults.FaultError as err:
                        # every admission-path site fires before the driver
                        # sees the request, so requeueing is all the cleanup
                        # there is (install() returns its slot on failure,
                        # cache.acquire registers nothing on a raise)
                        self._fault_requeue(req, err, now)
        finally:
            # preserve arrival order among the still-gated requests
            for r in reversed(deferred):
                self._queue.appendleft(r)

    def _admit_one(self, req: SolveRequest, now: float) -> None:
        faults.inject("service.admit", req=req.id)
        if (
            self._shed_deadline_factor is not None
            and req.deadline is not None
        ):
            # deadline-aware shed: if the recent median solve latency says
            # this request cannot make its deadline, reject it now instead of
            # spending padding + install work on a corpse (no latency history
            # yet → estimate 0 → never sheds)
            est = self._shed_deadline_factor * self.metrics.latency_ms(50) / 1e3
            if est > 0 and now + est > req.deadline:
                self._shed(
                    req,
                    f"deadline {req.deadline - now:.3f}s away < estimated "
                    f"{est:.3f}s to solve",
                )
                return
        level = max(req.engine_level, self._bucket_floor.get(req.bucket, 0))
        req.engine_level = level
        rt = self._runtime(req.bucket, level)
        padded = pad_csp(req.csp, req.bucket)

        def install() -> int:
            slot = rt.take_slot()
            try:
                rt.pool.install(slot, padded)
            except BaseException:
                # the pool registered nothing (its slot entry is only set on
                # success) — just return the slot to the free list
                rt.free_slots.append(slot)
                raise
            return slot

        # The cache budget counts the ENGINE's resident bytes for this
        # bucket shape — packed u32 words on pallas_packed (≈8× fewer
        # bytes than the logical bool network), padded u8 on pallas_dense,
        # the logical network elsewhere — so the same budget legally holds
        # proportionally more packed networks.
        # level-0 entries keep the bare Bucket as their cache key (the
        # public lookup(bucket, fp) contract); fallback levels key by
        # (bucket, level) so a demoted request's network never aliases the
        # primary engine's resident slot
        cache_key = req.bucket if level == 0 else (req.bucket, level)
        entry, _hit = self.cache.acquire(
            cache_key,
            req.fingerprint,
            rt.engine.network_nbytes(req.bucket.n_p, req.bucket.d_p),
            install,
        )
        # Size this request's speculation against live load: the spare-row
        # pool is what the store ACTUALLY has free, clamped by the engine's
        # advertised appetite, shared fairly with everyone still queued.
        # Under pressure (deep queue / no slack) this degrades to plain
        # admission — admit_group with (0, 0) is byte-identical to admit.
        want_split = req.split_budget if req.split_budget is not None else self._split_budget
        want_port = req.portfolio if req.portfolio is not None else self._portfolio
        split_eff, port_eff = speculative_budget(
            want_split,
            want_port,
            queue_depth=len(self._queue),
            spare_rows=min(
                rt.store.spare_rows(), rt.engine.speculative_rows_hint
            ),
            queue_limit=self._speculation_queue_limit,
        )
        req.stats = rt.driver.admit_group(
            req.id,
            padded,
            idx=entry.slot,
            split_budget=split_eff,
            portfolio=port_eff,
            portfolio_seed=self._portfolio_seed + req.id,
            supports_batch=rt.engine.supports_batch,
            batched_children=self._batched_children,
            n_active=req.n_vars,
            max_assignments=req.max_assignments,
            collect_stats=self._collect_stats,
        )
        rt.active[req.id] = (req, entry)
        req._rt_key = (req.bucket, level)
        req.status = RequestStatus.RUNNING
        req.admitted_at = self._clock()

    def _expire(self, now: float) -> int:
        """Retire queued/running requests whose deadline has passed."""
        expired = [
            req for req in self._queue
            if req.deadline is not None and now >= req.deadline
        ]
        for rt in self._buckets.values():
            expired.extend(
                req for req, _e in rt.active.values()
                if req.deadline is not None and now >= req.deadline
            )
        for req in expired:
            self._retire(req, None, RequestStatus.TIMED_OUT)
        return len(expired)

    def _retire(self, req: SolveRequest, solution, status: RequestStatus) -> None:
        if req.status is RequestStatus.QUEUED:
            self._queue.remove(req)
        elif req.status is RequestStatus.RUNNING:
            rt = self._buckets[req._rt_key]
            _req, entry = rt.active.pop(req.id)
            if rt.driver.is_active(req.id):  # still mid-flight (deadline/cancel)
                rt.driver.cancel(req.id)
            self.cache.release(entry)
        req.solution = solution
        req.status = status
        req.finished_at = self._clock()
        self.metrics.record_finish(
            req.finished_at, req.finished_at - req.submitted_at, status.value
        )
        # request-lifetime span on its own Perfetto track, in the TRACER's
        # timebase (the service clock may fast-forward); only when the stamp
        # was taken with tracing already on, so the pair shares one origin
        if obs.enabled() and req._trace_t0 > 0.0:
            obs.record_complete(
                "service.request", req._trace_t0, obs.now(),
                cat="service", track="requests",
                id=req.id, status=status.value, bucket=str(req.bucket),
            )
        if req.stats is not None:  # was admitted: file lifetime row consumption
            self.metrics.record_request_rows(
                req.stats.rows, req.stats.members, req.stats.cancelled_members
            )

    # --- introspection ------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        """Service-wide metrics + cache + per-bucket occupancy (JSON-ready).
        Fallback-level runtimes (level > 0) key as ``<bucket>@L<level>``;
        level-0 keys are the bare bucket string as before."""
        snap = self.metrics.snapshot()
        snap["cache"] = self.cache.stats()
        snap["engine_ladder"] = [
            getattr(e, "name", type(e).__name__) for e in self._ladder
        ]
        snap["bucket_floor"] = {
            str(b): lvl for b, lvl in sorted(self._bucket_floor.items())
        }
        snap["buckets"] = {
            (str(b) if lvl == 0 else f"{b}@L{lvl}"): {
                "capacity": rt.pool.capacity,
                "free_slots": len(rt.free_slots),
                "active": len(rt.active),
                "level": lvl,
                "consecutive_faults": rt.consecutive_faults,
                "resident_nbytes": rt.pool.resident_nbytes,
                **(
                    {
                        "device_frontier": True,
                        "frontier_rows": rt.store.capacity,
                        "frontier_rows_live": rt.store.rows_live,
                        "host_bytes_per_round": rt.store.host_bytes_per_round,
                    }
                    if isinstance(rt.store, FrontierTable)
                    else {"device_frontier": False}
                ),
            }
            for (b, lvl), rt in sorted(self._buckets.items())
        }
        return snap
