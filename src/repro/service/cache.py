"""Prepared-network cache: constraint-tensor fingerprint → resident slot.

A prepared network is O(n²d²) device memory, so a service cannot keep every
network it has ever seen resident — but workloads repeat (the same puzzle
re-submitted, a family's deterministic instances, retries), and re-preparing
is the one expensive step admission has. The cache maps a *fingerprint of the
constraint network* (cons + mask — NOT the domain, which is per-request) to
the bucket slot where that network is installed, with LRU eviction under an
explicit byte budget.

Byte accounting is in the ENGINE's resident representation, not logical cons
bytes: the service supplies each entry's ``nbytes`` from
`Engine.network_nbytes(bucket.n_p, bucket.d_p)`, so on `pallas_packed` an
entry costs packed uint32 words (≈8× fewer bytes than the bool network) and
the same budget legally holds ≈8× more networks resident.

Pinning: every in-flight search against a network holds a pin on its entry,
and eviction skips pinned entries unconditionally — a network is only ever
evicted between flights. The byte budget is therefore a *target*: if every
resident network is pinned the cache runs over budget rather than corrupt
live searches (admission control is the service's job, not the cache's).
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro import faults, obs
from repro.core.csp import CSP
from .buckets import Bucket  # noqa: F401  (re-export; keys are opaque here)


def network_fingerprint(csp: CSP) -> str:
    """Content hash of the constraint *network* (cons, mask, shape). Two CSPs
    sharing a fingerprint share a prepared slot; their domains stay separate
    (the domain rides each request, not the network)."""
    cons = np.asarray(csp.cons)
    mask = np.asarray(csp.mask)
    h = hashlib.sha1()
    h.update(repr(cons.shape).encode())
    h.update(np.packbits(cons).tobytes())
    h.update(np.packbits(mask).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class CacheEntry:
    """One resident network: where it lives and who is flying against it.
    ``bucket`` is an opaque hashable runtime key — the service keys runtimes
    by (Bucket, engine fallback level), so networks prepared on different
    ladder levels never alias a slot."""

    bucket: object
    fingerprint: str
    slot: int
    nbytes: int
    pins: int = 0


class PreparedNetworkCache:
    """LRU cache of resident prepared networks under a byte budget.

    ``acquire`` returns a pinned entry (installing via ``build`` on miss,
    evicting LRU *unpinned* entries first when over budget); ``release`` drops
    a pin when a search retires — the entry stays resident (warm) until
    evicted by a later admission. ``on_evict`` is the service's callback that
    returns the evicted entry's slot to its bucket pool.
    """

    def __init__(self, byte_budget: int, on_evict: Callable[[CacheEntry], None]):
        if byte_budget < 1:
            raise ValueError("cache needs a positive byte budget")
        self.byte_budget = byte_budget
        self._on_evict = on_evict
        self._entries: "OrderedDict[Tuple[Bucket, str], CacheEntry]" = OrderedDict()
        self.bytes_in_use = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, bucket: Bucket, fingerprint: str) -> Optional[CacheEntry]:
        """Peek without pinning or LRU promotion (introspection/tests)."""
        return self._entries.get((bucket, fingerprint))

    def acquire(
        self,
        bucket: Bucket,
        fingerprint: str,
        nbytes: int,
        build: Callable[[], int],
    ) -> Tuple[CacheEntry, bool]:
        """Pin (and on miss, install) the network. ``build()`` does the actual
        slot install and returns the slot id. Returns (entry, was_hit).

        A fault fired (or raised by ``build``) before the entry is registered
        leaves the cache byte-exact: no entry, no pin, no bytes accounted."""
        faults.inject("cache.lookup", fingerprint=fingerprint[:12])
        key = (bucket, fingerprint)
        with obs.span("cache.lookup", cat="cache") as _sp:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                entry.pins += 1
                self.hits += 1
                obs.counter_add("cache.hits")
                if _sp is not None:
                    _sp.args["hit"] = True
                return entry, True
            self.misses += 1
            obs.counter_add("cache.misses")
            if _sp is not None:
                _sp.args["hit"] = False
            self._evict_down_to(self.byte_budget - nbytes)
            # the miss path's build() (slot install) nests under this span —
            # a cache.lookup that cost milliseconds IS the re-preparation
            slot = build()
            entry = CacheEntry(bucket, fingerprint, slot, nbytes, pins=1)
            self._entries[key] = entry
            self.bytes_in_use += nbytes
        return entry, False

    def release(self, entry: CacheEntry) -> None:
        """Drop one pin (a search against this network retired)."""
        if entry.pins <= 0:
            raise ValueError(f"release without pin: {entry.fingerprint[:12]}")
        entry.pins -= 1

    def _evict_down_to(self, target_bytes: int) -> None:
        """Evict LRU-first until ``bytes_in_use <= target`` — skipping pinned
        entries unconditionally (in-flight networks are never evicted)."""
        if self.bytes_in_use <= target_bytes:
            return
        for key in list(self._entries):
            if self.bytes_in_use <= target_bytes:
                break
            entry = self._entries[key]
            if entry.pins > 0:
                continue
            del self._entries[key]
            self.bytes_in_use -= entry.nbytes
            self.evictions += 1
            obs.counter_add("cache.evictions")
            self._on_evict(entry)

    def stats(self) -> Dict[str, int]:
        return {
            "resident": len(self._entries),
            "bytes_in_use": self.bytes_in_use,
            "byte_budget": self.byte_budget,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }
