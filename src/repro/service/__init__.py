"""repro.service — continuous-batching solver service (DESIGN.md §7).

    from repro.service import SolverService

    svc = SolverService(engine="einsum")
    req = svc.submit(csp, deadline_s=1.0)        # futures-style handle
    solution, stats = req.result()               # drives the event loop

Requests arriving over time are routed to shape buckets, their constraint
networks deduplicated through a byte-budgeted prepared-network cache, and all
live searches in a bucket advance through ONE lockstep dispatch per round —
new admissions join mid-flight, finished searches free their rows mid-flight.
`repro.launch.serve` replays seeded Poisson arrival traces against it.

The request path is hardened end-to-end (DESIGN.md §12): seeded fault
injection (`repro.faults`), retry + engine-fallback ladders, per-round
watchdogs with bucket circuit breakers, and typed `Overloaded` load shedding.
"""

from .buckets import Bucket, bucket_for, pad_csp
from .cache import CacheEntry, PreparedNetworkCache, network_fingerprint
from .metrics import ServiceMetrics
from .service import InvalidRequest, RequestStatus, SolveRequest, SolverService
from .trace import (
    DEFAULT_VARIANTS,
    FastForwardClock,
    TraceEvent,
    dedup_trace,
    poisson_trace,
    replay,
    replay_rate_cell,
)

__all__ = [
    "Bucket",
    "bucket_for",
    "pad_csp",
    "CacheEntry",
    "PreparedNetworkCache",
    "network_fingerprint",
    "ServiceMetrics",
    "InvalidRequest",
    "RequestStatus",
    "SolveRequest",
    "SolverService",
    "DEFAULT_VARIANTS",
    "FastForwardClock",
    "TraceEvent",
    "dedup_trace",
    "poisson_trace",
    "replay",
    "replay_rate_cell",
]
