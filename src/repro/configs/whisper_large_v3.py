"""whisper-large-v3 — encoder-decoder, conv/mel frontend stubbed [arXiv:2212.04356].

The assigned "32L" is realized as 32 encoder + 32 decoder layers (the published
whisper-large-v3 layout). ``input_specs`` supplies precomputed 1500-frame
embeddings (the conv1d+mel frontend is a stub per the assignment). Learned
positions; the table is sized for the assigned decode shapes (far beyond
whisper's real 448-token decoder — noted in DESIGN.md §4).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    encoder_layers=32,
    encoder_frames=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    qkv_bias=True,
    pos="learned",
    max_pos=32768,
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2212.04356; hf openai/whisper-large-v3 (unverified tier)",
)
