"""qwen3-moe-235b-a22b — 128 experts top-8, GQA kv=4, head_dim 128
[hf:Qwen/Qwen3-235B-A22B lineage via Qwen3-30B-A3B]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,  # independent of d_model (qwen3)
    d_ff=1536,  # per-expert FFN width
    vocab=151936,
    n_experts=128,
    top_k=8,
    rope_theta=1e6,
    source="hf Qwen/Qwen3-235B-A22B / Qwen3-30B-A3B",
)
