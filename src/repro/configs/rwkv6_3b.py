"""rwkv6-3b — Finch, attention-free, data-dependent decay [arXiv:2404.05892; hf]."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    ssm="rwkv6",
    n_layers=32,
    d_model=2560,
    n_heads=40,  # 2560 / 64 head channels
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    attention="none",
    pos="none",
    norm="layernorm",
    ssm_lora=64,
    source="arXiv:2404.05892 (RWKV-6 Finch); hf RWKV/rwkv-6-world-3b",
)
