"""zamba2-7b — Mamba2 backbone + SHARED attention block [arXiv:2411.15242].

81 mamba2 layers; one shared (single-weight) attention+MLP block applied every
``attn_every`` layers with its own KV cache per invocation. Mamba state is O(1)
in context, so the arch runs long_500k (the shared attention uses the full
cache there — sharded over the cache_seq axis, DESIGN.md §4)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    ssm="mamba2",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_d_inner=7168,  # 2 * d_model
    attn_every=6,
    source="arXiv:2411.15242; hf Zyphra/Zamba2-7B (unverified tier)",
)
