"""Architecture registry — ``--arch <id>`` resolution + shape suite.

Each assigned architecture lives in its own module with the exact published
config; ``get_config(id)`` resolves ids, ``smoke_config`` derives the reduced
same-family CPU test config.
"""

from .base import SHAPES, SMOKE_SHAPE, ModelConfig, ShapeSpec, smoke_config
from . import (
    command_r_plus_104b,
    dbrx_132b,
    granite_8b,
    h2o_danube3_4b,
    qwen15_05b,
    qwen2_vl_2b,
    qwen3_moe_235b,
    rwkv6_3b,
    whisper_large_v3,
    zamba2_7b,
)

_ALL = [
    rwkv6_3b.CONFIG,
    whisper_large_v3.CONFIG,
    qwen15_05b.CONFIG,
    h2o_danube3_4b.CONFIG,
    command_r_plus_104b.CONFIG,
    granite_8b.CONFIG,
    zamba2_7b.CONFIG,
    qwen2_vl_2b.CONFIG,
    qwen3_moe_235b.CONFIG,
    dbrx_132b.CONFIG,
]

REGISTRY = {c.name: c for c in _ALL}
ARCH_IDS = list(REGISTRY)

# long_500k needs sub-quadratic attention (DESIGN.md §4): runs only for these.
LONG_CONTEXT_ARCHS = {"rwkv6-3b", "zamba2-7b", "h2o-danube-3-4b"}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return REGISTRY[name]


def cells(include_skipped: bool = False):
    """All assigned (arch, shape) cells; skipped == long_500k on full-attn."""
    for arch in ARCH_IDS:
        for shape in SHAPES.values():
            skipped = shape.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS
            if skipped and not include_skipped:
                continue
            yield arch, shape, skipped


__all__ = [
    "SHAPES",
    "SMOKE_SHAPE",
    "ModelConfig",
    "ShapeSpec",
    "smoke_config",
    "REGISTRY",
    "ARCH_IDS",
    "LONG_CONTEXT_ARCHS",
    "get_config",
    "cells",
]
