"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818 (danube series); unverified tier].

SWA bounds the KV cache at `window`, making the arch sub-quadratic in context —
it therefore RUNS the long_500k shape (windowed ring-buffer cache)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    attention="swa",
    window=4096,
    source="arXiv:2401.16818; h2oai/h2o-danube3-4b (unverified tier)",
)
