"""qwen2-vl-2b — VLM backbone with M-RoPE [arXiv:2409.12191; hf].

Vision patch frontend is a STUB (the assignment specifies backbone only):
``input_specs`` provides text tokens plus 3-component (t,h,w) position ids;
with t==h==w M-RoPE reduces to standard RoPE (property-tested)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
    pos="mrope",
    mrope_sections=(16, 24, 24),  # head_dim 128 -> half 64
    rope_theta=1e6,
    source="arXiv:2409.12191; hf Qwen/Qwen2-VL-2B",
)
