"""Model/run configuration — one frozen dataclass consumed by every layer.

``ModelConfig`` covers all five assigned families (dense / moe / ssm / hybrid /
enc-dec / vlm); per-arch files in this package instantiate it with the exact
published dimensions. ``ShapeSpec`` defines the assigned input-shape suite.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention / positions
    attention: str = "full"  # full | swa | none
    causal: bool = True
    window: int = 4096  # swa window
    qkv_bias: bool = False
    pos: str = "rope"  # rope | mrope | learned | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (0, 0, 0)
    max_pos: int = 8192  # learned-pos table size

    # norm / act / embeddings
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu(swiglu) | gelu
    tie_embeddings: bool = False

    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # ssm (rwkv6 / mamba2)
    ssm: str = ""  # rwkv6 | mamba2
    ssm_state: int = 64
    ssm_head_dim: int = 64
    ssm_d_inner: int = 0  # 0 -> 2 * d_model
    ssm_conv: int = 4
    ssm_lora: int = 64
    attn_every: int = 0  # hybrid: shared attention block every k ssm layers

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 1500

    # compute
    dtype: str = "bfloat16"
    remat: bool = True
    q_chunk: int = 2048  # query chunking threshold/size for long attention
    ssm_chunk: int = 64
    # dry-run cost accounting: unroll ALL scans so XLA cost_analysis counts
    # every iteration (scan bodies are otherwise counted once). Never used for
    # real execution or the full-depth memory compile.
    scan_unroll: bool = False

    # vocab padded up to a multiple of this for tensor-parallel divisibility
    # (whisper's 51866 is the only assigned vocab that needs it); pad logits are
    # masked in the loss and at decode, so semantics are unchanged.
    vocab_pad_to: int = 128

    # notes for DESIGN.md fidelity tracking
    source: str = ""

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return -(-self.vocab // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_ssm_d_inner(self) -> int:
        return self.ssm_d_inner or 2 * self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The assigned shape suite (identical for all 10 LM archs).
SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Smoke-test shapes (CPU-runnable).
SMOKE_SHAPE = ShapeSpec("smoke", 32, 2, "train")


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=128,
        vocab=256,
        head_dim=16,
        max_pos=128,
        window=16,
        q_chunk=16,
        ssm_chunk=8,
        ssm_state=8,
        ssm_head_dim=8,
        ssm_d_inner=128,
        ssm_lora=8,
        encoder_frames=8 if cfg.family == "encdec" else cfg.encoder_frames,
        encoder_layers=2 if cfg.encoder_layers else 0,
        remat=False,
    )
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=2)
    if cfg.attn_every:
        kw.update(attn_every=2, n_layers=5)
    if cfg.pos == "mrope":
        kw.update(mrope_sections=(4, 2, 2))
    return cfg.replace(**kw)
