"""Deterministic, stateless, sharded synthetic token pipeline.

Fault-tolerance/straggler posture (DESIGN.md §5): a batch is a pure function of
(seed, step) — there is NO iterator state to checkpoint or rebuild. Restart at
step k, on any mesh, reproduces exactly the batch a healthy run would have seen
(tested in tests/test_fault_tolerance.py). Skip-ahead for stragglers is
``make_batch(step + n)``.

The synthetic stream is a mixture of Zipf-ish unigram draws and copy runs so the
~100M-model example has structure to learn (copy-run prediction drives loss
visibly below the unigram entropy floor).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    copy_frac: float = 0.5  # fraction of the sequence covered by copy runs
    run_len: int = 16


def _fold(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def make_batch_np(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Pure f(config, step) -> batch. Host-side numpy."""
    rng = _fold(cfg.seed, step)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab
    # Zipf-ish unigram base
    ranks = np.arange(1, v + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    tokens = rng.choice(v, size=(b, s), p=probs).astype(np.int32)
    # overlay copy runs: token block repeated immediately
    n_runs = int(cfg.copy_frac * s / (2 * cfg.run_len))
    for i in range(b):
        starts = rng.integers(0, max(1, s - 2 * cfg.run_len), size=n_runs)
        for st in starts:
            tokens[i, st + cfg.run_len : st + 2 * cfg.run_len] = tokens[
                i, st : st + cfg.run_len
            ]
    labels = np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1).astype(np.int32)
    mask = np.ones((b, s), np.float32)
    mask[:, -1] = 0.0  # no target for the wrapped last position
    return {"tokens": tokens, "labels": labels, "loss_mask": mask}


def make_batch(cfg: DataConfig, step: int, extra_specs: Optional[dict] = None):
    """Device-ready batch (+ zero-filled stub modality inputs if requested)."""
    out = {k: jnp.asarray(v) for k, v in make_batch_np(cfg, step).items()}
    if extra_specs:
        for name, spec in extra_specs.items():
            if name in out:
                continue
            if name == "pos3":
                b, s, _ = spec.shape
                out[name] = jnp.broadcast_to(
                    jnp.arange(s, dtype=jnp.int32)[None, :, None], spec.shape
                )
            elif spec.dtype in (jnp.int32, np.int32):
                out[name] = jnp.zeros(spec.shape, jnp.int32)
            else:
                rng = _fold(cfg.seed ^ 0x5EED, step)
                out[name] = jnp.asarray(
                    rng.standard_normal(spec.shape).astype(np.float32)
                ).astype(spec.dtype)
    return out
