"""`repro.sweeps` — declarative sweep harness + paper-claims report.

The study pipeline (DESIGN.md §11) in three layers, one module each:

- **spec** (`SweepSpec`, `load_spec`): TOML study definitions under
  ``specs/`` ↔ frozen dataclasses ↔ deterministic cell grids — any
  list-valued knob is a sweep axis.
- **runner** (`run_spec`): executes not-yet-recorded cells via
  `repro.core.solve_many`, the Table-1/Fig-3 assignments protocol, or
  `repro.service.replay_rate_cell`, appending per-cell records (metrics +
  obs-registry delta) to resumable ``cells.jsonl`` artifacts in ``results/``.
- **report** (`build_report`, `check_report`): pivots committed artifacts
  into dependency-free SVG figures (`figures.line_chart`) and regenerates
  the repo-root ``RESULTS.md`` — one section per paper claim with a
  PASS/DEVIATES verdict. `check_report` is CI's byte-diff drift gate.

CLI: ``python -m repro.sweeps {list | run | report}``.
"""

from .figures import Series, line_chart
from .report import CLAIMS, build_report, check_report, collect, pivot
from .runner import DEFAULT_OUT_ROOT, load_cells, read_header, run_spec, sweep_dir
from .spec import SCHEMA, Cell, SweepSpec, available_specs, dumps_toml, load_spec, loads_toml

__all__ = [
    "CLAIMS", "Cell", "DEFAULT_OUT_ROOT", "SCHEMA", "Series", "SweepSpec",
    "available_specs", "build_report", "check_report", "collect",
    "dumps_toml", "line_chart", "load_cells", "load_spec", "loads_toml",
    "pivot", "read_header", "run_spec", "sweep_dir",
]
