"""Analysis + report generation: sweep JSONL artifacts -> figures -> RESULTS.md.

Every entry in `CLAIMS` binds ONE paper claim to the committed sweep that
tests it: which figure(s) to render from the sweep's ``cells.jsonl``, and a
*verdict rule* — a pure function of the recorded cells that returns
``PASS`` or ``DEVIATES`` plus a one-line justification. ``build_report``
renders all figures into ``results/figures/`` and writes the repo-root
``RESULTS.md`` with one section per claim (figure, verdict, the producing
spec inline, and cross-references into the code).

Everything here is a pure function of the committed artifacts: no clocks, no
environment probes, stable float formatting — so regenerating the report from
unchanged JSONL is byte-identical, which is exactly what the CI sweep-smoke
drift gate (`check_report`) asserts. Verdict rules deliberately key on
seeded-deterministic quantities (solve rates, recurrence/assignment counts,
cache hit-rates) or on scale-free ratios of timings, so a verdict never flips
with host speed.
"""

from __future__ import annotations

import dataclasses
import difflib
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .figures import Series, line_chart
from .runner import DEFAULT_OUT_ROOT, load_cells, sweep_dir
from .spec import SweepSpec, load_spec

REPO_ROOT = Path(__file__).resolve().parents[3]
RESULTS_MD = REPO_ROOT / "RESULTS.md"
FIG_DIR_NAME = "figures"

Records = List[Dict[str, Any]]


# --------------------------------------------------------------------------
# record pivoting
# --------------------------------------------------------------------------


def _get(rec: Dict[str, Any], path: Sequence[str]) -> Any:
    cur: Any = rec
    for k in path:
        cur = cur[k]
    return cur


def pivot(
    records: Records,
    x: str,
    y: Sequence[str],
    series_key: Optional[str] = None,
    where: Optional[Dict[str, Any]] = None,
    series_fmt: str = "{k}={v}",
) -> List[Series]:
    """Cell records -> plot series: x from ``params[x]``, y from the nested
    ``y`` path (e.g. ``("metrics", "solve_rate")``), one series per distinct
    ``params[series_key]`` value (sorted), filtered by ``where`` equality on
    params. Points within a series sort by x."""
    rows = []
    for rec in records:
        p = rec["params"]
        if where and any(p.get(k) != v for k, v in where.items()):
            continue
        rows.append((p.get(series_key) if series_key else None, p[x], _get(rec, y)))
    keys = sorted({k for k, _, _ in rows}, key=lambda v: (str(type(v)), v))
    out = []
    for k in keys:
        pts = sorted((xx, yy) for kk, xx, yy in rows if kk == k)
        label = series_fmt.format(k=series_key, v=k) if series_key else ""
        out.append(Series(label=label, x=[p[0] for p in pts], y=[p[1] for p in pts]))
    return out


def _vals(records: Records, key: str) -> List[Any]:
    return sorted({rec["params"][key] for rec in records})


# --------------------------------------------------------------------------
# claim definitions
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Figure:
    filename: str
    build: Callable[[Records, SweepSpec], str]  # -> SVG text
    caption: str


@dataclasses.dataclass(frozen=True)
class Claim:
    key: str                 # RESULTS.md anchor + summary-table row
    sweep: str               # committed spec name the claim reads
    title: str
    paper: str               # the paper's stated behavior, quoted/paraphrased
    figures: Tuple[Figure, ...]
    verdict: Callable[[Records, SweepSpec], Tuple[str, str]]
    notes: str = ""          # cross-references into the code


# --- claim 1: the recurrence count stays small ------------------------------


def _fig_recurrences(records: Records, spec: SweepSpec) -> str:
    series = pivot(
        records, "density", ("metrics", "mean_count"), "n",
        where={"engine": "einsum"}, series_fmt="n={v}",
    )
    return line_chart(
        series,
        title="Recurrence count per assignment enforcement (einsum engine)",
        subtitle=(f"random_binary, d={spec.problem['knobs'].get('d')}, "
                  f"tightness={spec.problem['knobs'].get('tightness')}; "
                  "mean over sampled assignments after AC-closing the root"),
        xlabel="constraint density p",
        ylabel="#Recurrence (mean)",
    )


def _fig_work_growth(records: Records, spec: SweepSpec) -> str:
    """AC3 revisions vs RTAC recurrences, each indexed to its own smallest-n
    value at the densest grid column — growth on one axis despite the two
    different work units."""
    dens = max(_vals(records, "density"))
    series = []
    for engine, label in (("ac3", "ac3 #Revision (indexed)"),
                          ("einsum", "einsum #Recurrence (indexed)")):
        s = pivot(records, "n", ("metrics", "mean_count"), None,
                  where={"engine": engine, "density": dens})[0]
        base = s.y[0] or 1.0
        series.append(Series(label=label, x=s.x, y=[v / base for v in s.y]))
    return line_chart(
        series,
        title="Per-assignment work growth with n (indexed to smallest n)",
        subtitle=(f"random_binary at density={_fmtv(dens)}; each curve ÷ its "
                  "own value at the smallest n — unit-free growth factors"),
        xlabel="variables n",
        ylabel="work ÷ work(smallest n)",
    )


def _verdict_recurrences(records: Records, spec: SweepSpec) -> Tuple[str, str]:
    ein = [r["metrics"]["mean_count"] for r in records
           if r["params"]["engine"] == "einsum"]
    ac3 = [(r["params"]["n"], r["metrics"]["mean_count"]) for r in records
           if r["params"]["engine"] == "ac3"]
    worst = max(ein)
    ns = sorted({n for n, _ in ac3})
    ac3_growth = (max(v for n, v in ac3 if n == ns[-1])
                  / max(max(v for n, v in ac3 if n == ns[0]), 1e-9))
    ein_by_n = [(r["params"]["n"], r["metrics"]["mean_count"]) for r in records
                if r["params"]["engine"] == "einsum"]
    ein_growth = (max(v for n, v in ein_by_n if n == ns[-1])
                  / max(max(v for n, v in ein_by_n if n == ns[0]), 1e-9))
    ok = worst <= 8.0 and ein_growth <= 2.5
    detail = (
        f"max mean #Recurrence over the whole grid is {worst:.2f} "
        f"(bound 8), growing {ein_growth:.2f}× from n={ns[0]} to n={ns[-1]} "
        f"while AC3 #Revision grows {ac3_growth:.1f}× on the same cells"
    )
    return ("PASS" if ok else "DEVIATES", detail)


# --- claim 2: per-assignment enforcement time ~flat -------------------------


def _fig_time_vs_n(records: Records, spec: SweepSpec) -> str:
    dens = max(_vals(records, "density"))
    series = [
        dataclasses.replace(
            pivot(records, "n", ("metrics", "per_assignment_ms"), None,
                  where={"engine": "ac3", "density": dens})[0],
            label="ac3 (sequential)"),
        dataclasses.replace(
            pivot(records, "n", ("metrics", "per_assignment_ms"), None,
                  where={"engine": "einsum", "density": dens})[0],
            label="einsum"),
        dataclasses.replace(
            pivot(records, "n", ("metrics", "batched_per_assignment_ms"), None,
                  where={"engine": "einsum", "density": dens})[0],
            label="einsum, batched"),
    ]
    return line_chart(
        series,
        title="Per-assignment enforcement time vs n (densest column)",
        subtitle=(f"random_binary at density={_fmtv(dens)}; batched = "
                  "enforce_batch amortized over simultaneous assignments "
                  "(CPU host — the GPU gap is the paper's headline)"),
        xlabel="variables n",
        ylabel="ms per assignment (log)",
        yscale="log",
    )


def _verdict_time(records: Records, spec: SweepSpec) -> Tuple[str, str]:
    dens = max(_vals(records, "density"))
    ns = _vals(records, "n")

    def t(engine: str, n: Any) -> float:
        for r in records:
            if (r["params"]["engine"] == engine and r["params"]["n"] == n
                    and r["params"]["density"] == dens):
                return r["metrics"]["per_assignment_ms"]
        raise KeyError((engine, n))

    ein_g = t("einsum", ns[-1]) / max(t("einsum", ns[0]), 1e-9)
    ac3_g = t("ac3", ns[-1]) / max(t("ac3", ns[0]), 1e-9)
    ok = ein_g < ac3_g
    detail = (
        f"n={ns[0]}→{ns[-1]} at density={_fmtv(dens)}: einsum per-assignment "
        f"time grows {ein_g:.2f}× vs {ac3_g:.2f}× for AC3 (scale-free ratio; "
        f"absolute CPU-host times in the figure)"
    )
    return ("PASS" if ok else "DEVIATES", detail)


# --- claim 3: Model RB phase transition at hardness 1 -----------------------


def _fig_solve_rate(records: Records, spec: SweepSpec) -> str:
    series = pivot(records, "hardness", ("metrics", "solve_rate"), "n",
                   series_fmt="n={v}")
    return line_chart(
        series,
        title="Model RB solve rate through the Xu–Li phase transition",
        subtitle=("tightness p = hardness · p_cr; instances a.a.s. SAT left "
                  "of hardness 1.0, UNSAT right of it"),
        xlabel="hardness (p / p_cr)",
        ylabel="solved fraction",
        xticks=sorted({r["params"]["hardness"] for r in records}),
    )


def _verdict_phase(records: Records, spec: SweepSpec) -> Tuple[str, str]:
    bad = []
    for r in records:
        h, sr = r["params"]["hardness"], r["metrics"]["solve_rate"]
        if h <= 0.7 and sr < 0.9:
            bad.append((h, sr))
        if h >= 1.3 and sr > 0.1:
            bad.append((h, sr))
    lo = max((r["metrics"]["solve_rate"] for r in records
              if r["params"]["hardness"] >= 1.3), default=0.0)
    hi = min((r["metrics"]["solve_rate"] for r in records
              if r["params"]["hardness"] <= 0.7), default=1.0)
    detail = (
        f"solve rate ≥ {hi:.2f} at hardness ≤ 0.7 and ≤ {lo:.2f} at "
        f"hardness ≥ 1.3 across every n (verdicts are seeded-deterministic)"
    )
    return ("PASS" if not bad else "DEVIATES", detail)


# --- claim 4: search effort peaks at the transition -------------------------


def _fig_phase_latency(records: Records, spec: SweepSpec) -> str:
    series = pivot(records, "hardness", ("metrics", "median_latency_ms"), "n",
                   series_fmt="n={v}")
    return line_chart(
        series,
        title="Median solve latency through the phase transition",
        subtitle=("per-instance enforcement seconds attributed by solve_many "
                  "round accounting; medians over the cell's replicates"),
        xlabel="hardness (p / p_cr)",
        ylabel="median solve latency, ms (log)",
        yscale="log",
        xticks=sorted({r["params"]["hardness"] for r in records}),
    )


def _fig_phase_effort(records: Records, spec: SweepSpec) -> str:
    series = pivot(records, "hardness", ("metrics", "median_assignments"), "n",
                   series_fmt="n={v}")
    return line_chart(
        series,
        title="Search effort through the phase transition",
        subtitle="median MAC assignments to a verdict, per instance",
        xlabel="hardness (p / p_cr)",
        ylabel="median #assignments",
        xticks=sorted({r["params"]["hardness"] for r in records}),
    )


def _verdict_effort(records: Records, spec: SweepSpec) -> Tuple[str, str]:
    ns = _vals(records, "n")
    n_top = ns[-1]
    cells = sorted(
        (r["params"]["hardness"], r["metrics"]["median_assignments"])
        for r in records if r["params"]["n"] == n_top
    )
    peak_h, peak_v = max(cells, key=lambda kv: kv[1])
    ok = 0.8 <= peak_h <= 1.25
    detail = (
        f"median assignments at n={n_top} peaks at hardness={_fmtv(peak_h)} "
        f"({peak_v:.0f} assignments) — "
        + ("inside" if ok else "outside") + " the transition window [0.8, 1.25]"
    )
    return ("PASS" if ok else "DEVIATES", detail)


# --- claim 5: service capacity ramp -----------------------------------------


def _fig_capacity(records: Records, spec: SweepSpec) -> str:
    series = pivot(records, "rate", ("metrics", "p95_ms"), None)
    series[0] = dataclasses.replace(series[0], label="p95 latency")
    slo = records[0]["metrics"].get("slo_p95_ms")
    return line_chart(
        series,
        title="Service capacity ramp: offered rate vs p95 latency",
        subtitle=(f"{'+'.join(spec.service.get('families', []))} Poisson "
                  "arrivals replayed to completion per cell "
                  "(FastForwardClock; queueing delay is real compute)"),
        xlabel="offered rate, requests/s",
        ylabel="p95 latency, ms (log)",
        yscale="log",
        refline=(slo, f"SLO {_fmtv(slo)} ms") if slo else None,
        xticks=sorted({r["params"]["rate"] for r in records}),
    )


def _verdict_capacity(records: Records, spec: SweepSpec) -> Tuple[str, str]:
    cells = sorted((r["params"]["rate"], r["metrics"]) for r in records)
    slo = cells[0][1].get("slo_p95_ms")
    if slo is None:
        return ("DEVIATES", "no slo_p95_ms in the sweep spec")
    ok_rates = [rate for rate, m in cells if m["p95_ms"] <= slo]
    breach = [rate for rate, m in cells if m["p95_ms"] > slo]
    ok = bool(ok_rates) and bool(breach) and min(breach) > max(ok_rates)
    detail = (
        f"p95 holds the {_fmtv(slo)} ms SLO up to "
        f"{_fmtv(max(ok_rates)) if ok_rates else '—'} req/s offered and "
        f"breaches from {_fmtv(min(breach)) if breach else '—'} req/s — "
        f"a finite measured capacity on this host"
    )
    return ("PASS" if ok else "DEVIATES", detail)


# --- claim 6: cache pool ramp ------------------------------------------------


def _fig_cache_pool(records: Records, spec: SweepSpec) -> str:
    series = pivot(records, "pool_size", ("metrics", "cache_hit_rate"), None)
    series[0] = dataclasses.replace(series[0], label="cache hit rate")
    return line_chart(
        series,
        title="Prepared-network cache: instance-pool size vs hit rate",
        subtitle=("dedup trace: arrivals draw instances from a pool of K "
                  "seeds per variant; hits skip prepare entirely"),
        xlabel="distinct instances per variant (pool size K)",
        ylabel="prepared-network cache hit rate",
        xticks=sorted({r["params"]["pool_size"] for r in records}),
    )


def _verdict_cache(records: Records, spec: SweepSpec) -> Tuple[str, str]:
    cells = sorted(
        (r["params"]["pool_size"], r["metrics"]["cache_hit_rate"])
        for r in records
    )
    monotone = all(b[1] <= a[1] + 0.02 for a, b in zip(cells, cells[1:]))
    ok = monotone and cells[0][1] >= 0.5
    detail = (
        f"hit rate falls {cells[0][1]:.2f} → {cells[-1][1]:.2f} as the pool "
        f"grows {cells[0][0]} → {cells[-1][0]} (deterministic: hits depend "
        f"only on the seeded arrival sequence and the byte budget)"
    )
    return ("PASS" if ok else "DEVIATES", detail)


CLAIMS: Tuple[Claim, ...] = (
    Claim(
        key="recurrence-count",
        sweep="recurrence_density",
        title="The number of recurrence iterations is quite small",
        paper=(
            "“In each iteration of the recurrence, all involved processes can "
            "be fully parallelized with tensor operations. And the number of "
            "iterations is quite small.” Per-assignment #Recurrence should sit "
            "in the low single digits and stay ~flat as n and density grow — "
            "while AC3's #Revision grows with n·density (paper Table 1; "
            "Berkholz arXiv 1406.4679 frames the propagation-depth bound)."
        ),
        figures=(
            Figure("recurrences_vs_density.svg", _fig_recurrences,
                   "Mean #Recurrence per enforced assignment vs density, one "
                   "curve per n."),
            Figure("work_growth_indexed.svg", _fig_work_growth,
                   "Growth of per-assignment work with n at the densest "
                   "column, each unit indexed to its smallest-n value."),
        ),
        verdict=_verdict_recurrences,
        notes=(
            "Protocol: AC-close the root, sample assignments uniformly over "
            "surviving values, enforce each against the prepared network "
            "(`repro.sweeps.runner` assignments mode — the committed fold of "
            "the old `bench_table1.py`). Counts come from "
            "`EnforceResult.n_recurrences`; AC3's unit is revise calls "
            "(`src/repro/engines/ac3.py`, `count_unit = \"revisions\"`)."
        ),
    ),
    Claim(
        key="per-assignment-time",
        sweep="recurrence_density",
        title="Tensor enforcement time stays ~flat where AC3's grows",
        paper=(
            "“…the resulting algorithm fully leverages the power of "
            "parallelization and GPU, and therefore is extremely efficient on "
            "large and densely connected constraint networks.” (paper Fig. 3: "
            "per-assignment RTAC time ~flat in n·density, AC3 growing; on this "
            "CPU container the claim under test is the growth *ratio*, not "
            "absolute device numbers.)"
        ),
        figures=(
            Figure("per_assignment_ms.svg", _fig_time_vs_n,
                   "Per-assignment enforcement wall time vs n at the densest "
                   "grid column, plus the batched enforce_batch variant."),
        ),
        verdict=_verdict_time,
        notes=(
            "The batched curve amortizes ONE vmapped fixpoint over all "
            "sampled assignments (`PreparedNetwork.enforce_batch`) — the "
            "beyond-paper lever the engines expose (DESIGN.md §3). The old "
            "`bench_fig3.py` lives on as this figure."
        ),
    ),
    Claim(
        key="phase-transition",
        sweep="model_rb_phase",
        title="Model RB crosses SAT→UNSAT at the predicted threshold",
        paper=(
            "The evaluation workload (Xu–Li Model RB) has *proven* exact "
            "phase transitions: instances are a.a.s. satisfiable below "
            "p_cr = 1 − e^(−α/r) and unsatisfiable above it, with the hard "
            "region hugging the threshold (`model_rb` positions tightness as "
            "hardness · p_cr)."
        ),
        figures=(
            Figure("model_rb_solve_rate.svg", _fig_solve_rate,
                   "Solved fraction per cell vs hardness, one curve per n."),
        ),
        verdict=_verdict_phase,
        notes=(
            "Generator: `repro.problems.model_rb` (knobs documented on the "
            "function: d = ⌈n^α⌉, m = ⌈r·n·ln n⌉ distinct scopes, exactly "
            "round(p·d²) disallowed tuples). Solved through "
            "`repro.core.solve_many` lockstep — verdicts bit-identical to "
            "sequential `mac_solve`."
        ),
    ),
    Claim(
        key="hardness-effort",
        sweep="model_rb_phase",
        title="Search effort and latency peak at the transition",
        paper=(
            "Hardness-parameterized reporting (Tardivo arXiv 1909.09213): "
            "solve cost should *peak* where instances straddle the threshold, "
            "not grow monotonically with tightness — easy-SAT below, "
            "quickly-refuted UNSAT above."
        ),
        figures=(
            Figure("model_rb_effort.svg", _fig_phase_effort,
                   "Median MAC assignments per instance vs hardness."),
            Figure("model_rb_latency.svg", _fig_phase_latency,
                   "Median per-instance solve latency vs hardness (log y)."),
        ),
        verdict=_verdict_effort,
        notes=(
            "Latency is per-instance enforcement seconds attributed by "
            "`solve_many`'s round accounting (attributions sum exactly to "
            "round wall-clock, DESIGN.md §8); assignment counts are "
            "seeded-deterministic, so the verdict never flips with host speed."
        ),
    ),
    Claim(
        key="service-capacity",
        sweep="service_capacity",
        title="The solver service has a measurable capacity knee",
        paper=(
            "Not a claim of the paper — the serving-scale corollary of its "
            "“large and densely connected networks” pitch (ROADMAP north "
            "star): offered load vs p95 must show a finite knee, found by "
            "ramping seeded Poisson traces until the SLO breaks."
        ),
        figures=(
            Figure("service_capacity.svg", _fig_capacity,
                   "Offered rate vs p95 latency with the SLO threshold."),
        ),
        verdict=_verdict_capacity,
        notes=(
            "Driver: `repro.service.replay_rate_cell` — one fresh "
            "`SolverService` per cell, same seeded arrival pattern at every "
            "rate (`SolverService.submit` knobs documented on the method; "
            "continuous batching per DESIGN.md §7). Absolute capacity is "
            "host-dependent; the committed figure records this container."
        ),
    ),
    Claim(
        key="cache-pool",
        sweep="cache_pool",
        title="Prepared-network cache hit-rate tracks instance recurrence",
        paper=(
            "Serving corollary: real traffic repeats instances, and the "
            "byte-budgeted prepared-network LRU should convert recurrence "
            "into hits — hit-rate falling as the distinct-instance pool "
            "grows (PR 6's dedup traces made the hits real)."
        ),
        figures=(
            Figure("cache_pool_hit_rate.svg", _fig_cache_pool,
                   "Dedup-trace pool size vs measured cache hit rate."),
        ),
        verdict=_verdict_cache,
        notes=(
            "Trace: `repro.service.dedup_trace` (pool of K seeds per "
            "variant). Hits/misses come from the obs registry's "
            "`cache.hits`/`cache.misses` counters, scoped per cell via "
            "`Registry.scope` — inspect any run with the `repro.obs` CLI "
            "(`python -m repro.obs summarize <run.json>`)."
        ),
    ),
)


# --------------------------------------------------------------------------
# report generation
# --------------------------------------------------------------------------


def _fmtv(v: Any) -> str:
    if isinstance(v, float) and v == int(v):
        return str(int(v))
    return str(v)


def collect(out_root: Optional[Path] = None) -> Dict[str, Tuple[SweepSpec, Records]]:
    """Load (spec, records) for every sweep the claims read. A missing or
    empty artifact directory is an error naming the command that produces it."""
    out_root = Path(out_root or DEFAULT_OUT_ROOT)
    loaded: Dict[str, Tuple[SweepSpec, Records]] = {}
    for claim in CLAIMS:
        if claim.sweep in loaded:
            continue
        spec = load_spec(claim.sweep)
        path = sweep_dir(spec, out_root) / "cells.jsonl"
        if not path.exists():
            raise FileNotFoundError(
                f"no artifacts for sweep {claim.sweep!r} at {path}; run "
                f"`python -m repro.sweeps run {claim.sweep}` first"
            )
        records = load_cells(path)
        missing = len(spec.cells()) - len(records)
        if missing > 0:
            raise RuntimeError(
                f"sweep {claim.sweep!r} has {missing} unrecorded cells; "
                f"resume it with `python -m repro.sweeps run {claim.sweep}`"
            )
        loaded[claim.sweep] = (spec, records)
    return loaded


def render_figures(
    loaded: Dict[str, Tuple[SweepSpec, Records]],
    fig_dir: Path,
    only_claim: Optional[str] = None,
) -> List[Path]:
    fig_dir.mkdir(parents=True, exist_ok=True)
    written = []
    for claim in CLAIMS:
        if only_claim and claim.key != only_claim:
            continue
        spec, records = loaded[claim.sweep]
        for fig in claim.figures:
            p = fig_dir / fig.filename
            p.write_text(fig.build(records, spec))
            written.append(p)
    return written


def claim_section(claim: Claim, spec: SweepSpec, records: Records,
                  index: int, fig_rel: str) -> str:
    """One RESULTS.md section: title, paper claim, verdict, figures, spec."""
    verdict, detail = claim.verdict(records, spec)
    lines = [
        f"## {index}. {claim.title}",
        "",
        f"**Paper claim.** {claim.paper}",
        "",
        f"**Verdict: {verdict}** — {detail}.",
        "",
    ]
    for fig in claim.figures:
        lines += [
            f"![{fig.caption}]({fig_rel}/{fig.filename})",
            "",
            f"*{fig.caption}*",
            "",
        ]
    if claim.notes:
        lines += [claim.notes, ""]
    lines += [
        "<details>",
        f"<summary>Sweep spec <code>src/repro/sweeps/specs/{claim.sweep}"
        ".toml</code> (click to expand)</summary>",
        "",
        "```toml",
        spec.to_toml().rstrip(),
        "```",
        "",
        "</details>",
        "",
    ]
    return "\n".join(lines)


def build_results_md(
    loaded: Dict[str, Tuple[SweepSpec, Records]],
    fig_rel: str = "results/figures",
) -> str:
    head = [
        "# RESULTS — paper claims, measured",
        "",
        "<!-- GENERATED FILE — edit specs/claims, then regenerate with:",
        "       python -m repro.sweeps run --all && python -m repro.sweeps report",
        "     CI's sweep-smoke leg fails if this file drifts from the",
        "     committed artifacts (see .github/workflows/ci.yml). -->",
        "",
        "Each section tests one claim of *Paralleling and Accelerating Arc",
        "Consistency Enforcement with Recurrent Tensor Computations* (or a",
        "serving-scale corollary) against this reproduction, using the",
        "declarative sweep harness in `src/repro/sweeps/` (DESIGN.md §11).",
        "Figures are rendered from the committed JSONL artifacts under",
        "`results/` and regenerate byte-identically; verdicts key on",
        "seeded-deterministic quantities or scale-free ratios, so they hold",
        "across hosts. Absolute milliseconds are this repo's CPU container —",
        "interpret trends, not device speed.",
        "",
        "| # | claim | sweep | verdict |",
        "|---|-------|-------|---------|",
    ]
    sections = []
    for i, claim in enumerate(CLAIMS, 1):
        spec, records = loaded[claim.sweep]
        verdict, _ = claim.verdict(records, spec)
        head.append(
            f"| {i} | [{claim.title}](#{i}-{_slug(claim.title)}) | "
            f"[`{claim.sweep}`](src/repro/sweeps/specs/{claim.sweep}.toml) | "
            f"{verdict} |"
        )
        sections.append(claim_section(claim, spec, records, i, fig_rel))
    head.append("")
    return "\n".join(head) + "\n" + "\n".join(sections)


def _slug(title: str) -> str:
    keep = [c.lower() if c.isalnum() else ("-" if c in " -" else "")
            for c in title]
    return "".join(keep).replace("--", "-").strip("-")


def build_report(
    out_root: Optional[Path] = None,
    results_md: Optional[Path] = None,
    fig_dir: Optional[Path] = None,
) -> List[Path]:
    """Render every figure + RESULTS.md from the committed artifacts.
    Returns the written paths."""
    out_root = Path(out_root or DEFAULT_OUT_ROOT)
    results_md = Path(results_md or RESULTS_MD)
    fig_dir = Path(fig_dir or out_root / FIG_DIR_NAME)
    loaded = collect(out_root)
    written = render_figures(loaded, fig_dir)
    try:
        rel = fig_dir.resolve().relative_to(results_md.resolve().parent)
        fig_rel = str(rel).replace("\\", "/")
    except ValueError:
        fig_rel = str(fig_dir)
    results_md.write_text(build_results_md(loaded, fig_rel=fig_rel))
    return [results_md] + written


def check_report(out_root: Optional[Path] = None) -> List[str]:
    """The doc-rot gate: regenerate RESULTS.md + every figure from the
    committed artifacts IN MEMORY and diff against the committed files.
    Returns a list of human-readable drift messages (empty = clean)."""
    out_root = Path(out_root or DEFAULT_OUT_ROOT)
    fig_dir = out_root / FIG_DIR_NAME
    loaded = collect(out_root)
    drift: List[str] = []
    for claim in CLAIMS:
        spec, records = loaded[claim.sweep]
        for fig in claim.figures:
            p = fig_dir / fig.filename
            fresh = fig.build(records, spec)
            if not p.exists():
                drift.append(f"missing figure {p}")
            elif p.read_text() != fresh:
                drift.append(f"figure drifts from artifacts: {p}")
    try:
        fig_rel = str(fig_dir.resolve().relative_to(RESULTS_MD.parent))
    except ValueError:
        fig_rel = str(fig_dir)
    fresh_md = build_results_md(loaded, fig_rel=fig_rel)
    if not RESULTS_MD.exists():
        drift.append(f"missing {RESULTS_MD}")
    elif RESULTS_MD.read_text() != fresh_md:
        diff = "\n".join(
            difflib.unified_diff(
                RESULTS_MD.read_text().splitlines(),
                fresh_md.splitlines(),
                "RESULTS.md (committed)", "RESULTS.md (regenerated)",
                lineterm="", n=1,
            )
        )
        drift.append(f"RESULTS.md drifts from artifacts:\n{diff}")
    return drift
