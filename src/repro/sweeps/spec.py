"""Declarative sweep specs: TOML files ↔ `SweepSpec` dataclasses ↔ cell grids.

A sweep spec names ONE study — a problem family (or a service workload), the
knobs to hold fixed, and the knobs to sweep — and expands deterministically
into a list of *cells*: every point of the Cartesian product of its axes. Any
knob whose TOML value is a **list** is an axis; scalars are fixed. The cell
list is a pure function of the spec (axes expand in sorted ``(table, key)``
order), so the same spec file always produces the byte-identical grid — the
property the runner's resume protocol and the committed artifacts lean on.

Spec layout (``schema = "repro-sweep/v1"``)::

    schema = "repro-sweep/v1"
    name = "model_rb_phase"            # artifact directory + RESULTS anchor
    title = "..."                      # human heading for the report
    mode = "solve_many"                # solve_many | assignments | service
    seed = 0                           # base seed for every derived stream
    replicates = 12                    # instances per cell (per-cell medians)

    [problem]                          # solve_many / assignments modes
    family = "model_rb"
    [problem.knobs]                    # validated against the family registry
    n = [10, 14]                       # list  -> sweep axis
    hardness = [0.5, 1.0, 1.5]         # list  -> sweep axis
    alpha = 0.8                        # scalar -> fixed knob

    [solver]                           # engine / search knobs (axes allowed)
    engine = "einsum"
    max_assignments = 4000

    [service]                          # service mode (axes allowed)
    families = ["model_rb"]
    kind = "poisson"                   # poisson | dedup
    rate = [4.0, 8.0, 16.0]            # offered-rate axis
    duration = 3.0
    slo_p95_ms = 500.0

    [report]                           # hints for the analysis module
    x = "hardness"
    series = "n"
    claim = "..."

TOML support: CI's tier-1 matrix still runs Python 3.10, which has no
``tomllib``, so this module carries a minimal parser for exactly the subset
the specs use (``[table]`` / ``[table.sub]`` headers, ``key = value`` with
strings, ints, floats, booleans, and flat homogeneous arrays, ``#`` comments).
When ``tomllib`` is importable it is preferred; `dumps_toml` emits the same
subset, and the spec round-trip is tested through both parsers.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import zlib
from pathlib import Path
from typing import Any, Dict, List, Mapping, Optional, Tuple

try:  # Python >= 3.11
    import tomllib as _tomllib
except ImportError:  # Python 3.10: the subset parser below takes over
    _tomllib = None

#: artifact + spec wire schema; bump together with the cell-record layout
SCHEMA = "repro-sweep/v1"

#: spec search path for `load_spec("name")` — the committed study definitions
SPEC_DIR = Path(__file__).resolve().parent / "specs"

MODES = ("solve_many", "assignments", "service")

#: cell keys excluded from the workload seed, so e.g. every engine enforces
#: the same sampled assignment sites and every offered rate replays the same
#: arrival pattern (see `workload_seed`)
NON_WORKLOAD_KEYS = ("engine", "rate")


# --------------------------------------------------------------------------
# minimal TOML subset (read + write)
# --------------------------------------------------------------------------


def _parse_scalar(tok: str, where: str):
    tok = tok.strip()
    if not tok:
        raise ValueError(f"{where}: empty value")
    if tok.startswith('"') and tok.endswith('"') and len(tok) >= 2:
        body = tok[1:-1]
        if '"' in body or "\\" in body:
            raise ValueError(f"{where}: escapes/quotes in strings unsupported")
        return body
    if tok in ("true", "false"):
        return tok == "true"
    try:
        return int(tok)
    except ValueError:
        pass
    try:
        return float(tok)
    except ValueError:
        raise ValueError(f"{where}: cannot parse value {tok!r}") from None


def _split_array(body: str, where: str) -> List[str]:
    """Split a flat array body on commas, respecting string quotes."""
    items, depth, cur = [], False, []
    for ch in body:
        if ch == '"':
            depth = not depth
            cur.append(ch)
        elif ch == "," and not depth:
            items.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if depth:
        raise ValueError(f"{where}: unterminated string in array")
    tail = "".join(cur).strip()
    if tail:
        items.append(tail)
    return [i for i in (s.strip() for s in items) if i]


def _parse_toml_subset(text: str) -> Dict[str, Any]:
    """Parse the spec TOML subset (see module docstring) into nested dicts."""
    root: Dict[str, Any] = {}
    table = root
    for lineno, raw in enumerate(text.splitlines(), 1):
        where = f"line {lineno}"
        line = raw.strip()
        # strip comments (respecting strings)
        if "#" in line:
            out, in_str = [], False
            for ch in line:
                if ch == '"':
                    in_str = not in_str
                if ch == "#" and not in_str:
                    break
                out.append(ch)
            line = "".join(out).strip()
        if not line:
            continue
        if line.startswith("["):
            if not line.endswith("]") or line.startswith("[["):
                raise ValueError(f"{where}: unsupported table header {line!r}")
            table = root
            for part in line[1:-1].strip().split("."):
                if not part:
                    raise ValueError(f"{where}: bad table name {line!r}")
                table = table.setdefault(part, {})
                if not isinstance(table, dict):
                    raise ValueError(f"{where}: {part!r} is not a table")
            continue
        if "=" not in line:
            raise ValueError(f"{where}: expected key = value, got {line!r}")
        key, _, val = line.partition("=")
        key, val = key.strip(), val.strip()
        if not key:
            raise ValueError(f"{where}: empty key")
        if val.startswith("["):
            if not val.endswith("]"):
                raise ValueError(f"{where}: multiline arrays unsupported")
            table[key] = [
                _parse_scalar(t, where) for t in _split_array(val[1:-1], where)
            ]
        else:
            table[key] = _parse_scalar(val, where)
    return root


def loads_toml(text: str) -> Dict[str, Any]:
    """Parse spec TOML — via ``tomllib`` when available, else the subset
    parser (both accept everything `dumps_toml` emits)."""
    if _tomllib is not None:
        return _tomllib.loads(text)
    return _parse_toml_subset(text)


def _fmt_scalar(v: Any) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, str):
        if '"' in v or "\\" in v or "\n" in v:
            raise ValueError(f"cannot emit string with quotes/escapes: {v!r}")
        return f'"{v}"'
    if isinstance(v, float):
        # repr keeps round-trip exactness; TOML floats need a '.' or exponent
        s = repr(v)
        return s if ("." in s or "e" in s or "inf" in s or "nan" in s) else s + ".0"
    if isinstance(v, int):
        return str(v)
    raise TypeError(f"unsupported TOML scalar {type(v).__name__}: {v!r}")


def _emit_table(out: List[str], table: Mapping[str, Any], prefix: str) -> None:
    subtables = []
    for k, v in table.items():
        if isinstance(v, Mapping):
            subtables.append((k, v))
        elif isinstance(v, (list, tuple)):
            out.append(f"{k} = [{', '.join(_fmt_scalar(i) for i in v)}]")
        else:
            out.append(f"{k} = {_fmt_scalar(v)}")
    for k, v in subtables:
        name = f"{prefix}.{k}" if prefix else k
        out.append("")
        out.append(f"[{name}]")
        _emit_table(out, v, name)


def dumps_toml(doc: Mapping[str, Any]) -> str:
    """Emit nested dicts as the TOML subset `loads_toml` accepts."""
    out: List[str] = []
    _emit_table(out, doc, "")
    return "\n".join(out).lstrip("\n") + "\n"


# --------------------------------------------------------------------------
# the spec dataclass
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Cell:
    """One grid point: the fully resolved knob values of a single run cell.

    ``params`` maps table name (``problem`` / ``solver`` / ``service``) to its
    resolved scalar knobs. ``cell_id`` is the stable identity the runner's
    resume protocol dedupes on — a pure function of the resolved values,
    independent of axis declaration order.
    """

    index: int
    params: Dict[str, Dict[str, Any]]

    @property
    def cell_id(self) -> str:
        flat = self.flat()
        return ",".join(f"{k}={flat[k]}" for k in sorted(flat))

    def flat(self) -> Dict[str, Any]:
        """One flat knob dict (table prefixes dropped; keys are unique by
        spec validation)."""
        out: Dict[str, Any] = {}
        for tab in sorted(self.params):
            out.update(self.params[tab])
        return out


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """One declarative study: fixed knobs + axes, expanded by `cells()`."""

    name: str
    mode: str
    title: str = ""
    seed: int = 0
    replicates: int = 1
    problem: Dict[str, Any] = dataclasses.field(default_factory=dict)
    solver: Dict[str, Any] = dataclasses.field(default_factory=dict)
    service: Dict[str, Any] = dataclasses.field(default_factory=dict)
    report: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # --- validation ---------------------------------------------------------

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"{self.name}: mode {self.mode!r} not in {MODES}")
        if self.replicates < 1:
            raise ValueError(f"{self.name}: replicates must be >= 1")
        if self.mode == "service":
            if self.problem:
                raise ValueError(f"{self.name}: service mode takes no [problem]")
            for req in ("families", "rate", "duration"):
                if req not in self.service:
                    raise ValueError(f"{self.name}: [service] needs {req!r}")
        else:
            fam = self.problem.get("family")
            if not fam:
                raise ValueError(f"{self.name}: [problem] needs family = ...")
            # knob names (and axis values) validate against the registry
            from repro.problems import get_problem

            family = get_problem(fam)
            family.validate_sweep(self.problem.get("knobs", {}))
        seen: Dict[str, str] = {}
        for tab, knobs in self._tables():
            for k in knobs:
                if k in seen:
                    raise ValueError(
                        f"{self.name}: knob {k!r} appears in both "
                        f"[{seen[k]}] and [{tab}]"
                    )
                seen[k] = tab

    def _tables(self) -> List[Tuple[str, Dict[str, Any]]]:
        tabs = [("solver", self.solver)]
        if self.mode == "service":
            tabs.append(("service", self.service))
        else:
            tabs.insert(0, ("problem", self.problem.get("knobs", {})))
        return tabs

    # --- grid expansion -----------------------------------------------------

    def axes(self) -> Dict[Tuple[str, str], List[Any]]:
        """Ordered ``(table, knob) -> values`` for every list-valued knob,
        sorted by ``(table, knob)`` so the grid order never depends on file
        formatting. ``service.families`` is a fixed list, never an axis."""
        axes: Dict[Tuple[str, str], List[Any]] = {}
        for tab, knobs in self._tables():
            for k, v in knobs.items():
                if (tab, k) == ("service", "families"):
                    continue
                if isinstance(v, (list, tuple)):
                    if not v:
                        raise ValueError(f"{self.name}: axis {tab}.{k} is empty")
                    axes[(tab, k)] = list(v)
        return dict(sorted(axes.items()))

    def cells(self) -> List[Cell]:
        """The full deterministic grid: Cartesian product of `axes()` over
        the fixed knobs, one `Cell` per point, ``replicates`` handled by the
        runner inside each cell (not as an axis)."""
        axes = self.axes()
        fixed: Dict[str, Dict[str, Any]] = {}
        for tab, knobs in self._tables():
            fixed[tab] = {
                k: v for k, v in knobs.items() if (tab, k) not in axes
            }
        if self.mode != "service":
            fixed.setdefault("problem", {})
            fixed["problem"]["family"] = self.problem["family"]
        cells = []
        for i, combo in enumerate(itertools.product(*axes.values())):
            params = {tab: dict(kv) for tab, kv in fixed.items()}
            for (tab, k), v in zip(axes.keys(), combo):
                params.setdefault(tab, {})[k] = v
            cells.append(Cell(index=i, params=params))
        return cells

    # --- seeding ------------------------------------------------------------

    def workload_seed(self, cell: Cell) -> int:
        """The cell's workload seed: a CRC of the spec seed and every resolved
        knob EXCEPT `NON_WORKLOAD_KEYS` — so cells that differ only in engine
        enforce identical instances/sites, and capacity-ramp cells that differ
        only in offered rate replay the same arrival pattern."""
        flat = {
            k: v for k, v in cell.flat().items() if k not in NON_WORKLOAD_KEYS
        }
        blob = json.dumps([self.seed, flat], sort_keys=True)
        return zlib.crc32(blob.encode()) & 0x7FFFFFFF

    # --- (de)serialization --------------------------------------------------

    def to_doc(self) -> Dict[str, Any]:
        doc: Dict[str, Any] = {
            "schema": SCHEMA,
            "name": self.name,
            "title": self.title,
            "mode": self.mode,
            "seed": self.seed,
            "replicates": self.replicates,
        }
        if self.problem:
            doc["problem"] = {
                k: v for k, v in self.problem.items() if k != "knobs"
            }
            if self.problem.get("knobs"):
                doc["problem"]["knobs"] = dict(self.problem["knobs"])
        if self.solver:
            doc["solver"] = dict(self.solver)
        if self.service:
            doc["service"] = dict(self.service)
        if self.report:
            doc["report"] = dict(self.report)
        return doc

    def to_toml(self) -> str:
        return dumps_toml(self.to_doc())

    @classmethod
    def from_doc(cls, doc: Mapping[str, Any]) -> "SweepSpec":
        schema = doc.get("schema")
        if schema != SCHEMA:
            raise ValueError(f"spec schema {schema!r} != {SCHEMA!r}")
        known = {
            "schema", "name", "title", "mode", "seed", "replicates",
            "problem", "solver", "service", "report",
        }
        unknown = set(doc) - known
        if unknown:
            raise ValueError(f"spec has unknown top-level keys {sorted(unknown)}")
        if "name" not in doc or "mode" not in doc:
            raise ValueError("spec needs name = ... and mode = ...")
        return cls(
            name=doc["name"],
            mode=doc["mode"],
            title=doc.get("title", ""),
            seed=int(doc.get("seed", 0)),
            replicates=int(doc.get("replicates", 1)),
            problem=dict(doc.get("problem", {})),
            solver=dict(doc.get("solver", {})),
            service=dict(doc.get("service", {})),
            report=dict(doc.get("report", {})),
        )

    @classmethod
    def from_toml(cls, text: str) -> "SweepSpec":
        return cls.from_doc(loads_toml(text))


def available_specs(spec_dir: Path = SPEC_DIR) -> List[str]:
    """Names of the committed study specs (``src/repro/sweeps/specs/``)."""
    return sorted(p.stem for p in spec_dir.glob("*.toml"))


def load_spec(name_or_path: str, spec_dir: Optional[Path] = None) -> SweepSpec:
    """Load a spec by committed name (``model_rb_phase``) or by file path."""
    spec_dir = spec_dir or SPEC_DIR
    p = Path(name_or_path)
    if not p.suffix:
        p = spec_dir / f"{name_or_path}.toml"
    if not p.exists():
        raise FileNotFoundError(
            f"no sweep spec {name_or_path!r}; committed specs: "
            f"{available_specs(spec_dir)}"
        )
    return SweepSpec.from_toml(p.read_text())
