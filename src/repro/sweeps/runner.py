"""Resumable sweep runner: spec cells -> versioned JSONL artifacts.

One sweep's artifacts live in ``<out_root>/<spec.name>/``:

    spec.toml       the spec exactly as expanded (the resume fingerprint)
    cells.jsonl     line 1: a header record {schema, sweep, n_cells, spec}
                    then ONE record per completed cell (append-only)

Resume semantics: `run_spec` reads any existing ``cells.jsonl``, verifies the
header's spec document matches the one being run (a changed spec refuses to
graft onto stale cells unless ``fresh=True`` wipes them), and executes only
the cells whose ``cell_id`` is not yet recorded — an interrupted sweep picks
up where it stopped and never duplicates a cell. Cell identity is the
resolved knob values (`Cell.cell_id`), not the grid position, so editing an
axis re-runs exactly the new points.

Every cell record carries the resolved params, the derived workload seed,
per-cell metric medians, and an obs-registry delta (`Registry.scope`) of just
that cell's counters/histograms — rounds_per_instance, launches_per_solve,
speculation outcomes — so figures can plot device-work trends without
rerunning anything.

Three cell modes (`SweepSpec.mode`):

``solve_many``    generate ``replicates`` instances per cell and solve them to
                  completion through `repro.core.solve_many` — solve-rate /
                  latency / search-effort vs hardness studies.
``assignments``   the paper's Table 1 / Fig. 3 protocol (this mode absorbed
                  ``benchmarks/bench_table1.py`` and ``bench_fig3.py``): AC-close
                  the root, sample assignments from surviving values, enforce
                  each against the prepared network, count recurrences (tensor
                  engines) or revisions (AC3) and per-assignment wall time,
                  plus the batched `enforce_batch` amortized variant.
``service``       one `repro.service.replay_rate_cell` per cell — offered-rate
                  capacity ramps and dedup cache-pool ramps.
"""

from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro import obs
from .spec import SCHEMA, Cell, SweepSpec

#: default artifact root for committed studies (repo-root ``results/``);
#: scratch runs pass their own out_root
DEFAULT_OUT_ROOT = Path(__file__).resolve().parents[3] / "results"


def _median(xs) -> float:
    return float(np.median(list(xs))) if len(xs) else 0.0


def _r(x: float, nd: int = 4) -> float:
    return round(float(x), nd)


# --------------------------------------------------------------------------
# cell executors
# --------------------------------------------------------------------------


def _run_solve_many_cell(spec: SweepSpec, cell: Cell, seed: int) -> Dict[str, Any]:
    from repro.core import solve_many
    from repro.problems import generate_batch

    p = dict(cell.params.get("problem", {}))
    family = p.pop("family")
    solver = dict(cell.params.get("solver", {}))
    engine = solver.pop("engine", "einsum")

    csps = generate_batch(family, spec.replicates, seed=seed, **p)
    telemetry: Dict[str, Any] = {}
    t0 = time.perf_counter()
    sols, stats = solve_many(csps, engine=engine, telemetry=telemetry, **solver)
    wall_s = time.perf_counter() - t0

    solved = [s is not None for s in sols]
    latency_ms = [1e3 * sum(st.enforce_seconds) for st in stats]
    return {
        "n_instances": len(csps),
        "n_solved": int(sum(solved)),
        "solve_rate": _r(sum(solved) / len(csps)),
        "exhausted": int(sum(st.exhausted for st in stats)),
        "wall_s": _r(wall_s, 3),
        "instances_per_s": _r(len(csps) / max(wall_s, 1e-9), 3),
        # per-instance medians — the cell's representative figures
        "median_latency_ms": _r(_median(latency_ms), 3),
        "p90_latency_ms": _r(float(np.percentile(latency_ms, 90)), 3),
        "median_assignments": _r(_median([st.n_assignments for st in stats]), 2),
        "p90_assignments": _r(
            float(np.percentile([st.n_assignments for st in stats], 90)), 2
        ),
        "median_rounds": _r(_median([st.rounds for st in stats]), 2),
        "median_recurrences": _r(
            _median([st.mean_recurrences for st in stats]), 3
        ),
        "launches_per_round": _r(telemetry.get("launches_per_round", 0.0), 3),
        "host_bytes_per_round": _r(telemetry.get("host_bytes_per_round", 0.0), 1),
    }


def _run_assignments_cell(spec: SweepSpec, cell: Cell, seed: int) -> Dict[str, Any]:
    import jax

    from repro.core import assign_np
    from repro.engines import get_engine
    from repro.problems import generate_batch

    p = dict(cell.params.get("problem", {}))
    family = p.pop("family")
    solver = dict(cell.params.get("solver", {}))
    engine = solver.pop("engine", "einsum")
    n_assignments = int(solver.pop("n_assignments", 10))
    batch_timing = bool(solver.pop("batch_timing", True))
    if solver:
        raise ValueError(f"assignments mode: unknown solver knobs {sorted(solver)}")

    eng = get_engine(engine)
    csps = generate_batch(family, spec.replicates, seed=seed, **p)
    rng = np.random.default_rng(seed)

    counts: List[float] = []
    times: List[float] = []
    batched: List[float] = []
    roots_ok = 0
    for csp in csps:
        n, d = csp.dom.shape
        prepared = eng.prepare(csp)  # once per instance — the expensive part
        root = prepared.enforce()
        if not bool(root.consistent):
            continue  # an AC-inconsistent root has no assignments to sample
        roots_ok += 1
        root_np = np.asarray(root.dom)

        # sample (var, surviving value) sites; seed is engine-independent
        # (see SweepSpec.workload_seed) so every engine enforces these exact
        # sites — the paper's Table 1 comparison stays apples-to-apples
        sites = []
        for _ in range(n_assignments):
            var = int(rng.integers(n))
            vals = np.nonzero(root_np[var])[0]
            sites.append((var, int(rng.choice(vals))))

        var0, val0 = sites[0]
        ch0 = np.zeros((n,), bool)
        ch0[var0] = True
        r = prepared.enforce(assign_np(root_np, var0, val0), ch0)  # warm compile
        jax.block_until_ready(r.dom)
        for var, val in sites:
            dom_a = assign_np(root_np, var, val)
            ch = np.zeros((n,), bool)
            ch[var] = True
            t0 = time.perf_counter()
            r = prepared.enforce(dom_a, ch)
            jax.block_until_ready(r.dom)  # no D2H copy inside the timed region
            times.append(time.perf_counter() - t0)
            counts.append(float(np.asarray(r.n_recurrences)))

        if batch_timing and eng.supports_batch:
            dom_b = np.stack([assign_np(root_np, v, a) for v, a in sites])
            ch_b = np.zeros((len(sites), n), bool)
            ch_b[np.arange(len(sites)), [v for v, _ in sites]] = True
            res = prepared.enforce_batch(dom_b, ch_b)  # warm compile
            jax.block_until_ready(res.dom)
            t0 = time.perf_counter()
            res = prepared.enforce_batch(dom_b, ch_b)
            jax.block_until_ready(res.dom)
            batched.append((time.perf_counter() - t0) / len(sites))

    out = {
        "count_unit": eng.count_unit,  # "recurrences" | "revisions"
        "n_instances": len(csps),
        "roots_consistent": roots_ok,
        "n_assignments": len(times),
        "mean_count": _r(float(np.mean(counts)) if counts else 0.0, 3),
        "max_count": _r(max(counts) if counts else 0.0, 1),
        "per_assignment_ms": _r(1e3 * _median(times), 4),
    }
    if batched:
        out["batched_per_assignment_ms"] = _r(1e3 * _median(batched), 4)
    return out


def _run_service_cell(spec: SweepSpec, cell: Cell, seed: int) -> Dict[str, Any]:
    from repro.service import replay_rate_cell

    svc = dict(cell.params.get("service", {}))
    solver = dict(cell.params.get("solver", {}))
    engine = solver.pop("engine", "einsum")
    # per-request budgets go to SolverService.submit — a capacity study caps
    # work per request so p95 measures queueing, not one pathological instance
    submit = {
        k: svc.pop(k) for k in ("max_assignments", "deadline_s") if k in svc
    }
    row = replay_rate_cell(
        engine=engine,
        families=list(svc.pop("families")),
        rate=float(svc.pop("rate")),
        duration=float(svc.pop("duration")),
        seed=seed,
        kind=svc.pop("kind", "poisson"),
        pool_size=int(svc.pop("pool_size", 3)),
        warmup=bool(svc.pop("warmup", False)),
        service_kwargs=solver or None,
        submit_kwargs=submit or None,
    )
    slo = svc.pop("slo_p95_ms", None)
    if svc:
        raise ValueError(f"service mode: unknown service knobs {sorted(svc)}")
    if slo is not None:
        row["slo_p95_ms"] = float(slo)
        row["slo_breached"] = bool(row["p95_ms"] > float(slo))
    return row


_CELL_RUNNERS: Dict[str, Callable[[SweepSpec, Cell, int], Dict[str, Any]]] = {
    "solve_many": _run_solve_many_cell,
    "assignments": _run_assignments_cell,
    "service": _run_service_cell,
}

#: obs counters worth carrying per cell (speculation + driver totals); the
#: full delta would drag every kernel build counter into every record
_OBS_COUNTERS = (
    "driver.rounds", "driver.launches", "driver.recurrences",
    "driver.cancelled_members",
    "speculation.denied", "speculation.split_granted",
    "speculation.portfolio_granted", "speculation.clamped",
    "cache.hits", "cache.misses",
)
_OBS_HISTS = (
    "many.rounds_per_instance", "many.launches_per_solve",
    "service.rows_per_request",
)


def _obs_delta(scope: obs.RegistryScope) -> Dict[str, Any]:
    delta = scope.delta()
    return {
        "counters": {
            k: delta["counters"][k] for k in _OBS_COUNTERS
            if k in delta["counters"]
        },
        "histograms": {
            k: delta["histograms"][k] for k in _OBS_HISTS
            if k in delta["histograms"]
        },
    }


# --------------------------------------------------------------------------
# the resumable runner
# --------------------------------------------------------------------------


def sweep_dir(spec: SweepSpec, out_root: Optional[Path] = None) -> Path:
    return Path(out_root or DEFAULT_OUT_ROOT) / spec.name


def _header(spec: SweepSpec) -> Dict[str, Any]:
    return {
        "schema": SCHEMA,
        "sweep": spec.name,
        "n_cells": len(spec.cells()),
        "spec": spec.to_doc(),
    }


def load_cells(path: Path) -> List[Dict[str, Any]]:
    """Completed cell records of one ``cells.jsonl`` (header line excluded).
    Raises on a schema mismatch; tolerates a truncated trailing line (the
    artifact of an interrupt mid-write — that cell simply reruns)."""
    records = []
    with path.open() as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                if i == 0:
                    raise
                continue  # torn tail write — drop, the runner redoes the cell
            if rec.get("schema") != SCHEMA:
                raise ValueError(
                    f"{path}: record schema {rec.get('schema')!r} != {SCHEMA!r}"
                )
            if i == 0:
                if "spec" not in rec:
                    raise ValueError(f"{path}: first line is not a sweep header")
                continue
            records.append(rec)
    return records


def read_header(path: Path) -> Dict[str, Any]:
    with path.open() as f:
        return json.loads(f.readline())


def run_spec(
    spec: SweepSpec,
    out_root: Optional[Path] = None,
    fresh: bool = False,
    progress: Optional[Callable[[str], None]] = print,
) -> Path:
    """Execute every not-yet-recorded cell of ``spec``; returns the artifact
    directory. Append-only and interrupt-safe (see module docstring)."""
    say = progress or (lambda _msg: None)
    d = sweep_dir(spec, out_root)
    d.mkdir(parents=True, exist_ok=True)
    cells_path = d / "cells.jsonl"
    header = _header(spec)

    done: Dict[str, Dict[str, Any]] = {}
    if cells_path.exists() and not fresh:
        prior = read_header(cells_path)
        if prior.get("spec") != header["spec"]:
            raise RuntimeError(
                f"{cells_path} was produced by a different spec; rerun with "
                f"fresh=True (CLI: --fresh) to discard it"
            )
        done = {rec["cell"]: rec for rec in load_cells(cells_path)}
        # Repair a torn tail (interrupted mid-write): drop the partial line
        # so appended records don't concatenate onto it.
        raw = cells_path.read_text()
        if raw and not raw.endswith("\n"):
            cells_path.write_text(raw[: raw.rfind("\n") + 1])
    else:
        cells_path.write_text(json.dumps(header) + "\n")
    (d / "spec.toml").write_text(spec.to_toml())

    cells = spec.cells()
    todo = [c for c in cells if c.cell_id not in done]
    say(f"sweep {spec.name}: {len(cells)} cells, {len(done)} recorded, "
        f"{len(todo)} to run")
    run_fn = _CELL_RUNNERS[spec.mode]
    for c in todo:
        seed = spec.workload_seed(c)
        t0 = time.perf_counter()
        with obs.REGISTRY.scope() as scope:
            metrics = run_fn(spec, c, seed)
        rec = {
            "schema": SCHEMA,
            "sweep": spec.name,
            "cell": c.cell_id,
            "params": c.flat(),
            "seed": seed,
            "replicates": spec.replicates,
            "cell_seconds": _r(time.perf_counter() - t0, 3),
            "metrics": metrics,
            "obs": _obs_delta(scope),
        }
        with cells_path.open("a") as f:
            f.write(json.dumps(rec) + "\n")
        say(f"  cell {c.index + 1}/{len(cells)} {c.cell_id}: "
            f"{rec['cell_seconds']:.2f}s")
    return d
