"""CLI for the sweep harness: ``python -m repro.sweeps <command>``.

Commands:

    list                     committed study specs (name, mode, grid size)
    run <spec>... [--all]    execute specs (resumable; --fresh discards
                             stale artifacts, --out redirects the root)
    report [--check]         regenerate RESULTS.md + results/figures/ from
                             the committed artifacts; --check diffs instead
                             of writing and exits 1 on drift (the CI gate)

``<spec>`` is a committed name (``model_rb_phase``) or a path to any
``.toml`` spec file. The full study refresh is::

    python -m repro.sweeps run --all && python -m repro.sweeps report
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .report import build_report, check_report
from .runner import run_spec
from .spec import available_specs, load_spec


def _cmd_list(args: argparse.Namespace) -> int:
    names = available_specs()
    if not names:
        print("no committed specs")
        return 0
    rows = []
    for name in names:
        spec = load_spec(name)
        rows.append((name, spec.mode, len(spec.cells()), spec.title))
    w0 = max(len(r[0]) for r in rows)
    w1 = max(len(r[1]) for r in rows)
    for name, mode, n, title in rows:
        print(f"{name:<{w0}}  {mode:<{w1}}  {n:>3} cells  {title}")
    return 0


def _cmd_run(args: argparse.Namespace) -> int:
    names = available_specs() if args.all else args.spec
    if not names:
        print("nothing to run: name specs or pass --all", file=sys.stderr)
        return 2
    for name in names:
        spec = load_spec(name)
        run_spec(spec, out_root=args.out, fresh=args.fresh)
    return 0


def _cmd_report(args: argparse.Namespace) -> int:
    if args.check:
        drift = check_report(out_root=args.out)
        if drift:
            for msg in drift:
                print(f"DRIFT: {msg}", file=sys.stderr)
            print(
                f"{len(drift)} drifting file(s); regenerate with "
                "`python -m repro.sweeps report` and commit",
                file=sys.stderr,
            )
            return 1
        print("report is in sync with the committed artifacts")
        return 0
    for p in build_report(out_root=args.out):
        print(f"wrote {p}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sweeps",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="command", required=True)

    sub.add_parser("list", help="committed study specs").set_defaults(
        fn=_cmd_list)

    p_run = sub.add_parser("run", help="execute sweep specs (resumable)")
    p_run.add_argument("spec", nargs="*",
                       help="spec names or .toml paths")
    p_run.add_argument("--all", action="store_true",
                       help="run every committed spec")
    p_run.add_argument("--fresh", action="store_true",
                       help="discard existing artifacts for these specs")
    p_run.add_argument("--out", type=Path, default=None,
                       help="artifact root (default: repo results/)")
    p_run.set_defaults(fn=_cmd_run)

    p_rep = sub.add_parser(
        "report", help="regenerate RESULTS.md + figures from artifacts")
    p_rep.add_argument("--check", action="store_true",
                       help="diff instead of writing; exit 1 on drift")
    p_rep.add_argument("--out", type=Path, default=None,
                       help="artifact root (default: repo results/)")
    p_rep.set_defaults(fn=_cmd_report)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
