"""repro.faults — deterministic, seeded fault injection (DESIGN.md §12).

Production failure modes — a kernel launch that dies, a garbage verdict
plane, a stale autotune schedule, an OOM-shaped allocation error — are rare
enough on a healthy box that the recovery machinery around them would rot
untested. This package makes them *reproducible*: named injection sites sit
on the real host-side boundaries of the request path, and a seeded `FaultPlan`
decides, per site, whether a given crossing raises.

Sites (the complete list is `KNOWN_SITES`; each names the host boundary it
guards):

- ``service.admit``   — request admission (`SolverService._admit_one`)
- ``cache.lookup``    — prepared-network cache acquire (`service/cache.py`)
- ``slot.install``    — slot-table install (`core.engine.SlotPool.install`)
- ``frontier.step``   — frontier round dispatch (`FrontierTable`/host store)
- ``kernel.launch``   — kernel-layer host entries (`kernels/ops.py` prepare
  paths and the launch edge of every dispatch)
- ``round.resolve``   — lockstep round resolution (`LockstepDriver._advance`)

The hook is ``inject(site, **ctx)``. With no plan configured (the default —
``REPRO_FAULTS`` unset) it is a single global-is-None check and returns
immediately, so the fault layer adds zero measurable overhead to production
paths; the acceptance gate for that claim is `check_regression` holding the
service p95 against the pre-faults baseline.

Recipes are strings, set programmatically via `configure` or from the
environment (``REPRO_FAULTS``, seeded by ``REPRO_FAULTS_SEED``):

    REPRO_FAULTS="all:0.05"                      # every site at 5%
    REPRO_FAULTS="frontier.step:0.1:oom"         # one site, OOM-shaped
    REPRO_FAULTS="cache.lookup:1.0:fault:2"      # fire exactly twice
    REPRO_FAULTS="all:0.05,round.resolve:0.2:garbage"

``site:rate[:kind[:max_fires]]``, comma-separated; ``all`` expands to every
known site (later entries override). Kinds map to the typed exceptions below:
``fault`` → `InjectedFault`, ``garbage`` → `GarbageVerdict` (NaN/garbage
verdict plane), ``stale`` → `StaleSchedule` (autotune schedule for a shape
that no longer exists), ``oom`` → `OomError` (also a `MemoryError`).

Determinism: each site draws from its own `numpy` Generator seeded by
``(seed, crc32(site))``, so whether the k-th crossing of a site faults is a
pure function of (recipe, seed, k) — independent of dict ordering, other
sites' traffic, or process hashing. That is what lets `tests/test_faults.py`
assert bit-identical verdicts against the no-fault oracle run.

Every fired injection ticks ``faults.injected`` and
``faults.injected.<site>`` in the `repro.obs` registry.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import zlib
from typing import Dict, Optional, Union

import numpy as np

from repro import obs

#: every wired injection site (recipes naming anything else are rejected)
KNOWN_SITES = (
    "service.admit",
    "cache.lookup",
    "slot.install",
    "frontier.step",
    "kernel.launch",
    "round.resolve",
)


class FaultError(Exception):
    """Base of every injectable failure. ``site`` names the injection site
    (or the real boundary that raised); the service's retry/fallback ladder
    catches exactly this type."""

    def __init__(self, site: str, detail: str = ""):
        self.site = site
        super().__init__(f"{site}: {detail}" if detail else site)


class InjectedFault(FaultError):
    """A generic injected failure (recipe kind ``fault``)."""


class GarbageVerdict(FaultError):
    """A verdict plane that came back NaN/garbage — the device returned
    bits that cannot be trusted as consistency metadata (kind ``garbage``)."""


class StaleSchedule(FaultError):
    """An autotune schedule referencing a bucket/block shape that no longer
    matches the compiled program (kind ``stale``)."""


class OomError(FaultError, MemoryError):
    """An OOM-shaped allocation failure at a device boundary (kind ``oom``).
    Subclasses `MemoryError` so generic OOM handling also sees it."""


class Overloaded(Exception):
    """Typed load-shed verdict: the service refused the request *before*
    spending padding/preparation work on it. ``retry_after_s`` is the
    service's estimate of when capacity frees up — the client-facing
    Retry-After hint."""

    def __init__(self, retry_after_s: float = 0.0, detail: str = "overloaded"):
        self.retry_after_s = float(retry_after_s)
        super().__init__(f"{detail} (retry after ~{retry_after_s:.2f}s)")


_KIND_EXC = {
    "fault": InjectedFault,
    "garbage": GarbageVerdict,
    "stale": StaleSchedule,
    "oom": OomError,
}

_KIND_DETAIL = {
    "fault": "injected fault",
    "garbage": "injected NaN/garbage verdict plane",
    "stale": "injected stale autotune schedule",
    "oom": "injected OOM-shaped allocation failure",
}


@dataclasses.dataclass(frozen=True)
class SiteSpec:
    """One site's injection policy: fire with probability ``rate`` per
    crossing, raising the ``kind`` exception, at most ``max_fires`` times
    (None = unbounded). ``rate=1.0`` fires on every crossing."""

    rate: float
    kind: str = "fault"
    max_fires: Optional[int] = None

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"fault rate must be in [0, 1], got {self.rate}")
        if self.kind not in _KIND_EXC:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; known: {sorted(_KIND_EXC)}"
            )
        if self.max_fires is not None and self.max_fires < 0:
            raise ValueError("max_fires must be >= 0 (or None)")


class FaultPlan:
    """A seeded injection plan over `KNOWN_SITES`. Each site owns an
    independent Generator seeded ``(seed, crc32(site))`` — crc32, not
    ``hash()``, because the latter is salted per process and would break
    cross-run determinism."""

    def __init__(self, sites: Dict[str, SiteSpec], seed: int = 0):
        unknown = sorted(set(sites) - set(KNOWN_SITES))
        if unknown:
            raise ValueError(
                f"unknown fault site(s) {unknown}; known: {list(KNOWN_SITES)}"
            )
        self.sites = dict(sites)
        self.seed = int(seed)
        self._rngs = {
            s: np.random.default_rng((self.seed, zlib.crc32(s.encode())))
            for s in self.sites
        }
        #: per-site observed crossings / raised faults (introspection + tests)
        self.draws: Dict[str, int] = {s: 0 for s in self.sites}
        self.fires: Dict[str, int] = {s: 0 for s in self.sites}

    def roll(self, site: str) -> Optional[str]:
        """One crossing of ``site``: returns the fault kind to raise, or None.
        Draws ALWAYS advance the site's RNG stream (even past ``max_fires``),
        so the k-th crossing's outcome never depends on earlier handling."""
        spec = self.sites.get(site)
        if spec is None:
            return None
        self.draws[site] += 1
        fire = self._rngs[site].random() < spec.rate
        if not fire:
            return None
        if spec.max_fires is not None and self.fires[site] >= spec.max_fires:
            return None
        self.fires[site] += 1
        return spec.kind

    @property
    def total_fires(self) -> int:
        return sum(self.fires.values())

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        parts = ", ".join(
            f"{s}:{sp.rate:g}:{sp.kind}" for s, sp in sorted(self.sites.items())
        )
        return f"<FaultPlan seed={self.seed} [{parts}] fires={self.total_fires}>"


def parse_recipe(recipe: str) -> Dict[str, SiteSpec]:
    """``site:rate[:kind[:max_fires]]`` comma-list → site specs. ``all``
    expands to every known site; later entries override earlier ones."""
    sites: Dict[str, SiteSpec] = {}
    for part in recipe.split(","):
        part = part.strip()
        if not part:
            continue
        fields = part.split(":")
        if len(fields) < 2 or len(fields) > 4:
            raise ValueError(
                f"bad fault recipe entry {part!r} "
                "(want site:rate[:kind[:max_fires]])"
            )
        site, rate = fields[0].strip(), float(fields[1])
        kind = fields[2].strip() if len(fields) > 2 and fields[2].strip() else "fault"
        max_fires = int(fields[3]) if len(fields) > 3 else None
        spec = SiteSpec(rate, kind, max_fires)
        targets = KNOWN_SITES if site == "all" else (site,)
        for t in targets:
            if t not in KNOWN_SITES:
                raise ValueError(
                    f"unknown fault site {t!r}; known: {list(KNOWN_SITES)}"
                )
            sites[t] = spec
    if not sites:
        raise ValueError(f"empty fault recipe {recipe!r}")
    return sites


# the process-wide plan; None = fault layer off (the production default)
_PLAN: Optional[FaultPlan] = None


def enabled() -> bool:
    return _PLAN is not None


def active() -> Optional[FaultPlan]:
    """The live plan (for introspection: ``active().fires`` etc.), or None."""
    return _PLAN


def configure(
    recipe: Union[str, Dict[str, SiteSpec], FaultPlan],
    seed: Optional[int] = None,
) -> FaultPlan:
    """Install a process-wide fault plan from a recipe string, a site-spec
    dict, or a ready `FaultPlan`. Returns the installed plan."""
    global _PLAN
    if isinstance(recipe, FaultPlan):
        plan = recipe
    else:
        sites = parse_recipe(recipe) if isinstance(recipe, str) else dict(recipe)
        plan = FaultPlan(sites, seed=0 if seed is None else seed)
    _PLAN = plan
    return plan


def clear() -> None:
    """Remove the process-wide plan — `inject` returns to its no-op path."""
    global _PLAN
    _PLAN = None


@contextlib.contextmanager
def injected(recipe: Union[str, Dict[str, SiteSpec]], seed: int = 0):
    """Scoped plan for tests: install, yield the plan, always restore the
    previous state (usually None) on exit."""
    global _PLAN
    prev = _PLAN
    plan = configure(recipe, seed=seed)
    try:
        yield plan
    finally:
        _PLAN = prev


def inject(site: str, **ctx) -> None:
    """The injection hook. With no plan installed this is ONE global check —
    the zero-overhead-off contract every hot path relies on. With a plan, the
    site's seeded RNG decides whether this crossing raises its typed fault;
    ``ctx`` rides into the exception detail and the obs span args."""
    plan = _PLAN
    if plan is None:
        return
    kind = plan.roll(site)
    if kind is None:
        return
    obs.counter_add("faults.injected")
    obs.counter_add(f"faults.injected.{site}")
    detail = _KIND_DETAIL[kind]
    if ctx:
        detail += " [" + ", ".join(f"{k}={v}" for k, v in sorted(ctx.items())) + "]"
    raise _KIND_EXC[kind](site, detail)


def enable_from_env() -> None:
    """Install a plan from ``REPRO_FAULTS`` / ``REPRO_FAULTS_SEED`` if set —
    called once at import, mirroring `repro.obs.enable_from_env`."""
    recipe = os.environ.get("REPRO_FAULTS")
    if recipe:
        configure(recipe, seed=int(os.environ.get("REPRO_FAULTS_SEED", "0")))


enable_from_env()
