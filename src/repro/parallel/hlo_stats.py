"""HLO text analysis — collective-byte accounting for the roofline.

``cost_analysis()`` gives FLOPs and HBM bytes but NOT collective traffic, so we
parse the (SPMD-partitioned) HLO for all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops, take each op's RESULT shape bytes and group
size (from replica_groups), and convert to per-device wire bytes with standard
ring-algorithm factors:

    all-reduce:          2·(g-1)/g · bytes
    all-gather:            (g-1)/g · bytes       (result bytes)
    reduce-scatter:        (g-1)/g · bytes·g     (operand = result·g)
    all-to-all:            (g-1)/g · bytes
    collective-permute:              bytes

This is per-device traffic over the slowest link on the ring, the quantity the
ICI roofline term wants.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(sig):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]<=[N]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind: op count, raw result bytes, ring wire bytes."""
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0}
    )
    for line in hlo_text.splitlines():
        ls = line.strip()
        for kind in _COLLECTIVES:
            # match " <shape(s)> kind(" — the op use, not metadata mentions
            token = f" {kind}("
            start_token = f"{kind}-start("
            if token not in ls and start_token not in ls:
                continue
            if "-done(" in ls:
                continue  # async completion carries no new shape
            lhs = ls.split(f" {kind}")[0]
            rb = _shape_bytes(lhs)
            g = _group_size(ls)
            if kind == "collective-permute":
                factor = 1.0  # pairwise; no replica_groups attribute
            elif g <= 1:
                # degenerate group — no wire traffic
                factor = 0.0
            elif kind == "all-reduce":
                factor = 2.0 * (g - 1) / g
            elif kind == "all-gather":
                factor = (g - 1) / g
            elif kind == "reduce-scatter":
                factor = float(g - 1)  # operand bytes = result·g; (g-1)/g·g
            else:  # all-to-all
                factor = (g - 1) / g
            s = stats[kind]
            s["count"] += 1
            s["result_bytes"] += rb
            s["wire_bytes"] += rb * factor
            break
    return dict(stats)


def total_wire_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(s["wire_bytes"] for s in stats.values())


def count_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
