"""Logical-axis sharding rules → concrete PartitionSpecs, with divisibility fallback.

MaxText-style: model code names dimensions logically ('batch', 'embed', 'heads',
'mlp', 'vocab', 'expert', ...); a rule table per run maps logical names to mesh
axes. Because the 10 assigned architectures have wildly different divisibility
(whisper: 20 heads, vocab 51866; command-r: kv_heads=8 < model=16), a requested
mapping is *demoted* — drop mesh axes right-to-left, then replicate — whenever
the dimension is not divisible or the mesh axis is already taken by another
dimension of the same tensor. Demotions are deterministic and recorded so the
dry-run artifact shows exactly what sharded where.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes (tried left-to-right as a unit, then demoted)
ParamRules = Dict[str, Tuple[str, ...]]

# Parameters: TP axes on 'model', FSDP on 'data' (+'pod' for the very largest).
DEFAULT_PARAM_RULES: ParamRules = {
    "layers": (),
    "embed": ("data",),  # FSDP: contracting dims sharded over data
    "embed_table": (),  # embedding feature dim: never FSDP (gather reshard cost)
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "qkv": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "expert_mlp": (),
    "state": (),
    "conv": (),
    "frames": (),
}

# Activations: batch data-parallel; TP dims on 'model'; seq for sequence-parallel.
DEFAULT_ACT_RULES: ParamRules = {
    "batch": ("pod", "data"),
    "seq": (),
    # residual-stream seq axis: mapped to 'model' (Megatron-style sequence
    # parallelism) when saved activation checkpoints would overflow HBM —
    # auto-enabled by build_train_step, recorded in the dry-run artifact.
    "seq_resid": (),
    "cache_seq": ("model", "data"),  # decode KV cache seq: model axis, plus data when batch=1 frees it (long_500k)
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    # attention-score q-dim: takes 'model' exactly when the head dims could
    # NOT (e.g. qwen2-vl's 12 heads / whisper's 20 heads on a 16-way axis) —
    # sequence-parallel attention instead of 16x-redundant replication. The
    # one-use-per-tensor demotion rule makes this self-targeting.
    "seq_q": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "expert_cap": (),
    "state": (),
    "layers": (),
    "frames": (),
}


def spec_for(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    rules: ParamRules,
    mesh_shape: Dict[str, int],
    log: Optional[list] = None,
) -> P:
    """Build a PartitionSpec honoring divisibility + one-use-per-mesh-axis."""
    used: set = set()
    parts = []
    for dim, (name, size) in enumerate(zip(axes, shape)):
        if name is None:
            parts.append(None)
            continue
        want = tuple(a for a in rules.get(name, ()) if a in mesh_shape)
        # demote: drop axes right-to-left until divisible & unused
        choice: Tuple[str, ...] = ()
        cand = list(want)
        while cand:
            prod = 1
            ok = True
            for a in cand:
                if a in used:
                    ok = False
                    break
                prod *= mesh_shape[a]
            if ok and size % prod == 0:
                choice = tuple(cand)
                break
            cand.pop()  # drop rightmost
        if log is not None and choice != want and want:
            log.append(f"demote dim{dim}({name},{size}): {want} -> {choice}")
        used.update(choice)
        parts.append(choice if len(choice) > 1 else (choice[0] if choice else None))
    return P(*parts)


# ---------------------------------------------------------------------------
# Context: mesh + rules available to model code for activation constraints.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardingCtx:
    mesh: Mesh
    param_rules: ParamRules
    act_rules: ParamRules
    log: list = dataclasses.field(default_factory=list)

    @property
    def mesh_shape(self) -> Dict[str, int]:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))


_tls = threading.local()


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_tls, "ctx", None)


@contextlib.contextmanager
def sharding_ctx(ctx: Optional[ShardingCtx]):
    prev = current_ctx()
    _tls.ctx = ctx
    try:
        yield ctx
    finally:
        _tls.ctx = prev


def make_ctx(mesh: Mesh, param_rules=None, act_rules=None) -> ShardingCtx:
    return ShardingCtx(
        mesh=mesh,
        param_rules=dict(param_rules or DEFAULT_PARAM_RULES),
        act_rules=dict(act_rules or DEFAULT_ACT_RULES),
    )


def shard_act(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Constrain an activation's sharding by logical axes. No-op outside a ctx."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = spec_for(axes, x.shape, ctx.act_rules, ctx.mesh_shape, ctx.log)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
