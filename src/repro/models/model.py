"""build_model(cfg) — facade constructor + input spec builder."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from .transformer import Model


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train/prefill: token batch (+ stub modality inputs).
    decode: one new token per sequence (cache specs come from the model).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.is_decode:
        specs = {"tokens": jax.ShapeDtypeStruct((b,), i32)}
        return specs
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["loss_mask"] = jax.ShapeDtypeStruct((b, s), jnp.float32)
    if cfg.family == "encdec":
        # conv/mel frontend is a stub: precomputed frame embeddings
        specs["enc_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_frames, cfg.d_model), jnp.bfloat16
        )
    if cfg.pos == "mrope":
        # vision frontend stub: 3-component (t,h,w) position ids
        specs["pos3"] = jax.ShapeDtypeStruct((b, s, 3), i32)
    return specs


def make_inputs(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0):
    """Concrete random inputs matching input_specs (smoke tests / examples)."""
    key = jax.random.PRNGKey(seed)
    specs = input_specs(cfg, shape)
    out = {}
    for name, spec in specs.items():
        key, k = jax.random.split(key)
        if spec.dtype == jnp.int32:
            if name == "pos3":
                b, s, _ = spec.shape
                pos = jnp.broadcast_to(jnp.arange(s)[None, :, None], spec.shape)
                out[name] = pos.astype(jnp.int32)
            else:
                out[name] = jax.random.randint(k, spec.shape, 0, cfg.vocab, jnp.int32)
        elif name == "loss_mask":
            out[name] = jnp.ones(spec.shape, spec.dtype)
        else:
            out[name] = jax.random.normal(k, spec.shape, spec.dtype)
    return out
