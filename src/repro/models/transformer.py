"""Model assembly for all assigned families.

One :class:`Model` facade per config with a uniform API:

    decls()                      parameter declarations (shapes + logical axes)
    init(key)                    concrete params
    forward(params, batch)       logits  (train / prefill path)
    loss(params, batch)          (scalar, metrics)  — next-token CE + MoE aux
    cache_decls(batch, cache_len)  decode-state declarations
    init_cache(batch, cache_len)   zeroed decode state
    decode_step(params, cache, tokens, pos[, extras]) -> (logits, cache)

Layer stacks are ``lax.scan`` over stacked params (HLO size depth-independent);
``cfg.remat`` wraps the scanned body in ``jax.checkpoint``. Families:

  dense | moe | vlm   pre-norm GQA attention + (SwiGLU MLP | MoE)
  ssm (rwkv6)         time-mix + channel-mix, no attention
  hybrid (zamba2)     mamba2 stack with a SHARED attention+MLP block applied
                      every ``attn_every`` layers (own KV cache per invocation)
  encdec (whisper)    bidirectional encoder over stub frame embeddings +
                      causal decoder with cross-attention
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import shard_act
from . import layers as L
from . import moe as M
from . import ssm as S
from .param import ParamDecl, abstract_params, init_params, is_decl

Array = jax.Array


def stack_decls(decls, n: int):
    return jax.tree.map(
        lambda d: ParamDecl((n,) + d.shape, ("layers",) + d.axes, d.init, d.scale, d.dtype),
        decls,
        is_leaf=is_decl,
    )


def _zero_aux() -> Dict[str, Array]:
    return {
        "moe_aux_loss": jnp.zeros((), jnp.float32),
        "moe_z_loss": jnp.zeros((), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Per-family decoder blocks (train/prefill path)
# ---------------------------------------------------------------------------


def _dense_block_decls(cfg):
    d = {
        "norm1": L.norm_decls(cfg.d_model, cfg.norm),
        "attn": L.attention_decls(cfg),
        "norm2": L.norm_decls(cfg.d_model, cfg.norm),
    }
    if cfg.n_experts:
        d["moe"] = M.moe_decls(cfg)
    else:
        d["mlp"] = L.mlp_decls(cfg)
    return d


def _dense_block(p, x, cfg, positions, aux):
    h = L.apply_norm(p["norm1"], x, cfg.norm)
    x = x + L.apply_attention(p["attn"], h, cfg, positions, q_chunk=cfg.q_chunk)
    h = L.apply_norm(p["norm2"], x, cfg.norm)
    if cfg.n_experts:
        y, a = M.apply_moe(p["moe"], h, cfg, cfg.capacity_factor)
        aux = {k: aux[k] + a[k] for k in aux}
    else:
        y = L.apply_mlp(p["mlp"], h, cfg)
    out = shard_act(x + y, ("batch", "seq_resid", "embed"))
    return out, aux


def _rwkv_block_decls(cfg):
    return {
        "norm1": L.norm_decls(cfg.d_model, cfg.norm),
        "time": S.rwkv6_decls(cfg),
        "norm2": L.norm_decls(cfg.d_model, cfg.norm),
    }


def _rwkv_block(p, x, cfg, states):
    x_prev_t, x_prev_c, s0 = states
    h = L.apply_norm(p["norm1"], x, cfg.norm)
    y, x_last_t, s_end = S.rwkv6_mix(p["time"], h, cfg, x_prev_t, s0, cfg.ssm_chunk)
    x = x + y
    h = L.apply_norm(p["norm2"], x, cfg.norm)
    y, x_last_c = S.rwkv6_channel_mix(p["time"], h, cfg, x_prev_c)
    out = shard_act(x + y, ("batch", "seq_resid", "embed"))
    return out, (x_last_t, x_last_c, s_end)


def _mamba_block_decls(cfg):
    return {
        "norm1": L.norm_decls(cfg.d_model, cfg.norm),
        "mamba": S.mamba2_decls(cfg),
    }


def _mamba_block(p, x, cfg, states):
    conv_tail, h0 = states
    h = L.apply_norm(p["norm1"], x, cfg.norm)
    y, tail, h_end = S.mamba2_mix(p["mamba"], h, cfg, conv_tail, h0, cfg.ssm_chunk)
    out = shard_act(x + y, ("batch", "seq_resid", "embed"))
    return out, (tail, h_end)


def _shared_attn_decls(cfg):
    return {
        "norm1": L.norm_decls(cfg.d_model, cfg.norm),
        "attn": L.attention_decls(cfg),
        "norm2": L.norm_decls(cfg.d_model, cfg.norm),
        "mlp": L.mlp_decls(cfg),
    }


def _shared_attn_block(p, x, cfg, positions):
    h = L.apply_norm(p["norm1"], x, cfg.norm)
    x = x + L.apply_attention(p["attn"], h, cfg, positions, q_chunk=cfg.q_chunk)
    h = L.apply_norm(p["norm2"], x, cfg.norm)
    return shard_act(x + L.apply_mlp(p["mlp"], h, cfg), ("batch", "seq_resid", "embed"))


# ---------------------------------------------------------------------------
# Model facade
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    cfg: Any

    # ----- declarations ----------------------------------------------------

    def decls(self):
        cfg = self.cfg
        out: Dict[str, Any] = {"embed": L.embed_decls(cfg)}
        if cfg.family in ("dense", "moe", "vlm"):
            out["layers"] = stack_decls(_dense_block_decls(cfg), cfg.n_layers)
        elif cfg.family == "ssm":
            out["layers"] = stack_decls(_rwkv_block_decls(cfg), cfg.n_layers)
        elif cfg.family == "hybrid":
            out["layers"] = stack_decls(_mamba_block_decls(cfg), cfg.n_layers)
            out["shared_attn"] = _shared_attn_decls(cfg)
        elif cfg.family == "encdec":
            enc_cfg = cfg.replace(causal=False)
            out["enc_layers"] = stack_decls(
                {
                    "norm1": L.norm_decls(cfg.d_model, cfg.norm),
                    "attn": L.attention_decls(enc_cfg),
                    "norm2": L.norm_decls(cfg.d_model, cfg.norm),
                    "mlp": L.mlp_decls(cfg),
                },
                cfg.encoder_layers,
            )
            out["enc_norm"] = L.norm_decls(cfg.d_model, cfg.norm)
            out["layers"] = stack_decls(
                {
                    "norm1": L.norm_decls(cfg.d_model, cfg.norm),
                    "self_attn": L.attention_decls(cfg),
                    "norm_x": L.norm_decls(cfg.d_model, cfg.norm),
                    "cross_attn": L.attention_decls(cfg),
                    "norm2": L.norm_decls(cfg.d_model, cfg.norm),
                    "mlp": L.mlp_decls(cfg),
                },
                cfg.n_layers,
            )
        else:
            raise ValueError(cfg.family)
        out["final_norm"] = L.norm_decls(cfg.d_model, cfg.norm)
        return out

    def init(self, key, dtype_override=None):
        return init_params(key, self.decls(), dtype_override)

    def abstract_params(self, dtype_override=None):
        return abstract_params(self.decls(), dtype_override)

    # ----- forward (train / prefill) ---------------------------------------

    def forward(self, params, batch: Dict[str, Array]) -> Tuple[Array, Dict]:
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.pos == "mrope":
            pos_in = batch.get("pos3")
            if pos_in is None:
                pos_in = jnp.broadcast_to(positions[..., None], (b, s, 3))
        else:
            pos_in = positions
        x = L.apply_embed(params["embed"], tokens, cfg, positions)
        aux = _zero_aux()

        if cfg.family in ("dense", "moe", "vlm"):
            x, aux = self._run_dense_stack(params["layers"], x, pos_in, aux)
        elif cfg.family == "ssm":
            x = self._run_rwkv_stack(params["layers"], x)
        elif cfg.family == "hybrid":
            x = self._run_hybrid_stack(params, x, pos_in)
        elif cfg.family == "encdec":
            enc = self._run_encoder(params, batch["enc_embed"])
            x = self._run_decoder_encdec(params["layers"], x, enc, pos_in)

        x = L.apply_norm(params["final_norm"], x, cfg.norm)
        logits = L.apply_unembed(params["embed"], x, cfg)
        return logits, aux

    def _maybe_remat(self, fn):
        return jax.checkpoint(fn) if self.cfg.remat else fn

    def _scan(self, body, init, xs):
        # scan_unroll=True only in dry-run cost lowering (see configs/base.py)
        return lax.scan(body, init, xs, unroll=True if self.cfg.scan_unroll else 1)

    def _run_dense_stack(self, stacked, x, pos_in, aux):
        cfg = self.cfg

        def body(carry, lp):
            x, aux = carry
            x, aux = _dense_block(lp, x, cfg, pos_in, aux)
            return (x, aux), None

        (x, aux), _ = self._scan(self._maybe_remat(body), (x, aux), stacked)
        return x, aux

    def _run_rwkv_stack(self, stacked, x):
        cfg = self.cfg
        b = x.shape[0]
        h = cfg.n_heads
        hd = cfg.d_model // h
        zero_states = (
            jnp.zeros((b, cfg.d_model), x.dtype),
            jnp.zeros((b, cfg.d_model), x.dtype),
            jnp.zeros((b, h, hd, hd), jnp.float32),
        )

        def body(x, lp):
            x, _ = _rwkv_block(lp, x, cfg, zero_states)
            return x, None

        x, _ = self._scan(self._maybe_remat(body), x, stacked)
        return x

    def _run_hybrid_stack(self, params, x, pos_in):
        cfg = self.cfg
        b = x.shape[0]
        di, n = cfg.resolved_ssm_d_inner, cfg.ssm_state
        nh = di // cfg.ssm_head_dim
        cdim = di + 2 * n
        zero_states = (
            jnp.zeros((b, cfg.ssm_conv - 1, cdim), x.dtype),
            jnp.zeros((b, nh, n, cfg.ssm_head_dim), jnp.float32),
        )
        shared = params["shared_attn"]
        k = cfg.attn_every

        def body(carry, inp):
            x = carry
            i, lp = inp
            x, _ = _mamba_block(lp, x, cfg, zero_states)
            x = lax.cond(
                (i + 1) % k == 0,
                lambda x: _shared_attn_block(shared, x, cfg, pos_in),
                lambda x: x,
                x,
            )
            return x, None

        idx = jnp.arange(cfg.n_layers)
        x, _ = self._scan(self._maybe_remat(body), x, (idx, params["layers"]))
        return x

    def _run_encoder(self, params, enc_embed):
        cfg = self.cfg
        enc_cfg = cfg.replace(causal=False, pos="none")
        x = shard_act(enc_embed.astype(getattr(jnp, cfg.dtype)), ("batch", "seq", "embed"))
        b, f, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(f)[None], (b, f))

        def body(x, lp):
            h = L.apply_norm(lp["norm1"], x, cfg.norm)
            x = x + L.apply_attention(lp["attn"], h, enc_cfg, pos, q_chunk=cfg.q_chunk)
            h = L.apply_norm(lp["norm2"], x, cfg.norm)
            x = shard_act(x + L.apply_mlp(lp["mlp"], h, cfg), ("batch", "seq_resid", "embed"))
            return x, None

        x, _ = self._scan(self._maybe_remat(body), x, params["enc_layers"])
        return L.apply_norm(params["enc_norm"], x, cfg.norm)

    def _run_decoder_encdec(self, stacked, x, enc, pos_in):
        cfg = self.cfg

        def body(x, lp):
            h = L.apply_norm(lp["norm1"], x, cfg.norm)
            x = x + L.apply_attention(lp["self_attn"], h, cfg, pos_in, q_chunk=cfg.q_chunk)
            h = L.apply_norm(lp["norm_x"], x, cfg.norm)
            x = x + L.apply_cross_attention(lp["cross_attn"], h, enc, cfg)
            h = L.apply_norm(lp["norm2"], x, cfg.norm)
            x = shard_act(x + L.apply_mlp(lp["mlp"], h, cfg), ("batch", "seq_resid", "embed"))
            return x, None

        x, _ = self._scan(self._maybe_remat(body), x, stacked)
        return x

    # ----- loss -------------------------------------------------------------

    def loss(self, params, batch) -> Tuple[Array, Dict]:
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        lg = logits.astype(jnp.float32)
        if self.cfg.padded_vocab != self.cfg.vocab:  # mask pad-token logits
            pad_mask = jnp.arange(self.cfg.padded_vocab) < self.cfg.vocab
            lg = jnp.where(pad_mask, lg, -1e30)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
        ce = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        total = ce + 0.01 * aux["moe_aux_loss"] + 0.001 * aux["moe_z_loss"]
        metrics = {"ce": ce, **aux}
        return total, metrics

    # ----- decode -----------------------------------------------------------

    def cache_decls(self, batch: int, cache_len: int):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        bf16 = jnp.bfloat16
        out: Dict[str, Any] = {
            "pos": ParamDecl((batch,), ("batch",), init="zeros", dtype=jnp.int32)
        }
        if cfg.family in ("dense", "moe", "vlm"):
            clen = min(cache_len, cfg.window) if cfg.attention == "swa" else cache_len
            shape = (cfg.n_layers, batch, clen, cfg.n_kv_heads, hd)
            axes = ("layers", "batch", "cache_seq", "kv_heads", None)
            out["k"] = ParamDecl(shape, axes, init="zeros", dtype=bf16)
            out["v"] = ParamDecl(shape, axes, init="zeros", dtype=bf16)
        elif cfg.family == "ssm":
            h = cfg.n_heads
            khd = cfg.d_model // h
            out["x_prev_t"] = ParamDecl((cfg.n_layers, batch, cfg.d_model), ("layers", "batch", "embed"), init="zeros", dtype=bf16)
            out["x_prev_c"] = ParamDecl((cfg.n_layers, batch, cfg.d_model), ("layers", "batch", "embed"), init="zeros", dtype=bf16)
            out["s"] = ParamDecl((cfg.n_layers, batch, h, khd, khd), ("layers", "batch", "heads", None, None), init="zeros")
        elif cfg.family == "hybrid":
            di, n = cfg.resolved_ssm_d_inner, cfg.ssm_state
            nh = di // cfg.ssm_head_dim
            cdim = di + 2 * n
            out["conv_tail"] = ParamDecl((cfg.n_layers, batch, cfg.ssm_conv - 1, cdim), ("layers", "batch", None, "mlp"), init="zeros", dtype=bf16)
            out["h"] = ParamDecl((cfg.n_layers, batch, nh, n, cfg.ssm_head_dim), ("layers", "batch", "heads", None, None), init="zeros")
            n_inv = cfg.n_layers // cfg.attn_every
            shape = (n_inv, batch, cache_len, cfg.n_kv_heads, hd)
            axes = ("layers", "batch", "cache_seq", "kv_heads", None)
            out["k"] = ParamDecl(shape, axes, init="zeros", dtype=bf16)
            out["v"] = ParamDecl(shape, axes, init="zeros", dtype=bf16)
        elif cfg.family == "encdec":
            shape = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, hd)
            axes = ("layers", "batch", "cache_seq", "kv_heads", None)
            out["k"] = ParamDecl(shape, axes, init="zeros", dtype=bf16)
            out["v"] = ParamDecl(shape, axes, init="zeros", dtype=bf16)
            fshape = (cfg.n_layers, batch, cfg.encoder_frames, cfg.n_kv_heads, hd)
            faxes = ("layers", "batch", "frames", "kv_heads", None)
            out["enc_k"] = ParamDecl(fshape, faxes, init="zeros", dtype=bf16)
            out["enc_v"] = ParamDecl(fshape, faxes, init="zeros", dtype=bf16)
        return out

    def init_cache(self, batch: int, cache_len: int):
        return init_params(jax.random.PRNGKey(0), self.cache_decls(batch, cache_len))

    def abstract_cache(self, batch: int, cache_len: int):
        return abstract_params(self.cache_decls(batch, cache_len))

    def decode_step(self, params, cache, tokens: Array):
        """tokens (B,) int32 — one new token per sequence. Returns
        (logits (B, vocab), new cache)."""
        cfg = self.cfg
        b = tokens.shape[0]
        pos = cache["pos"]
        x = jnp.take(params["embed"]["tok"].astype(getattr(jnp, cfg.dtype)), tokens, axis=0)
        if cfg.pos == "learned":
            x = x + jnp.take(params["embed"]["pos"].astype(x.dtype), pos, axis=0)
        x = x[:, None, :]  # (B, 1, D)

        if cfg.family in ("dense", "moe", "vlm"):
            x = self._decode_dense(params, cache, x, pos)
        elif cfg.family == "ssm":
            x = self._decode_rwkv(params, cache, x, pos)
        elif cfg.family == "hybrid":
            x = self._decode_hybrid(params, cache, x, pos)
        elif cfg.family == "encdec":
            x = self._decode_encdec(params, cache, x, pos)

        x = L.apply_norm(params["final_norm"], x, cfg.norm)
        logits = L.apply_unembed(params["embed"], x, cfg)[:, 0]
        if cfg.padded_vocab != cfg.vocab:  # never emit pad tokens
            pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
            logits = jnp.where(pad_mask, logits, -1e30)
        cache["pos"] = pos + 1
        return logits, cache

    def _decode_dense(self, params, cache, x, pos):
        cfg = self.cfg

        def body(x, lp_kv):
            lp, kc, vc = lp_kv
            h = L.apply_norm(lp["norm1"], x, cfg.norm)
            att, kc, vc = L.decode_attention(lp["attn"], h, cfg, kc, vc, pos)
            x = x + att
            h = L.apply_norm(lp["norm2"], x, cfg.norm)
            if cfg.n_experts:
                y, _ = M.apply_moe(lp["moe"], h, cfg, cfg.capacity_factor)
            else:
                y = L.apply_mlp(lp["mlp"], h, cfg)
            return x + y, (kc, vc)

        x, (ks, vs) = self._scan(body, x, (params["layers"], cache["k"], cache["v"]))
        cache["k"], cache["v"] = ks, vs
        return x

    def _decode_rwkv(self, params, cache, x, pos):
        cfg = self.cfg

        def body(x, lp_st):
            lp, xt, xc, s0 = lp_st
            x2 = x[:, 0]
            h = L.apply_norm(lp["norm1"], x2[:, None], cfg.norm)[:, 0]
            y, xt_new, s_new = S.rwkv6_decode_step(lp["time"], h, cfg, xt, xc, s0)
            x2 = x2 + y
            h = L.apply_norm(lp["norm2"], x2[:, None], cfg.norm)[:, 0]
            y, xc_new = S.rwkv6_channel_mix_step(lp["time"], h, cfg, xc)
            return (x2 + y)[:, None], (xt_new, xc_new, s_new)

        x, (xts, xcs, ss) = self._scan(
            body, x, (params["layers"], cache["x_prev_t"], cache["x_prev_c"], cache["s"])
        )
        cache["x_prev_t"], cache["x_prev_c"], cache["s"] = xts, xcs, ss
        return x

    def _decode_hybrid(self, params, cache, x, pos):
        cfg = self.cfg
        k = cfg.attn_every
        shared = params["shared_attn"]
        n_inv = cfg.n_layers // k

        def shared_step(x, kc, vc):
            h = L.apply_norm(shared["norm1"], x, cfg.norm)
            att, kc, vc = L.decode_attention(shared["attn"], h, cfg, kc, vc, pos)
            x = x + att
            h = L.apply_norm(shared["norm2"], x, cfg.norm)
            return x + L.apply_mlp(shared["mlp"], h, cfg), kc, vc

        def body(carry, inp):
            x = carry
            i, lp, tail, h0 = inp
            h = L.apply_norm(lp["norm1"], x[:, 0][:, None], cfg.norm)[:, 0]
            y, tail_new, h_new = S.mamba2_decode_step(lp["mamba"], h, cfg, tail, h0)
            x = x + y[:, None]
            return x, (tail_new, h_new)

        # mamba layers via scan; shared attention applied at invocation points
        # outside the scan (it has its own unstacked cache).
        xs = x
        tails, hs = [], []
        # group layers between shared-attn invocations (static python loop over
        # n_inv+1 segments keeps HLO small: segments reuse the same scan body)
        lidx = jnp.arange(cfg.n_layers)
        seg_bounds = [(g * k, min((g + 1) * k, cfg.n_layers)) for g in range(n_inv)]
        rem = (n_inv * k, cfg.n_layers)
        # in-place updates keep the (donated) cache buffers aliased — no copies
        for g, (lo, hi) in enumerate(seg_bounds + ([rem] if rem[0] < rem[1] else [])):
            seg = jax.tree.map(lambda a, lo=lo, hi=hi: a[lo:hi], params["layers"])
            seg_tail = cache["conv_tail"][lo:hi]
            seg_h = cache["h"][lo:hi]
            xs, (t_new, h_new) = self._scan(
                body, xs, (lidx[lo:hi], seg, seg_tail, seg_h)
            )
            cache["conv_tail"] = lax.dynamic_update_slice_in_dim(
                cache["conv_tail"], t_new.astype(cache["conv_tail"].dtype), lo, axis=0
            )
            cache["h"] = lax.dynamic_update_slice_in_dim(
                cache["h"], h_new.astype(cache["h"].dtype), lo, axis=0
            )
            if g < n_inv:
                xs, kc, vc = shared_step(xs, cache["k"][g], cache["v"][g])
                cache["k"] = cache["k"].at[g].set(kc)
                cache["v"] = cache["v"].at[g].set(vc)
        return xs

    def _decode_encdec(self, params, cache, x, pos):
        cfg = self.cfg

        def body(x, inp):
            lp, kc, vc, ek, ev = inp
            h = L.apply_norm(lp["norm1"], x, cfg.norm)
            att, kc, vc = L.decode_attention(lp["self_attn"], h, cfg, kc, vc, pos)
            x = x + att
            h = L.apply_norm(lp["norm_x"], x, cfg.norm)
            q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"].astype(h.dtype))
            o = L._sdpa(q, ek, ev, causal=False)
            x = x + jnp.einsum(
                "bshk,hkd->bsd", o, lp["cross_attn"]["wo"].astype(h.dtype)
            )
            h = L.apply_norm(lp["norm2"], x, cfg.norm)
            x = x + L.apply_mlp(lp["mlp"], h, cfg)
            return x, (kc, vc)

        x, (ks, vs) = self._scan(
            body,
            x,
            (params["layers"], cache["k"], cache["v"], cache["enc_k"], cache["enc_v"]),
        )
        cache["k"], cache["v"] = ks, vs
        return x

    def prefill_encdec_cache(self, params, cache, enc_embed):
        """Precompute cross-attention K/V from encoder output (decode setup)."""
        cfg = self.cfg
        enc = self._run_encoder(params, enc_embed)

        def body(_, lp):
            dt = enc.dtype
            ek = jnp.einsum("bfd,dhk->bfhk", enc, lp["cross_attn"]["wk"].astype(dt))
            ev = jnp.einsum("bfd,dhk->bfhk", enc, lp["cross_attn"]["wv"].astype(dt))
            return None, (ek, ev)

        _, (eks, evs) = self._scan(body, None, params["layers"])
        cache["enc_k"], cache["enc_v"] = eks, evs
        return cache
