"""Attention-free sequence mixers: RWKV6 ("Finch") and Mamba2 (SSD).

Both are linear-recurrence layers trained in *chunked parallel* form — within a
chunk the recurrence unrolls into dense matmuls (MXU work), across chunks a
``lax.scan`` carries the state. The chunked forms are exact (not approximations)
and numerically safe: all decay exponentials are of non-positive arguments.

RWKV6 recurrence (per head; K/V = head dims, w data-dependent per channel):

    S_t = Diag(w_t) S_{t-1} + k_t v_tᵀ
    o_t = r_tᵀ (S_{t-1} + Diag(u) k_t v_tᵀ)

Mamba2 / SSD (per head; scalar data-dependent decay a_t):

    h_t = a_t h_{t-1} + B_t (dt_t · x_t)ᵀ
    y_t = C_tᵀ h_t + D · x_t

Decode carries (state, token-shift x / conv tail) per layer — O(1) per token,
which is why these archs run the ``long_500k`` shape (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import shard_act
from .param import ParamDecl

Array = jax.Array


# ===========================================================================
# RWKV6
# ===========================================================================


def rwkv6_decls(cfg) -> Dict[str, ParamDecl]:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    lora = cfg.ssm_lora  # low-rank size for the data-dependent decay
    return {
        # time-mix lerp coefficients (first-order token-shift mixing)
        "mu_r": ParamDecl((d,), ("embed",), init="zeros"),
        "mu_k": ParamDecl((d,), ("embed",), init="zeros"),
        "mu_v": ParamDecl((d,), ("embed",), init="zeros"),
        "mu_g": ParamDecl((d,), ("embed",), init="zeros"),
        "mu_w": ParamDecl((d,), ("embed",), init="zeros"),
        "w_r": ParamDecl((d, h, hd), ("embed", "heads", "head_dim")),
        "w_k": ParamDecl((d, h, hd), ("embed", "heads", "head_dim")),
        "w_v": ParamDecl((d, h, hd), ("embed", "heads", "head_dim")),
        "w_g": ParamDecl((d, h, hd), ("embed", "heads", "head_dim")),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(x A) B))  (Finch)
        "decay_w0": ParamDecl((h, hd), ("heads", "head_dim"), init="zeros"),
        "decay_a": ParamDecl((d, lora), ("embed", None)),
        "decay_b": ParamDecl((lora, h, hd), (None, "heads", "head_dim")),
        "bonus_u": ParamDecl((h, hd), ("heads", "head_dim"), init="zeros"),
        "ln_out_scale": ParamDecl((h, hd), ("heads", "head_dim"), init="ones"),
        "w_o": ParamDecl((h, hd, d), ("heads", "head_dim", "embed")),
        # channel-mix
        "mu_ck": ParamDecl((d,), ("embed",), init="zeros"),
        "mu_cr": ParamDecl((d,), ("embed",), init="zeros"),
        "w_ck": ParamDecl((d, cfg.d_ff), ("embed", "mlp")),
        "w_cv": ParamDecl((cfg.d_ff, d), ("mlp", "embed")),
        "w_cr": ParamDecl((d, d), ("embed", None)),
    }


def _token_shift(x: Array, x_prev: Array) -> Array:
    """(B,S,D) -> previous-token tensor; x_prev (B,D) seeds position 0."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _lerp(x, xx, mu):
    return x + (xx - x) * mu.astype(x.dtype)


def _wkv_chunk(r, k, v, logw, u, s0):
    """Exact chunked WKV. r/k/v (B,H,L,hd), logw (B,H,L,hd) ≤ 0, s0 (B,H,hd,hd).
    Returns (o (B,H,L,hd), s_end)."""
    f32 = jnp.float32
    r, k, v, logw = (t.astype(f32) for t in (r, k, v, logw))
    c = jnp.cumsum(logw, axis=2)  # (B,H,L,hd) cumulative log-decay, ≤ 0
    c_prev = c - logw  # c_{i-1} (exclusive)
    l = r.shape[2]
    # intra-chunk: A[i,j] = Σ_kdim r_i k_j exp(c_{i-1} - c_j), strictly j < i
    # computed via (L, L, hd) differences — all exponents ≤ 0, no overflow.
    diff = c_prev[:, :, :, None, :] - c[:, :, None, :, :]  # (B,H,L,L,hd)
    tri = jnp.tril(jnp.ones((l, l), bool), -1)[None, None, :, :, None]
    amat = jnp.sum(
        jnp.where(tri, r[:, :, :, None, :] * k[:, :, None, :, :] * jnp.exp(diff), 0.0),
        axis=-1,
    )  # (B,H,L,L)
    o_intra = jnp.einsum("bhij,bhjv->bhiv", amat, v)
    # current-token bonus: (r_i ⊙ u ⊙ k_i)·v_i
    bonus = jnp.sum(r * u[None, :, None, :].astype(f32) * k, axis=-1, keepdims=True) * v
    # inter-chunk: o_i += (r_i ⊙ exp(c_{i-1})) S_0
    r_dec = r * jnp.exp(c_prev)
    o_inter = jnp.einsum("bhlk,bhkv->bhlv", r_dec, s0)
    # state to next chunk: S = Diag(exp(c_L)) S_0 + Σ_i (k_i exp(c_L - c_i)) v_iᵀ
    c_last = c[:, :, -1:, :]  # (B,H,1,hd)
    k_dec = k * jnp.exp(c_last - c)
    s_end = jnp.exp(c_last[:, :, 0, :, None]) * s0 + jnp.einsum(
        "bhlk,bhlv->bhkv", k_dec, v
    )
    return o_intra + o_inter + bonus, s_end


def rwkv6_mix(p, x: Array, cfg, x_prev: Array, s0: Array, chunk: int = 64):
    """Time-mix over a sequence. x (B,S,D); x_prev (B,D); s0 (B,H,hd,hd).
    Returns (out (B,S,D), x_last (B,D), s_end)."""
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    dt = x.dtype
    xx = _token_shift(x, x_prev)
    xr = _lerp(x, xx, p["mu_r"])
    xk = _lerp(x, xx, p["mu_k"])
    xv = _lerp(x, xx, p["mu_v"])
    xg = _lerp(x, xx, p["mu_g"])
    xw = _lerp(x, xx, p["mu_w"])
    r = jnp.einsum("bsd,dhk->bhsk", xr, p["w_r"].astype(dt))
    k = jnp.einsum("bsd,dhk->bhsk", xk, p["w_k"].astype(dt))
    v = jnp.einsum("bsd,dhk->bhsk", xv, p["w_v"].astype(dt))
    g = jnp.einsum("bsd,dhk->bhsk", xg, p["w_g"].astype(dt))
    # data-dependent decay (fp32, clipped to keep exp(-exp(.)) sane)
    lo = jnp.tanh(
        jnp.einsum("bsd,dl->bsl", xw.astype(jnp.float32), p["decay_a"].astype(jnp.float32))
    )
    wraw = p["decay_w0"].astype(jnp.float32)[None, None] + jnp.einsum(
        "bsl,lhk->bshk", lo, p["decay_b"].astype(jnp.float32)
    )
    logw = -jnp.exp(jnp.clip(wraw, -8.0, 4.0))  # ≤ 0, per (B,S,H,hd)
    logw = jnp.transpose(logw, (0, 2, 1, 3))  # (B,H,S,hd)

    if s % chunk != 0:
        chunk = s  # single chunk fallback (smoke-test sizes)
    nc = s // chunk

    def to_chunks(t):  # (B,H,S,hd) -> (nc,B,H,L,hd)
        return t.reshape(b, h, nc, chunk, hd).transpose(2, 0, 1, 3, 4)

    u = p["bonus_u"]

    def body(state, inp):
        rc, kc, vc, lwc = inp
        o, s_next = _wkv_chunk(rc, kc, vc, lwc, u, state)
        return s_next, o

    if cfg.remat:
        # without this, scan's backward saves each chunk's full linearization
        # residuals (incl. the (B,H,L,L,hd) decay tensor) — O(S·L·hd) memory
        body = jax.checkpoint(body)

    s_end, o_chunks = lax.scan(
        body,
        s0.astype(jnp.float32),
        (to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(logw)),
        unroll=True if cfg.scan_unroll else 1,
    )
    o = o_chunks.transpose(1, 2, 0, 3, 4).reshape(b, h, s, hd)
    # group-norm per head then gate
    mu = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = (o - mu) * lax.rsqrt(var + 1e-5) * p["ln_out_scale"].astype(jnp.float32)[None, :, None, :]
    o = (o.astype(dt) * jax.nn.silu(g.astype(jnp.float32)).astype(dt))
    o = jnp.transpose(o, (0, 2, 1, 3))  # (B,S,H,hd)
    out = jnp.einsum("bshk,hkd->bsd", o, p["w_o"].astype(dt))
    return out, x[:, -1, :], s_end


def rwkv6_channel_mix(p, x: Array, cfg, x_prev: Array):
    dt = x.dtype
    xx = _token_shift(x, x_prev)
    xk = _lerp(x, xx, p["mu_ck"])
    xr = _lerp(x, xx, p["mu_cr"])
    kk = jnp.einsum("bsd,df->bsf", xk, p["w_ck"].astype(dt))
    kk = jnp.square(jax.nn.relu(kk))
    kk = shard_act(kk, ("batch", "seq", "mlp"))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["w_cv"].astype(dt))
    rr = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr.astype(jnp.float32), p["w_cr"].astype(jnp.float32))
    ).astype(dt)
    return rr * vv, x[:, -1, :]


def rwkv6_decode_step(p, x: Array, cfg, x_prev_t, x_prev_c, s0):
    """One token. x (B,D). States: x_prev_* (B,D), s0 (B,H,hd,hd) fp32."""
    b, d = x.shape
    h = cfg.n_heads
    hd = d // h
    dt = x.dtype
    f32 = jnp.float32
    xr = _lerp(x, x_prev_t, p["mu_r"])
    xk = _lerp(x, x_prev_t, p["mu_k"])
    xv = _lerp(x, x_prev_t, p["mu_v"])
    xg = _lerp(x, x_prev_t, p["mu_g"])
    xw = _lerp(x, x_prev_t, p["mu_w"])
    r = jnp.einsum("bd,dhk->bhk", xr, p["w_r"].astype(dt)).astype(f32)
    k = jnp.einsum("bd,dhk->bhk", xk, p["w_k"].astype(dt)).astype(f32)
    v = jnp.einsum("bd,dhk->bhk", xv, p["w_v"].astype(dt)).astype(f32)
    g = jnp.einsum("bd,dhk->bhk", xg, p["w_g"].astype(dt))
    lo = jnp.tanh(jnp.einsum("bd,dl->bl", xw.astype(f32), p["decay_a"].astype(f32)))
    wraw = p["decay_w0"].astype(f32)[None] + jnp.einsum(
        "bl,lhk->bhk", lo, p["decay_b"].astype(f32)
    )
    w = jnp.exp(-jnp.exp(jnp.clip(wraw, -8.0, 4.0)))  # (B,H,hd)
    u = p["bonus_u"].astype(f32)[None]
    kv = k[..., :, None] * v[..., None, :]  # (B,H,hd_k,hd_v)
    o = jnp.einsum("bhk,bhkv->bhv", r, s0 + u[..., None] * kv)
    s_new = w[..., None] * s0 + kv
    mu = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = (o - mu) * lax.rsqrt(var + 1e-5) * p["ln_out_scale"].astype(f32)[None]
    o = o.astype(dt) * jax.nn.silu(g.astype(f32)).astype(dt)
    out = jnp.einsum("bhk,hkd->bd", o, p["w_o"].astype(dt))
    return out, x, s_new


def rwkv6_channel_mix_step(p, x: Array, cfg, x_prev):
    dt = x.dtype
    xk = _lerp(x, x_prev, p["mu_ck"])
    xr = _lerp(x, x_prev, p["mu_cr"])
    kk = jnp.square(jax.nn.relu(jnp.einsum("bd,df->bf", xk, p["w_ck"].astype(dt))))
    vv = jnp.einsum("bf,fd->bd", kk, p["w_cv"].astype(dt))
    rr = jax.nn.sigmoid(
        jnp.einsum("bd,de->be", xr.astype(jnp.float32), p["w_cr"].astype(jnp.float32))
    ).astype(dt)
    return rr * vv, x


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================


def mamba2_decls(cfg) -> Dict[str, ParamDecl]:
    d = cfg.d_model
    di = cfg.ssm_d_inner  # 2 * d_model by default
    n = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    conv = cfg.ssm_conv
    cdim = di + 2 * n
    return {
        "w_in": ParamDecl((d, 2 * di + 2 * n + nh), ("embed", "mlp")),
        "conv_w": ParamDecl((conv, cdim), ("conv", "mlp"), init="normal", scale=0.5),
        "conv_b": ParamDecl((cdim,), ("mlp",), init="zeros"),
        "a_log": ParamDecl((nh,), ("heads",), init="zeros"),
        "dt_bias": ParamDecl((nh,), ("heads",), init="zeros"),
        "skip_d": ParamDecl((nh,), ("heads",), init="ones"),
        "norm_scale": ParamDecl((di,), ("mlp",), init="ones"),
        "w_out": ParamDecl((di, d), ("mlp", "embed")),
    }


def _causal_conv(x: Array, w: Array, b: Array, tail: Array) -> Tuple[Array, Array]:
    """Depthwise causal conv. x (B,S,C), w (K,C), tail (B,K-1,C) from the
    previous segment. Returns (y (B,S,C), new_tail)."""
    k = w.shape[0]
    xp = jnp.concatenate([tail, x], axis=1)  # (B, S+K-1, C)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    y = jax.nn.silu(y + b[None, None, :])
    new_tail = xp[:, -(k - 1) :, :] if k > 1 else tail
    return y.astype(x.dtype), new_tail


def _ssd_chunk(xh, bmat, cmat, loga, h0):
    """Exact chunked SSD (scalar per-head decay).
    xh (B,H,L,hd) = dt·x; bmat/cmat (B,L,N); loga (B,H,L) ≤ 0; h0 (B,H,N,hd)."""
    f32 = jnp.float32
    xh, bmat, cmat, loga = (t.astype(f32) for t in (xh, bmat, cmat, loga))
    l = xh.shape[2]
    ca = jnp.cumsum(loga, axis=-1)  # (B,H,L)
    ca_prev = ca - loga
    # intra: y_i = Σ_{j<=i} exp(ca_i - ca_j) (C_i·B_j) xh_j
    dmat = ca[:, :, :, None] - ca[:, :, None, :]  # (B,H,L,L) ≤ 0 on tril
    tri = jnp.tril(jnp.ones((l, l), bool))[None, None]
    cb = jnp.einsum("bin,bjn->bij", cmat, bmat)[:, None]  # (B,1,L,L)
    amat = jnp.where(tri, jnp.exp(dmat) * cb, 0.0)  # (B,H,L,L)
    y_intra = jnp.einsum("bhij,bhjv->bhiv", amat, xh)
    # inter: y_i += exp(ca_i) C_i · h0
    y_inter = jnp.einsum("bin,bhnv,bhi->bhiv", cmat, h0, jnp.exp(ca))
    # state: h_L = exp(ca_L) h0 + Σ_j exp(ca_L - ca_j) B_j xh_jᵀ
    ca_last = ca[:, :, -1:]
    bw = jnp.exp(ca_last - ca)[:, :, :, None] * bmat[:, None]  # (B,H,L,N)
    h_end = jnp.exp(ca_last)[..., None] * h0 + jnp.einsum("bhln,bhlv->bhnv", bw, xh)
    return y_intra + y_inter, h_end


def mamba2_mix(p, x: Array, cfg, conv_tail: Array, h0: Array, chunk: int = 64):
    """x (B,S,D); conv_tail (B,K-1,C); h0 (B,H,N,hd) fp32.
    Returns (out, new_tail, h_end)."""
    b, s, d = x.shape
    di, n = cfg.ssm_d_inner, cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = di // hd
    dt = x.dtype
    f32 = jnp.float32
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(dt))
    z, xin, bc, dtr = jnp.split(zxbcdt, [di, 2 * di, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, bc], axis=-1)  # (B,S,di+2n)
    conv_out, new_tail = _causal_conv(conv_in, p["conv_w"], p["conv_b"], conv_tail)
    xc, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)
    dt_a = jax.nn.softplus(dtr.astype(f32) + p["dt_bias"].astype(f32)[None, None])
    loga = -jnp.exp(p["a_log"].astype(f32))[None, None] * dt_a  # (B,S,H) ≤ 0
    xh = xc.reshape(b, s, nh, hd).astype(f32) * dt_a[..., None]  # dt·x
    xh = jnp.transpose(xh, (0, 2, 1, 3))  # (B,H,S,hd)
    loga_t = jnp.transpose(loga, (0, 2, 1))  # (B,H,S)

    if s % chunk != 0:
        chunk = s
    nc = s // chunk

    def body(state, inp):
        xc_, b_, c_, la_ = inp
        y, h_next = _ssd_chunk(xc_, b_, c_, la_, state)
        return h_next, y

    if cfg.remat:
        body = jax.checkpoint(body)  # see rwkv6_mix

    xs = xh.reshape(b, nh, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    bs_ = bmat.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3).astype(f32)
    cs_ = cmat.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3).astype(f32)
    las = loga_t.reshape(b, nh, nc, chunk).transpose(2, 0, 1, 3)
    h_end, ys = lax.scan(body, h0.astype(f32), (xs, bs_, cs_, las),
                         unroll=True if cfg.scan_unroll else 1)
    y = ys.transpose(1, 2, 0, 3, 4).reshape(b, nh, s, hd)
    y = y + p["skip_d"].astype(f32)[None, :, None, None] * jnp.transpose(
        xc.reshape(b, s, nh, hd), (0, 2, 1, 3)
    ).astype(f32)
    y = jnp.transpose(y, (0, 2, 1, 3)).reshape(b, s, di)
    # gated RMSNorm (mamba2 style)
    y = y * jax.nn.silu(z.astype(f32))
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = (y * lax.rsqrt(ms + 1e-6) * p["norm_scale"].astype(f32)[None, None]).astype(dt)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt))
    return out, new_tail, h_end


def mamba2_decode_step(p, x: Array, cfg, conv_tail: Array, h0: Array):
    """One token. x (B,D); conv_tail (B,K-1,C); h0 (B,H,N,hd)."""
    b, d = x.shape
    di, n = cfg.ssm_d_inner, cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = di // hd
    dt = x.dtype
    f32 = jnp.float32
    zxbcdt = jnp.einsum("bd,de->be", x, p["w_in"].astype(dt))
    z, xin, bc, dtr = jnp.split(zxbcdt, [di, 2 * di, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, bc], axis=-1)[:, None, :]  # (B,1,C)
    y1, new_tail = _causal_conv(conv_in, p["conv_w"], p["conv_b"], conv_tail)
    xc, bmat, cmat = jnp.split(y1[:, 0], [di, di + n], axis=-1)
    dt_a = jax.nn.softplus(dtr.astype(f32) + p["dt_bias"].astype(f32)[None])
    a = jnp.exp(-jnp.exp(p["a_log"].astype(f32))[None] * dt_a)  # (B,H)
    xh = xc.reshape(b, nh, hd).astype(f32) * dt_a[..., None]
    h_new = a[..., None, None] * h0 + bmat.astype(f32)[:, None, :, None] * xh[:, :, None, :]
    y = jnp.einsum("bn,bhnv->bhv", cmat.astype(f32), h_new)
    y = y + p["skip_d"].astype(f32)[None, :, None] * xc.reshape(b, nh, hd).astype(f32)
    y = y.reshape(b, di) * jax.nn.silu(z.astype(f32))
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = (y * lax.rsqrt(ms + 1e-6) * p["norm_scale"].astype(f32)[None]).astype(dt)
    out = jnp.einsum("be,ed->bd", y, p["w_out"].astype(dt))
    return out, new_tail, h_new
