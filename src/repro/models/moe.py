"""Mixture-of-Experts layer — top-k routing, capacity-bounded sort-based dispatch.

Dispatch is the static-shape sorted-scatter formulation (no (T, E, C) one-hot
einsum, which would be petabytes at these scales):

  1. route: top-k expert ids + renormalized gates per token
  2. group tokens (default: one group per sequence so the group dim shards over
     ('pod','data') like the batch; decode uses a single group)
  3. within each group, stable-sort the t·k slots by expert id, take the first
     C = ceil(t·k/E · capacity_factor) per expert, scatter into (E, C, D)
     buffers (overflow slots drop — standard capacity dropping)
  4. batched expert FFN: einsum over (group, E, C, D) with weights sharded on
     the 'expert' → 'model' axis (expert parallelism; XLA inserts the
     all-to-all at the group→expert reshard)
  5. combine: gather back to slots, weight by gates, segment-sum per token

Aux quantities (load-balance loss, router z-loss) are returned for training.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import shard_act
from .param import ParamDecl

Array = jax.Array


def moe_decls(cfg) -> Dict[str, ParamDecl]:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    decls = {
        "router": ParamDecl((d, e), ("embed", "expert")),
        "w_up": ParamDecl((e, d, f), ("expert", "embed", "expert_mlp")),
        "w_down": ParamDecl((e, f, d), ("expert", "expert_mlp", "embed")),
    }
    if cfg.act == "silu":
        decls["w_gate"] = ParamDecl((e, d, f), ("expert", "embed", "expert_mlp"))
    return decls


def _capacity(tokens_per_group: int, k: int, n_experts: int, factor: float) -> int:
    return max(1, int(-(-tokens_per_group * k * factor // n_experts)))


def apply_moe(
    p,
    x: Array,  # (B, S, D)
    cfg,
    capacity_factor: float = 1.25,
) -> Tuple[Array, Dict[str, Array]]:
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    dt = x.dtype

    # one group per sequence (groups shard over batch axes); decode: one group
    g = b if s > 1 else 1
    tg = (b * s) // g
    xg = x.reshape(g, tg, d)
    cap = _capacity(tg, k, e, capacity_factor)

    # -- route (fp32) --------------------------------------------------------
    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (g, tg, e)
    gate_vals, expert_idx = lax.top_k(probs, k)  # (g, tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # aux losses (Switch-style load balance + z-loss)
    me = jnp.mean(probs, axis=1)  # (g, e)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=2), axis=1
    )  # (g, e) fraction routed
    aux_loss = e * jnp.mean(jnp.sum(me * ce, axis=-1))
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # -- dispatch: sort slots by expert, position-in-expert, capacity drop ----
    # NOTE (EXPERIMENTS.md §Perf#7): the scatter below SPMD-lowers to
    # replicate + all-reduce over 'model' (~148 GB/layer on qwen3-moe). A
    # take-based inversion (gather xe[e,c] = x[token_of_slot]) was measured
    # and made total wire 2.3× WORSE — the scatter reappears transposed in
    # the backward pass. The structural fix (explicit shard_map all_to_all
    # expert parallelism) is the identified next step; see DESIGN.md §5.
    tk = tg * k
    slot_e = expert_idx.reshape(g, tk)  # (g, tk)
    slot_gate = gate_vals.reshape(g, tk).astype(dt)
    slot_tok = jnp.broadcast_to(jnp.arange(tg)[:, None], (tg, k)).reshape(tk)
    slot_tok = jnp.broadcast_to(slot_tok, (g, tk))

    order = jnp.argsort(slot_e, axis=-1, stable=True)  # (g, tk)
    se = jnp.take_along_axis(slot_e, order, axis=-1)
    stok = jnp.take_along_axis(slot_tok, order, axis=-1)
    sgate = jnp.take_along_axis(slot_gate, order, axis=-1)
    # position of each sorted slot within its expert run
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e)))(se)  # (g, e)
    pos = jnp.arange(tk)[None, :] - jnp.take_along_axis(starts, se, axis=-1)
    keep = pos < cap
    dest = jnp.where(keep, se * cap + pos, e * cap)  # out-of-range == drop

    gathered_x = jnp.take_along_axis(xg, stok[..., None], axis=1)  # (g, tk, d)
    buf = jnp.zeros((g, e * cap, d), dt)
    buf = jax.vmap(lambda bf, dst, val: bf.at[dst].set(val, mode="drop"))(
        buf, dest, gathered_x
    )
    xe = buf.reshape(g, e, cap, d)
    xe = shard_act(xe, ("batch", "expert", "expert_cap", "embed"))

    # -- expert FFN (batched over experts; expert dim sharded over 'model') ---
    up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(dt))
    if cfg.act == "silu":
        gate = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dt))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    ye = shard_act(ye, ("batch", "expert", "expert_cap", "embed"))

    # -- combine --------------------------------------------------------------
    yflat = ye.reshape(g, e * cap, d)
    slot_y = jax.vmap(lambda yf, dst: yf.at[dst, :].get(mode="fill", fill_value=0))(
        yflat, jnp.where(keep, dest, e * cap - 1)
    )  # (g, tk, d)
    slot_y = slot_y * (sgate * keep.astype(dt))[..., None]
    out = jnp.zeros((g, tg, d), dt)
    out = jax.vmap(lambda o, tok, val: o.at[tok].add(val))(out, stok, slot_y)

    aux = {"moe_aux_loss": aux_loss, "moe_z_loss": z_loss}
    return out.reshape(b, s, d), aux
