"""Transformer building blocks — pure-JAX, logical-axis-annotated, scan-friendly.

Every block provides ``<block>_decls(cfg) -> {name: ParamDecl}`` and an apply
function. Activations are annotated with logical axes via ``shard_act`` at block
boundaries; params carry logical axes in their decls. Compute runs in
``cfg.dtype`` (bf16) with fp32 master params and fp32 softmax/norm internals.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.sharding import shard_act
from .param import ParamDecl

Array = jax.Array


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_decls(d_model: int, kind: str) -> Dict[str, ParamDecl]:
    d = {"scale": ParamDecl((d_model,), ("embed",), init="ones")}
    if kind == "layernorm":
        d["bias"] = ParamDecl((d_model,), ("embed",), init="zeros")
    return d


def apply_norm(p, x: Array, kind: str, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def _rope_angles(positions: Array, head_dim: int, theta: float) -> Tuple[Array, Array]:
    """positions (...,) -> cos/sin (..., head_dim//2) fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x (B, S, H, hd), positions (B, S) -> rotated x (same dtype)."""
    head_dim = x.shape[-1]
    cos, sin = _rope_angles(positions, head_dim, theta)  # (B, S, half)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: Array, positions3: Array, theta: float, sections: Tuple[int, int, int]
) -> Array:
    """Qwen2-VL M-RoPE. positions3 (B, S, 3) = (t, h, w) ids; ``sections`` split
    head_dim//2 frequency bands among the three position streams. With
    t==h==w (text) this reduces exactly to standard RoPE."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = positions3.astype(jnp.float32)  # (B, S, 3)
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half
    )  # (half,) static
    pos_per_freq = jnp.take_along_axis(
        pos, jnp.broadcast_to(sec_id, pos.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1,
    )  # (B, S, half)
    ang = pos_per_freq * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention_decls(cfg) -> Dict[str, ParamDecl]:
    hd = cfg.resolved_head_dim
    d = {
        "wq": ParamDecl((cfg.d_model, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDecl((cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDecl((cfg.d_model, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDecl((cfg.n_heads, hd, cfg.d_model), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        d["bq"] = ParamDecl((cfg.n_heads, hd), ("heads", "head_dim"), init="zeros")
        d["bk"] = ParamDecl((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"), init="zeros")
        d["bv"] = ParamDecl((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"), init="zeros")
    return d


def _qkv(p, x: Array, cfg) -> Tuple[Array, Array, Array]:
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def _heads_unshardable(h: int, kv: int) -> bool:
    """True iff neither q-heads nor kv-heads can take the 'model' axis — the
    case where unconstrained attention replicates the full score computation
    on every model shard (qwen2-vl: 12H/2KV; whisper: 20H/20KV on 16-way)."""
    from repro.parallel.sharding import current_ctx

    ctx = current_ctx()
    if ctx is None:
        return False
    tp = ctx.mesh_shape.get("model", 1)
    return tp > 1 and h % tp != 0 and kv % tp != 0


def _sdpa(
    q: Array,  # (B, Sq, H, hd)
    k: Array,  # (B, Sk, KV, hd)
    v: Array,  # (B, Sk, KV, hd)
    *,
    causal: bool,
    window: int = 0,  # >0: sliding-window
    q_offset: Any = 0,  # absolute position of q[0] (int or traced scalar)
    kv_valid: Optional[Array] = None,  # (B, Sk) bool — valid cache slots
) -> Array:
    """Grouped-query scaled dot-product attention, fp32 softmax."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    if _heads_unshardable(h, kv):
        # sequence-parallel fallback ONLY when no head dim divides the model
        # axis (annotating shardable-head archs was measured to FIGHT natural
        # propagation and add reshard traffic — EXPERIMENTS.md §Perf H2).
        scores = shard_act(scores, ("batch", "kv_heads", "heads", "seq_q", None))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    qpos = q_offset + jnp.arange(sq)[:, None]  # (sq, 1)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    if kv_valid is not None:
        scores = jnp.where(kv_valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, sq, h, hd)


def sdpa_chunked(
    q: Array, k: Array, v: Array, *, causal: bool, window: int, q_chunk: int,
    unroll: bool = False, remat: bool = True,
) -> Array:
    """Query-chunked attention: scan over q chunks so the score matrix never
    exceeds (B, chunk, H, Sk). Used for long-sequence prefill/train."""
    b, s, h, hd = q.shape
    if s % q_chunk != 0 or s <= q_chunk:
        return _sdpa(q, k, v, causal=causal, window=window)
    nchunk = s // q_chunk
    qs = q.reshape(b, nchunk, q_chunk, h, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, args):
        i, qc = args
        out = _sdpa(qc, k, v, causal=causal, window=window, q_offset=i * q_chunk)
        return carry, out

    if remat:
        body = jax.checkpoint(body)  # don't save per-chunk probs for bwd
    _, outs = lax.scan(body, None, (jnp.arange(nchunk), qs), unroll=True if unroll else 1)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def apply_attention(
    p,
    x: Array,
    cfg,
    positions: Array,  # (B, S) or (B, S, 3) for mrope
    q_chunk: int = 0,
) -> Array:
    """Full self-attention block body (no norm/residual)."""
    q, k, v = _qkv(p, x, cfg)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    q = shard_act(q, ("batch", "seq", "heads", None))
    k = shard_act(k, ("batch", "seq", "kv_heads", None))
    v = shard_act(v, ("batch", "seq", "kv_heads", None))
    window = cfg.window if cfg.attention == "swa" else 0
    if q_chunk and x.shape[1] > q_chunk:
        o = sdpa_chunked(q, k, v, causal=cfg.causal, window=window, q_chunk=q_chunk,
                         unroll=cfg.scan_unroll, remat=cfg.remat)
    else:
        o = _sdpa(q, k, v, causal=cfg.causal, window=window)
    o = shard_act(o, ("batch", "seq", "heads", None))
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def apply_cross_attention(p, x: Array, enc: Array, cfg) -> Array:
    """Encoder-decoder cross attention (whisper). q from x, k/v from enc."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bfd,dhk->bfhk", enc, p["wk"].astype(dt))
    v = jnp.einsum("bfd,dhk->bfhk", enc, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    o = _sdpa(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))


# --- decode path (single new token against a cache) ------------------------


def init_cache_decls(cfg, batch: int, cache_len: int) -> Dict[str, ParamDecl]:
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, hd)
    axes = ("layers", "batch", "cache_seq", "kv_heads", None)
    return {
        "k": ParamDecl(shape, axes, init="zeros", dtype=jnp.bfloat16),
        "v": ParamDecl(shape, axes, init="zeros", dtype=jnp.bfloat16),
    }


def decode_attention(
    p,
    x: Array,  # (B, 1, D)
    cfg,
    k_cache: Array,  # (B, Sc, KV, hd) — this layer's cache
    v_cache: Array,
    pos: Array,  # (B,) int32 — index of the new token
) -> Tuple[Array, Array, Array]:
    """One-token attention; returns (out, new_k_cache, new_v_cache)."""
    b, _, d = x.shape
    sc = k_cache.shape[1]
    q, k, v = _qkv(p, x, cfg)  # (B, 1, H/KV, hd)
    posb = pos[:, None]  # (B, 1)
    if cfg.pos == "rope":
        q = apply_rope(q, posb, cfg.rope_theta)
        k = apply_rope(k, posb, cfg.rope_theta)
    elif cfg.pos == "mrope":
        pos3 = jnp.broadcast_to(posb[..., None], (b, 1, 3))
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    # ring-buffer write for SWA, linear write otherwise
    slot = (pos % sc) if cfg.attention == "swa" else pos  # (B,)
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, slot].set(k[:, 0])
    v_cache = v_cache.at[bidx, slot].set(v[:, 0])
    kpos = jnp.arange(sc)[None, :]
    if cfg.attention == "swa":
        # slots hold positions within the last `sc`; valid = written at least once
        valid = kpos < jnp.minimum(pos[:, None] + 1, sc)
    else:
        valid = kpos <= pos[:, None]
    o = _sdpa(q, k_cache, v_cache, causal=False, kv_valid=valid)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_decls(cfg, d_ff: Optional[int] = None) -> Dict[str, ParamDecl]:
    ff = d_ff or cfg.d_ff
    d = {
        "w_up": ParamDecl((cfg.d_model, ff), ("embed", "mlp")),
        "w_down": ParamDecl((ff, cfg.d_model), ("mlp", "embed")),
    }
    if cfg.act == "silu":  # swiglu
        d["w_gate"] = ParamDecl((cfg.d_model, ff), ("embed", "mlp"))
    return d


def apply_mlp(p, x: Array, cfg) -> Array:
    dt = x.dtype
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    if cfg.act == "silu":
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = shard_act(h, ("batch", "seq", "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def embed_decls(cfg) -> Dict[str, ParamDecl]:
    # 'embed_table' (not FSDP'd): gathers over a table whose feature dim is
    # sharded over 'data' force involuntary full-remat reshards in SPMD —
    # vocab-only sharding keeps the gather local-ish (mask + psum over model).
    v = cfg.padded_vocab
    d = {"tok": ParamDecl((v, cfg.d_model), ("vocab", "embed_table"), init="embed")}
    if not cfg.tie_embeddings:
        d["unembed"] = ParamDecl((cfg.d_model, v), ("embed_table", "vocab"))
    if cfg.pos == "learned":
        d["pos"] = ParamDecl((cfg.max_pos, cfg.d_model), (None, "embed_table"), init="embed")
    return d


def apply_embed(p, tokens: Array, cfg, positions: Optional[Array] = None) -> Array:
    x = jnp.take(p["tok"].astype(getattr(jnp, cfg.dtype)), tokens, axis=0)
    if cfg.pos == "learned":
        x = x + jnp.take(p["pos"].astype(x.dtype), positions, axis=0)
    return shard_act(x, ("batch", "seq", "embed"))


def apply_unembed(p, x: Array, cfg) -> Array:
    dt = x.dtype
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["tok"].astype(dt))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["unembed"].astype(dt))
    return shard_act(logits, ("batch", "seq", "vocab"))
