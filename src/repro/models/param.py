"""Parameter declaration system — shapes + logical axes declared once.

Every model declares its parameters as a pytree of :class:`ParamDecl`; from the
same declaration we derive
  - concrete initialized params           (training)
  - ``ShapeDtypeStruct`` abstract params  (multi-pod dry-run — no allocation)
  - ``PartitionSpec`` trees               (via `repro.parallel.sharding` rules)

Layer stacks declare a leading ``layers`` axis and are consumed by
``lax.scan`` so HLO size is depth-independent.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = no sharding)
    init: str = "normal"  # normal | zeros | ones | embed
    scale: Optional[float] = None  # stddev override for 'normal'
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def _tree_map_decl(f: Callable, tree):
    return jax.tree.map(f, tree, is_leaf=is_decl)


def abstract_params(decls, dtype_override=None):
    """ShapeDtypeStruct tree — used by the dry-run (no device allocation)."""
    return _tree_map_decl(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype_override or d.dtype), decls
    )


def init_params(key: jax.Array, decls, dtype_override=None):
    flat, treedef = jax.tree.flatten(decls, is_leaf=is_decl)
    keys = jax.random.split(key, len(flat))
    out = []
    for k, d in zip(keys, flat):
        dtype = dtype_override or d.dtype
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, dtype)
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            scale = d.scale if d.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
            if d.init == "embed":
                scale = d.scale if d.scale is not None else 0.02
            arr = (scale * jax.random.normal(k, d.shape)).astype(dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def logical_axes(decls):
    """Tree of logical-axis tuples, same structure as params."""
    return _tree_map_decl(lambda d: d.axes, decls)


def count_params(decls) -> int:
    flat, _ = jax.tree.flatten(decls, is_leaf=is_decl)
    return int(sum(int(np.prod(d.shape)) for d in flat))
