"""Sudoku via RTAC-driven MAC search — propagation does almost all the work.

    PYTHONPATH=src python examples/sudoku.py                # the classic puzzle
    PYTHONPATH=src python examples/sudoku.py GIVENS [SEED]  # a generated one,
                                                 # via the repro.problems registry

Fewer givens = harder (the generator's difficulty knob).
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import mac_solve, sudoku_csp
from repro.problems import generate

PUZZLE = np.array(
    [
        [5, 3, 0, 0, 7, 0, 0, 0, 0],
        [6, 0, 0, 1, 9, 5, 0, 0, 0],
        [0, 9, 8, 0, 0, 0, 0, 6, 0],
        [8, 0, 0, 0, 6, 0, 0, 0, 3],
        [4, 0, 0, 8, 0, 3, 0, 0, 1],
        [7, 0, 0, 0, 2, 0, 0, 0, 6],
        [0, 6, 0, 0, 0, 0, 2, 8, 0],
        [0, 0, 0, 4, 1, 9, 0, 0, 5],
        [0, 0, 0, 0, 8, 0, 0, 7, 9],
    ]
)


def main(givens=None, seed=0):
    if givens is None:
        csp = sudoku_csp(PUZZLE)
    else:
        csp = generate("sudoku", givens=givens, seed=seed)
        print(f"generated puzzle: givens={givens} seed={seed}")
    sol, stats = mac_solve(csp, engine="einsum")
    assert sol is not None, "puzzle should be solvable"
    grid = np.asarray(sol).reshape(9, 9) + 1
    for r in range(9):
        row = " ".join(str(v) for v in grid[r])
        print(row[:6] + "| " + row[6:12] + "| " + row[12:])
        if r in (2, 5):
            print("-" * 21)
    print(
        f"\n{stats.n_assignments} assignments, {stats.n_backtracks} backtracks, "
        f"mean {stats.mean_recurrences:.2f} recurrences/enforcement"
    )


if __name__ == "__main__":
    main(
        int(sys.argv[1]) if len(sys.argv) > 1 else None,
        int(sys.argv[2]) if len(sys.argv) > 2 else 0,
    )
