"""Distributed RTAC: shard the constraint tensor over a (data, model) mesh.

Runs on 8 emulated host devices (the same shard_map program runs unchanged on
a real TPU mesh): constraint-tensor x-rows sharded over 'model', a batch of
candidate domains (search nodes) over 'data'.

    PYTHONPATH=src python examples/distributed_ac.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro.core import random_csp
from repro.engines import get_engine
from repro.launch.mesh import make_mesh


def main():
    mesh = make_mesh((2, 4), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"on {jax.device_count()} devices")

    csp = random_csp(n_vars=64, dom_size=16, density=0.5, tightness=0.35, seed=0)
    B = 8
    rng = np.random.default_rng(0)
    doms = np.tile(np.asarray(csp.dom)[None], (B, 1, 1))
    for i in range(B):  # perturb: simulate B search nodes
        var = rng.integers(64)
        keep = rng.integers(16)
        doms[i, var, :] = False
        doms[i, var, keep] = True

    # prepare once: shards the constraint x-rows over 'model' and builds the
    # jitted shard_map fixpoint; the hot path ships only the domain batch
    prepared = get_engine("sharded", mesh=mesh).prepare(csp)
    res = prepared.enforce_batch(doms)  # compile+run
    res.dom.block_until_ready()
    t0 = time.perf_counter()
    res = prepared.enforce_batch(doms)
    res.dom.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"batch of {B} enforcements: {1e3*dt:.1f} ms "
          f"(consistent: {np.asarray(res.consistent).tolist()})")

    # verify against the single-device path
    ref_prepared = get_engine("einsum").prepare(csp)
    for i in range(B):
        ref = ref_prepared.enforce(doms[i])
        assert bool(ref.consistent) == bool(res.consistent[i])
        if bool(ref.consistent):
            assert (np.asarray(ref.dom) == np.asarray(res.dom[i])).all()
    print("sharded results == single-device results ✓")


if __name__ == "__main__":
    main()
