"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps.

Exercises the full production path — config system, sharded train step
(pjit + logical-axis rules), AdamW, deterministic data pipeline, async
checkpointing with auto-resume:

    PYTHONPATH=src python examples/train_lm.py --steps 200

(CPU container: ~100M params is minutes-per-100-steps; pass --tiny for a
seconds-scale sanity run.)
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.launch.train import train
from repro.models.model import build_model
from repro.models.param import count_params

# ~100M-parameter llama-style config (same family as granite-8b)
LM_100M = ModelConfig(
    name="lm-100m",
    family="dense",
    n_layers=10,
    d_model=640,
    n_heads=10,
    n_kv_heads=5,
    d_ff=1792,
    vocab=32768,
    source="example driver (~100M)",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    cfg = LM_100M
    if args.tiny:
        cfg = cfg.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                          d_ff=256, vocab=2048, name="lm-tiny")
    n = count_params(build_model(cfg).decls())
    print(f"[example] {cfg.name}: {n/1e6:.1f}M params")

    import repro.configs as C

    C.REGISTRY[cfg.name] = cfg  # register the example config
    losses = train(
        cfg.name,
        steps=args.steps,
        seq_len=256,
        global_batch=8,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
        log_every=10,
    )
    k = max(len(losses) // 10, 1)
    print(f"[example] loss first-{k}-mean={sum(losses[:k])/k:.3f} "
          f"last-{k}-mean={sum(losses[-k:])/k:.3f}")
    assert sum(losses[-k:]) < sum(losses[:k]), "loss should decrease"


if __name__ == "__main__":
    main()
