"""N-queens via MAC search — RTAC vs AC3 engines side by side.

    PYTHONPATH=src python examples/nqueens_search.py [n]
"""

import sys
import time

sys.path.insert(0, "src")

from repro.core import check_solution, mac_solve, nqueens_csp


def board(sol):
    n = len(sol)
    return "\n".join(
        " ".join("Q" if sol[c] == r else "." for c in range(n)) for r in range(n)
    )


def main(n: int = 10):
    csp = nqueens_csp(n)
    for engine in ("einsum", "ac3"):
        t0 = time.perf_counter()
        sol, stats = mac_solve(csp, engine=engine)
        dt = time.perf_counter() - t0
        assert sol is not None and check_solution(csp, sol)
        if engine == "ac3":
            unit, mean = "revisions", stats.mean_revisions
        else:
            unit, mean = "recurrences", stats.mean_recurrences
        print(
            f"[{engine:6s}] {n}-queens solved in {dt:.2f}s, "
            f"{stats.n_assignments} assignments, "
            f"mean {mean:.1f} {unit}/enforcement, "
            f"mean {stats.mean_enforce_ms:.2f} ms/enforcement"
        )
    print(board(sol))


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 10)
