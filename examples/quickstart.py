"""Quickstart: enforce arc consistency on a CSP with RTAC, then solve it.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import check_solution, mac_solve, random_csp, solve_many
from repro.engines import get_engine
from repro.problems import generate_batch


def main():
    # a random network (paper §5.2 generator), parameterized to be satisfiable
    csp = random_csp(n_vars=50, dom_size=12, density=0.25, tightness=0.2, seed=42)
    print(f"CSP: {csp.n_vars} vars, |dom|={csp.dom_size}, "
          f"{int(np.asarray(csp.mask).sum()) // 2} constraints")

    # 1. prepare the network once, then enforce arc consistency (Eq. 1
    #    fixpoint, device-resident) against the prepared form
    prepared = get_engine("einsum").prepare(csp)
    res = prepared.enforce()
    removed = int(np.asarray(csp.dom).sum() - np.asarray(res.dom).sum())
    print(f"RTAC: consistent={bool(res.consistent)} "
          f"recurrences={int(res.n_recurrences)} values_removed={removed}")

    # 2. full MAC backtrack search (paper Alg. 2); all candidate values of the
    #    branching variable are enforced in ONE batched dispatch by default
    sol, stats = mac_solve(csp, engine="einsum")
    if sol is None:
        print("no solution")
    else:
        assert check_solution(csp, sol)
        print(f"solution found: {sol[:10]}... "
              f"({stats.n_assignments} assignments, "
              f"mean {stats.mean_recurrences:.2f} recurrences/enforcement)")

    # 3. generate a whole workload (repro.problems registry) and solve all
    #    instances as ONE lockstep portfolio — every round is a single
    #    enforce_many dispatch against the stacked prepared networks, and
    #    each result is identical to solving that instance alone
    csps = generate_batch("model_rb", 16, n=16, hardness=1.0, seed=7)
    sols, many_stats = solve_many(csps, engine="einsum")
    solved = sum(s is not None for s in sols)
    print(f"workload: {solved}/{len(csps)} Model-RB instances satisfiable "
          f"at the phase transition, "
          f"{sum(st.n_assignments for st in many_stats)} assignments total")


if __name__ == "__main__":
    main()
