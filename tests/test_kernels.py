"""Pallas kernel validation — interpret-mode vs the pure-jnp oracle (ref.py).

Per instructions: sweep shapes/dtypes and assert allclose (here: exact equality
— the kernels are boolean) against the oracle, plus hypothesis-random CSPs and
end-to-end fixpoint equality. The stacked (instance-axis-in-the-grid) kernel
variants are validated row-by-row: every row must equal the oracle applied to
that row's OWN network.

The whole module is `pytest.mark.pallas`: interpret mode executes kernel
bodies in Python, so these run in CI's dedicated pallas leg, not the main
tier-1 matrix.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import enforce, random_csp
from repro.core.engine import pad_changed, pad_dom
from repro.engines import get_engine
from repro.kernels import ops
from repro.kernels.ref import (
    pack_bits_ref,
    revise_packed_ref,
    revise_ref,
)

pytestmark = pytest.mark.pallas

SHAPE_SWEEP = [
    # (n_vars, dom_size, block_rx, block_ry)
    (4, 3, 4, 4),
    (8, 5, 8, 8),
    (10, 6, 8, 8),
    (16, 8, 8, 8),
    (16, 8, 4, 8),
    (16, 8, 8, 4),
    (24, 33, 8, 8),  # d > 32: multi-word bitpack
    (12, 64, 4, 4),
]


def _changed_patterns(n, seed):
    rng = np.random.default_rng(seed)
    return [
        np.ones(n, bool),
        rng.random(n) < 0.5,
        np.eye(n, dtype=bool)[rng.integers(n)],
    ]


@pytest.mark.parametrize("n,d,brx,bry", SHAPE_SWEEP)
def test_dense_kernel_matches_oracle(n, d, brx, bry):
    csp = random_csp(n, d, density=0.6, tightness=0.4, seed=n * 100 + d)
    net, dom_p, (n_p, d_p) = ops.prepare_dense(csp, brx, bry)
    rf = ops._dense_revise_fn(n_p, d_p, brx, bry, True)
    for changed in _changed_patterns(n, seed=d):
        ch = jnp.asarray(changed)
        oracle = revise_ref(csp.cons, csp.mask, csp.dom, ch)
        got = rf(net, dom_p, jnp.pad(ch, (0, n_p - n)))[:n, :d]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))


@pytest.mark.parametrize("n,d,brx,bry", SHAPE_SWEEP)
def test_packed_kernel_matches_oracle(n, d, brx, bry):
    csp = random_csp(n, d, density=0.6, tightness=0.4, seed=n * 100 + d)
    net, dom_p, (n_p, d_p, w) = ops.prepare_packed(csp, brx, bry)
    rf = ops._packed_revise_fn(n_p, d_p, w, brx, bry, True)
    for changed in _changed_patterns(n, seed=d):
        ch = jnp.asarray(changed)
        oracle = revise_ref(csp.cons, csp.mask, csp.dom, ch)
        got = rf(net, dom_p, jnp.pad(ch, (0, n_p - n)))[:n, :d]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))


# --- stacked kernels: R rows, each against its OWN network -------------------

STACK_SWEEP = [
    (8, 5, 8, 8),
    (16, 8, 8, 8),
    (16, 8, 4, 8),
    (24, 33, 8, 8),  # d > 32: multi-word bitpack
]


def _stacked_fixture(n, d, brx, bry, prepare):
    """3 networks, 5 rows via idx [2,0,1,2,0], mixed changed patterns."""
    csps = [random_csp(n, d, 0.6, 0.4, seed=300 + i) for i in range(3)]
    prepared = [prepare(c, brx, bry) for c in csps]
    dims = prepared[0][2]
    cons_g = jnp.stack([p[0][0] for p in prepared])
    mask_g = jnp.stack([p[0][1] for p in prepared])
    idx = np.array([2, 0, 1, 2, 0], np.int32)
    rng = np.random.default_rng(n * 7 + d)
    doms = np.stack([np.asarray(csps[j].dom) for j in idx])
    changed = rng.random((len(idx), n)) < 0.5
    changed[0] = True  # one all-changed row (the root-propagation shape)
    return csps, (cons_g, mask_g), dims, idx, doms, changed


@pytest.mark.parametrize("n,d,brx,bry", STACK_SWEEP)
def test_stacked_dense_rows_match_oracle(n, d, brx, bry):
    csps, (cons_g, mask_g), (n_p, d_p), idx, doms, changed = _stacked_fixture(
        n, d, brx, bry, ops.prepare_dense
    )
    rf = ops._dense_rows_fn(n_p, d_p, brx, bry, True)
    dom_p = pad_dom(jnp.asarray(doms), n_p, d_p)
    ch_p = pad_changed(jnp.asarray(changed), n, n_p, batch=(len(idx),))
    got = np.asarray(rf((cons_g[idx], mask_g[idx]), dom_p, ch_p))
    for row, j in enumerate(idx):
        oracle = revise_ref(
            csps[j].cons, csps[j].mask, jnp.asarray(doms[row]), jnp.asarray(changed[row])
        )
        np.testing.assert_array_equal(got[row, :n, :d], np.asarray(oracle))


@pytest.mark.parametrize("n,d,brx,bry", STACK_SWEEP)
def test_stacked_packed_rows_match_oracle(n, d, brx, bry):
    csps, (cons_g, mask_g), (n_p, d_p, w), idx, doms, changed = _stacked_fixture(
        n, d, brx, bry, ops.prepare_packed
    )
    rf = ops._packed_rows_fn(n_p, d_p, w, brx, bry, True)
    dom_p = pad_dom(jnp.asarray(doms), n_p, d_p)
    ch_p = pad_changed(jnp.asarray(changed), n, n_p, batch=(len(idx),))
    got = np.asarray(rf((cons_g[idx], mask_g[idx]), dom_p, ch_p))
    for row, j in enumerate(idx):
        oracle = revise_ref(
            csps[j].cons, csps[j].mask, jnp.asarray(doms[row]), jnp.asarray(changed[row])
        )
        np.testing.assert_array_equal(got[row, :n, :d], np.asarray(oracle))


def test_enforce_rows_generic_matches_solo_recurrence_counts():
    """The stacked fixpoint freezes converged/wiped-out rows: per-row domains,
    verdicts AND recurrence counts equal solo `enforce_generic` runs even
    though the while_loop runs until the slowest row converges."""
    n, d, brx, bry = 10, 6, 8, 8
    csps = [random_csp(n, d, 0.7, 0.5, seed=40 + i) for i in range(3)]
    prepared = [ops.prepare_packed(c, brx, bry) for c in csps]
    n_p, d_p, w = prepared[0][2]
    tables = (
        jnp.stack([p[0][0] for p in prepared]),
        jnp.stack([p[0][1] for p in prepared]),
    )
    rf = ops._packed_rows_fn(n_p, d_p, w, brx, bry, True)
    idx = np.array([0, 1, 2, 1], np.int32)
    doms = np.stack([np.asarray(csps[j].dom) for j in idx])
    doms[3, 0, 1:] = False  # a row that starts near wipeout
    from repro.core import rtac

    res = rtac.enforce_rows_generic(
        tables,
        pad_dom(jnp.asarray(doms), n_p, d_p),
        pad_changed(None, n, n_p, batch=(len(idx),)),
        jnp.asarray(idx),
        revise_rows_fn=rf,
    )
    for row, j in enumerate(idx):
        solo = rtac.enforce_generic(
            prepared[j][0],
            pad_dom(jnp.asarray(doms[row]), n_p, d_p),
            pad_changed(None, n, n_p),
            revise_fn=ops._packed_revise_fn(n_p, d_p, w, brx, bry, True),
        )
        assert bool(np.asarray(res.consistent)[row]) == bool(np.asarray(solo.consistent))
        assert int(np.asarray(res.n_recurrences)[row]) == int(np.asarray(solo.n_recurrences))
        if bool(np.asarray(solo.consistent)):
            np.testing.assert_array_equal(
                np.asarray(res.dom)[row], np.asarray(solo.dom)
            )


def test_packed_oracle_matches_dense_oracle():
    """The bitpacked formulation itself (ref-level) is equivalent."""
    csp = random_csp(9, 37, density=0.7, tightness=0.5, seed=11)
    ch = jnp.ones((9,), jnp.bool_)
    dense = revise_ref(csp.cons, csp.mask, csp.dom, ch)
    cons_pk = pack_bits_ref(csp.cons)
    dom_pk = pack_bits_ref(csp.dom)
    packed = revise_packed_ref(cons_pk, csp.mask, dom_pk, ch)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(packed))


def test_pack_bits_roundtrip_values():
    bits = jnp.asarray(np.random.default_rng(0).random((5, 70)) < 0.5)
    words = pack_bits_ref(bits)
    assert words.shape == (5, 3)
    # unpack manually and compare
    un = (
        (words[..., :, None] >> jnp.arange(32, dtype=jnp.uint32)) & 1
    ).astype(bool).reshape(5, 96)[:, :70]
    np.testing.assert_array_equal(np.asarray(un), np.asarray(bits))


@settings(max_examples=15, deadline=None)
@given(
    st.integers(3, 12),
    st.integers(2, 9),
    st.floats(0.2, 1.0),
    st.floats(0.2, 0.7),
    st.integers(0, 999),
)
def test_end_to_end_kernel_enforcement(n, d, dens, tight, seed):
    csp = random_csp(n, d, dens, tight, seed)
    ref = enforce(csp.cons, csp.mask, csp.dom)
    for engine in ("pallas_dense", "pallas_packed"):
        got = get_engine(engine).prepare(csp).enforce()
        assert bool(got.consistent) == bool(ref.consistent)
        assert int(got.n_recurrences) == int(ref.n_recurrences)
        if bool(ref.consistent):
            np.testing.assert_array_equal(np.asarray(got.dom), np.asarray(ref.dom))
