"""Pallas kernel validation — interpret-mode vs the pure-jnp oracle (ref.py).

Per instructions: sweep shapes/dtypes and assert allclose (here: exact equality
— the kernels are boolean) against the oracle, plus hypothesis-random CSPs and
end-to-end fixpoint equality.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import enforce, random_csp
from repro.engines import get_engine
from repro.kernels import ops
from repro.kernels.ref import (
    pack_bits_ref,
    revise_packed_ref,
    revise_ref,
)

SHAPE_SWEEP = [
    # (n_vars, dom_size, block_rx, block_ry)
    (4, 3, 4, 4),
    (8, 5, 8, 8),
    (10, 6, 8, 8),
    (16, 8, 8, 8),
    (16, 8, 4, 8),
    (16, 8, 8, 4),
    (24, 33, 8, 8),  # d > 32: multi-word bitpack
    (12, 64, 4, 4),
]


def _changed_patterns(n, seed):
    rng = np.random.default_rng(seed)
    return [
        np.ones(n, bool),
        rng.random(n) < 0.5,
        np.eye(n, dtype=bool)[rng.integers(n)],
    ]


@pytest.mark.parametrize("n,d,brx,bry", SHAPE_SWEEP)
def test_dense_kernel_matches_oracle(n, d, brx, bry):
    csp = random_csp(n, d, density=0.6, tightness=0.4, seed=n * 100 + d)
    net, dom_p, (n_p, d_p) = ops.prepare_dense(csp, brx, bry)
    rf = ops._dense_revise_fn(n_p, d_p, brx, bry, True)
    for changed in _changed_patterns(n, seed=d):
        ch = jnp.asarray(changed)
        oracle = revise_ref(csp.cons, csp.mask, csp.dom, ch)
        got = rf(net, dom_p, jnp.pad(ch, (0, n_p - n)))[:n, :d]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))


@pytest.mark.parametrize("n,d,brx,bry", SHAPE_SWEEP)
def test_packed_kernel_matches_oracle(n, d, brx, bry):
    csp = random_csp(n, d, density=0.6, tightness=0.4, seed=n * 100 + d)
    net, dom_p, (n_p, d_p, w) = ops.prepare_packed(csp, brx, bry)
    rf = ops._packed_revise_fn(n_p, d_p, w, brx, bry, True)
    for changed in _changed_patterns(n, seed=d):
        ch = jnp.asarray(changed)
        oracle = revise_ref(csp.cons, csp.mask, csp.dom, ch)
        got = rf(net, dom_p, jnp.pad(ch, (0, n_p - n)))[:n, :d]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))


def test_packed_oracle_matches_dense_oracle():
    """The bitpacked formulation itself (ref-level) is equivalent."""
    csp = random_csp(9, 37, density=0.7, tightness=0.5, seed=11)
    ch = jnp.ones((9,), jnp.bool_)
    dense = revise_ref(csp.cons, csp.mask, csp.dom, ch)
    cons_pk = pack_bits_ref(csp.cons)
    dom_pk = pack_bits_ref(csp.dom)
    packed = revise_packed_ref(cons_pk, csp.mask, dom_pk, ch)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(packed))


def test_pack_bits_roundtrip_values():
    bits = jnp.asarray(np.random.default_rng(0).random((5, 70)) < 0.5)
    words = pack_bits_ref(bits)
    assert words.shape == (5, 3)
    # unpack manually and compare
    un = (
        (words[..., :, None] >> jnp.arange(32, dtype=jnp.uint32)) & 1
    ).astype(bool).reshape(5, 96)[:, :70]
    np.testing.assert_array_equal(np.asarray(un), np.asarray(bits))


@settings(max_examples=15, deadline=None)
@given(
    st.integers(3, 12),
    st.integers(2, 9),
    st.floats(0.2, 1.0),
    st.floats(0.2, 0.7),
    st.integers(0, 999),
)
def test_end_to_end_kernel_enforcement(n, d, dens, tight, seed):
    csp = random_csp(n, d, dens, tight, seed)
    ref = enforce(csp.cons, csp.mask, csp.dom)
    for engine in ("pallas_dense", "pallas_packed"):
        got = get_engine(engine).prepare(csp).enforce()
        assert bool(got.consistent) == bool(ref.consistent)
        assert int(got.n_recurrences) == int(ref.n_recurrences)
        if bool(ref.consistent):
            np.testing.assert_array_equal(np.asarray(got.dom), np.asarray(ref.dom))
