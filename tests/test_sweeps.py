"""Sweep harness tests: spec round-trips, grid determinism, runner resume,
and the report golden path — all CPU-tiny and engine-light."""

import json

import pytest

from repro.sweeps import (
    SCHEMA,
    SweepSpec,
    load_cells,
    load_spec,
    loads_toml,
    run_spec,
    sweep_dir,
)
from repro.sweeps.spec import _parse_toml_subset, available_specs


def _tiny_spec(**overrides):
    doc = {
        "schema": SCHEMA,
        "name": "t_tiny",
        "title": "tiny",
        "mode": "solve_many",
        "seed": 3,
        "replicates": 2,
        "problem": {
            "family": "random_binary",
            "knobs": {"n": [6, 8], "tightness": [0.2, 0.3], "d": 4,
                      "density": 0.5},
        },
        "solver": {"engine": "einsum"},
    }
    doc.update(overrides)
    return SweepSpec.from_doc(doc)


# --------------------------------------------------------------------------
# TOML subset parser + round-trip
# --------------------------------------------------------------------------


def test_toml_round_trip_both_parsers():
    """dumps_toml output parses identically through tomllib (when present)
    and the fallback subset parser — the 3.10 CI leg uses the fallback."""
    spec = _tiny_spec()
    text = spec.to_toml()
    via_default = loads_toml(text)          # tomllib on 3.11+, fallback on 3.10
    via_fallback = _parse_toml_subset(text)  # always the fallback
    assert via_default == via_fallback
    assert SweepSpec.from_doc(via_fallback) == spec


def test_toml_subset_scalars_arrays_comments():
    doc = _parse_toml_subset(
        '\n'.join([
            '# leading comment',
            'name = "x"  # trailing comment',
            'count = 3',
            'ratio = 0.5',
            'flag = true',
            'items = [1, 2, 3]',
            'mixed = ["a", "b"]',
            '',
            '[table]',
            'k = "v"',
            '[table.sub]',
            'j = 2',
        ])
    )
    assert doc == {
        "name": "x", "count": 3, "ratio": 0.5, "flag": True,
        "items": [1, 2, 3], "mixed": ["a", "b"],
        "table": {"k": "v", "sub": {"j": 2}},
    }


def test_toml_subset_rejects_garbage():
    for bad in ("just words", "[unclosed", 'k = "no end', "k ="):
        with pytest.raises(ValueError):
            _parse_toml_subset(bad)


def test_committed_specs_load_and_expand():
    names = available_specs()
    assert {"model_rb_phase", "recurrence_density", "service_capacity",
            "cache_pool", "smoke"} <= set(names)
    for name in names:
        spec = load_spec(name)
        cells = spec.cells()
        assert cells, name
        # to_toml -> from_toml is identity for every committed spec
        assert SweepSpec.from_toml(spec.to_toml()) == spec


# --------------------------------------------------------------------------
# deterministic grid expansion
# --------------------------------------------------------------------------


def test_grid_is_deterministic_and_sorted():
    """Byte-identical cell list on re-expansion, independent of knob
    declaration order in the file."""
    a = _tiny_spec()
    ids = [c.cell_id for c in a.cells()]
    assert ids == [c.cell_id for c in a.cells()]
    assert len(set(ids)) == len(ids) == 4
    # same knobs, reversed declaration order -> same grid
    b = _tiny_spec(problem={
        "family": "random_binary",
        "knobs": {"density": 0.5, "d": 4, "tightness": [0.2, 0.3],
                  "n": [6, 8]},
    })
    assert [c.cell_id for c in b.cells()] == ids


def test_workload_seed_ignores_engine():
    spec = SweepSpec.from_doc({
        "schema": SCHEMA, "name": "t_seed", "mode": "assignments",
        "problem": {"family": "random_binary", "knobs": {"n": [6]}},
        "solver": {"engine": ["einsum", "ac3"], "n_assignments": 2},
    })
    cells = spec.cells()
    assert len(cells) == 2
    assert spec.workload_seed(cells[0]) == spec.workload_seed(cells[1])


def test_spec_validation_errors():
    with pytest.raises(ValueError):
        _tiny_spec(mode="nope")
    with pytest.raises(TypeError):  # unknown generator knob
        _tiny_spec(problem={"family": "random_binary",
                            "knobs": {"bogus": [1, 2]}})
    with pytest.raises(ValueError):  # duplicate knob across tables
        _tiny_spec(solver={"engine": "einsum", "n": 4})
    with pytest.raises(ValueError):  # service mode needs rate
        SweepSpec.from_doc({
            "schema": SCHEMA, "name": "t_svc", "mode": "service",
            "service": {"families": ["model_rb"], "duration": 1.0},
        })


# --------------------------------------------------------------------------
# resumable runner
# --------------------------------------------------------------------------


def test_runner_resume_after_interrupt(tmp_path):
    """Interrupting a sweep (simulated by truncating cells.jsonl) and
    re-running executes only the missing cells — no duplicates."""
    spec = _tiny_spec()
    d = run_spec(spec, out_root=tmp_path, progress=None)
    cells_path = d / "cells.jsonl"
    lines = cells_path.read_text().splitlines(keepends=True)
    assert len(lines) == 1 + 4  # header + one record per cell
    full = load_cells(cells_path)

    # interrupt: keep header + 2 records + a torn partial third line
    cells_path.write_text("".join(lines[:3]) + lines[3][: len(lines[3]) // 2])
    assert len(load_cells(cells_path)) == 2  # torn tail tolerated

    run_spec(spec, out_root=tmp_path, progress=None)
    resumed = load_cells(cells_path)
    ids = [r["cell"] for r in resumed]
    assert sorted(ids) == sorted(r["cell"] for r in full)
    assert len(set(ids)) == len(ids) == 4
    # identical params+seed produce identical deterministic metrics
    by_id_full = {r["cell"]: r for r in full}
    for r in resumed:
        assert r["seed"] == by_id_full[r["cell"]]["seed"]
        assert r["metrics"]["solve_rate"] == \
            by_id_full[r["cell"]]["metrics"]["solve_rate"]


def test_runner_refuses_changed_spec(tmp_path):
    spec = _tiny_spec()
    run_spec(spec, out_root=tmp_path, progress=None)
    changed = _tiny_spec(seed=99)
    with pytest.raises(RuntimeError, match="different spec"):
        run_spec(changed, out_root=tmp_path, progress=None)
    # fresh=True wipes and reruns the new grid
    d = run_spec(changed, out_root=tmp_path, fresh=True, progress=None)
    assert all(r["seed"] != s for r, s in zip(
        load_cells(d / "cells.jsonl"),
        [spec.workload_seed(c) for c in spec.cells()],
    ))


def test_record_schema_and_obs_delta(tmp_path):
    spec = _tiny_spec()
    d = run_spec(spec, out_root=tmp_path, progress=None)
    for rec in load_cells(d / "cells.jsonl"):
        assert rec["schema"] == SCHEMA
        assert set(rec) >= {"cell", "params", "seed", "metrics", "obs",
                            "cell_seconds"}
        m = rec["metrics"]
        assert 0.0 <= m["solve_rate"] <= 1.0
        assert m["n_instances"] == spec.replicates
        # per-cell obs delta scoped that cell's driver work
        assert rec["obs"]["counters"].get("driver.rounds", 0) > 0
    assert sweep_dir(spec, tmp_path) == d
    assert (d / "spec.toml").exists()


# --------------------------------------------------------------------------
# report: figures + golden section from fixture artifacts
# --------------------------------------------------------------------------


def _fixture_records(spec, metric_rows):
    """Minimal cell records for report tests."""
    recs = []
    for i, (params, metrics) in enumerate(metric_rows):
        recs.append({
            "schema": SCHEMA, "sweep": spec.name, "cell": str(i),
            "params": params, "seed": i, "replicates": spec.replicates,
            "cell_seconds": 0.1, "metrics": metrics, "obs": {},
        })
    return recs


def test_report_section_golden_and_deterministic():
    """A claim section built from fixture records is stable across calls and
    carries figure, verdict, and spec — the byte-stability the CI drift gate
    (`check_report`) relies on."""
    from repro.sweeps.report import CLAIMS, claim_section

    claim = next(c for c in CLAIMS if c.key == "phase-transition")
    spec = load_spec(claim.sweep)
    rows = []
    for n in (10, 14):
        for h, sr in ((0.6, 1.0), (1.0, 0.5), (1.4, 0.0)):
            rows.append((
                {"n": n, "hardness": h, "engine": "einsum"},
                {"solve_rate": sr, "median_assignments": 4.0,
                 "median_latency_ms": 1.0},
            ))
    records = _fixture_records(spec, rows)
    sec1 = claim_section(claim, spec, records, 3, "figs")
    sec2 = claim_section(claim, spec, records, 3, "figs")
    assert sec1 == sec2  # byte-identical regeneration
    assert "**Verdict: PASS**" in sec1
    assert "figs/model_rb_solve_rate.svg" in sec1
    assert "```toml" in sec1 and claim.sweep in sec1
    # figures are pure functions of the records
    fig = claim.figures[0]
    assert fig.build(records, spec) == fig.build(records, spec)
    svg = fig.build(records, spec)
    assert svg.startswith("<svg") and svg.rstrip().endswith("</svg>")


def test_report_verdict_deviates_on_bad_data():
    from repro.sweeps.report import CLAIMS, claim_section

    claim = next(c for c in CLAIMS if c.key == "phase-transition")
    spec = load_spec(claim.sweep)
    rows = [(
        {"n": 10, "hardness": 1.4, "engine": "einsum"},
        {"solve_rate": 0.9, "median_assignments": 1.0,
         "median_latency_ms": 1.0},  # solved deep in the UNSAT region
    )]
    sec = claim_section(claim, spec, _fixture_records(spec, rows), 3, "figs")
    assert "**Verdict: DEVIATES**" in sec


def test_committed_results_pass_drift_gate():
    """The committed results/ + RESULTS.md regenerate byte-identically —
    exactly what CI's sweep-smoke leg asserts."""
    from repro.sweeps.report import check_report
    from repro.sweeps.runner import DEFAULT_OUT_ROOT

    if not DEFAULT_OUT_ROOT.exists():
        pytest.skip("no committed results/ (fresh checkout before first run)")
    assert check_report() == []


def test_line_chart_guardrails():
    from repro.sweeps import Series, line_chart

    with pytest.raises(ValueError, match="at least one"):
        line_chart([], title="t", xlabel="x", ylabel="y")
    too_many = [Series(str(i), [0, 1], [0, i]) for i in range(5)]
    with pytest.raises(ValueError, match="palette"):
        line_chart(too_many, title="t", xlabel="x", ylabel="y")
    svg = line_chart(
        [Series("a", [1, 2, 4], [1.0, 10.0, 100.0]),
         Series("b", [1, 2, 4], [2.0, 3.0, 4.0])],
        title="t", xlabel="x", ylabel="y", yscale="log",
        refline=(50.0, "SLO"),
    )
    assert svg == line_chart(  # deterministic output
        [Series("a", [1, 2, 4], [1.0, 10.0, 100.0]),
         Series("b", [1, 2, 4], [2.0, 3.0, 4.0])],
        title="t", xlabel="x", ylabel="y", yscale="log",
        refline=(50.0, "SLO"),
    )
    assert "SLO" in svg and "#d03b3b" in svg  # labelled threshold line
    assert svg.count("<circle") == 6  # surface-ringed markers per point


def test_registry_scope_isolates_cells():
    from repro import obs

    obs.counter_add("t_scope.outer", 2.0)
    with obs.REGISTRY.scope() as scope:
        obs.counter_add("t_scope.inner", 3.0)
        obs.observe("t_scope.h", 1.0)
        obs.observe("t_scope.h", 5.0)
    delta = scope.delta()
    assert delta["counters"].get("t_scope.inner") == 3.0
    assert "t_scope.outer" not in delta["counters"]
    assert delta["histograms"]["t_scope.h"]["count"] == 2
    # the scope never mutates the registry itself
    assert json.dumps(obs.snapshot())  # still a valid full snapshot
