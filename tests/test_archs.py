"""Per-architecture smoke tests (assigned requirement): reduced same-family
configs, one forward + one train step + one decode step on CPU, asserting
output shapes and finiteness; decode-vs-teacher-forcing consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.configs.base import ShapeSpec
from repro.launch.mesh import host_device_mesh
from repro.launch.steps import TrainState, build_train_step, make_optimizer
from repro.models.model import build_model, make_inputs
from repro.parallel.sharding import make_ctx

SHAPE = ShapeSpec("smoke", 16, 2, "train")


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = smoke_config(get_config(request.param))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_inputs(cfg, SHAPE)
    return request.param, cfg, model, params, batch


def test_forward_shape_and_finite(arch_setup):
    arch, cfg, model, params, batch = arch_setup
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (SHAPE.global_batch, SHAPE.seq_len, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), arch


def test_train_step_decreases_nothing_nan(arch_setup):
    arch, cfg, model, params, batch = arch_setup
    mesh = host_device_mesh(1, 1)
    ctx = make_ctx(mesh)
    jit_step, _, _ = build_train_step(cfg, SHAPE, ctx, microbatches=1)
    opt = make_optimizer()
    # the step donates its input state — give it a copy so the module-scoped
    # fixture params survive for the decode test, and snapshot for the delta
    before = jax.tree.map(lambda p: np.asarray(p).copy(), params)
    tr_params = jax.tree.map(jnp.copy, params)
    state = TrainState(params=tr_params, opt=opt.init(tr_params))
    state, metrics = jit_step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(
            lambda p, q: float(np.sum(np.abs(p - np.asarray(q)))), before, state.params
        ),
    )
    assert delta > 0, arch


def test_decode_matches_forward(arch_setup):
    arch, cfg, model, params, batch = arch_setup
    s = SHAPE.seq_len
    logits, _ = jax.jit(model.forward)(params, batch)
    cache = model.init_cache(batch=SHAPE.global_batch, cache_len=s)
    if cfg.family == "encdec":
        cache = model.prefill_encdec_cache(params, cache, batch["enc_embed"])
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(s):
        lg, cache = step(params, cache, batch["tokens"][:, t])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1).astype(jnp.float32)
    ref = logits.astype(jnp.float32)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    rel = float(jnp.max(jnp.abs(dec - ref))) / scale
    # attention archs are exact; ssm (bf16 chunk-order) gets tolerance; moe
    # more so — capacity-based token dropping differs between prefill and
    # decode, so a few positions legitimately route differently
    tol = 0.25 if cfg.n_experts else (0.12 if cfg.ssm else 1e-3)
    assert rel < tol, (arch, rel)


def test_microbatched_train_matches_unbatched():
    cfg = smoke_config(get_config("granite-8b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_inputs(cfg, SHAPE)
    mesh = host_device_mesh(1, 1)
    opt = make_optimizer()

    losses = {}
    for m in (1, 2):
        ctx = make_ctx(mesh)
        jit_step, _, _ = build_train_step(cfg, SHAPE, ctx, microbatches=m)
        p = jax.tree.map(jnp.copy, params)  # the step donates its state
        state = TrainState(params=p, opt=opt.init(p))
        _, metrics = jit_step(state, batch)
        losses[m] = float(metrics["loss"])
    assert abs(losses[1] - losses[2]) / abs(losses[1]) < 2e-2, losses
