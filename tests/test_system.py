"""End-to-end behaviour tests for the paper's system (RTAC pipeline)."""

import numpy as np
import jax.numpy as jnp

from repro.core import (
    check_solution,
    enforce,
    enforce_ac3,
    mac_solve,
    random_csp,
)


def test_paper_pipeline_end_to_end():
    """Generate (paper §5.2) -> enforce (Alg. 1) -> search (Alg. 2) -> verify."""
    csp = random_csp(n_vars=30, dom_size=8, density=0.4, tightness=0.25, seed=0)
    res = enforce(csp.cons, csp.mask, csp.dom)
    assert bool(res.consistent)
    sol, stats = mac_solve(csp, engine="einsum")
    assert sol is not None and check_solution(csp, sol)
    assert stats.mean_recurrences < 8


def test_recurrences_much_smaller_than_revisions():
    """The paper's headline claim (Table 1): #Recurrence << #Revision, and
    #Recurrence stays ~flat as density grows. Runs through the sweep
    harness's assignments mode — the committed ``recurrence_density`` study
    uses this exact cell executor."""
    from repro.sweeps import SweepSpec
    from repro.sweeps.runner import _run_assignments_cell

    spec = SweepSpec(
        name="t_table1", mode="assignments", replicates=1,
        problem={
            "family": "random_binary",
            "knobs": {"n": 100, "d": 20, "tightness": 0.3,
                      "density": [0.25, 0.75]},
        },
        solver={"engine": ["einsum", "ac3"], "n_assignments": 5,
                "batch_timing": False},
    )
    counts = {}  # (engine, density) -> mean count
    for cell in spec.cells():
        # engine is excluded from the workload seed, so both engines
        # enforce the same sampled sites of the same instance
        m = _run_assignments_cell(spec, cell, spec.workload_seed(cell))
        assert m["roots_consistent"] == m["n_instances"], m
        flat = cell.flat()
        counts[(flat["engine"], flat["density"])] = m["mean_count"]
    recs = [counts[("einsum", d)] for d in (0.25, 0.75)]
    revs = [counts[("ac3", d)] for d in (0.25, 0.75)]
    assert all(k <= 6 for k in recs), recs
    assert all(r > 10 * k for r, k in zip(revs, recs)), (revs, recs)
    # revisions grow with density; recurrences roughly flat (paper Table 1)
    assert revs[1] > revs[0]
    assert abs(recs[1] - recs[0]) < 3.0


def test_sharded_enforcer_multidevice_subprocess():
    """Spawn a subprocess with 8 host devices: shard_map RTAC == reference."""
    import subprocess, sys, textwrap

    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import random_csp, enforce
        from repro.core.sharded import make_sharded_enforcer, shard_csp_arrays
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 4), ("data", "model"))
        csp = random_csp(16, 8, 0.7, 0.4, seed=3)
        B = 4
        dom_b = jnp.tile(csp.dom[None], (B, 1, 1))
        dom_b = dom_b.at[1, 0, :4].set(False)
        dom_b = dom_b.at[2, 5, 1:].set(False)
        changed_b = jnp.ones((B, 16), jnp.bool_)
        enf = make_sharded_enforcer(mesh)
        cons_s, mask_s, dom_s = shard_csp_arrays(mesh, csp.cons, csp.mask, dom_b)
        res = enf(cons_s, mask_s, dom_s, changed_b)
        for i in range(B):
            ref = enforce(csp.cons, csp.mask, dom_b[i])
            assert bool(ref.consistent) == bool(res.consistent[i])
            if bool(ref.consistent):
                assert (np.asarray(ref.dom) == np.asarray(res.dom[i])).all()
        print("SHARDED_OK")
        """
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, cwd="/root/repo",
        timeout=600,
    )
    assert "SHARDED_OK" in out.stdout, out.stderr[-2000:]
