"""Fused in-kernel fixpoint validation — interpret-mode parity sweeps.

The fused kernels (`dense_fixpoint_stacked` / `packed_fixpoint_stacked`) run
the WHOLE AC recurrence inside one `pl.pallas_call`; the stepped path
(`rtac.enforce_rows_generic` around per-iteration revise kernels) is the
oracle. Parity must be bit-identical — domains, verdicts, AND per-row
recurrence counts — on odd/padded shapes (n, d, W not multiples of the block
sizes), across every schedule knob (instance tiling block_r, sweep tiles
block_rx/block_ry, loop-nest order "xy"/"yx"), because the autotuner is free
to pick any of them. Also covers the `kernels/ref.py` single-revise oracle
chained on the host, engine/solve_many-level fused-vs-stepped equality, and
the autotune cache round-trip.

All `pytest.mark.pallas` (interpret mode executes kernel bodies in Python),
run in CI's dedicated pallas leg.
"""

import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import random_csp, rtac
from repro.core.engine import pad_changed, pad_dom
from repro.core.search import solve_many
from repro.engines import get_engine
from repro.kernels import autotune, ops
from repro.kernels.ref import revise_ref

pytestmark = pytest.mark.pallas

# (n_vars, dom_size, block_rx, block_ry) — odd n/d so every case exercises the
# padding boundary; (24, 33) is multi-word bitpack, (12, 64) exactly 2 words
SHAPE_SWEEP = [
    (4, 3, 4, 4),
    (10, 6, 8, 8),
    (16, 8, 4, 8),
    (24, 33, 8, 8),
    (12, 64, 4, 4),
]

#: fused-schedule knobs every case sweeps: (block_r, sweep). 5 rows means
#: block_r=1 tiles exactly and block_r=8 exercises `effective_block_r`'s
#: fallback through the padded round width.
SCHEDULES = [(1, "xy"), (1, "yx"), (4, "xy"), (4, "yx")]


def _rows_fixture(n, d, brx, bry, prepare):
    """3 networks, 4 rows via idx [0,1,2,1]; row 3 starts near wipeout and the
    seed mixes root (all-changed) with sparse patterns."""
    csps = [random_csp(n, d, 0.7, 0.5, seed=40 + i) for i in range(3)]
    prepared = [prepare(c, brx, bry) for c in csps]
    dims = prepared[0][2]
    tables = (
        jnp.stack([p[0][0] for p in prepared]),
        jnp.stack([p[0][1] for p in prepared]),
    )
    idx = np.array([0, 1, 2, 1], np.int32)
    doms = np.stack([np.asarray(csps[j].dom) for j in idx])
    doms[3, 0, 1:] = False
    changed = np.ones((len(idx), n), dtype=bool)
    changed[1] = np.random.default_rng(n * 13 + d).random(n) < 0.5
    return csps, tables, dims, idx, doms, changed


def _stepped_oracle(tables, dims, idx, dom_p, ch_p, rows_fn):
    return rtac.enforce_rows_generic(
        tables, dom_p, ch_p, jnp.asarray(idx), revise_rows_fn=rows_fn
    )


@pytest.mark.parametrize("n,d,brx,bry", SHAPE_SWEEP)
def test_dense_fused_bit_identical_to_stepped(n, d, brx, bry):
    csps, tables, (n_p, d_p), idx, doms, changed = _rows_fixture(
        n, d, brx, bry, ops.prepare_dense
    )
    r = len(idx)
    dom_p = pad_dom(jnp.asarray(doms), n_p, d_p)
    ch_p = pad_changed(jnp.asarray(changed), n, n_p, batch=(r,))
    ref = _stepped_oracle(
        tables, (n_p, d_p), idx, dom_p, ch_p,
        ops._dense_rows_fn(n_p, d_p, brx, bry, True),
    )
    from repro.kernels import rtac_support

    for block_r, sweep in SCHEDULES:
        br = autotune.effective_block_r(block_r, r)
        got_dom, got_cons, got_k = rtac_support.dense_fixpoint_stacked(
            tables[0][idx],
            dom_p.astype(jnp.uint8).reshape(r, 1, n_p * d_p),
            ch_p.astype(jnp.uint8).reshape(r, 1, n_p),
            tables[1][idx],
            d=d_p, block_r=br, block_rx=brx, block_ry=bry, sweep=sweep,
        )
        np.testing.assert_array_equal(
            np.asarray(got_dom).reshape(r, n_p, d_p).astype(bool),
            np.asarray(ref.dom),
        )
        np.testing.assert_array_equal(
            np.asarray(got_cons)[:, 0].astype(bool), np.asarray(ref.consistent)
        )
        np.testing.assert_array_equal(
            np.asarray(got_k)[:, 0], np.asarray(ref.n_recurrences)
        )


@pytest.mark.parametrize("n,d,brx,bry", SHAPE_SWEEP)
def test_packed_fused_bit_identical_to_stepped(n, d, brx, bry):
    csps, tables, (n_p, d_p, w), idx, doms, changed = _rows_fixture(
        n, d, brx, bry, ops.prepare_packed
    )
    r = len(idx)
    dom_p = pad_dom(jnp.asarray(doms), n_p, d_p)
    ch_p = pad_changed(jnp.asarray(changed), n, n_p, batch=(r,))
    ref = _stepped_oracle(
        tables, (n_p, d_p, w), idx, dom_p, ch_p,
        ops._packed_rows_fn(n_p, d_p, w, brx, bry, True),
    )
    from repro.kernels import bitpack_support, ref as kref

    dom_words = kref.pack_bits_ref(dom_p).reshape(r, 1, n_p * w)
    for block_r, sweep in SCHEDULES:
        br = autotune.effective_block_r(block_r, r)
        got_dom, got_cons, got_k = bitpack_support.packed_fixpoint_stacked(
            tables[0][idx],
            dom_words,
            ch_p.astype(jnp.uint8).reshape(r, 1, n_p),
            tables[1][idx],
            d=d_p, w=w, block_r=br, block_rx=brx, block_ry=bry, sweep=sweep,
        )
        np.testing.assert_array_equal(
            np.asarray(got_dom).reshape(r, n_p, d_p).astype(bool),
            np.asarray(ref.dom),
        )
        np.testing.assert_array_equal(
            np.asarray(got_cons)[:, 0].astype(bool), np.asarray(ref.consistent)
        )
        np.testing.assert_array_equal(
            np.asarray(got_k)[:, 0], np.asarray(ref.n_recurrences)
        )


@pytest.mark.parametrize("n,d,brx,bry", [(10, 6, 8, 8), (24, 33, 8, 8)])
def test_fused_rows_fn_matches_ref_oracle_chain(n, d, brx, bry):
    """Independent oracle: chain `kernels/ref.py`'s single revise on the host
    (the pure-jnp Prop. 2 tensor form, no Pallas) to a fixpoint per row and
    compare the fused result row-by-row — counts included."""
    csps, tables, (n_p, d_p, w), idx, doms, changed = _rows_fixture(
        n, d, brx, bry, ops.prepare_packed
    )
    r = len(idx)
    dom_p = pad_dom(jnp.asarray(doms), n_p, d_p)
    ch_p = pad_changed(jnp.asarray(changed), n, n_p, batch=(r,))
    fused = ops._packed_fixpoint_rows_fn(n_p, d_p, w, brx, bry, True)(
        (tables[0][idx], tables[1][idx]), dom_p, ch_p
    )
    for row, j in enumerate(idx):
        dom = jnp.asarray(doms[row])
        ch = jnp.asarray(changed[row])
        consistent, k = True, 0
        while True:
            if not bool(jnp.all(jnp.any(dom, axis=-1))):
                consistent = False
                break
            if not bool(jnp.any(ch)):
                break
            viol = revise_ref(csps[j].cons, csps[j].mask, dom, ch)
            new_dom = dom & ~viol
            ch = jnp.any(new_dom != dom, axis=-1)
            dom = new_dom
            k += 1
        assert bool(np.asarray(fused.consistent)[row]) == consistent
        assert int(np.asarray(fused.n_recurrences)[row]) == k
        if consistent:
            np.testing.assert_array_equal(
                np.asarray(fused.dom)[row, :n, :d], np.asarray(dom)
            )


@pytest.mark.parametrize("engine", ["pallas_dense", "pallas_packed"])
def test_engine_enforce_many_fused_equals_stepped(engine):
    csps = [random_csp(9, 5, 0.6, 0.5, seed=70 + i) for i in range(4)]
    doms = jnp.stack([c.dom for c in csps])
    ef = get_engine(engine, fixpoint="fused")
    es = get_engine(engine, fixpoint="stepped")
    rf = ef.enforce_many(ef.prepare_many(csps), doms)
    rs = es.enforce_many(es.prepare_many(csps), doms)
    np.testing.assert_array_equal(np.asarray(rf.dom), np.asarray(rs.dom))
    np.testing.assert_array_equal(
        np.asarray(rf.consistent), np.asarray(rs.consistent)
    )
    np.testing.assert_array_equal(
        np.asarray(rf.n_recurrences), np.asarray(rs.n_recurrences)
    )


def test_solve_many_fused_equals_stepped_and_bills_one_launch_per_round():
    csps = [random_csp(9, 5, 0.6, 0.5, seed=7 + i) for i in range(4)]
    out = {}
    for mode in ("fused", "stepped"):
        tel = {}
        sols, stats = solve_many(
            csps, engine=get_engine("pallas_packed", fixpoint=mode), telemetry=tel
        )
        out[mode] = (sols, stats, tel)
    sols_f, stats_f, tel_f = out["fused"]
    sols_s, stats_s, tel_s = out["stepped"]
    assert sols_f == sols_s
    assert [st.recurrences for st in stats_f] == [st.recurrences for st in stats_s]
    assert tel_f["rounds"] == tel_s["rounds"]
    # the tentpole claim: fused bills exactly one launch per lockstep round;
    # stepped bills the per-round max recurrence depth (strictly more here)
    assert tel_f["fused_fixpoint"] and not tel_s["fused_fixpoint"]
    assert tel_f["launches"] == tel_f["rounds"]
    assert tel_f["launches_per_round"] == 1.0
    assert tel_s["launches"] > tel_s["rounds"]
    assert all(st.launches >= 1 for st in stats_f)


def test_fixpoint_mode_validation_and_env_default(monkeypatch):
    with pytest.raises(ValueError):
        get_engine("pallas_packed", fixpoint="nope")
    monkeypatch.setenv("REPRO_PALLAS_FIXPOINT", "stepped")
    assert get_engine("pallas_packed").fused_fixpoint is False
    monkeypatch.delenv("REPRO_PALLAS_FIXPOINT")
    assert get_engine("pallas_packed").fused_fixpoint is True


# --- autotune cache ----------------------------------------------------------


def test_autotune_cache_roundtrip(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    autotune.reset()
    try:
        cfg = autotune.tune("packed", 16, 8, r=2, repeats=1, path=path)
        key = autotune.bucket_key("packed", 16, 8, 1, 2)
        payload = json.loads(path.read_text())
        assert payload["schema"] == autotune.SCHEMA
        assert payload["configs"][key] == cfg.to_dict()
        # a fresh in-memory table reloads the winner from disk
        autotune.reset()
        got = autotune.get_config("packed", 16, 8, 1, 2, 8, 8)
        assert got == cfg
        # ensure_tuned is a pure cache hit now — no re-timing
        assert autotune.ensure_tuned("packed", 16, 8, 1, 2, path=path) == cfg
    finally:
        autotune.reset()


def test_autotune_untuned_bucket_falls_back_to_engine_defaults(tmp_path, monkeypatch):
    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path / "missing.json"))
    autotune.reset()
    try:
        cfg = autotune.get_config("dense", 16, 8, 0, 4, 4, 8)
        assert (cfg.block_rx, cfg.block_ry, cfg.sweep) == (4, 8, "xy")
    finally:
        autotune.reset()


def test_autotune_sanitizes_stale_tiles_and_block_r():
    # a cached schedule whose tiles no longer divide n_p must fall back
    stale = autotune.TuneConfig(block_r=8, block_rx=5, block_ry=16, sweep="yx")
    fixed = autotune._sanitize(stale, n_p=16, block_rx=8, block_ry=8)
    assert (fixed.block_rx, fixed.block_ry, fixed.sweep) == (8, 16, "yx")
    assert autotune.effective_block_r(8, 6) == 6
    assert autotune.effective_block_r(8, 5) == 5
    assert autotune.effective_block_r(4, 6) == 3
    assert autotune.effective_block_r(8, 8) == 8
