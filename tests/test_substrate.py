"""Substrate tests: optimizer, data pipeline, checkpointing, fault tolerance,
MoE invariants, layer properties."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, make_batch_np
from repro.optim.adamw import AdamW, constant_schedule, cosine_schedule, global_norm


# --------------------------- optimizer --------------------------------------


def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=constant_schedule(0.1), weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clipping():
    opt = AdamW(lr=constant_schedule(0.0), clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, metrics = opt.update({"w": jnp.full(3, 100.0)}, state, params)
    assert float(metrics["grad_norm"]) > 100.0  # reported pre-clip


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup_steps=10, total_steps=100)
    assert float(lr(jnp.array(0))) == 0.0
    assert abs(float(lr(jnp.array(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.array(100))) < float(lr(jnp.array(50)))


def test_bf16_moments_still_converge():
    opt = AdamW(lr=constant_schedule(0.1), weight_decay=0.0, moment_dtype=jnp.bfloat16)
    params = {"w": jnp.array([4.0])}
    state = opt.init(params)
    for _ in range(200):
        params, state, _ = opt.update({"w": 2 * params["w"]}, state, params)
    assert abs(float(params["w"][0])) < 0.2
    assert state.m["w"].dtype == jnp.bfloat16


# --------------------------- data pipeline ----------------------------------


def test_data_deterministic_and_stateless():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=4)
    b1 = make_batch_np(cfg, 7)
    b2 = make_batch_np(cfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch_np(cfg, 8)
    assert (b1["tokens"] != b3["tokens"]).any()


def test_data_restart_exactness():
    """A restarted consumer sees exactly the stream a healthy one would."""
    cfg = DataConfig(vocab=50, seq_len=16, global_batch=2)
    healthy = [make_batch_np(cfg, s)["tokens"] for s in range(10)]
    restarted = [make_batch_np(cfg, s)["tokens"] for s in range(5, 10)]
    for a, b in zip(healthy[5:], restarted):
        np.testing.assert_array_equal(a, b)


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=2)
    b = make_batch_np(cfg, 0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["loss_mask"][:, -1] == 0).all()


# --------------------------- checkpointing ----------------------------------


def _tree():
    return {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "n": {"b": jnp.ones((4,), jnp.bfloat16), "s": jnp.zeros((), jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = _tree()
    mgr.save(3, tree)
    out = mgr.restore(3, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_latest_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_async_and_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save_async(5, _tree())
    mgr.wait()
    assert mgr.latest_step() == 5
    out = mgr.restore(5, _tree())
    assert out["n"]["b"].dtype == jnp.bfloat16


def test_checkpoint_atomicity_ignores_partial(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, _tree())
    # simulate a crash mid-write: stray tmp dir + step dir without manifest
    (tmp_path / "tmp.99.123").mkdir()
    (tmp_path / "step_0000000099").mkdir()
    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1


# --------------------------- fault tolerance (train resume) -----------------


def test_train_resume_is_bit_deterministic(tmp_path):
    from repro.launch.train import train

    kw = dict(
        arch="qwen1.5-0.5b", smoke=True, seq_len=32, global_batch=2,
        ckpt_every=5, log_every=1000,
    )
    full = train(steps=10, ckpt_dir=str(tmp_path / "a"), **kw)
    # interrupted run: first 5 steps, then a fresh process-equivalent resume
    train(steps=5, ckpt_dir=str(tmp_path / "b"), **kw)
    resumed = train(steps=10, ckpt_dir=str(tmp_path / "b"), **kw)
    np.testing.assert_allclose(full[5:], resumed, rtol=1e-5)


# --------------------------- MoE invariants ---------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_moe_routing_invariants(seed):
    from repro.configs import get_config, smoke_config
    from repro.models.moe import apply_moe, moe_decls
    from repro.models.param import init_params

    cfg = smoke_config(get_config("qwen3-moe-235b-a22b"))
    p = init_params(jax.random.PRNGKey(seed), moe_decls(cfg))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, cfg.d_model), jnp.float32)
    y, aux = apply_moe(p, x, cfg, capacity_factor=8.0)  # big capacity: no drops
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux["moe_aux_loss"]) > 0.0
    # with no drops, scaling gates by top-k renormalization keeps output
    # bounded by max expert response; just check nonzero flow per token
    assert float(jnp.mean(jnp.abs(y))) > 0.0


def test_moe_capacity_dropping_zeroes_overflow():
    from repro.configs import get_config, smoke_config
    from repro.models.moe import apply_moe, moe_decls
    from repro.models.param import init_params

    cfg = smoke_config(get_config("qwen3-moe-235b-a22b"))
    p = init_params(jax.random.PRNGKey(0), moe_decls(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model), jnp.float32)
    y_small, _ = apply_moe(p, x, cfg, capacity_factor=0.1)
    y_big, _ = apply_moe(p, x, cfg, capacity_factor=8.0)
    # tighter capacity must strictly reduce total routed mass
    assert float(jnp.sum(jnp.abs(y_small))) < float(jnp.sum(jnp.abs(y_big)))


# --------------------------- layer properties -------------------------------


def test_mrope_reduces_to_rope_for_text():
    from repro.models.layers import apply_mrope, apply_rope

    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    pos3 = jnp.broadcast_to(pos[..., None], (2, 8, 3))
    a = apply_rope(x, pos, 10000.0)
    b = apply_mrope(x, pos3, 10000.0, (4, 2, 2))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_swa_equals_full_when_window_covers_seq():
    from repro.models.layers import _sdpa

    q = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 4, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 16), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 2, 16), jnp.float32)
    full = _sdpa(q, k, v, causal=True)
    swa = _sdpa(q, k, v, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(full), np.asarray(swa), atol=1e-6)


def test_chunked_attention_matches_unchunked():
    from repro.models.layers import _sdpa, sdpa_chunked

    q = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 4, 8), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 4, 8), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 4, 8), jnp.float32)
    a = _sdpa(q, k, v, causal=True)
    b = sdpa_chunked(q, k, v, causal=True, window=0, q_chunk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
