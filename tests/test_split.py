"""Speculative search determinism gate (ISSUE 7, DESIGN.md §9).

Tree splitting and portfolio racing let ONE search occupy many frontier rows;
the load-bearing claim is that speculation buys wall-clock only — the VERDICT
is untouched. Every test here pits a speculative run against the sequential
`mac_solve` oracle:

- SAT stays SAT (the witness may differ — racers branch differently — but it
  must satisfy the instance);
- UNSAT stays UNSAT and is only declared when the verdict contract holds
  (the cover set tiling the tree is exhausted, or a complete portfolio
  member proved it alone);
- a tripped assignment budget is inconclusive for the whole group, exactly
  as it is for the sequential search.

CI runs this module as its own matrix leg (`pytest -m parity`) twice —
``JAX_ENABLE_X64`` off and on — because the verdict must not hinge on float
width anywhere in the fixpoint.
"""

import numpy as np
import pytest

from repro.core import check_solution, mac_solve, solve_many
from repro.core.search import (
    PortfolioSpec,
    _select_var_anti,
    default_portfolio,
)
from repro.problems import generate, generate_batch
from repro.service.buckets import speculative_budget

pytestmark = pytest.mark.parity

#: every stacked engine the fabric serves; pallas runs interpret-mode (tiny
#: instances keep it in budget) and is still excluded from the non-parity legs
ENGINES = [
    "einsum",
    "full",
    "ac3",
    pytest.param("pallas_packed", marks=pytest.mark.pallas),
]


def _mixed_batch(n_sat_biased=4, seed=0):
    """Small mix straddling SAT and UNSAT so parity is checked on both."""
    csps = list(generate_batch("model_rb", n_sat_biased, n=10, hardness=1.0,
                               seed=seed))
    csps.append(generate("pigeonhole", n=4))  # certainly UNSAT
    csps.append(generate("coloring_random", n=10, edge_prob=0.5, k=3, seed=seed))
    return csps


def _assert_verdict_parity(csp, sol, oracle_sol):
    assert (sol is None) == (oracle_sol is None)
    if sol is not None:
        assert check_solution(csp, sol)


# --- mac_solve: one request, many rows ---------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_split_verdict_parity(engine):
    for i, csp in enumerate(_mixed_batch(seed=11)):
        oracle_sol, _ = mac_solve(csp, engine=engine)
        sol, st = mac_solve(csp, engine=engine, split_budget=3)
        _assert_verdict_parity(csp, sol, oracle_sol)
        assert st.members >= 1 and st.cancelled_members < st.members + 3


@pytest.mark.parametrize("engine", ENGINES)
def test_portfolio_verdict_parity(engine):
    for csp in _mixed_batch(seed=23):
        oracle_sol, _ = mac_solve(csp, engine=engine)
        sol, st = mac_solve(csp, engine=engine, portfolio=3)
        _assert_verdict_parity(csp, sol, oracle_sol)
        assert st.members == 4  # owner + 3 racers admitted up front


@pytest.mark.parametrize("engine", ENGINES)
def test_combined_solve_many_parity(engine):
    # solve_many needs one shared shape; hardness-1.0 Model RB straddles the
    # phase transition so the seeds mix SAT and UNSAT instances
    csps = generate_batch("model_rb", 6, n=10, hardness=1.0, seed=37)
    oracle = [mac_solve(c, engine=engine)[0] for c in csps]
    sols, stats = solve_many(csps, engine=engine, split_budget=2, portfolio=2)
    for csp, sol, ref in zip(csps, sols, oracle):
        _assert_verdict_parity(csp, sol, ref)
    assert all(st.members >= 3 for st in stats)  # owner + 2 racers at least


def test_unsat_via_complete_portfolio_member():
    """A portfolio racer is a COMPLETE search: its None-unexhausted return
    proves UNSAT for the whole group without waiting for the cover."""
    csp = generate("pigeonhole", n=5)
    sol, st = mac_solve(csp, engine="einsum", split_budget=2, portfolio=2)
    assert sol is None and not st.exhausted


def test_budget_trip_is_inconclusive_for_the_group():
    """When the shared assignment budget trips, the WHOLE group reports
    exhausted — a speculative run may never convert a budget trip into a
    false UNSAT."""
    for seed in range(6):
        csp = generate("model_rb", n=10, hardness=1.0, seed=seed)
        sol, st = mac_solve(
            csp, engine="einsum", max_assignments=5, split_budget=3, portfolio=2
        )
        oracle_sol, _ = mac_solve(csp, engine="einsum")
        if sol is not None:
            assert check_solution(csp, sol)  # a member won before the trip
        else:
            # None is either a genuine UNSAT (matching the oracle) or an
            # explicitly inconclusive exhaustion — never a silent wrong verdict
            assert st.exhausted or oracle_sol is None


def test_plain_mac_solve_is_bit_identical():
    """``split_budget=0, portfolio=0`` IS the sequential oracle — stats and
    all (the default path never routes through the group machinery). Only the
    wall-clock attribution may differ between two runs."""
    import dataclasses

    csp = generate("model_rb", n=10, hardness=1.0, seed=3)
    ref_sol, ref_st = mac_solve(csp, engine="einsum")
    sol, st = mac_solve(csp, engine="einsum", split_budget=0, portfolio=0)
    assert sol == ref_sol
    strip = lambda s: dataclasses.replace(s, enforce_seconds=[])
    assert strip(st) == strip(ref_st)


# --- service admission sizing ------------------------------------------------


def test_speculative_budget_policy_units():
    # empty queue, plenty of slack: the request gets what it asked for
    assert speculative_budget(3, 2, 0, 16, 4) == (3, 2)
    # queue at the limit, or no slack: speculation off entirely
    assert speculative_budget(3, 2, 4, 16, 4) == (0, 0)
    assert speculative_budget(3, 2, 0, 1, 4) == (0, 0)
    # slack is shared with the queue, split-first
    assert speculative_budget(8, 8, 1, 16, 4) == (7, 0)
    assert speculative_budget(2, 8, 1, 16, 4) == (2, 5)
    # never negative
    assert speculative_budget(-3, -2, 0, 16, 4) == (0, 0)


def test_service_speculation_verdict_parity():
    from repro.service import RequestStatus, SolverService

    csps = _mixed_batch(seed=41)
    oracle = [mac_solve(c, engine="einsum")[0] for c in csps]
    svc = SolverService("einsum", split_budget=3, portfolio=2, initial_slots=4)
    reqs = [svc.submit(c) for c in csps]
    svc.run_until_idle()
    for csp, req, ref in zip(csps, reqs, oracle):
        assert req.status is RequestStatus.DONE
        _assert_verdict_parity(csp, req.solution, ref)
    snap = svc.snapshot()
    assert snap["median_rows_per_request"] > 0
    assert 0.0 <= snap["speculative_cancel_rate"] <= 1.0


def test_service_per_request_override_disables_speculation():
    from repro.service import SolverService

    csp = generate("model_rb", n=10, hardness=1.0, seed=7)
    svc = SolverService("einsum", split_budget=3, portfolio=2, initial_slots=4)
    req = svc.submit(csp, split_budget=0, portfolio=0)
    req.result()
    assert req.stats.members == 1
    ref_sol, ref_st = mac_solve(csp, engine="einsum")
    assert req.solution == ref_sol
    assert req.stats.recurrences == ref_st.recurrences


# --- heuristic diversity units ----------------------------------------------


def test_anti_mrv_picks_largest_open_domain():
    dom = np.zeros((4, 5), bool)
    dom[0, :1] = True   # assigned-sized
    dom[1, :2] = True
    dom[2, :5] = True   # largest open
    dom[3, :3] = True
    assigned = np.array([True, False, False, False])
    assert _select_var_anti(dom, assigned) == 2
    # ties break to the lowest index, deterministically
    dom[3, :5] = True
    assert _select_var_anti(dom, assigned) == 2


def test_default_portfolio_is_diverse_and_seeded():
    specs = default_portfolio(5, seed=9)
    assert len(specs) == 5
    assert len({(s.heuristic, s.value_order) for s in specs}) == 5
    assert all(isinstance(s, PortfolioSpec) for s in specs)
    assert [s.seed for s in specs] == [9, 10, 11, 12, 13]
    # wraps the cycle past its length rather than failing
    assert len(default_portfolio(7, seed=0)) == 7
