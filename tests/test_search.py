"""MAC backtrack search (paper Alg. 2) — end-to-end correctness."""

import numpy as np
import pytest

from repro.core import (
    check_solution,
    coloring_csp,
    count_solutions,
    mac_solve,
    nqueens_csp,
    random_csp,
    solve_brute,
)


@pytest.mark.parametrize("engine", ["einsum", "full", "ac3"])
def test_nqueens(engine):
    csp = nqueens_csp(8)
    sol, stats = mac_solve(csp, engine=engine)
    assert sol is not None and check_solution(csp, sol)
    assert stats.n_assignments > 0


def test_nqueens_unbatched_children():
    csp = nqueens_csp(8)
    sol, _ = mac_solve(csp, engine="einsum", batched_children=False)
    assert sol is not None and check_solution(csp, sol)


def test_legacy_engine_names_removed():
    """The pre-Engine names were deleted after their deprecation release."""
    csp = nqueens_csp(6)
    for legacy in ("rtac", "rtac_full"):
        with pytest.raises(ValueError, match="unknown engine"):
            mac_solve(csp, engine=legacy)


def test_nqueens_unsat():
    csp = nqueens_csp(3)  # 3-queens has no solution
    for engine in ("einsum", "ac3"):
        sol, _ = mac_solve(csp, engine=engine)
        assert sol is None


@pytest.mark.parametrize("seed", range(6))
def test_random_csp_against_brute(seed):
    csp = random_csp(7, 4, density=0.7, tightness=0.5, seed=seed)
    cons, mask, dom = map(np.asarray, (csp.cons, csp.mask, csp.dom))
    brute = solve_brute(cons, mask, dom)
    sol, _ = mac_solve(csp, engine="einsum")
    sol3, _ = mac_solve(csp, engine="ac3")
    assert (sol is None) == (brute is None) == (sol3 is None)
    if sol is not None:
        assert check_solution(csp, sol) and check_solution(csp, sol3)


def test_coloring():
    # cycle of length 5 needs 3 colours
    n = 5
    adj = np.zeros((n, n), bool)
    for i in range(n):
        adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = True
    sol2, _ = mac_solve(coloring_csp(adj, 2))
    assert sol2 is None
    sol3, _ = mac_solve(coloring_csp(adj, 3))
    assert sol3 is not None and check_solution(coloring_csp(adj, 3), sol3)


def test_rtac_and_ac3_agree_on_assignment_counts():
    """Same heuristic + same propagation strength => identical search trees."""
    csp = nqueens_csp(7)
    _, st_r = mac_solve(csp, engine="einsum")
    _, st_a = mac_solve(csp, engine="ac3")
    assert st_r.n_assignments == st_a.n_assignments
    assert st_r.n_backtracks == st_a.n_backtracks


def test_stats_units_are_separated():
    """Table-1 honesty: tensor engines fill `recurrences`, AC3 fills
    `revisions` — never the other list."""
    csp = nqueens_csp(7)
    _, st_r = mac_solve(csp, engine="einsum")
    assert st_r.recurrences and not st_r.revisions
    assert st_r.mean_recurrences > 0 and st_r.mean_revisions == 0.0
    _, st_a = mac_solve(csp, engine="ac3")
    assert st_a.revisions and not st_a.recurrences
    assert st_a.mean_revisions > 0 and st_a.mean_recurrences == 0.0
    # AC3 is sequential (supports_batch=False): children are enforced lazily,
    # so there is exactly one enforcement per visited assignment + the root —
    # the paper's per-assignment #Revision semantics.
    assert len(st_a.revisions) == st_a.n_assignments + 1


def test_budget_cap():
    csp = nqueens_csp(10)
    sol, stats = mac_solve(csp, engine="einsum", max_assignments=3)
    assert stats.n_assignments <= 4
