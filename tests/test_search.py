"""MAC backtrack search (paper Alg. 2) — end-to-end correctness."""

import numpy as np
import pytest

from repro.core import (
    check_solution,
    coloring_csp,
    count_solutions,
    mac_solve,
    nqueens_csp,
    random_csp,
    solve_brute,
)


@pytest.mark.parametrize("engine", ["rtac", "rtac_full", "ac3"])
def test_nqueens(engine):
    csp = nqueens_csp(8)
    sol, stats = mac_solve(csp, engine=engine)
    assert sol is not None and check_solution(csp, sol)
    assert stats.n_assignments > 0


def test_nqueens_batched_children():
    csp = nqueens_csp(8)
    sol, _ = mac_solve(csp, engine="rtac", batched_children=True)
    assert sol is not None and check_solution(csp, sol)


def test_nqueens_unsat():
    csp = nqueens_csp(3)  # 3-queens has no solution
    for engine in ("rtac", "ac3"):
        sol, _ = mac_solve(csp, engine=engine)
        assert sol is None


@pytest.mark.parametrize("seed", range(6))
def test_random_csp_against_brute(seed):
    csp = random_csp(7, 4, density=0.7, tightness=0.5, seed=seed)
    cons, mask, dom = map(np.asarray, (csp.cons, csp.mask, csp.dom))
    brute = solve_brute(cons, mask, dom)
    sol, _ = mac_solve(csp, engine="rtac")
    sol3, _ = mac_solve(csp, engine="ac3")
    assert (sol is None) == (brute is None) == (sol3 is None)
    if sol is not None:
        assert check_solution(csp, sol) and check_solution(csp, sol3)


def test_coloring():
    # cycle of length 5 needs 3 colours
    n = 5
    adj = np.zeros((n, n), bool)
    for i in range(n):
        adj[i, (i + 1) % n] = adj[(i + 1) % n, i] = True
    sol2, _ = mac_solve(coloring_csp(adj, 2))
    assert sol2 is None
    sol3, _ = mac_solve(coloring_csp(adj, 3))
    assert sol3 is not None and check_solution(coloring_csp(adj, 3), sol3)


def test_rtac_and_ac3_agree_on_assignment_counts():
    """Same heuristic + same propagation strength => identical search trees."""
    csp = nqueens_csp(7)
    _, st_r = mac_solve(csp, engine="rtac")
    _, st_a = mac_solve(csp, engine="ac3")
    assert st_r.n_assignments == st_a.n_assignments
    assert st_r.n_backtracks == st_a.n_backtracks


def test_budget_cap():
    csp = nqueens_csp(10)
    sol, stats = mac_solve(csp, engine="rtac", max_assignments=3)
    assert stats.n_assignments <= 4
