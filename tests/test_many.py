"""Multi-instance layer: `Engine.prepare_many`/`enforce_many` parity across
backends, and `solve_many` ≡ sequential `mac_solve` — solutions AND
per-instance search statistics — on three problem families, including the
acceptance-criterion batch of 32 Model-RB instances.
"""

import numpy as np
import pytest

from repro.core import mac_solve, solve_many
from repro.engines import available_engines, get_engine
from repro.problems import generate_batch

ENGINES = available_engines()


def _batch(name="model_rb", count=6, **kw):
    kw.setdefault("seed", 0)
    return generate_batch(name, count, **kw)


# --- enforce_many parity ----------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_enforce_many_matches_per_instance_enforce(engine):
    csps = _batch(count=5, n=12, hardness=0.9)
    eng = get_engine(engine)
    pm = eng.prepare_many(csps)
    assert pm.n_instances == 5 and (pm.n_vars, pm.dom_size) == (12, csps[0].dom_size)

    doms = np.stack([np.asarray(c.dom) for c in csps])
    res = pm.enforce_many(doms)
    for i, csp in enumerate(csps):
        one = eng.prepare(csp).enforce()
        assert bool(np.asarray(res.consistent)[i]) == bool(np.asarray(one.consistent))
        if bool(np.asarray(one.consistent)):
            np.testing.assert_array_equal(np.asarray(res.dom)[i], np.asarray(one.dom))
        # per-instance work counters survive the shared dispatch
        assert int(np.asarray(res.n_recurrences)[i]) == int(np.asarray(one.n_recurrences))


@pytest.mark.parametrize(
    "engine",
    [
        "einsum",
        "full",
        "ac3",
        pytest.param("pallas_dense", marks=pytest.mark.pallas),
        pytest.param("pallas_packed", marks=pytest.mark.pallas),
    ],
)
def test_enforce_many_instance_idx_routing(engine):
    csps = _batch(count=4, n=10, hardness=0.8)
    pm = get_engine(engine).prepare_many(csps)
    doms = np.stack([np.asarray(c.dom) for c in csps])
    ref = pm.enforce_many(doms)
    idx = np.array([2, 0, 2, 3, 1], np.int32)  # repeats + permutation
    res = pm.enforce_many(doms[idx], instance_idx=idx)
    for row, j in enumerate(idx):
        assert bool(np.asarray(res.consistent)[row]) == bool(np.asarray(ref.consistent)[j])
        np.testing.assert_array_equal(np.asarray(res.dom)[row], np.asarray(ref.dom)[j])


def test_prepare_many_validates_shapes_and_idx():
    eng = get_engine("einsum")
    with pytest.raises(ValueError, match="at least one"):
        eng.prepare_many([])
    mixed = _batch(count=1, n=10) + _batch(count=1, n=12)
    with pytest.raises(ValueError, match="must share"):
        eng.prepare_many(mixed)
    csps = _batch(count=3, n=10)
    pm = eng.prepare_many(csps)
    doms = np.stack([np.asarray(c.dom) for c in csps])
    with pytest.raises(ValueError, match="instance_idx"):
        pm.enforce_many(doms[:2])  # 2 rows, 3 instances, no idx
    with pytest.raises(ValueError, match="out of range"):
        pm.enforce_many(doms, instance_idx=[0, 1, 7])


# --- solve_many ≡ sequential mac_solve (acceptance criterion) ---------------


def _assert_portfolio_matches_sequential(csps, engine, **kw):
    sols, stats = solve_many(csps, engine=engine, **kw)
    assert len(sols) == len(stats) == len(csps)
    n_solved = 0
    for i, csp in enumerate(csps):
        ref_sol, ref_st = mac_solve(csp, engine=engine, **kw)
        assert sols[i] == ref_sol, f"instance {i}: solution diverged"
        assert stats[i].n_assignments == ref_st.n_assignments, f"instance {i}"
        assert stats[i].n_backtracks == ref_st.n_backtracks, f"instance {i}"
        assert stats[i].recurrences == ref_st.recurrences, f"instance {i}"
        assert stats[i].revisions == ref_st.revisions, f"instance {i}"
        n_solved += sols[i] is not None
    return n_solved


def test_solve_many_model_rb_32_instances():
    # the paper's workload class, at the phase transition: a mix of SAT and
    # UNSAT instances, every one bit-identical to its sequential solve
    csps = _batch("model_rb", count=32, n=10, hardness=1.0, seed=5)
    n_solved = _assert_portfolio_matches_sequential(csps, "einsum")
    assert 0 < n_solved < 32  # straddles the transition — both outcomes present


def test_solve_many_coloring_family():
    csps = _batch("coloring_random", count=8, n=12, edge_prob=0.3, k=3, seed=1)
    _assert_portfolio_matches_sequential(csps, "einsum")


def test_solve_many_pigeonhole_family():
    # deterministic UNSAT instances: every search must exhaust identically
    csps = _batch("pigeonhole", count=4, n=5)
    sols, _ = solve_many(csps, engine="einsum")
    assert sols == [None] * 4
    _assert_portfolio_matches_sequential(csps, "einsum")


def test_solve_many_sequential_engine_fallback():
    # ac3 has supports_batch=False: solve_many degrades to per-instance drives
    csps = _batch("model_rb", count=4, n=10, hardness=1.0, seed=5)
    _assert_portfolio_matches_sequential(csps, "ac3")


def test_solve_many_unbatched_children():
    csps = _batch("model_rb", count=4, n=10, hardness=1.0, seed=5)
    _assert_portfolio_matches_sequential(csps, "einsum", batched_children=False)


def test_solve_many_per_instance_budget():
    csps = _batch("pigeonhole", count=3, n=7)  # hard UNSAT: budget must bite
    sols, stats = solve_many(csps, engine="einsum", max_assignments=5)
    assert sols == [None] * 3
    for st in stats:
        assert st.n_assignments <= 6
    ref_sol, ref_st = mac_solve(csps[0], engine="einsum", max_assignments=5)
    assert ref_sol is None and stats[0].n_assignments == ref_st.n_assignments


def test_solve_many_empty():
    assert solve_many([], engine="einsum") == ([], [])


def test_solve_many_stats_are_per_instance():
    csps = _batch("model_rb", count=3, n=10, hardness=0.6, seed=2)
    sols, stats = solve_many(csps, engine="einsum")
    for st in stats:
        assert st.recurrences and not st.revisions  # tensor-engine unit filed
        assert st.enforce_seconds  # lockstep rounds attributed to participants
