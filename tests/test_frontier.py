"""Device-resident search frontiers (ISSUE 5 acceptance, DESIGN.md §8).

The load-bearing claims:

- a lockstep round moves NO (R, n, d) domain tensor host↔device — every
  implicit transfer is disallowed by ``jax.transfer_guard("disallow")`` over a
  whole driver run, and the metered (explicit) metadata traffic is O(R·d),
  strictly below the counterfactual domain traffic;
- solutions and per-instance `SearchStats` (recurrence counts, assignments,
  backtracks) stay bit-identical to sequential `mac_solve` across the stacked
  engines;
- `LockstepDriver` attributes each round's wall-clock to its participants so
  the per-search attributions sum exactly to the measured round seconds
  (padded rounds included).
"""

import numpy as np
import pytest

import jax

from repro.core import check_solution, mac_solve, solve_many
from repro.core.search import HostFrontierStore, LockstepDriver
from repro.engines import get_engine
from repro.problems import generate, generate_batch

STACKED = [
    "einsum",
    "full",
    pytest.param("pallas_dense", marks=pytest.mark.pallas),
    pytest.param("pallas_packed", marks=pytest.mark.pallas),
]


def _drive_to_completion(driver):
    results = {}
    while driver.has_work:
        results.update(driver.round())
    return results


def _frontier_driver(eng, csps, capacity=64):
    prepared = eng.prepare_many(csps)
    networks = eng.frontier_networks(prepared)
    store = eng.open_frontier(
        lambda: networks, prepared.n_vars, prepared.dom_size, capacity=capacity
    )
    driver = LockstepDriver(store, prepared.n_vars, count_unit=eng.count_unit)
    return store, driver


# --- the tentpole claim: rounds are device-resident --------------------------


@pytest.mark.parametrize("engine", STACKED)
def test_lockstep_rounds_move_no_domains(engine):
    """Admit a workload, then run EVERY round under
    ``jax.transfer_guard("disallow")``: any implicit host↔device transfer —
    in particular an (R, n, d) domain tensor crossing either way — fails the
    round. The only explicit transfers are the metered per-row metadata and
    the once-per-search solution extraction; results stay bit-identical to
    sequential `mac_solve`."""
    csps = generate_batch("model_rb", 4, n=10, hardness=1.0, seed=5)
    eng = get_engine(engine)
    store, driver = _frontier_driver(eng, csps)
    for i, csp in enumerate(csps):
        driver.admit(i, csp, idx=i)  # root upload: the one sanctioned domain put

    with jax.transfer_guard("disallow"):
        results = _drive_to_completion(driver)

    assert sorted(results) == list(range(len(csps)))
    outcomes = set()
    for i, csp in enumerate(csps):
        sol, stats = results[i]
        ref_sol, ref_st = mac_solve(csp, engine=eng)
        assert sol == ref_sol, f"instance {i}: solution diverged"
        assert stats.n_assignments == ref_st.n_assignments
        assert stats.n_backtracks == ref_st.n_backtracks
        assert stats.recurrences == ref_st.recurrences
        assert stats.revisions == ref_st.revisions
        if sol is not None:
            assert check_solution(csp, sol)
        outcomes.add(sol is not None)
    assert outcomes == {True, False}  # the mix straddles SAT and UNSAT

    # metadata is O(R·d): strictly below what the pre-§8 protocol moved
    # (the full (R, n, d) bool domains, host->device and back, at its own
    # plain-pow2 round widths)
    assert store.rounds > 0
    assert store.host_bytes_per_round < store.domain_bytes_per_round


def test_solve_many_runs_under_transfer_guard_end_to_end():
    """The public portfolio entry point itself — prepare, admit, rounds,
    extraction — completes under ``disallow`` on the contraction engines (every
    transfer it makes is explicit), and the telemetry shows the per-round
    metadata staying below the counterfactual domain traffic."""
    csps = generate_batch("random_binary", 6, n=14, d=10, density=0.4,
                          tightness=0.45, seed=3)
    ref = [mac_solve(c, engine="einsum") for c in csps]
    telemetry = {}
    with jax.transfer_guard("disallow"):
        sols, stats = solve_many(csps, engine="einsum", telemetry=telemetry)
    for i, (ref_sol, ref_st) in enumerate(ref):
        assert sols[i] == ref_sol
        assert stats[i].recurrences == ref_st.recurrences
    assert telemetry["device_frontier"]
    assert 0 < telemetry["host_bytes_per_round"] < telemetry["domain_bytes_per_round"]


def test_service_rounds_run_under_transfer_guard():
    """Once requests are admitted, every service round is the device-resident
    frontier dispatch: the whole drain loop runs under ``disallow``."""
    from repro.service import RequestStatus, SolverService

    csps = generate_batch("model_rb", 3, n=10, hardness=1.0, seed=5)
    svc = SolverService(engine="einsum")
    reqs = [svc.submit(c) for c in csps]
    svc.step()  # admission: pad_csp + slot install + root upload (sanctioned)
    with jax.transfer_guard("disallow"):
        while any(not r.done() for r in reqs):
            svc.step()
    for req, csp in zip(reqs, csps):
        ref_sol, ref_st = mac_solve(csp, engine="einsum")
        assert req.status is RequestStatus.DONE
        assert req.solution == ref_sol
        assert req.stats.recurrences == ref_st.recurrences
    snap = svc.snapshot()
    (info,) = snap["buckets"].values()
    assert info["device_frontier"]
    assert info["host_bytes_per_round"] > 0


# --- frontier table mechanics ------------------------------------------------


def test_frontier_table_grows_past_initial_capacity():
    """A deep search overflows a deliberately tiny table: capacity doubles on
    device (no data motion) and the search is unaffected."""
    csp = generate("nqueens", n=8)
    eng = get_engine("einsum")
    store, driver = _frontier_driver(eng, [csp], capacity=2)
    driver.admit(0, csp, idx=0)
    results = _drive_to_completion(driver)
    sol, stats = results[0]
    ref_sol, ref_st = mac_solve(csp, engine="einsum")
    assert sol == ref_sol and stats.recurrences == ref_st.recurrences
    assert store.capacity > 2  # it actually grew
    assert store.rows_live == 0  # retirement reclaimed every row


def test_frontier_rows_are_freed_and_reused():
    """Dead branches and retired searches return rows to the free list; a
    following search reuses them rather than growing the table."""
    csps = generate_batch("model_rb", 2, n=10, hardness=1.0, seed=5)
    eng = get_engine("einsum")
    store, driver = _frontier_driver(eng, csps, capacity=64)
    driver.admit(0, csps[0], idx=0)
    _drive_to_completion(driver)
    assert store.rows_live == 0
    cap = store.capacity
    driver.admit(1, csps[1], idx=1)
    _drive_to_completion(driver)
    assert store.rows_live == 0 and store.capacity == cap


def test_group_sibling_cancel_frees_rows_mid_flight():
    """Speculative row groups (DESIGN.md §9): the first member to reach SAT
    cancels its siblings MID-FLIGHT — their rows (including branch children
    already resident) must return to the free list with no orphaned slots,
    and the winner's verdict must match the sequential oracle."""
    csps = generate_batch("model_rb", 2, n=10, hardness=1.0, seed=5)
    eng = get_engine("einsum")
    store, driver = _frontier_driver(eng, csps, capacity=64)
    st = driver.admit_group(0, csps[0], idx=0, split_budget=3, portfolio=2)
    results = _drive_to_completion(driver)
    sol, _ = results[0]
    ref_sol, _ = mac_solve(csps[0], engine="einsum")
    assert (sol is None) == (ref_sol is None)
    if sol is not None:
        assert check_solution(csps[0], sol)
    assert store.rows_live == 0  # every member's rows reclaimed
    assert st.members >= 3 and st.cancelled_members <= st.members - 1
    # the freed rows are genuinely reusable: a second group rides the same table
    cap = store.capacity
    driver.admit_group(1, csps[1], idx=1, split_budget=2, portfolio=1)
    _drive_to_completion(driver)
    assert store.rows_live == 0 and store.capacity == cap


def test_group_cancel_mid_flight_releases_every_row():
    """Cancelling the whole group while siblings are live (the service's
    deadline path) frees every member's rows immediately."""
    csp = generate("pigeonhole", n=6)  # UNSAT: the group cannot finish early
    eng = get_engine("einsum")
    store, driver = _frontier_driver(eng, [csp], capacity=64)
    st = driver.admit_group(0, csp, idx=0, split_budget=2, portfolio=2)
    driver.round()  # get the group genuinely in flight
    driver.round()
    assert driver.is_active(0)
    cancelled = driver.cancel(0)
    assert cancelled is st
    # the pipelined in-flight round resolves on the next beat; afterwards no
    # row may remain live and the driver must be fully drained
    while driver.has_work:
        driver.round()
    assert store.rows_live == 0
    assert not driver.is_active(0)


def test_group_rounds_run_under_transfer_guard():
    """Tree splitting is pure routing metadata: a split sibling's first
    request is a child-create against the owner's still-resident parent row,
    so speculative rounds stay free of implicit host<->device transfers."""
    csps = generate_batch("model_rb", 2, n=10, hardness=1.0, seed=5)
    eng = get_engine("einsum")
    store, driver = _frontier_driver(eng, csps, capacity=64)
    for i, c in enumerate(csps):
        # admission uploads roots (explicit, sanctioned); splitting happens
        # later, inside the guarded rounds
        driver.admit_group(i, c, idx=i, split_budget=3)
    with jax.transfer_guard("disallow"):
        results = _drive_to_completion(driver)
    for i, c in enumerate(csps):
        ref_sol, _ = mac_solve(c, engine="einsum")
        sol, _ = results[i]
        assert (sol is None) == (ref_sol is None)
    assert store.rows_live == 0


def test_frontier_table_rejects_duplicate_keys_and_empty_rounds():
    csp = generate("nqueens", n=6)
    eng = get_engine("einsum")
    store, driver = _frontier_driver(eng, [csp])
    driver.admit(0, csp, idx=0)
    with pytest.raises(ValueError, match="already"):
        store.begin(0, 0, np.asarray(csp.dom))
    with pytest.raises(ValueError, match="at least one"):
        store.dispatch([])


# --- satellite: round wall-clock attribution ---------------------------------


@pytest.mark.parametrize("kind", ["device", "host"])
def test_round_attribution_sums_to_round_seconds(kind):
    """Each round's wall-clock is split over its REAL rows (not the padded
    count), so the per-search ``enforce_seconds`` attributions sum exactly to
    the driver's measured round seconds — including rounds padded up to a
    power of two (3 searches -> 4 rows)."""
    csps = generate_batch("model_rb", 3, n=10, hardness=1.0, seed=5)
    eng = get_engine("einsum")
    if kind == "device":
        _store, driver = _frontier_driver(eng, csps)
    else:
        prepared = eng.prepare_many(csps)
        store = HostFrontierStore(prepared.n_vars, prepared.enforce_many,
                                  pad_rounds=True)
        driver = LockstepDriver(store, prepared.n_vars, count_unit=eng.count_unit)
    all_stats = [driver.admit(i, c, idx=i) for i, c in enumerate(csps)]
    _drive_to_completion(driver)
    attributed = sum(sum(st.enforce_seconds) for st in all_stats)
    measured = sum(driver.round_seconds)
    assert measured > 0
    np.testing.assert_allclose(attributed, measured, rtol=1e-9)


# --- satellite: routing caches ----------------------------------------------


def test_driver_routing_cache_reused_across_stable_rounds():
    """The sorted key order is rebuilt only when membership changes and the
    np.repeat routing array only when the round shape changes — stable rounds
    reuse the exact same array object."""
    csps = generate_batch("pigeonhole", 2, n=5)  # UNSAT: many uniform rounds
    eng = get_engine("einsum")
    _store, driver = _frontier_driver(eng, csps)
    for i, c in enumerate(csps):
        driver.admit(i, c, idx=i)
    seen = []
    while driver.has_work:
        cache = driver._route_cache
        if cache is not None:
            seen.append(id(cache[1]))
        driver.round()
    assert len(set(seen)) < len(seen)  # at least one round reused the array


# --- satellite: vectorized check_solution ------------------------------------


def test_check_solution_vectorized_semantics():
    csp = generate("nqueens", n=6)
    sol, _ = mac_solve(csp, engine="einsum")
    assert check_solution(csp, sol)
    # two queens on the same column violate a pairwise constraint
    conflict = list(sol)
    conflict[1] = conflict[0]
    assert not check_solution(csp, conflict)
    # narrowing the domain makes the old solution value out-of-domain
    dom = np.asarray(csp.dom).copy()
    dom[0, sol[0]] = False
    assert not check_solution(csp._replace(dom=dom), sol)


def test_check_solution_matches_pairwise_reference():
    rng = np.random.default_rng(0)
    csps = generate_batch("model_rb", 4, n=8, hardness=0.9, seed=7)
    for csp in csps:
        dom = np.asarray(csp.dom)
        cons = np.asarray(csp.cons)
        mask = np.asarray(csp.mask)
        n, d = dom.shape
        for _ in range(20):
            sol = [int(v) for v in rng.integers(0, d, size=n)]
            ref = True
            for x in range(n):
                if not dom[x, sol[x]]:
                    ref = False
                    break
                for y in range(x + 1, n):
                    if mask[x, y] and not cons[x, y, sol[x], sol[y]]:
                        ref = False
                        break
                if not ref:
                    break
            assert check_solution(csp, sol) == ref
