"""Extended coverage: HLO collective parser, elastic checkpoint restore,
gradient compression, sharding-rule demotions, dry-run artifact schema,
sudoku end-to-end."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.hlo_stats import collective_stats, total_wire_bytes
from repro.parallel.sharding import DEFAULT_PARAM_RULES, spec_for


# --------------------------- hlo_stats parser --------------------------------

HLO_SNIPPET = """
HloModule test
  %p = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p), replica_groups=[32,16]<=[512], to_apply=%add
  %ag = bf16[64,512]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={1}
  %rs = f32[8,32]{1,0} reduce-scatter(%y), replica_groups=[2,256]<=[512], to_apply=%add
  %cp = u8[1024]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""


def test_collective_parser_kinds_and_factors():
    stats = collective_stats(HLO_SNIPPET)
    assert stats["all-reduce"]["count"] == 1
    # f32[128,256] = 131072 B, g=16 -> ring 2*(15/16)
    assert abs(stats["all-reduce"]["wire_bytes"] - 131072 * 2 * 15 / 16) < 1
    # bf16[64,512] = 65536 B, g=4 -> (3/4)
    assert abs(stats["all-gather"]["wire_bytes"] - 65536 * 0.75) < 1
    # reduce-scatter result 1024 B, g=256 -> (g-1)*result
    assert abs(stats["reduce-scatter"]["wire_bytes"] - 1024 * 255) < 1
    assert stats["collective-permute"]["wire_bytes"] == 1024
    assert total_wire_bytes(stats) > 0


def test_parser_on_real_sharded_lowering():
    """An actually-partitioned program must show nonzero collectives."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.parallel.hlo_stats import collective_stats

        mesh = make_mesh((8,), ("model",))
        def f(a, b):
            return a @ b
        sa = NamedSharding(mesh, P(None, "model"))
        sb = NamedSharding(mesh, P("model", None))
        out = NamedSharding(mesh, P(None, None))
        c = jax.jit(f, in_shardings=(sa, sb), out_shardings=out).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
        ).compile()
        stats = collective_stats(c.as_text())
        assert any(s["count"] > 0 for s in stats.values()), stats
        print("PARSER_OK")
        """
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd="/root/repo", timeout=300,
    )
    assert "PARSER_OK" in out.stdout, out.stderr[-1500:]


# --------------------------- sharding demotions ------------------------------


def test_spec_demotion_and_one_use():
    mesh_shape = {"data": 16, "model": 16}
    # whisper heads: 20 % 16 != 0 -> replicated
    log = []
    s = spec_for(("embed", "heads", None), (1280, 20, 64), DEFAULT_PARAM_RULES, mesh_shape, log)
    assert s == jax.sharding.PartitionSpec("data", None, None)
    assert any("heads" in e for e in log)
    # one-use: two dims both wanting 'model' -> second demoted
    rules = {"a": ("model",), "b": ("model",)}
    s = spec_for(("a", "b"), (32, 32), rules, mesh_shape)
    assert s == jax.sharding.PartitionSpec("model", None)


def test_cache_seq_takes_data_only_when_batch_cannot():
    from repro.parallel.sharding import DEFAULT_ACT_RULES

    mesh_shape = {"data": 16, "model": 16}
    # batch=128 divisible: batch gets data, cache_seq only model
    s = spec_for(
        (None, "batch", "cache_seq", "kv_heads", None),
        (64, 128, 32768, 8, 128),
        DEFAULT_ACT_RULES,
        mesh_shape,
    )
    assert s[1] == "data" and s[2] == "model"
    # batch=1: cache_seq gets (model, data)
    s = spec_for(
        (None, "batch", "cache_seq", "kv_heads", None),
        (64, 1, 524288, 8, 128),
        DEFAULT_ACT_RULES,
        mesh_shape,
    )
    assert s[1] is None and s[2] == ("model", "data")


# --------------------------- elastic checkpoint restore ----------------------


def test_checkpoint_restores_across_meshes_subprocess(tmp_path):
    code = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import CheckpointManager
        from repro.launch.mesh import make_mesh

        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        mgr = CheckpointManager(r"{tmp_path}")
        # save sharded on mesh A (4-way data)
        mesh_a = make_mesh((4, 1), ("data", "model"))
        tree_a = jax.device_put(tree, NamedSharding(mesh_a, P("data", None)))
        mgr.save(1, tree_a)
        # restore sharded on mesh B (4-way model, other dim)
        mesh_b = make_mesh((1, 4), ("data", "model"))
        sh = {{"w": NamedSharding(mesh_b, P(None, "model"))}}
        out = mgr.restore(1, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
        assert out["w"].sharding.spec == P(None, "model")
        print("ELASTIC_OK")
        """
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd="/root/repo", timeout=300,
    )
    assert "ELASTIC_OK" in out.stdout, out.stderr[-1500:]


# --------------------------- gradient compression ----------------------------


def test_quantize_roundtrip_error_bounded():
    from repro.optim.compression import dequantize, quantize_int8

    x = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 3.0
    qt = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize(qt) - x))
    assert float(err) <= float(qt.scale) / 2 + 1e-6


def test_error_feedback_accumulates_lost_mass():
    from repro.optim.compression import compress_decompress, init_error_feedback

    g = {"w": jnp.full((64,), 1e-4)}  # tiny vs scale -> quantizes to 0 at first
    ef = init_error_feedback(g)
    total = jnp.zeros((64,))
    for _ in range(10):
        dq, ef, _ = compress_decompress(g, ef)
        total = total + dq["w"]
    # with EF, the running sum tracks the true sum (10 * 1e-4)
    np.testing.assert_allclose(np.asarray(total), 1e-3, rtol=0.3)


def test_compressed_psum_matches_f32_psum_subprocess():
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.optim.compression import compressed_psum

        mesh = make_mesh((4,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64), jnp.float32)

        def f(xs):
            exact = jax.lax.psum(xs, "pod")
            approx = compressed_psum(xs, "pod")
            return exact, approx

        exact, approx = jax.jit(
            shard_map(f, mesh=mesh, in_specs=P("pod"), out_specs=(P("pod"), P("pod")),
                      check_rep=False)
        )(x)
        rel = np.max(np.abs(np.asarray(exact) - np.asarray(approx))) / (
            np.max(np.abs(np.asarray(exact))) + 1e-9)
        assert rel < 0.05, rel
        print("PSUM_OK")
        """
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd="/root/repo", timeout=300,
    )
    assert "PSUM_OK" in out.stdout, out.stderr[-1500:]


# --------------------------- dry-run artifact schema --------------------------

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


@pytest.mark.skipif(not ART.exists(), reason="dry-run artifacts not generated")
def test_dryrun_artifacts_cover_all_live_cells():
    from repro.configs import cells

    expected = {(a, s.name, m) for a, s, _ in cells() for m in ("single", "multi")}
    have = set()
    for f in ART.glob("*.json"):
        rec = json.loads(f.read_text())
        if "arch" in rec:
            have.add((rec["arch"], rec["shape"], rec["mesh"]))
    missing = expected - have
    assert not missing, f"missing {len(missing)} cells: {sorted(missing)[:5]}"


@pytest.mark.skipif(not ART.exists(), reason="dry-run artifacts not generated")
def test_dryrun_artifacts_have_roofline_fields():
    for f in list(ART.glob("*.json"))[:10]:
        rec = json.loads(f.read_text())
        if "arch" not in rec:
            continue
        e = rec["cost_extrapolated"]
        assert e["flops"] > 0, f.name
        assert e["bytes"] > 0, f.name
        assert "memory_analysis" in rec and "temp_size_in_bytes" in rec["memory_analysis"]


# --------------------------- sudoku ------------------------------------------


def test_sudoku_solved_by_propagation():
    from examples.sudoku import PUZZLE
    from repro.core import mac_solve, sudoku_csp

    csp = sudoku_csp(PUZZLE)
    sol, stats = mac_solve(csp, engine="einsum")
    assert sol is not None
    grid = np.asarray(sol).reshape(9, 9) + 1
    assert (np.sort(grid, axis=1) == np.arange(1, 10)[None, :]).all()
    assert (np.sort(grid, axis=0) == np.arange(1, 10)[:, None]).all()
    assert stats.n_backtracks == 0  # AC propagation alone solves this puzzle
