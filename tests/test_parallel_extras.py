"""Extended coverage: HLO collective parser, sharding-rule demotions,
sudoku end-to-end."""

import subprocess
import sys
import textwrap

import jax
import numpy as np

from repro.parallel.hlo_stats import collective_stats, total_wire_bytes
from repro.parallel.sharding import DEFAULT_PARAM_RULES, spec_for


# --------------------------- hlo_stats parser --------------------------------

HLO_SNIPPET = """
HloModule test
  %p = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p), replica_groups=[32,16]<=[512], to_apply=%add
  %ag = bf16[64,512]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={1}
  %rs = f32[8,32]{1,0} reduce-scatter(%y), replica_groups=[2,256]<=[512], to_apply=%add
  %cp = u8[1024]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""


def test_collective_parser_kinds_and_factors():
    stats = collective_stats(HLO_SNIPPET)
    assert stats["all-reduce"]["count"] == 1
    # f32[128,256] = 131072 B, g=16 -> ring 2*(15/16)
    assert abs(stats["all-reduce"]["wire_bytes"] - 131072 * 2 * 15 / 16) < 1
    # bf16[64,512] = 65536 B, g=4 -> (3/4)
    assert abs(stats["all-gather"]["wire_bytes"] - 65536 * 0.75) < 1
    # reduce-scatter result 1024 B, g=256 -> (g-1)*result
    assert abs(stats["reduce-scatter"]["wire_bytes"] - 1024 * 255) < 1
    assert stats["collective-permute"]["wire_bytes"] == 1024
    assert total_wire_bytes(stats) > 0


def test_parser_on_real_sharded_lowering():
    """An actually-partitioned program must show nonzero collectives."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.parallel.hlo_stats import collective_stats

        mesh = make_mesh((8,), ("model",))
        def f(a, b):
            return a @ b
        sa = NamedSharding(mesh, P(None, "model"))
        sb = NamedSharding(mesh, P("model", None))
        out = NamedSharding(mesh, P(None, None))
        c = jax.jit(f, in_shardings=(sa, sb), out_shardings=out).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
        ).compile()
        stats = collective_stats(c.as_text())
        assert any(s["count"] > 0 for s in stats.values()), stats
        print("PARSER_OK")
        """
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd="/root/repo", timeout=300,
    )
    assert "PARSER_OK" in out.stdout, out.stderr[-1500:]


# --------------------------- sharding demotions ------------------------------


def test_spec_demotion_and_one_use():
    mesh_shape = {"data": 16, "model": 16}
    # whisper heads: 20 % 16 != 0 -> replicated
    log = []
    s = spec_for(("embed", "heads", None), (1280, 20, 64), DEFAULT_PARAM_RULES, mesh_shape, log)
    assert s == jax.sharding.PartitionSpec("data", None, None)
    assert any("heads" in e for e in log)
    # one-use: two dims both wanting 'model' -> second demoted
    rules = {"a": ("model",), "b": ("model",)}
    s = spec_for(("a", "b"), (32, 32), rules, mesh_shape)
    assert s == jax.sharding.PartitionSpec("model", None)


def test_cache_seq_takes_data_only_when_batch_cannot():
    from repro.parallel.sharding import DEFAULT_ACT_RULES

    mesh_shape = {"data": 16, "model": 16}
    # batch=128 divisible: batch gets data, cache_seq only model
    s = spec_for(
        (None, "batch", "cache_seq", "kv_heads", None),
        (64, 128, 32768, 8, 128),
        DEFAULT_ACT_RULES,
        mesh_shape,
    )
    assert s[1] == "data" and s[2] == "model"
    # batch=1: cache_seq gets (model, data)
    s = spec_for(
        (None, "batch", "cache_seq", "kv_heads", None),
        (64, 1, 524288, 8, 128),
        DEFAULT_ACT_RULES,
        mesh_shape,
    )
    assert s[1] is None and s[2] == ("model", "data")


# --------------------------- sudoku ------------------------------------------


def test_sudoku_solved_by_propagation():
    from examples.sudoku import PUZZLE
    from repro.core import mac_solve, sudoku_csp

    csp = sudoku_csp(PUZZLE)
    sol, stats = mac_solve(csp, engine="einsum")
    assert sol is not None
    grid = np.asarray(sol).reshape(9, 9) + 1
    assert (np.sort(grid, axis=1) == np.arange(1, 10)[None, :]).all()
    assert (np.sort(grid, axis=0) == np.arange(1, 10)[:, None]).all()
    assert stats.n_backtracks == 0  # AC propagation alone solves this puzzle
