"""Observability layer (DESIGN.md §10): tracer integrity, registry schema,
Perfetto export, and the no-semantic-footprint guarantee.

The load-bearing claims:

- nested spans close in order with correct parent links, and the ring stays
  bounded under arbitrarily long runs;
- tracing changes NOTHING: solver verdicts and per-instance stats are
  bit-identical with tracing off, on ("async"), and on with fenced timing,
  and the fenced mode stays clean under ``jax.transfer_guard("disallow")``;
- the exported timeline is valid Chrome trace-event JSON (what
  ui.perfetto.dev loads), and ``driver.round`` spans decompose into child
  phases covering ≥ 90% of round wall-clock on a real service run;
- `ServiceMetrics` snapshots are NaN-free on empty windows and at
  ``window=1``, via the one shared percentile/mean implementation.
"""

import json
import math

import jax
import pytest

from repro import obs
from repro.core import mac_solve, solve_many
from repro.problems import generate, generate_batch
from repro.service import FastForwardClock, SolverService, poisson_trace, replay
from repro.service.buckets import speculative_budget
from repro.service.metrics import ServiceMetrics


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends tracing-off with an empty registry, so the
    suite leaves no footprint on other test modules."""
    obs.disable()
    obs.REGISTRY.reset()
    yield
    obs.disable()
    obs.REGISTRY.reset()


# --- tracer core -------------------------------------------------------------


def test_nested_spans_parent_links_and_ordering():
    tracer = obs.enable()
    with obs.span("outer", cat="t") as s_out:
        with obs.span("inner", cat="t") as s_in:
            with obs.span("leaf", cat="t") as s_leaf:
                pass
        with obs.span("inner2", cat="t") as s_in2:
            pass
    assert tracer.open_spans == 0
    assert s_in.parent == s_out.sid and s_in2.parent == s_out.sid
    assert s_leaf.parent == s_in.sid
    assert s_out.parent == 0
    # children close before parents land in the ring, intervals nest
    spans = tracer.snapshot_spans()
    assert [s["name"] for s in spans] == ["leaf", "inner", "inner2", "outer"]
    by_sid = {s["sid"]: s for s in spans}
    for s in spans:
        p = by_sid.get(s["parent"])
        if p is not None:
            assert p["t0"] <= s["t0"]
            assert s["t0"] + s["dur"] <= p["t0"] + p["dur"] + 1e-9


def test_span_args_attach_at_enter_and_after():
    tracer = obs.enable()
    with obs.span("work", rows=7) as s:
        s.args["hit"] = True
    rec = tracer.snapshot_spans()[0]
    assert rec["args"] == {"rows": 7, "hit": True}


def test_ring_bounded_under_long_runs():
    tracer = obs.enable(capacity=32)
    for i in range(100):
        with obs.span("tick", i=i):
            pass
    assert len(tracer.spans) == 32
    assert tracer.dropped == 100 - 32
    # oldest rolled off: the survivors are the most recent 32
    assert [s["args"]["i"] for s in tracer.snapshot_spans()] == list(range(68, 100))


def test_mismatched_exit_force_closes_instead_of_corrupting():
    tracer = obs.enable()
    outer = tracer.begin("outer")
    tracer.begin("orphan")  # never explicitly closed
    tracer.end(outer)
    assert tracer.open_spans == 0
    assert tracer.force_closed == 1
    names = [s["name"] for s in tracer.snapshot_spans()]
    assert names == ["orphan", "outer"]


def test_disabled_path_is_inert():
    assert not obs.enabled()
    ctx = obs.span("anything", rows=3)
    ctx2 = obs.span("else")
    assert ctx is ctx2  # one shared null context manager — no allocation
    with ctx as s:
        assert s is None
    assert obs.now() == 0.0
    obs.record_complete("late", 0.0, 1.0)  # no tracer: silently dropped
    obs.fence(object())  # no jax import, no-op on arbitrary values


def test_disable_returns_tracer_with_spans_intact():
    obs.enable()
    with obs.span("kept"):
        pass
    tracer = obs.disable()
    assert not obs.enabled()
    assert [s["name"] for s in tracer.snapshot_spans()] == ["kept"]


def test_enable_from_env():
    assert not obs.enable_from_env({})
    assert not obs.enable_from_env({"REPRO_TRACE": "0"})
    assert not obs.enable_from_env({"REPRO_TRACE": "false"})
    assert not obs.enable_from_env({"REPRO_TRACE": "off"})
    assert not obs.enabled()
    assert obs.enable_from_env(
        {"REPRO_TRACE": "1", "REPRO_TRACE_TIMING": "fenced", "REPRO_TRACE_RING": "64"}
    )
    tracer = obs.get_tracer()
    assert tracer.timing == "fenced" and tracer.capacity == 64


def test_tracer_rejects_bad_config():
    with pytest.raises(ValueError):
        obs.Tracer(timing="blocking")
    with pytest.raises(ValueError):
        obs.Tracer(capacity=0)


# --- no semantic footprint: verdict parity across tracing modes --------------


def test_mac_solve_verdicts_identical_across_tracing_modes():
    """Tracing off / async / fenced: bit-identical solutions and stats."""
    csps = [
        generate("model_rb", n=10, hardness=1.0, seed=3),
        generate("coloring_random", n=12, edge_prob=0.3, k=3, seed=1),
    ]
    ref = [mac_solve(c, engine="einsum") for c in csps]
    for timing in ("async", "fenced"):
        obs.enable(timing=timing)
        for c, (ref_sol, ref_st) in zip(csps, ref):
            sol, st = mac_solve(c, engine="einsum")
            assert sol == ref_sol
            assert st.n_assignments == ref_st.n_assignments
            assert st.n_backtracks == ref_st.n_backtracks
            assert st.recurrences == ref_st.recurrences
        obs.disable()


def test_fenced_tracing_stays_clean_under_transfer_guard():
    """`fence()` uses block_until_ready — no transfer — so the device-resident
    frontier's ``disallow`` audit passes with fenced tracing on, and the
    verdicts match the untraced run."""
    csps = generate_batch("model_rb", 4, n=10, hardness=1.0, seed=5)
    ref_sols, ref_stats = solve_many(csps, engine="einsum")
    obs.enable(timing="fenced")
    with jax.transfer_guard("disallow"):
        sols, stats = solve_many(csps, engine="einsum")
    assert sols == ref_sols
    assert [s.recurrences for s in stats] == [s.recurrences for s in ref_stats]
    tracer = obs.disable()
    names = {s["name"] for s in tracer.snapshot_spans()}
    assert {"driver.round", "frontier.step", "kernel.launch"} <= names


def test_driver_counters_published_by_solve_many():
    csps = generate_batch("model_rb", 3, n=10, hardness=1.0, seed=2)
    solve_many(csps, engine="einsum")
    snap = obs.snapshot()
    assert snap["counters"]["driver.rounds"] > 0
    assert snap["counters"]["driver.launches"] > 0
    assert snap["counters"]["many.solves"] == 3
    hist = snap["histograms"]["many.rounds_per_instance"]
    assert hist["count"] == 3 and hist["max"] >= hist["p50"] > 0


# --- registry ----------------------------------------------------------------


def test_registry_snapshot_schema_and_reduction():
    obs.counter_add("a.count")
    obs.counter_add("a.count", 4)
    obs.gauge_set("b.level", 7.5)
    for v in range(1, 11):
        obs.observe("c.lat", float(v))
    snap = obs.snapshot()
    assert snap["schema"] == "repro-obs/v1"
    assert snap["counters"] == {"a.count": 5}
    assert snap["gauges"] == {"b.level": 7.5}
    h = snap["histograms"]["c.lat"]
    assert h["count"] == 10 and h["min"] == 1.0 and h["max"] == 10.0
    assert h["p50"] == pytest.approx(5.5)
    obs.REGISTRY.reset()
    assert obs.snapshot()["counters"] == {}


def test_shared_percentile_helpers_never_nan():
    assert obs.percentile([], 95) == 0.0
    assert obs.mean([]) == 0.0
    s = obs.summarize([])
    assert s["count"] == 0
    assert all(not math.isnan(float(v)) for v in s.values())
    assert obs.percentile([3.0], 99) == 3.0  # window=1 degenerates finitely


def test_speculative_budget_publishes_grant_deny():
    # queue at limit: denied
    assert speculative_budget(2, 2, queue_depth=9, spare_rows=64, queue_limit=9) == (0, 0)
    # slack: granted (possibly clamped)
    split, port = speculative_budget(2, 2, queue_depth=0, spare_rows=64, queue_limit=9)
    assert (split, port) == (2, 2)
    snap = obs.snapshot()["counters"]
    assert snap["speculation.denied"] == 1
    assert snap["speculation.split_granted"] == 2
    assert snap["speculation.portfolio_granted"] == 2


# --- ServiceMetrics: NaN-free empty / window=1 snapshots ---------------------


def test_metrics_empty_snapshot_is_exact_zeros():
    snap = ServiceMetrics().snapshot()
    for key, val in snap.items():
        assert not math.isnan(float(val)), key
    assert snap["p95_ms"] == 0.0 and snap["p99_ms"] == 0.0
    assert snap["throughput_rps"] == 0.0
    assert snap["mean_launches_per_round"] == 0.0
    assert snap["median_rows_per_request"] == 0.0


def test_metrics_window_one_stays_finite():
    m = ServiceMetrics(window=1)
    m.record_submit(0.0)
    m.record_finish(1.0, 0.25, "done")
    m.record_finish(2.0, 0.75, "done")  # window=1: only the last sample held
    m.record_round(rows=4, searches=2, seconds=0.01, launches=3)
    m.record_queue_depth(5)
    m.record_request_rows(2, members=1, cancelled=0)
    snap = m.snapshot()
    for key, val in snap.items():
        assert not math.isnan(float(val)), key
    assert snap["p50_ms"] == snap["p99_ms"] == pytest.approx(750.0)
    assert snap["mean_launches_per_round"] == 3.0


# --- export: Chrome trace-event schema + coverage ----------------------------


def _valid_chrome_trace(doc):
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    events = doc["traceEvents"]
    assert events[0] == {
        "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
        "args": {"name": "repro"},
    }
    named_tids = set()
    for ev in events:
        assert ev["ph"] in ("X", "M")
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        if ev["ph"] == "M" and ev["name"] == "thread_name":
            named_tids.add(ev["tid"])
        if ev["ph"] == "X":
            assert ev["ts"] >= 0.0 and ev["dur"] >= 0.0
            assert isinstance(ev["name"], str) and isinstance(ev["args"], dict)
            assert ev["tid"] in named_tids  # every track is labeled
    return events


def test_chrome_trace_schema_from_synthetic_spans():
    tracer = obs.enable()
    with obs.span("driver.round", cat="driver"):
        with obs.span("kernel.launch", cat="kernel", rows=4):
            pass
    t0 = tracer.now()
    obs.record_complete("service.request", t0, t0 + 0.01,
                        track="requests", id=0, status="done")
    doc = obs.chrome_trace(tracer.snapshot_spans(), origin=tracer.origin)
    events = _valid_chrome_trace(doc)
    body = [ev for ev in events if ev["ph"] == "X"]
    assert {ev["name"] for ev in body} == {
        "driver.round", "kernel.launch", "service.request"
    }
    # round-trips through JSON untouched
    assert json.loads(json.dumps(doc)) == doc


def test_child_coverage_degenerate_cases():
    assert obs.child_coverage([], "driver.round") == 1.0
    spans = [
        {"sid": 1, "parent": 0, "name": "driver.round", "t0": 0.0, "dur": 1.0},
        {"sid": 2, "parent": 1, "name": "frontier.step", "t0": 0.0, "dur": 0.95},
    ]
    assert obs.child_coverage(spans, "driver.round") == pytest.approx(0.95)


# --- the acceptance run: traced service replay -------------------------------


def _traced_service_run(timing="async"):
    obs.enable(timing=timing)
    events = poisson_trace(["model_rb"], rate=6.0, duration=1.0, seed=0)
    clock = FastForwardClock()
    svc = SolverService(engine="einsum", clock=clock)
    requests = replay(svc, events, clock)
    return svc, requests, obs.get_tracer()


def test_traced_service_round_coverage_and_request_spans():
    """ISSUE 8 acceptance: driver.round child spans cover ≥ 90% of round
    wall-clock, request-lifetime spans are filed per retired request, and the
    registry carries the same solve counts the service reports."""
    svc, requests, tracer = _traced_service_run()
    spans = tracer.snapshot_spans()
    assert obs.child_coverage(spans, "driver.round") >= 0.9
    req_spans = [s for s in spans if s["name"] == "service.request"]
    assert len(req_spans) == len(requests)
    assert {s["args"]["status"] for s in req_spans} <= {"done", "timed_out", "cancelled"}
    snap = obs.snapshot()
    assert snap["counters"]["service.completed"] == svc.metrics.n_completed
    assert snap["counters"]["cache.misses"] >= 1
    _valid_chrome_trace(obs.chrome_trace(spans, origin=tracer.origin))


def test_traced_service_verdicts_match_untraced():
    svc0, ref, _tracer0 = _traced_service_run()
    obs.disable()
    obs.REGISTRY.reset()
    events = poisson_trace(["model_rb"], rate=6.0, duration=1.0, seed=0)
    clock = FastForwardClock()
    svc = SolverService(engine="einsum", clock=clock)
    untraced = replay(svc, events, clock)
    assert [r.solution for r in ref] == [r.solution for r in untraced]
    assert [r.stats.n_assignments for r in ref] == [
        r.stats.n_assignments for r in untraced
    ]


# --- run dump + CLI ----------------------------------------------------------


def test_run_dump_roundtrip_and_cli(tmp_path, capsys):
    from repro.obs.__main__ import main as obs_main

    _svc, _requests, tracer = _traced_service_run()
    run_path = tmp_path / "run.json"
    payload = obs.dump_run(run_path, tracer=tracer)
    assert payload["schema"] == "repro-obs/v1"
    assert payload["snapshot"]["schema"] == "repro-obs/v1"
    assert payload["tracer"]["timing"] == "async"
    assert len(payload["spans"]) > 0

    assert obs_main(["summarize", str(run_path)]) == 0
    out = capsys.readouterr().out
    assert "driver.round" in out and "child coverage" in out
    assert "service.completed" in out

    trace_path = tmp_path / "out.perfetto.json"
    assert obs_main(["export", str(run_path), "-o", str(trace_path)]) == 0
    doc = json.loads(trace_path.read_text())
    _valid_chrome_trace(doc)

    # write_trace directly from the live tracer agrees event-for-event
    direct = tmp_path / "direct.json"
    obs.write_trace(direct, tracer)
    assert json.loads(direct.read_text())["traceEvents"] == doc["traceEvents"]


def test_load_run_rejects_foreign_schema(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema": "something/v9"}))
    with pytest.raises(ValueError):
        obs.load_run(bad)
    with pytest.raises(RuntimeError):
        obs.write_trace(tmp_path / "x.json", None)  # tracing off


def test_run_payload_with_tracing_off():
    obs.counter_add("solo.count")
    payload = obs.run_payload()
    assert payload["spans"] == [] and payload["tracer"] is None
    assert payload["snapshot"]["counters"]["solo.count"] == 1
