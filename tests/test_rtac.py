"""Property tests for the paper's core claims (Prop. 1 / Prop. 2).

RTAC's fixpoint must equal the classical AC closure computed by two independent
implementations (queue-based AC3 and a naive definitional sweep), on arbitrary
random CSPs — including inconsistent ones.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    ac_closure_brute,
    assign,
    enforce,
    enforce_ac3,
    enforce_batch,
    enforce_full,
    nqueens_csp,
    random_csp,
    to_paper_cons,
)

csp_params = st.tuples(
    st.integers(2, 10),  # n_vars
    st.integers(2, 6),  # dom_size
    st.floats(0.1, 1.0),  # density
    st.floats(0.1, 0.8),  # tightness
    st.integers(0, 10_000),  # seed
)


@settings(max_examples=40, deadline=None)
@given(csp_params)
def test_rtac_equals_ac3_equals_brute(params):
    n, d, dens, tight, seed = params
    csp = random_csp(n, d, dens, tight, seed)
    cons, mask, dom = map(np.asarray, (csp.cons, csp.mask, csp.dom))
    bd, bc = ac_closure_brute(cons, mask, dom)
    a3 = enforce_ac3(cons, mask, dom)
    r = enforce(csp.cons, csp.mask, csp.dom)
    rf = enforce_full(csp.cons, csp.mask, csp.dom)
    assert bc == a3.consistent == bool(r.consistent) == bool(rf.consistent)
    if bc:
        np.testing.assert_array_equal(bd, a3.dom)
        np.testing.assert_array_equal(bd, np.asarray(r.dom))
        np.testing.assert_array_equal(bd, np.asarray(rf.dom))


@settings(max_examples=20, deadline=None)
@given(csp_params)
def test_idempotence(params):
    """Enforcing an already-AC network changes nothing and converges in ≤1
    recurrence (Prop. 1(2a): the fixpoint is stable)."""
    n, d, dens, tight, seed = params
    csp = random_csp(n, d, dens, tight, seed)
    r1 = enforce(csp.cons, csp.mask, csp.dom)
    if not bool(r1.consistent):
        return
    r2 = enforce(csp.cons, csp.mask, r1.dom)
    assert bool(r2.consistent)
    np.testing.assert_array_equal(np.asarray(r1.dom), np.asarray(r2.dom))
    assert int(r2.n_recurrences) <= 1


@settings(max_examples=20, deadline=None)
@given(csp_params)
def test_incremental_after_assignment(params):
    """Prop. 2 contract: after an assignment on an AC network, enforcing with
    changed0={var} equals full re-enforcement."""
    n, d, dens, tight, seed = params
    csp = random_csp(n, d, dens, tight, seed)
    r0 = enforce(csp.cons, csp.mask, csp.dom)
    if not bool(r0.consistent):
        return
    dom_np = np.asarray(r0.dom)
    var = int(np.argmax(dom_np.sum(1)))
    val = int(np.argmax(dom_np[var]))
    dom_a = assign(r0.dom, var, val)
    ch = jnp.zeros((n,), jnp.bool_).at[var].set(True)
    inc = enforce(csp.cons, csp.mask, dom_a, ch)
    full = enforce_full(csp.cons, csp.mask, dom_a)
    assert bool(inc.consistent) == bool(full.consistent)
    if bool(inc.consistent):
        np.testing.assert_array_equal(np.asarray(inc.dom), np.asarray(full.dom))


def test_paper_cons_encoding_equivalent():
    """Our (cons-zeros + mask) encoding == the paper's all-ones encoding."""
    csp = random_csp(8, 5, 0.5, 0.4, seed=7)
    paper = to_paper_cons(csp)
    full_mask = jnp.ones_like(csp.mask)  # paper: every pair "constrained"
    r_ours = enforce(csp.cons, csp.mask, csp.dom)
    r_paper = enforce(paper, full_mask, csp.dom)
    assert bool(r_ours.consistent) == bool(r_paper.consistent)
    np.testing.assert_array_equal(np.asarray(r_ours.dom), np.asarray(r_paper.dom))


def test_batched_matches_single():
    csp = random_csp(10, 6, 0.6, 0.4, seed=3)
    doms = []
    for i in range(4):
        d = np.asarray(csp.dom).copy()
        d[i % 10, : i + 1] = False
        doms.append(d)
    dom_b = jnp.asarray(np.stack(doms))
    res = enforce_batch(csp.cons, csp.mask, dom_b)
    for i in range(4):
        ref = enforce(csp.cons, csp.mask, dom_b[i])
        assert bool(ref.consistent) == bool(res.consistent[i])
        if bool(ref.consistent):
            np.testing.assert_array_equal(np.asarray(ref.dom), np.asarray(res.dom[i]))


def test_wipeout_detected():
    csp = random_csp(6, 4, 1.0, 0.4, seed=1)
    dom = np.asarray(csp.dom).copy()
    dom[2, :] = False  # empty domain
    r = enforce(csp.cons, csp.mask, jnp.asarray(dom))
    assert not bool(r.consistent)


def test_recurrence_count_matches_paper_band():
    """Paper Table 1: dense random nets converge in ~3-5 recurrences."""
    ks = []
    for seed in range(5):
        csp = random_csp(100, 20, 0.5, 0.3, seed)
        r = enforce(csp.cons, csp.mask, csp.dom)
        ks.append(int(r.n_recurrences))
    assert max(ks) <= 8, ks  # generous band; exact stats in benchmarks
