"""Robustness fabric (DESIGN.md §12): seeded fault injection, retry/fallback
recovery, round watchdog, load shedding, and the chaos-parity acceptance —
every future resolves under injected faults and the verdicts that ARE
produced are bit-identical to fault-free `mac_solve`.
"""

import numpy as np
import pytest

from repro import faults
from repro.core import mac_solve
from repro.problems import generate
from repro.service import (
    FastForwardClock,
    InvalidRequest,
    RequestStatus,
    SolverService,
    poisson_trace,
    replay,
)

#: shortened backoffs so recovery tests run in milliseconds of trace time
FAST = {"backoff_base_s": 0.01, "backoff_cap_s": 0.05}


# --- plan / recipe layer ------------------------------------------------------


def test_recipe_parsing_kinds_and_all_expansion():
    sites = faults.parse_recipe("all:0.05")
    assert set(sites) == set(faults.KNOWN_SITES)
    assert all(s.rate == 0.05 and s.kind == "fault" for s in sites.values())
    sites = faults.parse_recipe("all:0.05,round.resolve:0.2:garbage:3")
    spec = sites["round.resolve"]  # later entries override the expansion
    assert (spec.rate, spec.kind, spec.max_fires) == (0.2, "garbage", 3)
    assert sites["cache.lookup"].rate == 0.05

    for bad in ("", "kernel.launch", "nope.site:0.5", "cache.lookup:2.0",
                "cache.lookup:0.5:weird", "cache.lookup:0.5:fault:-1"):
        with pytest.raises(ValueError):
            faults.parse_recipe(bad)


def test_plan_is_deterministic_and_streams_are_independent():
    """Whether the k-th crossing of a site fires is a pure function of
    (recipe, seed, k) — other sites' traffic must not perturb it."""
    def fire_pattern(interleave: bool):
        plan = faults.FaultPlan(faults.parse_recipe("all:0.3"), seed=7)
        out = []
        for k in range(50):
            if interleave:  # extra traffic on a DIFFERENT site
                plan.roll("cache.lookup")
            out.append(plan.roll("kernel.launch"))
        return out

    assert fire_pattern(False) == fire_pattern(True)
    assert any(k is not None for k in fire_pattern(False))


def test_max_fires_bounds_fires_but_still_advances_the_stream():
    bounded = faults.FaultPlan({"slot.install": faults.SiteSpec(1.0, "oom", 2)})
    free = faults.FaultPlan({"slot.install": faults.SiteSpec(1.0, "oom")})
    b = [bounded.roll("slot.install") for _ in range(5)]
    f = [free.roll("slot.install") for _ in range(5)]
    assert b == ["oom", "oom", None, None, None]
    assert f == ["oom"] * 5
    assert bounded.fires["slot.install"] == 2
    assert bounded.draws["slot.install"] == 5  # draws never stop


def test_off_by_default_and_injected_scope_restores():
    assert not faults.enabled()
    faults.inject("kernel.launch")  # no plan: must be a silent no-op
    with faults.injected("cache.lookup:1.0:stale") as plan:
        assert faults.enabled() and faults.active() is plan
        with pytest.raises(faults.StaleSchedule) as ei:
            faults.inject("cache.lookup", fingerprint="abc")
        assert ei.value.site == "cache.lookup"
        assert "abc" in str(ei.value)
    assert not faults.enabled()


def test_typed_exception_hierarchy():
    assert issubclass(faults.OomError, MemoryError)
    for exc in (faults.InjectedFault, faults.GarbageVerdict,
                faults.StaleSchedule, faults.OomError):
        assert issubclass(exc, faults.FaultError)
    # Overloaded is a client verdict, NOT a retryable fault
    assert not issubclass(faults.Overloaded, faults.FaultError)
    assert faults.Overloaded(1.5).retry_after_s == 1.5


# --- submit validation --------------------------------------------------------


def test_submit_validation_rejects_garbage_eagerly():
    svc = SolverService(engine="einsum")
    good = generate("nqueens", n=8)

    class Junk:
        dom = np.ones((4, 3), bool)

    junk = Junk()
    junk.dom = np.ones(7, bool)  # not 2-D
    with pytest.raises(InvalidRequest):
        svc.submit(junk)
    with pytest.raises(InvalidRequest):
        svc.submit(good, deadline_s=float("inf"))
    with pytest.raises(InvalidRequest):
        svc.submit(good, deadline_s=-1.0)
    with pytest.raises(InvalidRequest):
        svc.submit(good, max_assignments=0)
    # the service is still healthy after rejecting garbage
    req = svc.submit(good)
    sol, _ = req.result()
    assert sol is not None


# --- load shedding ------------------------------------------------------------


def test_queue_depth_shed_returns_typed_overloaded():
    clock = FastForwardClock()
    svc = SolverService(engine="einsum", clock=clock, shed_queue_depth=2)
    csps = [generate("nqueens", n=8, seed=(0, i)) for i in range(6)]
    reqs = [svc.submit(c) for c in csps]
    shed = [r for r in reqs if r.status is RequestStatus.SHED]
    kept = [r for r in reqs if r.status is not RequestStatus.SHED]
    assert shed and len(kept) >= 2  # the burst beyond the bound was refused
    for r in shed:
        assert isinstance(r.error, faults.Overloaded)
        assert r.error.retry_after_s > 0  # the Retry-After hint
        assert r.done() and r.solution is None
    svc.run_until_idle()
    assert all(r.status is RequestStatus.DONE for r in kept)
    assert svc.snapshot()["shed"] == len(shed)


# --- round watchdog -----------------------------------------------------------


def test_watchdog_recurrence_bound_quarantines_as_failed():
    svc = SolverService(engine="einsum", round_recurrences=1)
    req = svc.submit(generate("model_rb", n=10, hardness=1.0, seed=(5, 0)))
    sol, stats = req.result()
    assert req.status is RequestStatus.FAILED
    assert isinstance(req.error, faults.FaultError)
    assert req.error.site == "round.watchdog"
    assert "recurrence depth" in str(req.error)
    snap = svc.snapshot()
    assert snap["failed"] == 1
    # quarantine freed the request's rows and pins mid-flight
    for b in snap["buckets"].values():
        assert b["active"] == 0
    assert all(e.pins == 0 for e in svc.cache._entries.values())


def test_watchdog_bounds_validated():
    with pytest.raises(ValueError):
        SolverService(engine="einsum", round_wall_s=0.0)
    with pytest.raises(ValueError):
        SolverService(engine="einsum", round_recurrences=0)


# --- fallback ladder ----------------------------------------------------------


def test_demotion_to_success_keeps_verdicts_correct():
    """retry_cap=0 + bounded kernel faults: every faulted request demotes down
    the ladder (full -> einsum) and still lands the fault-free verdict."""
    csps = [generate("model_rb", n=10, hardness=1.0, seed=(3, i))
            for i in range(4)]
    with faults.injected("kernel.launch:1.0:oom:1", seed=1):
        svc = SolverService(engine="full", retry_cap=0, **FAST)
        reqs = [svc.submit(c) for c in csps]
        svc.run_until_idle()
    snap = svc.snapshot()
    assert snap["demotions"] > 0
    assert snap["failed"] == 0 and snap["shed"] == 0
    assert "einsum" in snap["engine_ladder"]
    for req, csp in zip(reqs, csps):
        assert req.status is RequestStatus.DONE
        ref_sol, _ = mac_solve(csp, engine="einsum")
        assert req.solution == ref_sol


def test_breaker_trips_floor_the_bucket():
    """K consecutive faulted rounds on one bucket trip its circuit breaker:
    later admissions of that bucket start at the demoted level directly."""
    csp = generate("model_rb", n=10, hardness=1.0, seed=(9, 0))
    with faults.injected("round.resolve:1.0:garbage:4", seed=0):
        svc = SolverService(engine="full", retry_cap=8, breaker_threshold=2,
                            **FAST)
        req = svc.submit(csp)
        req.result()
    snap = svc.snapshot()
    assert snap["breaker_trips"] >= 1
    assert snap["bucket_floor"]  # the offending bucket is floored
    assert req.status is RequestStatus.DONE
    assert req.solution == mac_solve(csp, engine="einsum")[0]


# --- chaos parity (the acceptance gate) ---------------------------------------


def _oracle(events):
    return [mac_solve(ev.build(), engine="einsum") for ev in events]


def test_chaos_parity_every_site_five_percent():
    """The ISSUE acceptance: a poisson_mixed replay with EVERY site injecting
    at 5% resolves 100% of its futures, and every DONE verdict (solution AND
    search stats) is bit-identical to fault-free sequential mac_solve."""
    events = poisson_trace(["model_rb", "coloring_random"], rate=12.0,
                           duration=3.0, seed=0)
    oracle = _oracle(events)
    with faults.injected("all:0.05", seed=0) as plan:
        clock = FastForwardClock()
        svc = SolverService(engine="einsum", clock=clock, retry_cap=3, **FAST)
        reqs = replay(svc, events, clock)
    assert plan.total_fires > 0  # the drill actually injected
    assert all(r.done() for r in reqs)  # liveness: no future left behind
    n_done = 0
    for req, (ref_sol, ref_st) in zip(reqs, oracle):
        if req.status is not RequestStatus.DONE:
            assert req.status is RequestStatus.FAILED  # no shed/deadline here
            assert isinstance(req.error, faults.FaultError)
            continue
        n_done += 1
        assert req.solution == ref_sol
        assert req.stats.n_assignments == ref_st.n_assignments
        assert req.stats.n_backtracks == ref_st.n_backtracks
        assert req.stats.recurrences == ref_st.recurrences
        assert req.stats.revisions == ref_st.revisions
    assert n_done > len(reqs) // 2  # recovery carried the bulk to verdicts
    # drained clean: no in-flight searches, no leaked cache pins (resident
    # prepared networks legitimately keep occupying slots — that's the LRU)
    for b in svc.snapshot()["buckets"].values():
        assert b["active"] == 0
    assert all(e.pins == 0 for e in svc.cache._entries.values())


@pytest.mark.parametrize("site", faults.KNOWN_SITES)
def test_single_site_chaos_parity(site):
    """Each site alone at a high rate (bounded fires): the recovery path for
    that specific boundary must preserve verdict parity."""
    events = poisson_trace(["model_rb"], rate=8.0, duration=1.5, seed=2)
    oracle = _oracle(events)
    with faults.injected(f"{site}:0.5:fault:3", seed=3):
        clock = FastForwardClock()
        svc = SolverService(engine="einsum", clock=clock, retry_cap=4, **FAST)
        reqs = replay(svc, events, clock)
    assert all(r.done() for r in reqs)
    for req, (ref_sol, ref_st) in zip(reqs, oracle):
        assert req.status is RequestStatus.DONE, (site, req.status, req.error)
        assert req.solution == ref_sol
        assert req.stats.recurrences == ref_st.recurrences


@pytest.mark.pallas
def test_device_frontier_chaos_frees_all_rows():
    """Faults on the device-resident frontier path (FrontierTable): recovery
    plus the fallback ladder must return every frontier row — rows_live back
    to 0 on every device table once the replay drains."""
    events = poisson_trace(["model_rb"], rate=6.0, duration=1.5, seed=6)
    oracle = _oracle(events)
    with faults.injected("frontier.step:0.3:fault:2,kernel.launch:0.3:oom:2",
                         seed=7):
        clock = FastForwardClock()
        svc = SolverService(engine="pallas_packed", clock=clock, retry_cap=4,
                            **FAST)
        reqs = replay(svc, events, clock)
    assert all(r.done() for r in reqs)
    for req, (ref_sol, _) in zip(reqs, oracle):
        if req.status is RequestStatus.DONE:
            assert req.solution == ref_sol
    for b in svc.snapshot()["buckets"].values():
        assert b["active"] == 0
        if b.get("device_frontier"):
            assert b["frontier_rows_live"] == 0
    assert all(e.pins == 0 for e in svc.cache._entries.values())


def test_garbage_and_oom_kinds_recover_like_faults():
    events = poisson_trace(["model_rb"], rate=8.0, duration=1.5, seed=4)
    oracle = _oracle(events)
    recipe = "round.resolve:0.3:garbage:2,slot.install:0.3:oom:2"
    with faults.injected(recipe, seed=5):
        clock = FastForwardClock()
        svc = SolverService(engine="einsum", clock=clock, retry_cap=4, **FAST)
        reqs = replay(svc, events, clock)
    assert all(r.done() for r in reqs)
    for req, (ref_sol, _) in zip(reqs, oracle):
        assert req.status is RequestStatus.DONE
        assert req.solution == ref_sol
