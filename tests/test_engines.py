"""Engine-layer tests: registry, cross-backend parity, prepare-once contract.

Parity: every registered engine must produce the identical AC closure and
consistency verdict through the single Engine API — on a slice of the paper's
§5.2 grid, on n-queens, and on a wipeout instance — and ``enforce_batch`` must
equal looped ``enforce``.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import CSPBenchSpec, PAPER_GRID, Engine, mac_solve, nqueens_csp, random_csp
from repro.core.engine import PreparedNetwork
from repro.engines import available_engines, get_engine
from repro.kernels import ops

ENGINES = available_engines()


def _closure(engine_name, csp, dom=None, changed0=None):
    prepared = get_engine(engine_name).prepare(csp)
    res = prepared.enforce(dom, changed0)
    return np.asarray(res.dom), bool(np.asarray(res.consistent))


# --- parity ---------------------------------------------------------------

# a small slice of the paper grid (full d=20 cells; n reduced only via the
# spec so the generator's structure is untouched)
GRID_SLICE = [
    PAPER_GRID[0],  # n=100, density=0.10
    dataclasses.replace(PAPER_GRID[14], n_vars=40),  # density=1.00 cell, shrunk
]


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("spec", GRID_SLICE, ids=lambda s: f"n{s.n_vars}p{s.density}")
def test_paper_grid_parity(engine, spec):
    csp = spec.build()
    ref_dom, ref_ok = _closure("einsum", csp)
    got_dom, got_ok = _closure(engine, csp)
    assert got_ok == ref_ok
    if ref_ok:
        np.testing.assert_array_equal(got_dom, ref_dom)


@pytest.mark.parametrize("engine", ENGINES)
def test_nqueens_parity(engine):
    csp = nqueens_csp(8)
    ref_dom, ref_ok = _closure("einsum", csp)
    got_dom, got_ok = _closure(engine, csp)
    assert got_ok == ref_ok
    if ref_ok:
        np.testing.assert_array_equal(got_dom, ref_dom)


@pytest.mark.parametrize("engine", ENGINES)
def test_wipeout_parity(engine):
    csp = random_csp(6, 4, density=1.0, tightness=0.4, seed=1)
    dom = np.asarray(csp.dom).copy()
    dom[2, :] = False  # empty domain → inconsistent
    _, ok = _closure(engine, csp, dom)
    assert ok is False


@pytest.mark.parametrize("engine", ENGINES)
def test_batch_matches_looped_enforce(engine):
    csp = nqueens_csp(8)
    eng = get_engine(engine)
    prepared = eng.prepare(csp)
    root = prepared.enforce()
    root_dom = np.asarray(root.dom)
    assert bool(np.asarray(root.consistent))

    doms, chs = [], []
    for v in range(4):  # assign queen 0 to rows 0..3
        d = root_dom.copy()
        d[0, :] = False
        d[0, v] = True
        doms.append(d)
        ch = np.zeros((8,), bool)
        ch[0] = True
        chs.append(ch)
    doms = np.stack(doms)
    chs = np.stack(chs)

    batch = prepared.enforce_batch(doms, chs)
    for i in range(4):
        one = prepared.enforce(doms[i], chs[i])
        assert bool(np.asarray(batch.consistent[i])) == bool(np.asarray(one.consistent))
        if bool(np.asarray(one.consistent)):
            np.testing.assert_array_equal(
                np.asarray(batch.dom[i]), np.asarray(one.dom)
            )


# --- registry / API -------------------------------------------------------


def test_registry_contents():
    assert set(ENGINES) >= {"einsum", "full", "pallas_dense", "pallas_packed", "sharded", "ac3"}
    for legacy in ("rtac", "rtac_full"):  # removed after the deprecation release
        with pytest.raises(ValueError, match="unknown engine"):
            get_engine(legacy)


def test_unknown_engine_raises():
    with pytest.raises(ValueError, match="unknown engine"):
        get_engine("does_not_exist")


# --- prepare-once contract (acceptance criterion) --------------------------


class CountingEngine(Engine):
    """Test double: delegates to an inner engine, counting ``prepare`` calls."""

    name = "counting"

    def __init__(self, inner: Engine):
        self.inner = inner
        self.count_unit = inner.count_unit
        self.prepare_calls = 0

    def prepare(self, csp) -> PreparedNetwork:
        self.prepare_calls += 1
        inner_prepared = self.inner.prepare(csp)
        return PreparedNetwork(self, csp, inner_prepared)

    def _prepare_payload(self, csp):  # pragma: no cover - prepare() overridden
        raise AssertionError

    def enforce(self, prepared, dom, changed0=None):
        return prepared.payload.enforce(dom, changed0)

    def enforce_batch(self, prepared, doms, changed0=None):
        return prepared.payload.enforce_batch(doms, changed0)


@pytest.mark.parametrize("batched", [True, False])
def test_prepare_called_exactly_once_per_mac_solve(batched):
    eng = CountingEngine(get_engine("einsum"))
    csp = nqueens_csp(8)
    sol, stats = mac_solve(csp, engine=eng, batched_children=batched)
    assert sol is not None
    assert stats.n_assignments > 1  # many enforcements happened...
    assert eng.prepare_calls == 1  # ...but the network was prepared ONCE


# --- kernel-shim network memoization (per-CSP cache) ------------------------


def test_kernel_prepare_memoized_per_csp():
    csp = random_csp(10, 6, 0.6, 0.4, seed=5)
    net1, _, dims1 = ops.prepare_dense(csp)
    net2, _, dims2 = ops.prepare_dense(csp)
    assert dims1 == dims2
    assert net1[0] is net2[0]  # same prepared cons2 object — cache hit

    other = random_csp(10, 6, 0.6, 0.4, seed=6)
    net3, _, _ = ops.prepare_dense(other)
    assert net3[0] is not net1[0]  # different CSP — different network

    # same cons object, different mask → must MISS (the network embeds mask)
    import jax.numpy as jnp

    relaxed = csp._replace(mask=jnp.zeros_like(csp.mask))
    net4, _, _ = ops.prepare_dense(relaxed)
    assert net4[1] is not net1[1]
    assert not np.asarray(net4[1]).any()  # built from the relaxed mask

    pk1, _, _ = ops.prepare_packed(csp)
    pk2, _, _ = ops.prepare_packed(csp)
    assert pk1[0] is pk2[0]
