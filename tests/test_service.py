"""Service layer: continuous batching ≡ sequential `mac_solve`, prepared-
network cache safety (no in-flight eviction), and shape-bucket routing.

The load-bearing claim (ISSUE 3 acceptance): a `SolverService` fed requests
*over time* — staggered admission, mixed families, mixed shapes, searches
joining and leaving rounds mid-flight — returns solutions AND per-instance
search statistics bit-identical to running `mac_solve` on each CSP alone.
"""

import numpy as np
import pytest

import repro.core.engine as engine_mod
from repro.core import check_solution, mac_solve
from repro.engines import get_engine
from repro.problems import generate, generate_batch
from repro.service import (
    Bucket,
    FastForwardClock,
    PreparedNetworkCache,
    RequestStatus,
    SolverService,
    bucket_for,
    network_fingerprint,
    pad_csp,
    poisson_trace,
    replay,
)


def _assert_matches_sequential(req, csp, engine="einsum", **kw):
    ref_sol, ref_st = mac_solve(csp, engine=engine, **kw)
    assert req.status is RequestStatus.DONE
    assert req.solution == ref_sol
    assert req.stats.n_assignments == ref_st.n_assignments
    assert req.stats.n_backtracks == ref_st.n_backtracks
    assert req.stats.recurrences == ref_st.recurrences
    assert req.stats.revisions == ref_st.revisions


# --- continuous-batching parity (acceptance criterion) -----------------------


def test_staggered_admission_matches_sequential_mixed_families():
    """Requests arriving mid-flight across two buckets: results and stats are
    bit-identical to sequential mac_solve on every instance."""
    rb = generate_batch("model_rb", 6, n=10, hardness=1.0, seed=5)
    col = generate_batch("coloring_random", 4, n=12, edge_prob=0.3, k=3, seed=1)
    svc = SolverService(engine="einsum", initial_slots=2)

    reqs = [svc.submit(c) for c in rb[:3]]
    svc.step()
    svc.step()  # first wave is mid-search when the second wave arrives
    reqs += [svc.submit(c) for c in rb[3:] + col]
    svc.run_until_idle()

    outcomes = set()
    for req, csp in zip(reqs, rb + col):
        _assert_matches_sequential(req, csp)
        if req.solution is not None:
            assert check_solution(csp, req.solution)
        outcomes.add(req.solution is not None)
    assert outcomes == {True, False}  # the mix straddles SAT and UNSAT


def test_single_request_future_api():
    csp = generate("nqueens", n=8)
    svc = SolverService(engine="einsum")
    req = svc.submit(csp)
    assert not req.done()
    sol, stats = req.result()  # drives the event loop
    assert req.done() and req.status is RequestStatus.DONE
    _assert_matches_sequential(req, csp)
    assert req.latency_s is not None and req.latency_s >= 0
    assert sol is not None and check_solution(csp, sol)
    assert stats is req.stats


def test_sequential_engine_service_parity():
    """AC3 (supports_batch=False) rides the generic host-routing slot pool and
    still matches its own sequential mac_solve exactly."""
    csps = generate_batch("model_rb", 3, n=10, hardness=1.0, seed=5)
    svc = SolverService(engine="ac3")
    reqs = [svc.submit(c) for c in csps]
    svc.run_until_idle()
    for req, csp in zip(reqs, csps):
        _assert_matches_sequential(req, csp, engine="ac3")


def test_per_request_assignment_budget():
    csp = generate("pigeonhole", n=7)  # hard UNSAT: the budget must bite
    svc = SolverService(engine="einsum")
    req = svc.submit(csp, max_assignments=5)
    sol, stats = req.result()
    assert sol is None
    assert stats.exhausted  # budget-capped is inconclusive, NOT a proof of UNSAT
    ref_sol, ref_st = mac_solve(csp, engine="einsum", max_assignments=5)
    assert ref_sol is None and ref_st.exhausted
    assert stats.n_assignments == ref_st.n_assignments


def test_unsat_without_budget_is_not_exhausted():
    sol, stats = mac_solve(generate("pigeonhole", n=5), engine="einsum")
    assert sol is None and not stats.exhausted  # genuine UNSAT proof


def test_deadline_expires_only_the_late_request():
    clock = FastForwardClock()
    svc = SolverService(engine="einsum", clock=clock)
    hard = svc.submit(generate("pigeonhole", n=8), deadline_s=0.0)  # due instantly
    easy = svc.submit(generate("nqueens", n=8))
    svc.run_until_idle()
    assert hard.status is RequestStatus.TIMED_OUT and hard.solution is None
    assert easy.status is RequestStatus.DONE
    _assert_matches_sequential(easy, generate("nqueens", n=8))


def test_cancel_frees_cache_pin():
    svc = SolverService(engine="einsum")
    req = svc.submit(generate("pigeonhole", n=8))
    svc.step()  # admitted + pinned
    entry = svc.cache.lookup(req.bucket, req.fingerprint)
    assert entry is not None and entry.pins == 1
    assert svc.cancel(req) and req.status is RequestStatus.CANCELLED
    assert entry.pins == 0
    assert not svc.cancel(req)  # already terminal
    svc.run_until_idle()


def test_trace_replay_completes_and_measures():
    events = poisson_trace(["model_rb", "coloring_random"], rate=10.0,
                           duration=1.5, seed=0)
    assert events and all(e.t < 1.5 for e in events)
    clock = FastForwardClock()
    svc = SolverService(engine="einsum", clock=clock)
    requests = replay(svc, events, clock)
    assert len(requests) == len(events)
    assert all(r.status is RequestStatus.DONE for r in requests)
    snap = svc.snapshot()
    assert snap["completed"] == len(events)
    assert snap["throughput_rps"] > 0
    assert 0 <= snap["p50_ms"] <= snap["p95_ms"] <= snap["p99_ms"]
    assert snap["mean_rows_per_dispatch"] >= 1.0


# --- bitpacked slot fabric (ISSUE 4 acceptance) ------------------------------


@pytest.mark.pallas
@pytest.mark.parametrize("engine", ["pallas_packed", "pallas_dense"])
def test_pallas_service_parity_zero_host_routing(engine, monkeypatch):
    """SolverService on the Pallas engines: staggered admission, results and
    per-request stats bit-identical to sequential mac_solve, and ZERO
    `route_rows_on_host` calls — every round is the device-resident stacked
    slot-table dispatch (dispatch-counting test double)."""
    calls = []
    real = engine_mod.route_rows_on_host

    def counting(*args, **kw):
        calls.append(args)
        return real(*args, **kw)

    monkeypatch.setattr(engine_mod, "route_rows_on_host", counting)
    csps = generate_batch("model_rb", 3, n=10, hardness=1.0, seed=5)
    svc = SolverService(engine=engine, initial_slots=2)
    reqs = [svc.submit(c) for c in csps[:2]]
    svc.step()  # first wave mid-flight when the last request arrives
    reqs.append(svc.submit(csps[2]))
    svc.run_until_idle()
    for req, csp in zip(reqs, csps):
        _assert_matches_sequential(req, csp, engine=engine)
    assert calls == []  # device-resident slot table: zero host routing


def test_slot_table_advertisement_routes_pool_kind():
    """Engines advertise slot-table support; the pool kind follows the
    advertisement, never a backend-name check."""
    for name in ("einsum", "full", "pallas_dense", "pallas_packed"):
        eng = get_engine(name)
        assert eng.slot_table
        assert eng.open_slot_pool(8, 4, 2).stacked
    for name in ("ac3", "sharded"):
        eng = get_engine(name)
        assert not eng.slot_table
        assert not eng.open_slot_pool(8, 4, 2).stacked


@pytest.mark.parametrize(
    "engine",
    ["einsum", pytest.param("pallas_packed", marks=pytest.mark.pallas)],
)
def test_slot_pool_grow_preserves_resident_networks(engine):
    """`SlotPool.grow` keeps installed networks intact (results identical
    before/after), opens usable new slots, and refuses to shrink."""
    csps = generate_batch("model_rb", 3, n=10, hardness=0.9, seed=3)
    d = csps[0].dom.shape[1]
    eng = get_engine(engine)
    pool = eng.open_slot_pool(10, d, 2)
    pool.install(0, csps[0])
    pool.install(1, csps[1])
    doms = np.stack([np.asarray(c.dom) for c in csps[:2]])
    before = pool.enforce_rows(doms, slot_idx=np.array([0, 1]))
    bytes_before = pool.resident_nbytes

    pool.grow(4)
    assert pool.capacity == 4
    after = pool.enforce_rows(doms, slot_idx=np.array([0, 1]))
    np.testing.assert_array_equal(np.asarray(before.dom), np.asarray(after.dom))
    np.testing.assert_array_equal(
        np.asarray(before.n_recurrences), np.asarray(after.n_recurrences)
    )
    assert pool.resident_nbytes >= bytes_before  # tables grew, networks intact

    pool.install(3, csps[2])  # a newly grown slot is immediately usable
    got = pool.enforce_rows(np.asarray(csps[2].dom)[None], slot_idx=np.array([3]))
    ref = eng.prepare(csps[2]).enforce()
    assert bool(np.asarray(got.consistent)[0]) == bool(np.asarray(ref.consistent))
    if bool(np.asarray(ref.consistent)):
        np.testing.assert_array_equal(np.asarray(got.dom)[0], np.asarray(ref.dom))

    with pytest.raises(ValueError, match="cannot shrink"):
        pool.grow(2)
    with pytest.raises(ValueError, match="empty"):
        pool.enforce_rows(doms[:1], slot_idx=np.array([2]))


# --- prepared-network cache --------------------------------------------------


def test_packed_byte_accounting_admits_8x_more_networks():
    """The LRU budget counts the ENGINE's resident bytes: on the same budget,
    packed-word accounting holds 8 resident networks where the logical
    (unpacked bool) accounting holds exactly one."""
    n, d = 16, 32  # d = 32: one full u32 word per variable, no packing waste
    packed = get_engine("pallas_packed").network_nbytes(n, d)
    unpacked = get_engine("einsum").network_nbytes(n, d)
    budget = 8 * packed
    assert budget // unpacked == 1  # unpacked accounting: ONE network fits

    evicted = []
    cache = PreparedNetworkCache(budget, on_evict=evicted.append)
    for i in range(8):
        entry, hit = cache.acquire(Bucket(n, d), f"fp{i}", packed, lambda i=i: i)
        assert not hit
        cache.release(entry)
    assert len(cache) == 8 and cache.evictions == 0  # all 8 stay resident
    assert cache.bytes_in_use <= cache.byte_budget


def test_pinned_entries_survive_packed_accounting_pressure():
    """Eviction under the packed byte budget still never touches pinned
    entries: over-budget with everything pinned evicts nothing; releasing one
    pin makes exactly that entry evictable."""
    nbytes = get_engine("pallas_packed").network_nbytes(16, 32)
    evicted = []
    cache = PreparedNetworkCache(2 * nbytes, on_evict=evicted.append)
    e0, _ = cache.acquire(Bucket(16, 32), "fp0", nbytes, lambda: 0)
    e1, _ = cache.acquire(Bucket(16, 32), "fp1", nbytes, lambda: 1)
    # both pinned; a third admission runs over budget rather than evict
    e2, _ = cache.acquire(Bucket(16, 32), "fp2", nbytes, lambda: 2)
    assert cache.evictions == 0 and cache.bytes_in_use > cache.byte_budget
    cache.release(e0)  # fp0 unpinned -> the only legal victim
    e3, _ = cache.acquire(Bucket(16, 32), "fp3", nbytes, lambda: 3)
    assert [e.slot for e in evicted] == [0]
    assert cache.lookup(Bucket(16, 32), "fp0") is None
    assert all(
        cache.lookup(Bucket(16, 32), fp) is not None for fp in ("fp1", "fp2", "fp3")
    )
    for e in (e1, e2, e3):
        cache.release(e)


def test_cache_hit_shares_resident_slot():
    csp = generate("nqueens", n=8)  # deterministic: same network every time
    svc = SolverService(engine="einsum")
    r1 = svc.submit(csp)
    r2 = svc.submit(csp)
    svc.step()
    entry = svc.cache.lookup(r1.bucket, r1.fingerprint)
    assert entry is not None and entry.pins == 2  # both flights share one slot
    svc.run_until_idle()
    assert svc.cache.hits == 1 and svc.cache.misses == 1
    assert entry.pins == 0  # warm but unpinned after both retire
    _assert_matches_sequential(r1, csp)
    _assert_matches_sequential(r2, csp)


def test_cache_eviction_never_evicts_inflight_network():
    """Byte budget of ~2 networks under 4 concurrent distinct networks: the
    cache must run over budget rather than evict anything pinned."""
    # under-constrained (SAT side): no root wipeout, so all four searches
    # are still in flight after the first round
    csps = generate_batch("model_rb", 4, n=10, hardness=0.8, seed=5)
    bucket = bucket_for(10, csps[0].dom.shape[1])
    svc = SolverService(
        engine="einsum", cache_bytes=2 * bucket.network_nbytes + 1
    )
    reqs = [svc.submit(c) for c in csps]
    svc.step()  # all four admitted concurrently, all pinned
    entries = [svc.cache.lookup(r.bucket, r.fingerprint) for r in reqs]
    assert all(e is not None and e.pins == 1 for e in entries)
    assert svc.cache.evictions == 0  # over budget, but everything is in flight
    assert svc.cache.bytes_in_use > svc.cache.byte_budget
    svc.run_until_idle()
    for req, csp in zip(reqs, csps):
        _assert_matches_sequential(req, csp)

    # once unpinned, a new distinct admission DOES evict LRU entries
    more = generate_batch("model_rb", 2, n=10, hardness=0.8, seed=77)
    extra = [svc.submit(c) for c in more]
    svc.run_until_idle()
    assert svc.cache.evictions > 0
    assert svc.cache.lookup(reqs[0].bucket, reqs[0].fingerprint) is None  # LRU gone
    for req, csp in zip(extra, more):
        _assert_matches_sequential(req, csp)


def test_evicted_slot_is_reused():
    cache_calls = []
    cache = PreparedNetworkCache(100, on_evict=lambda e: cache_calls.append(e.slot))
    e1, hit = cache.acquire(Bucket(8, 4), "fp1", 60, lambda: 0)
    assert not hit and e1.pins == 1
    cache.release(e1)
    e2, hit = cache.acquire(Bucket(8, 4), "fp2", 60, lambda: 1)  # evicts fp1
    assert not hit and cache_calls == [0]
    assert cache.lookup(Bucket(8, 4), "fp1") is None
    e1b, hit = cache.acquire(Bucket(8, 4), "fp1", 60, lambda: 0)  # rebuilt
    assert not hit
    with pytest.raises(ValueError, match="without pin"):
        cache.release(e1)


def test_fingerprint_separates_network_from_domain():
    csp = generate("model_rb", n=10, seed=3)
    # different domain, same constraint network -> same fingerprint
    narrowed = csp._replace(dom=csp.dom.at[0, 1:].set(False))
    assert network_fingerprint(csp) == network_fingerprint(narrowed)
    other = generate("model_rb", n=10, seed=4)
    assert network_fingerprint(csp) != network_fingerprint(other)


# --- shape buckets -----------------------------------------------------------


def test_bucket_routing_round_trips_shapes():
    for n, d in [(3, 2), (8, 4), (9, 5), (16, 8), (17, 9), (100, 20)]:
        b = bucket_for(n, d)
        assert b.contains(n, d)
        assert b.n_p >= n and b.d_p >= d
        # idempotent: a bucket shape maps to itself
        assert bucket_for(b.n_p, b.d_p) == Bucket(b.n_p, b.d_p)
        # powers of two (with the floor), so bucket count stays O(log² shape)
        assert b.n_p & (b.n_p - 1) == 0 and b.d_p & (b.d_p - 1) == 0


def test_pad_csp_preserves_search_semantics():
    csp = generate("model_rb", n=10, hardness=1.0, seed=2)
    b = bucket_for(*csp.dom.shape)
    padded = pad_csp(csp, b)
    assert padded.dom.shape == (b.n_p, b.d_p)
    n, d = csp.dom.shape
    pd = np.asarray(padded.dom)
    assert not pd[:n, d:].any()  # padded values absent from real domains
    assert (pd[n:, 0] == True).all() and not pd[n:, 1:].any()  # noqa: E712
    assert not np.asarray(padded.mask)[n:, :].any()  # padded vars unconstrained
    with pytest.raises(ValueError, match="does not fit"):
        pad_csp(csp, Bucket(4, 4))


def test_requests_route_to_distinct_buckets():
    svc = SolverService(engine="einsum")
    small = svc.submit(generate("model_rb", n=8, seed=0))
    big = svc.submit(generate("random_binary", n=20, d=10, density=0.3,
                              tightness=0.3, seed=0))
    assert small.bucket != big.bucket
    svc.run_until_idle()
    snap = svc.snapshot()
    assert len(snap["buckets"]) == 2
    for info in snap["buckets"].values():
        assert info["resident_nbytes"] > 0  # slot tables are device-resident
    for req in (small, big):
        assert req.status is RequestStatus.DONE


def test_slot_pool_grows_beyond_initial_capacity():
    csps = generate_batch("model_rb", 5, n=10, hardness=0.8, seed=9)
    svc = SolverService(engine="einsum", initial_slots=1)
    reqs = [svc.submit(c) for c in csps]
    svc.run_until_idle()
    for req, csp in zip(reqs, csps):
        _assert_matches_sequential(req, csp)
    (bucket_info,) = svc.snapshot()["buckets"].values()
    assert bucket_info["capacity"] >= 5
