"""Property tests for every generator in the `repro.problems` registry:
seed-determinism, declared structure (arity / density / tightness) respected,
and AC-closure parity between the `einsum` and `ac3` engines on generated
instances.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mac_solve
from repro.engines import get_engine
from repro.problems import (
    available_problems,
    generate,
    generate_batch,
    get_problem,
    model_rb_params,
)
from repro.problems.coloring import kneser_adjacency
from repro.problems.structured import sudoku_solution_grid

# CI-sized knobs per family (defaults are demo-sized).
SMALL = {
    "model_rb": dict(n=12),
    "random_binary": dict(n=10, d=5),
    "coloring_random": dict(n=12, k=3),
    "coloring_kneser": dict(),
    "pigeonhole": dict(n=5),
    "nqueens": dict(n=6),
    "sudoku": dict(givens=48),
}

FAMILIES = available_problems()


def test_registry_covers_the_suite():
    assert set(FAMILIES) >= {
        "model_rb",
        "random_binary",
        "coloring_random",
        "coloring_kneser",
        "pigeonhole",
        "nqueens",
        "sudoku",
    }
    assert set(SMALL) == set(FAMILIES), "every family needs a CI-sized config"


def test_unknown_problem_and_knob_raise():
    with pytest.raises(ValueError, match="unknown problem"):
        generate("does_not_exist")
    with pytest.raises(TypeError, match="unknown knob"):
        generate("model_rb", bogus=1)


# --- seed determinism -------------------------------------------------------


@pytest.mark.parametrize("name", FAMILIES)
def test_seed_determinism(name):
    a = generate(name, seed=7, **SMALL[name])
    b = generate(name, seed=7, **SMALL[name])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    if not get_problem(name).deterministic:
        c = generate(name, seed=8, **SMALL[name])
        assert any(
            not np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(a, c)
        ), f"{name}: different seeds produced identical instances"


@pytest.mark.parametrize("name", ["model_rb", "coloring_random"])
def test_batch_instances_are_batch_size_independent(name):
    big = generate_batch(name, 5, seed=3, **SMALL[name])
    small = generate_batch(name, 2, seed=3, **SMALL[name])
    shapes = {(c.n_vars, c.dom_size) for c in big}
    assert len(shapes) == 1  # the prepare_many shape contract
    for x, y in zip(big[1], small[1]):  # instance 1 identical in both batches
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --- structural invariants shared by every family ---------------------------


@pytest.mark.parametrize("name", FAMILIES)
def test_tensor_structure(name):
    csp = generate(name, seed=11, **SMALL[name])
    cons, mask, dom = map(np.asarray, (csp.cons, csp.mask, csp.dom))
    n, d = dom.shape
    assert cons.shape == (n, n, d, d) and mask.shape == (n, n)
    assert not mask.diagonal().any()
    np.testing.assert_array_equal(mask, mask.T)
    # zero blocks exactly where unconstrained, relation symmetry elsewhere
    assert not cons[~mask].any()
    np.testing.assert_array_equal(cons, np.transpose(cons, (1, 0, 3, 2)))
    assert (dom.sum(axis=1) >= 1).all()  # no variable starts wiped out


# --- declared arity / density / tightness -----------------------------------


@settings(max_examples=15, deadline=None)
@given(st.tuples(st.integers(8, 16), st.floats(0.3, 1.1), st.integers(0, 10_000)))
def test_model_rb_declared_counts(params):
    n, hardness, seed = params
    alpha, r = 0.8, 0.7
    csp = generate("model_rb", seed=seed, n=n, alpha=alpha, r=r, hardness=hardness)
    d, m, p_cr = model_rb_params(n, alpha, r)
    cons, mask = np.asarray(csp.cons), np.asarray(csp.mask)
    assert csp.dom_size == d
    assert mask.sum() == 2 * m  # exactly m distinct scopes
    q = int(round(hardness * p_cr * d * d))  # replicates the generator exactly
    xs, ys = np.nonzero(np.triu(mask, k=1))
    for x, y in zip(xs, ys):
        assert cons[x, y].sum() == d * d - q  # exact per-constraint tightness


def test_model_rb_explicit_p_and_validation():
    csp = generate("model_rb", n=10, p=0.0)
    assert np.asarray(csp.cons)[np.asarray(csp.mask)].all()  # nothing disallowed
    with pytest.raises(ValueError, match="outside"):
        generate("model_rb", n=10, p=1.5)


@settings(max_examples=10, deadline=None)
@given(st.tuples(st.integers(6, 14), st.floats(0.1, 0.9), st.integers(0, 10_000)))
def test_coloring_random_structure(params):
    n, p, seed = params
    csp = generate("coloring_random", seed=seed, n=n, edge_prob=p, k=3)
    cons, mask = np.asarray(csp.cons), np.asarray(csp.mask)
    neq = ~np.eye(3, dtype=bool)
    for x, y in zip(*np.nonzero(mask)):
        np.testing.assert_array_equal(cons[x, y], neq)  # pure ≠ relations


def test_kneser_petersen():
    adj = kneser_adjacency(5, 2)  # the Petersen graph
    assert adj.shape == (10, 10)
    assert adj.sum() == 2 * 15  # 15 edges
    assert (adj.sum(axis=0) == 3).all()  # 3-regular
    assert generate("coloring_kneser").dom_size == 3  # χ = 5 − 4 + 2
    with pytest.raises(ValueError, match="Kneser"):
        kneser_adjacency(4, 2)


def test_pigeonhole_structure():
    csp = generate("pigeonhole", n=5)
    assert csp.n_vars == 5 and csp.dom_size == 4  # default: one hole short
    mask = np.asarray(csp.mask)
    assert mask.sum() == 5 * 4  # complete graph
    assert generate("pigeonhole", n=5, holes=7).dom_size == 7


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_sudoku_solution_grid_is_valid(seed):
    g = sudoku_solution_grid(seed)
    full = set(range(1, 10))
    for i in range(9):
        assert set(g[i]) == full and set(g[:, i]) == full
        r, c = 3 * (i // 3), 3 * (i % 3)
        assert set(g[r : r + 3, c : c + 3].reshape(-1)) == full


@settings(max_examples=8, deadline=None)
@given(st.tuples(st.integers(20, 60), st.integers(0, 10_000)))
def test_sudoku_givens_respected(params):
    givens, seed = params
    csp = generate("sudoku", seed=seed, givens=givens)
    dom = np.asarray(csp.dom)
    assert (dom.sum(axis=1) == 1).sum() == givens  # exactly `givens` clues


def test_sudoku_generated_puzzle_is_solvable():
    # carved from a valid grid ⇒ satisfiable (the carving solution survives)
    from repro.core import check_solution

    csp = generate("sudoku", seed=7, givens=40)
    sol, _ = mac_solve(csp, engine="einsum")
    assert sol is not None and check_solution(csp, sol)


# --- AC-closure parity: einsum vs ac3 on every generated family -------------


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_ac_closure_parity_einsum_vs_ac3(seed):
    for name in FAMILIES:
        csp = generate(name, seed=seed, **SMALL[name])
        r_t = get_engine("einsum").prepare(csp).enforce()
        r_a = get_engine("ac3").prepare(csp).enforce()
        assert bool(np.asarray(r_t.consistent)) == bool(np.asarray(r_a.consistent)), name
        if bool(np.asarray(r_t.consistent)):
            np.testing.assert_array_equal(np.asarray(r_t.dom), np.asarray(r_a.dom))
