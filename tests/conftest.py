"""Shared test config.

The property tests use ``hypothesis`` when available; this container doesn't
ship it, so we install a minimal deterministic stand-in into ``sys.modules``
before collection. It supports exactly the surface the suite uses —
``given``/``settings`` and the ``integers``/``floats``/``tuples`` strategies —
drawing ``max_examples`` pseudo-random examples from an RNG seeded by the test
name (stable across runs; no shrinking, no database).
"""

from __future__ import annotations

import sys

try:  # pragma: no cover - prefer the real library when present
    import hypothesis  # noqa: F401
except ImportError:
    import random
    import types
    import zlib

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(lo, hi):
        return _Strategy(lambda r: r.randint(lo, hi))

    def _floats(lo, hi):
        return _Strategy(lambda r: r.uniform(lo, hi))

    def _tuples(*ss):
        return _Strategy(lambda r: tuple(s.draw(r) for s in ss))

    def _settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def _given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    drawn = tuple(s.draw(rng) for s in strategies)
                    fn(*args, *drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = _integers
    st_mod.floats = _floats
    st_mod.tuples = _tuples

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = _given
    hyp_mod.settings = _settings
    hyp_mod.strategies = st_mod

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
